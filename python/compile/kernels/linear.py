"""L1 Pallas kernel: fused bias-folded linear layer ``y = [x,1] @ Wᵀ``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
(m × d_out) into MXU-aligned blocks; each program loads an (bm × d_in+1)
activation panel and a (bn × d_in+1) weight panel into VMEM and contracts
them on the MXU. The bias is folded as a homogeneous coordinate so there is
no separate bias-add pass over HBM.

``interpret=True`` always: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
everywhere. Real-TPU perf is estimated from the VMEM footprint in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    # x_ref: (bm, d_in+1) biased activation tile; w_ref: (bn, d_in+1).
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_block(n, target):
    """Largest divisor of n that is ≤ target (keeps the grid exact)."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return n


def _matmul_bias_pallas(x, w, bm=128, bn=128):
    m, d_in = x.shape
    d_out = w.shape[0]
    assert w.shape[1] == d_in + 1, (w.shape, d_in)
    xb = jnp.concatenate([x, jnp.ones((m, 1), dtype=x.dtype)], axis=1)
    bm = _pick_block(m, bm)
    bn = _pick_block(d_out, bn)
    grid = (m // bm, d_out // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_in + 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d_in + 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=True,
    )(xb, w)


@jax.custom_vjp
def matmul_bias(x, w):
    """``y = [x, 1] @ w.T`` via a tiled Pallas kernel.

    x: (m, d_in); w: (d_out, d_in+1) with the bias as the last column.
    Interpret-mode ``pallas_call`` does not support reverse-mode autodiff,
    so the backward pass is supplied explicitly (dense contractions — the
    same shapes a transposed kernel instance would compute on TPU).
    """
    return _matmul_bias_pallas(x, w)


def _matmul_bias_fwd(x, w):
    return _matmul_bias_pallas(x, w), (x, w)


def _matmul_bias_bwd(res, dy):
    x, w = res
    m = x.shape[0]
    xb = jnp.concatenate([x, jnp.ones((m, 1), dtype=x.dtype)], axis=1)
    dw = dy.T @ xb
    dxb = dy @ w
    return dxb[:, :-1], dw


matmul_bias.defvjp(_matmul_bias_fwd, _matmul_bias_bwd)


def vmem_bytes(m, d_in, d_out, bm=128, bn=128, itemsize=4):
    """Estimated VMEM footprint of one program instance (perf model)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(d_out, bn)
    return (bm * (d_in + 1) + bn * (d_in + 1) + bm * bn) * itemsize
