"""L1 Pallas kernels for the SINGD preconditioner statistics.

The memory-critical step of SINGD is ``Π̂(BᵀB/m)`` with ``B = A K``:

- ``precond_gram`` — dense projection (INGD / SINGD-Dense): tiles the
  (d × d) Gram output; each program keeps a (bd × bd) accumulator in VMEM
  and streams the m-dimension of B through it — the dense log-space matrix
  never round-trips to HBM per-tile.
- ``precond_gram_diag`` — diagonal projection (SINGD-Diag): only the
  row-sum of B² is ever computed, O(d) output. This is the kernel-level
  expression of the paper's memory claim: the structure choice changes the
  *kernel*, not just post-processing.

interpret=True for CPU-PJRT executability (see linear.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .linear import _pick_block


def _gram_kernel(b1_ref, b2_ref, o_ref, *, inv_m):
    # b1: (m, bd1) column panel i; b2: (m, bd2) column panel j.
    b1 = b1_ref[...]
    b2 = b2_ref[...]
    acc = jax.lax.dot_general(
        b1, b2, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = (acc * inv_m).astype(o_ref.dtype)


@jax.jit
def precond_gram(b):
    """Dense ``H = BᵀB/m`` tiled over (d × d) output panels."""
    m, d = b.shape
    bd = _pick_block(d, 128)
    grid = (d // bd, d // bd)
    return pl.pallas_call(
        functools.partial(_gram_kernel, inv_m=1.0 / m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bd), lambda i, j: (0, i)),
            pl.BlockSpec((m, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), b.dtype),
        interpret=True,
    )(b, b)


def _diag_kernel(b_ref, o_ref, *, inv_m):
    b = b_ref[...]
    o_ref[...] = (jnp.sum(b * b, axis=0) * inv_m).astype(o_ref.dtype)


@jax.jit
def precond_gram_diag(b):
    """Diagonal of ``BᵀB/m`` — O(d) output, never forms the Gram matrix."""
    m, d = b.shape
    bd = _pick_block(d, 256)
    grid = (d // bd,)
    return pl.pallas_call(
        functools.partial(_diag_kernel, inv_m=1.0 / m),
        grid=grid,
        in_specs=[pl.BlockSpec((m, bd), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), b.dtype),
        interpret=True,
    )(b)


@jax.jit
def singd_diag_update(k_diag, a, lam, beta1):
    """Fused SINGD-Diag K-side refresh (see ref.singd_diag_update)."""
    b = a * k_diag[None, :]
    h_diag = precond_gram_diag(b)
    m_k = 0.5 * (h_diag + lam * k_diag * k_diag - 1.0)
    return k_diag * (1.0 - beta1 * m_k)
