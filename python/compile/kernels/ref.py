"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package has an entry here with identical semantics;
``python/tests/test_kernels.py`` asserts allclose between the two across a
hypothesis-driven sweep of shapes and dtypes. These references are also the
ground truth the Rust native implementations were validated against
conceptually (same formulas as ``rust/src/structured``/``rust/src/optim``).
"""

import jax.numpy as jnp


def matmul_bias(x, w):
    """Linear layer with folded bias: ``y = [x, 1] @ w.T``.

    x: (m, d_in) activations; w: (d_out, d_in + 1) weight whose last column
    is the bias.
    """
    m = x.shape[0]
    xb = jnp.concatenate([x, jnp.ones((m, 1), dtype=x.dtype)], axis=1)
    return xb @ w.T


def precond_gram(b):
    """Dense Gram statistic ``H = BᵀB / m`` (the SINGD ``H_K`` with B = A K)."""
    m = b.shape[0]
    return (b.T @ b) / m


def precond_gram_diag(b):
    """Diagonal of ``BᵀB/m`` without forming the dense Gram matrix."""
    m = b.shape[0]
    return jnp.sum(b * b, axis=0) / m


def singd_diag_update(k_diag, a, lam, beta1, d_o):
    """One SINGD-Diag preconditioner refresh of the K side (Fig. 4 with
    diagonal structure and the IKFAC trace weights).

    k_diag: (d,) diagonal of K; a: (m, d) layer inputs.
    Returns the updated diagonal.
    """
    b = a * k_diag[None, :]
    h_diag = precond_gram_diag(b)  # diag(Kᵀ U K)
    m_k = 0.5 * (h_diag + lam * k_diag * k_diag - 1.0)
    return k_diag * (1.0 - beta1 * m_k)


def softmax_xent(logits, y_onehot):
    """Mean softmax cross-entropy (matches ``rust/src/model::softmax_xent``)."""
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(axis=1, keepdims=True)), axis=1))
    logp = logits - logits.max(axis=1, keepdims=True) - logz[:, None]
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=1))
