"""L2: JAX forward/backward graphs, lowered once to HLO by aot.py.

Two compute graphs, both pure functions of their tensor inputs (so the Rust
coordinator owns all state and just streams tensors through PJRT):

- ``mlp_fwdbwd`` — the cross-check model: a 2-layer MLP with bias-folded
  weights and softmax-CE, architecture-identical to ``rust/src/model::Mlp``.
  Returns (loss, dW1, dW2). The Rust runtime test executes the artifact and
  compares against the native model bit-for-bit-ish.

- ``transformer_lm_fwdbwd`` — the e2e workhorse: a pre-LN causal
  transformer LM (token one-hot embed → blocks → LN → tied-free head).
  Returns loss, per-layer gradients, and per-layer Kronecker statistics
  ``(A_l, G_l)`` obtained with the zero-probe trick: a probe tensor is
  added to each layer's pre-activation, and d(loss)/d(probe) *is* the
  output-side gradient that SINGD's ``C`` factor needs. The Rust SINGD
  optimizer consumes these exactly like the native models' stats.

All linear layers go through the L1 Pallas kernel ``kernels.linear.
matmul_bias`` so the kernels lower into the same HLO artifact.
"""

import jax
import jax.numpy as jnp

from .kernels import linear as klinear
from .kernels import ref


def mlp_fwdbwd(x, y_onehot, w1, w2):
    """(loss, dW1, dW2) for the 2-layer ReLU MLP with folded biases."""

    def loss_fn(params):
        w1_, w2_ = params
        h = jax.nn.relu(klinear.matmul_bias(x, w1_))
        logits = klinear.matmul_bias(h, w2_)
        return ref.softmax_xent(logits, y_onehot)

    loss, grads = jax.value_and_grad(loss_fn)((w1, w2))
    return (loss, grads[0], grads[1])


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------


def transformer_param_shapes(vocab, dim, depth, mlp_ratio=2):
    """Ordered (name, (d_out, d_in+1)) list — the contract with Rust.

    Order: embed, then per block (wq, wk, wv, wo, w1, w2), then head.
    """
    shapes = [("embed", (dim, vocab + 1))]
    for b in range(depth):
        shapes += [
            (f"b{b}.wq", (dim, dim + 1)),
            (f"b{b}.wk", (dim, dim + 1)),
            (f"b{b}.wv", (dim, dim + 1)),
            (f"b{b}.wo", (dim, dim + 1)),
            (f"b{b}.w1", (dim * mlp_ratio, dim + 1)),
            (f"b{b}.w2", (dim, dim * mlp_ratio + 1)),
        ]
    shapes.append(("head", (vocab, dim + 1)))
    return shapes


def _layernorm(x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def _transformer_with_probes(params, probes, tokens, targets, vocab, dim, depth, mlp_ratio):
    """Returns (loss, activations). ``probes`` are zeros added to each
    layer's pre-activation so grad-wrt-probe = output-side gradient G_l."""
    m, s = tokens.shape
    onehot = jax.nn.one_hot(tokens.astype(jnp.int32), vocab, dtype=params[0].dtype)
    rows = onehot.reshape(m * s, vocab)

    acts = []  # layer inputs A_l (without bias col; Rust appends it)
    idx = 0

    def lin(x):
        nonlocal idx
        acts.append(x)
        y = klinear.matmul_bias(x, params[idx]) + probes[idx]
        idx += 1
        return y

    h = lin(rows)  # embed
    scale = 1.0 / jnp.sqrt(jnp.asarray(dim, dtype=h.dtype))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    for _ in range(depth):
        x1 = _layernorm(h)
        q = lin(x1).reshape(m, s, dim)
        k = lin(x1).reshape(m, s, dim)
        v = lin(x1).reshape(m, s, dim)
        scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
        scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
        p = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bqk,bkd->bqd", p, v).reshape(m * s, dim)
        h = h + lin(att)  # wo projection + residual
        x2 = _layernorm(h)
        h = h + lin(jax.nn.relu(lin(x2)))  # mlp (w1 inside relu, w2 outside)

    hf = _layernorm(h)
    logits = lin(hf)  # head → (m·s, vocab)
    # Next-token targets, flattened (m·s,) — provided by Rust.
    tgt_onehot = jax.nn.one_hot(targets.astype(jnp.int32).reshape(m * s), vocab, dtype=h.dtype)
    loss = ref.softmax_xent(logits, tgt_onehot)
    return loss, acts


def transformer_lm_fwdbwd(tokens, targets, *params_flat, vocab, dim, depth, mlp_ratio=2):
    """Full training step computation.

    Inputs: tokens (m, s) float-encoded ids; targets (m, s) next-token ids;
    params in ``transformer_param_shapes`` order.

    Outputs (flat tuple): loss, then per layer: dW_l, A_l, G_l where
    A_l = layer input rows (m·s, d_in) and G_l = d(mean loss)/d(pre-act)
    rows (m·s, d_out). Rust rescales G by m·s to match KFAC conventions.
    """
    params = list(params_flat)
    m, s = tokens.shape
    n_layers = len(params)
    shapes = transformer_param_shapes(vocab, dim, depth, mlp_ratio)
    assert n_layers == len(shapes), (n_layers, len(shapes))
    probes = [jnp.zeros((m * s, shp[0]), dtype=params[0].dtype) for _, shp in shapes]

    def loss_fn(params, probes):
        loss, acts = _transformer_with_probes(
            params, probes, tokens, targets, vocab, dim, depth, mlp_ratio
        )
        return loss, acts

    (loss, acts), (dparams, dprobes) = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
        params, probes
    )
    out = [loss]
    for layer in range(n_layers):
        out += [dparams[layer], acts[layer], dprobes[layer]]
    return tuple(out)
