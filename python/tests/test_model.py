"""L2 correctness: model graphs — shapes, gradients, probe-trick stats."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_mlp_fwdbwd_shapes_and_grad():
    rng = np.random.default_rng(0)
    m, d_in, hidden, classes = 8, 16, 32, 4
    x = jnp.asarray(rng.standard_normal((m, d_in)), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(m) % classes, classes)
    w1 = jnp.asarray(rng.standard_normal((hidden, d_in + 1)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((classes, hidden + 1)) * 0.3, jnp.float32)
    loss, d1, d2 = model.mlp_fwdbwd(x, y, w1, w2)
    assert d1.shape == w1.shape and d2.shape == w2.shape
    assert float(loss) > 0

    # Finite-difference on one weight.
    eps = 1e-3
    idx = (3, 5)
    w1p = w1.at[idx].add(eps)
    w1m = w1.at[idx].add(-eps)
    lp, _, _ = model.mlp_fwdbwd(x, y, w1p, w2)
    lm, _, _ = model.mlp_fwdbwd(x, y, w1m, w2)
    fd = (float(lp) - float(lm)) / (2 * eps)
    assert abs(fd - float(d1[idx])) < 1e-2 * (1 + abs(fd))


def _tiny_lm():
    vocab, dim, depth = 11, 8, 1
    shapes = model.transformer_param_shapes(vocab, dim, depth)
    rng = np.random.default_rng(3)
    params = [
        jnp.asarray(rng.standard_normal(shp) * (2.0 / shp[1]) ** 0.5, jnp.float32)
        for _, shp in shapes
    ]
    fn = functools.partial(model.transformer_lm_fwdbwd, vocab=vocab, dim=dim, depth=depth)
    return vocab, dim, depth, shapes, params, fn


def test_transformer_output_layout():
    vocab, dim, depth, shapes, params, fn = _tiny_lm()
    m, s = 2, 5
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(0, vocab, (m, s)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, vocab, (m, s)), jnp.float32)
    out = fn(tokens, targets, *params)
    assert len(out) == 1 + 3 * len(shapes)
    loss = out[0]
    assert loss.shape == () and float(loss) > 0
    for layer, (_, (d_out, d_in1)) in enumerate(shapes):
        dw, a, g = out[1 + 3 * layer : 4 + 3 * layer]
        assert dw.shape == (d_out, d_in1), (layer, dw.shape)
        assert a.shape == (m * s, d_in1 - 1), (layer, a.shape)
        assert g.shape == (m * s, d_out), (layer, g.shape)


def test_probe_stats_reproduce_gradient():
    # KFAC consistency: dW = Gᵀ [A, 1] for every layer (G is d(mean
    # loss)/d(pre-activation) rows, so no extra 1/m factor).
    vocab, dim, depth, shapes, params, fn = _tiny_lm()
    m, s = 2, 4
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, vocab, (m, s)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, vocab, (m, s)), jnp.float32)
    out = fn(tokens, targets, *params)
    for layer in range(len(shapes)):
        dw, a, g = out[1 + 3 * layer : 4 + 3 * layer]
        ab = jnp.concatenate([a, jnp.ones((a.shape[0], 1), a.dtype)], axis=1)
        rebuilt = g.T @ ab
        np.testing.assert_allclose(
            np.asarray(rebuilt), np.asarray(dw), rtol=1e-4, atol=1e-5,
        )


def test_transformer_grad_matches_fd():
    vocab, dim, depth, shapes, params, fn = _tiny_lm()
    m, s = 2, 4
    rng = np.random.default_rng(6)
    tokens = jnp.asarray(rng.integers(0, vocab, (m, s)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, vocab, (m, s)), jnp.float32)
    out = fn(tokens, targets, *params)
    eps = 1e-3
    layer, idx = 2, (1, 3)  # wk of block 0
    pp = [p for p in params]
    pp[layer] = params[layer].at[idx].add(eps)
    lp = fn(tokens, targets, *pp)[0]
    pp[layer] = params[layer].at[idx].add(-eps)
    lm = fn(tokens, targets, *pp)[0]
    fd = (float(lp) - float(lm)) / (2 * eps)
    an = float(out[1 + 3 * layer][idx])
    assert abs(fd - an) < 2e-2 * (1 + abs(fd)), (fd, an)


def test_softmax_xent_matches_uniform():
    logits = jnp.zeros((4, 10))
    y = jax.nn.one_hot(jnp.arange(4) % 10, 10)
    loss = ref.softmax_xent(logits, y)
    np.testing.assert_allclose(float(loss), np.log(10.0), rtol=1e-6)


def test_param_shapes_contract():
    shapes = model.transformer_param_shapes(vocab=32, dim=16, depth=2)
    assert shapes[0] == ("embed", (16, 33))
    assert shapes[-1] == ("head", (32, 17))
    assert len(shapes) == 2 + 6 * 2
