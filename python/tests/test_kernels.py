"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is THE
correctness signal for the kernel layer (kernels run under interpret=True,
so these tests exercise exactly what the AOT artifacts contain).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear as klinear
from compile.kernels import precond as kprecond
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DTYPES = [jnp.float32, jnp.bfloat16]


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def tol_for(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    d_in=st.integers(1, 40),
    d_out=st.integers(1, 40),
    dt=st.sampled_from(range(len(DTYPES))),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_matches_ref(m, d_in, d_out, dt, seed):
    dtype = DTYPES[dt]
    rng = np.random.default_rng(seed)
    x = rand(rng, (m, d_in), dtype)
    w = rand(rng, (d_out, d_in + 1), dtype)
    got = klinear.matmul_bias(x, w)
    want = ref.matmul_bias(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol_for(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    d=st.integers(1, 48),
    dt=st.sampled_from(range(len(DTYPES))),
    seed=st.integers(0, 2**31 - 1),
)
def test_precond_gram_matches_ref(m, d, dt, seed):
    dtype = DTYPES[dt]
    rng = np.random.default_rng(seed)
    b = rand(rng, (m, d), dtype)
    got = kprecond.precond_gram(b)
    want = ref.precond_gram(b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol_for(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    d=st.integers(1, 96),
    dt=st.sampled_from(range(len(DTYPES))),
    seed=st.integers(0, 2**31 - 1),
)
def test_precond_gram_diag_matches_ref(m, d, dt, seed):
    dtype = DTYPES[dt]
    rng = np.random.default_rng(seed)
    b = rand(rng, (m, d), dtype)
    got = kprecond.precond_gram_diag(b)
    want = ref.precond_gram_diag(b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol_for(dtype)
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 48),
    d=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_singd_diag_update_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, (m, d), jnp.float32)
    k = jnp.abs(rand(rng, (d,), jnp.float32)) + 0.5
    got = kprecond.singd_diag_update(k, a, 1e-3, 0.05)
    want = ref.singd_diag_update(k, a, 1e-3, 0.05, d_o=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(0)
    b = rand(rng, (16, 12), jnp.float32)
    h = np.asarray(kprecond.precond_gram(b))
    np.testing.assert_allclose(h, h.T, rtol=1e-6)
    eig = np.linalg.eigvalsh(h)
    assert eig.min() > -1e-5


def test_block_picking_always_divides():
    from compile.kernels.linear import _pick_block

    for n in range(1, 300):
        b = _pick_block(n, 128)
        assert n % b == 0 and 1 <= b <= min(n, 128)


def test_vmem_footprint_model_monotone():
    small = klinear.vmem_bytes(256, 64, 64)
    large = klinear.vmem_bytes(256, 512, 512)
    assert small < large
    # A 128×128 tile at d_in=512 stays well under 16 MiB VMEM.
    assert klinear.vmem_bytes(4096, 512, 4096) < 16 * 2**20


@pytest.mark.parametrize("dtype", DTYPES)
def test_kernels_preserve_dtype(dtype):
    rng = np.random.default_rng(1)
    x = rand(rng, (8, 6), dtype)
    w = rand(rng, (5, 7), dtype)
    assert klinear.matmul_bias(x, w).dtype == dtype
    assert kprecond.precond_gram(x).dtype == dtype
