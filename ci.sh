#!/usr/bin/env bash
# CI gate for the SINGD reproduction.
#
#   ./ci.sh          — fmt check, clippy, release build, tests, smoke bench
#   ./ci.sh quick    — skip the smoke bench
#   ./ci.sh bench    — additionally run the full hotpath bench (perf log)
#
# The hotpath bench's --smoke mode runs one iteration per case so the
# packed/pooled kernels stay exercised in CI without burning minutes; the
# full run regenerates BENCH_hotpath.json for EXPERIMENTS.md §Perf.
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (deny warnings) =="
# Style lints that the pedagogical kernel/loop code intentionally trips
# (index-heavy numeric loops) are allowed; everything else is denied.
cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::manual_memcpy \
    -A clippy::op_ref

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== determinism suites (SINGD_THREADS x SINGD_RANKS matrix) =="
# The bitwise contracts must hold at every pool size and world size:
# serial vs pooled kernels (tests/parallel.rs) and serial vs distributed
# training (tests/dist.rs, which also exercises the SINGD_RANKS default).
for t in 1 4; do
    echo "-- SINGD_THREADS=$t: parallel suite"
    SINGD_THREADS=$t cargo test -q --test parallel
    for r in 1 4; do
        echo "-- SINGD_THREADS=$t SINGD_RANKS=$r: dist suite"
        SINGD_THREADS=$t SINGD_RANKS=$r cargo test -q --test dist
    done
done

if [ "$mode" != "quick" ]; then
    echo "== hotpath bench (smoke) =="
    cargo bench --bench hotpath -- --smoke
    echo "== dist_scaling bench (smoke) =="
    cargo bench --bench dist_scaling -- --smoke
fi

if [ "$mode" = "bench" ]; then
    echo "== hotpath bench (full) =="
    cargo bench --bench hotpath
    echo "== dist_scaling bench (full) =="
    cargo bench --bench dist_scaling
fi

echo "CI OK"
