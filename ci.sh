#!/usr/bin/env bash
# CI gate for the SINGD reproduction.
#
#   ./ci.sh          — fmt check, clippy, release build, tests, smoke bench
#   ./ci.sh quick    — skip the smoke bench
#   ./ci.sh bench    — additionally run the full hotpath bench (perf log)
#
# The hotpath bench's --smoke mode runs one iteration per case so the
# packed/pooled kernels stay exercised in CI without burning minutes; the
# full run regenerates BENCH_hotpath.json for EXPERIMENTS.md §Perf.
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (deny warnings) =="
# Style lints that the pedagogical kernel/loop code intentionally trips
# (index-heavy numeric loops) are allowed; everything else is denied.
cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::manual_memcpy \
    -A clippy::op_ref

echo "== cargo build --release =="
cargo build --release

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
# rust/src/dist carries #![deny(missing_docs)]; this leg additionally
# fails on broken intra-doc links anywhere in the crate.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -q

echo "== docs link check (relative paths + file:line anchors) =="
python3 tools/check_links.py ARCHITECTURE.md PROTOCOL.md README.md EXPERIMENTS.md ROADMAP.md

# Hard per-suite timeout for anything that exercises a rendezvous
# (in-process or socket): a hung rendezvous must fail fast, never stall
# the suite. Also applied to the tier-1 test run below, which includes
# the dist and dist_proc suites.
DIST_TIMEOUT="${SINGD_CI_DIST_TIMEOUT:-900}"

echo "== cargo test -q =="
timeout "$((2 * DIST_TIMEOUT))" cargo test -q

echo "== determinism suites (SINGD_THREADS x SINGD_RANKS x SINGD_TRANSPORT x SINGD_ALGO matrix) =="
# The bitwise contracts must hold at every pool size, world size,
# transport and collective algorithm: serial vs pooled kernels
# (tests/parallel.rs) and serial vs distributed training (tests/dist.rs,
# which also exercises the SINGD_RANKS / SINGD_TRANSPORT / SINGD_ALGO
# env defaults — DistCfg::local follows SINGD_ALGO, so the whole dist
# suite trains through both schedules). Every dist leg runs under a hard
# timeout so a hung rendezvous fails fast instead of stalling the suite;
# the ranks=4 leg fans out over both transports and both algorithms.
for t in 1 4; do
    echo "-- SINGD_THREADS=$t: parallel suite"
    SINGD_THREADS=$t cargo test -q --test parallel
    for r in 1 4; do
        transports="local"
        algos="ring"
        if [ "$r" = 4 ]; then transports="local socket"; algos="star ring"; fi
        for tr in $transports; do
            for al in $algos; do
                echo "-- SINGD_THREADS=$t SINGD_RANKS=$r SINGD_TRANSPORT=$tr SINGD_ALGO=$al: dist suite"
                SINGD_THREADS=$t SINGD_RANKS=$r SINGD_TRANSPORT=$tr SINGD_ALGO=$al \
                    timeout "$DIST_TIMEOUT" cargo test -q --test dist
            done
        done
    done
done

echo "== multi-process transport suite (separate OS processes) =="
# tests/dist_proc.rs drives the singd binary: --transport socket at
# ranks=4 must be bitwise identical (param_digest) to --transport local
# and to serial ranks=1, for SINGD and KFAC, under both strategies.
timeout "$DIST_TIMEOUT" cargo test -q --test dist_proc

if [ "$mode" != "quick" ]; then
    echo "== hotpath bench (smoke) =="
    cargo bench --bench hotpath -- --smoke
    echo "== dist_scaling bench (smoke) =="
    cargo bench --bench dist_scaling -- --smoke
fi

if [ "$mode" = "bench" ]; then
    echo "== hotpath bench (full) =="
    cargo bench --bench hotpath
    echo "== dist_scaling bench (full) =="
    cargo bench --bench dist_scaling
fi

echo "CI OK"
