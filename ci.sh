#!/usr/bin/env bash
# CI gate for the SINGD reproduction.
#
#   ./ci.sh          — fmt check, clippy, release build, tests, smoke bench
#   ./ci.sh quick    — skip the smoke bench
#   ./ci.sh bench    — additionally run the full hotpath bench (perf log)
#
# The hotpath bench's --smoke mode runs one iteration per case so the
# packed/pooled kernels stay exercised in CI without burning minutes; the
# full run regenerates BENCH_hotpath.json for EXPERIMENTS.md §Perf.
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (deny warnings) =="
# Style lints that the pedagogical kernel/loop code intentionally trips
# (index-heavy numeric loops) are allowed; everything else is denied.
cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::manual_memcpy \
    -A clippy::op_ref

echo "== cargo build --release =="
cargo build --release

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
# rust/src/dist carries #![deny(missing_docs)]; this leg additionally
# fails on broken intra-doc links anywhere in the crate.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -q

echo "== docs link check (relative paths + file:line anchors) =="
python3 tools/check_links.py ARCHITECTURE.md PROTOCOL.md README.md EXPERIMENTS.md ROADMAP.md

# Hard per-suite timeout for anything that exercises a rendezvous
# (in-process or socket): a hung rendezvous must fail fast, never stall
# the suite. Also applied to the tier-1 test run below, which includes
# the dist and dist_proc suites.
DIST_TIMEOUT="${SINGD_CI_DIST_TIMEOUT:-900}"

echo "== cargo test -q =="
timeout "$((2 * DIST_TIMEOUT))" cargo test -q

echo "== determinism suites (SINGD_THREADS x SINGD_RANKS x SINGD_TRANSPORT x SINGD_ALGO x SINGD_OVERLAP x SINGD_STREAM matrix) =="
# The bitwise contracts must hold at every pool size, world size,
# transport, collective algorithm, overlap mode and streaming mode:
# serial vs pooled kernels (tests/parallel.rs) and serial vs distributed
# training (tests/dist.rs, which also exercises the SINGD_RANKS /
# SINGD_TRANSPORT / SINGD_ALGO / SINGD_OVERLAP / SINGD_STREAM env
# defaults — DistCfg::local follows SINGD_ALGO, SINGD_OVERLAP and
# SINGD_STREAM, so the whole dist suite trains through both schedules,
# both overlap modes and both streaming modes). Every dist leg runs
# under a hard timeout so a hung rendezvous fails fast instead of
# stalling the suite. The full transport × algo × overlap × stream cube
# at ranks=4 would be 16 cells per pool size; redundant cells are
# pruned while keeping every axis pair covered somewhere: ring (whose
# pipelined schedule is what overlap changes most) runs both overlap
# modes on both transports, star — also overlap-sensitive end-to-end,
# since the driver's per-layer pending gathers ride it too — runs
# overlap=1 on local and overlap=0 on socket, and the stream values are
# spread so each transport sees both stream modes under overlap=1
# (stream is inert under overlap=0 — pinned by the stream_ cells — so
# those legs' value is arbitrary). The unpruned shape/stage grid runs
# in-process inside tests/dist.rs itself (stream_ and accum_ cells
# drive both stream modes and the micro-batch folds explicitly,
# whatever the env says).
run_dist_leg() { # t r transport algo overlap stream
    echo "-- SINGD_THREADS=$1 SINGD_RANKS=$2 SINGD_TRANSPORT=$3 SINGD_ALGO=$4 SINGD_OVERLAP=$5 SINGD_STREAM=$6: dist suite"
    SINGD_THREADS=$1 SINGD_RANKS=$2 SINGD_TRANSPORT=$3 SINGD_ALGO=$4 SINGD_OVERLAP=$5 SINGD_STREAM=$6 \
        timeout "$DIST_TIMEOUT" cargo test -q --test dist
}
for t in 1 4; do
    echo "-- SINGD_THREADS=$t: parallel suite"
    SINGD_THREADS=$t cargo test -q --test parallel
    # ranks=1: the serial-delegation cell (transport/algo/overlap moot).
    run_dist_leg "$t" 1 local ring 1 1
done
# ranks=4 at the realistic pool size: ring × both transports × both
# overlap modes; star covers one overlap mode per transport (both modes
# across the pair). Stream: each transport's overlapped ring leg runs
# stream=0 here (stream=1 cells at t=1 and star-local below).
for tr in local socket; do
    run_dist_leg 4 4 "$tr" ring 0 1
    run_dist_leg 4 4 "$tr" ring 1 0
done
run_dist_leg 4 4 local star 1 1
run_dist_leg 4 4 socket star 0 0
# ranks=4 at SINGD_THREADS=1 (scoped-thread rank bodies): the overlap
# axis interacts with rank scheduling here, so keep ring 0/1 on the
# local transport plus a socket ring cell (ring is the algorithm the
# overlap axis actually changes; socket star is covered at t=4). The
# overlapped legs run stream=1, completing the per-transport pair.
run_dist_leg 1 4 local ring 0 1
run_dist_leg 1 4 local ring 1 1
run_dist_leg 1 4 socket ring 1 1

echo "== multi-process transport suite (separate OS processes) =="
# tests/dist_proc.rs drives the singd binary: --transport socket at
# ranks=4 must be bitwise identical (param_digest) to --transport local
# and to serial ranks=1, for SINGD and KFAC, under both strategies.
timeout "$DIST_TIMEOUT" cargo test -q --test dist_proc

echo "== optimizer-zoo determinism legs (rkfac + mac) =="
# The zoo_ cells in tests/dist.rs train RK-FAC and MAC through the full
# strategy x algo x stream grid in-process; here each transport gets one
# pruned env cell (hard timeout, default ring/overlap/stream) so both
# new methods ride the same matrix axis as the resident optimizers
# without doubling the cube. The real-OS-process digest leg
# (dist_proc socket_ranks4_digest_matches_serial_for_rkfac_and_mac)
# already ran in the multi-process suite above.
for tr in local socket; do
    echo "-- SINGD_RANKS=4 SINGD_TRANSPORT=$tr: zoo cells"
    SINGD_RANKS=4 SINGD_TRANSPORT=$tr \
        timeout "$DIST_TIMEOUT" cargo test -q --test dist zoo_
done

echo "== elastic fault-tolerance / chaos suite =="
# Checkpoint/resume determinism and elastic regroup, in-process at
# ranks=4 (tests/dist resume_* and elastic_*) plus the multi-process
# chaos leg (tests/dist_proc): hard-kill a worker mid-step, survivors
# re-rendezvous into world 3, reshard from the checkpoint, and the
# digest must match the uninterrupted resumed run. Every leg runs under
# the hard timeout — a deadlocked regroup fails fast.
SINGD_RANKS=4 SINGD_TRANSPORT=local timeout "$DIST_TIMEOUT" cargo test -q --test dist resume_
SINGD_RANKS=4 SINGD_TRANSPORT=local timeout "$DIST_TIMEOUT" cargo test -q --test dist elastic_
timeout "$DIST_TIMEOUT" cargo test -q --test dist_proc resume_
timeout "$DIST_TIMEOUT" cargo test -q --test dist_proc elastic_

echo "== wire-dtype compressed-collective suite (SINGD_WIRE_DTYPE axis) =="
# The wire_* cells in tests/dist.rs pin the ISSUE-8 contract: at a fixed
# wire dtype, collectives and training are bitwise invariant across
# transport x algo x overlap, the traffic counters are dtype-sized, and
# fp16-storage runs (GradScaler armed) resume bitwise from checkpoint v4.
# Only the wire_ prefix runs under SINGD_WIRE_DTYPE=bf16: the wider dist
# suite's serial-equality and f32-frame bandwidth pins are f32-wire
# contracts by design (a half wire rightly breaks them), and the bf16
# axis rides DistCfg::local's env default through the wire_ cells.
for wd in f32 bf16; do
    for tr in local socket; do
        echo "-- SINGD_RANKS=4 SINGD_TRANSPORT=$tr SINGD_WIRE_DTYPE=$wd: wire suite"
        SINGD_RANKS=4 SINGD_TRANSPORT=$tr SINGD_WIRE_DTYPE=$wd \
            timeout "$DIST_TIMEOUT" cargo test -q --test dist wire_
    done
done

echo "== trace leg (--trace-dir artifacts validated by tools/check_trace.py) =="
# A small traced distributed job on each transport: every rank must
# export a well-formed r<N>.jsonl + r<N>.trace.json pair (socket workers
# inherit the dir via the pinned SINGD_TRACE env), and the checker's
# schema/loadability/overlap pass must be clean. The bitwise
# non-interference of tracing is asserted by the test suites above; this
# leg guards the artifact format end to end through the release binary.
trace_tmp="$(mktemp -d)"
trap 'rm -rf "$trace_tmp"' EXIT
cat > "$trace_tmp/job.toml" <<'EOF'
label = "ci-trace"
[model]
arch = "mlp"
width = 32
[data]
classes = 4
n_train = 128
n_test = 32
[optim]
method = "singd:diag"
lr = 0.01
damping = 0.1
t_update = 1
[train]
epochs = 1
batch_size = 32
seed = 11
EOF
for tr in local socket; do
    echo "-- traced train_dist ($tr transport)"
    timeout "$DIST_TIMEOUT" env -u SINGD_TRACE -u SINGD_LOG \
        target/release/singd train --config "$trace_tmp/job.toml" \
        --ranks 4 --transport "$tr" --algo ring \
        --trace-dir "$trace_tmp/$tr"
    python3 tools/check_trace.py "$trace_tmp/$tr"
    for r in 0 1 2 3; do
        test -s "$trace_tmp/$tr/r$r.jsonl" || {
            echo "missing r$r.jsonl ($tr)"; exit 1; }
    done
done

echo "== accumulation smoke (--accum-steps digest parity through the binary) =="
# Power-of-two micro-batch folds must reproduce the unsplit digest bit
# for bit (rust/src/optim/accum.rs contract) — serial and at ranks=4
# factor-sharded with streaming on (the default), end to end through
# the release binary. Reuses the trace leg's job config.
run_digest() { # train flags...
    timeout "$DIST_TIMEOUT" env -u SINGD_TRACE -u SINGD_LOG -u SINGD_STREAM \
        target/release/singd train --config "$trace_tmp/job.toml" "$@" \
        | awk '{for (i = 1; i < NF; i++) if ($i == "param_digest") print $(i + 1)}'
}
base_digest="$(run_digest --ranks 1)"
test -n "$base_digest" || { echo "no param_digest from serial run"; exit 1; }
for k in 2 4; do
    split_digest="$(run_digest --ranks 1 --accum-steps "$k")"
    [ "$base_digest" = "$split_digest" ] || {
        echo "accum-steps=$k serial digest mismatch: $base_digest vs $split_digest"; exit 1; }
done
dist_digest="$(run_digest --ranks 4 --strategy factor-sharded --accum-steps 2)"
[ "$base_digest" = "$dist_digest" ] || {
    echo "accum-steps=2 ranks=4 digest mismatch: $base_digest vs $dist_digest"; exit 1; }

if [ "$mode" != "quick" ]; then
    echo "== hotpath bench (smoke) =="
    cargo bench --bench hotpath -- --smoke
    echo "== dist_scaling bench (smoke) =="
    cargo bench --bench dist_scaling -- --smoke
    echo "== ablations bench (smoke; regenerates BENCH_ablations.json) =="
    # Unlike hotpath, the smoke leg DOES rewrite BENCH_ablations.json:
    # the zoo rows' state-bytes ordering (mac < rkfac < kfac) is exact at
    # any epoch count, and the JSON's "smoke" flag marks the timings as
    # 1-epoch noise. The full `bench` mode refreshes the real numbers.
    cargo bench --bench ablations -- --smoke
fi

if [ "$mode" = "bench" ]; then
    echo "== hotpath bench (full) =="
    cargo bench --bench hotpath
    echo "== dist_scaling bench (full) =="
    cargo bench --bench dist_scaling
    echo "== ablations bench (full) =="
    cargo bench --bench ablations
fi

echo "CI OK"
