#!/usr/bin/env bash
# CI gate for the SINGD reproduction.
#
#   ./ci.sh          — fmt check, clippy, release build, tests, smoke bench
#   ./ci.sh quick    — skip the smoke bench
#   ./ci.sh bench    — additionally run the full hotpath bench (perf log)
#
# The hotpath bench's --smoke mode runs one iteration per case so the
# packed/pooled kernels stay exercised in CI without burning minutes; the
# full run regenerates BENCH_hotpath.json for EXPERIMENTS.md §Perf.
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (deny warnings) =="
# Style lints that the pedagogical kernel/loop code intentionally trips
# (index-heavy numeric loops) are allowed; everything else is denied.
cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::manual_memcpy \
    -A clippy::op_ref

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "$mode" != "quick" ]; then
    echo "== hotpath bench (smoke) =="
    cargo bench --bench hotpath -- --smoke
fi

if [ "$mode" = "bench" ]; then
    echo "== hotpath bench (full) =="
    cargo bench --bench hotpath
fi

echo "CI OK"
