#!/usr/bin/env python3
"""Link checker for the repo's markdown docs (ci.sh leg).

Verifies, for every file passed on the command line:

1. **Relative markdown links** `[text](path)` resolve to an existing
   file or directory (external schemes and pure `#anchor` links are
   skipped; a `path#fragment` is checked for the file part only).
2. **file:line anchors** like `rust/src/dist/mod.rs:123` (backtick-code
   or bare) name an existing file with at least that many lines, so the
   architecture book's pointers into the source cannot rot silently.
3. **Bare code-span file references** like `rust/tests/dist.rs` exist.

Exit status 0 when every reference resolves; 1 otherwise, listing every
failure. Paths are resolved relative to the repository root (the parent
of this script's directory).
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# path.ext:123 anchors, in or out of backticks (extensions we track).
FILE_LINE = re.compile(r"`?([A-Za-z0-9_./-]+\.(?:rs|md|sh|py|toml|json)):(\d+)`?")
# `path/to/file.ext` code spans (no :line).
CODE_FILE = re.compile(r"`([A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:rs|md|sh|py|toml|json))`")

SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def line_count(path: Path) -> int:
    with open(path, "rb") as f:
        return sum(1 for _ in f)


def check_file(md: Path) -> list:
    errors = []
    text = md.read_text(encoding="utf-8")
    counted = {}

    def exists(rel: str) -> bool:
        return (ROOT / rel).exists()

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if rel and not exists(rel):
            errors.append(f"{md.name}: broken link -> {target}")

    for m in FILE_LINE.finditer(text):
        rel, line = m.group(1), int(m.group(2))
        p = ROOT / rel
        if not p.is_file():
            errors.append(f"{md.name}: file:line anchor to missing file -> {rel}:{line}")
            continue
        if rel not in counted:
            counted[rel] = line_count(p)
        if line > counted[rel]:
            errors.append(
                f"{md.name}: stale anchor {rel}:{line} (file has {counted[rel]} lines)"
            )

    for m in CODE_FILE.finditer(text):
        rel = m.group(1)
        # Skip things that are clearly not repo paths (no directory part
        # and not present at the root — e.g. generic example names).
        if "/" not in rel and not exists(rel):
            continue
        if not exists(rel):
            errors.append(f"{md.name}: code-span path does not exist -> {rel}")

    return errors


def main() -> int:
    targets = [Path(a) for a in sys.argv[1:]]
    if not targets:
        print("usage: check_links.py <file.md> [...]", file=sys.stderr)
        return 2
    all_errors = []
    for t in targets:
        p = t if t.is_absolute() else ROOT / t
        if not p.is_file():
            all_errors.append(f"{t}: document missing")
            continue
        all_errors.extend(check_file(p))
    if all_errors:
        for e in all_errors:
            print(f"LINKCHECK FAIL  {e}", file=sys.stderr)
        return 1
    print(f"link check OK ({len(targets)} document(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
