#!/usr/bin/env python3
"""Deterministic discrete-event model of the traced-epoch hidden-comm
fractions in BENCH_dist_scaling.json (`overlap_efficiency` rows).

Methodology record for EXPERIMENTS.md §Dist-Stream: the container this
PR was authored in ships no Rust toolchain, so the trace-derived
overlap-efficiency rows cannot be wall-clock measurements; a native
`cargo bench --bench dist_scaling` run overwrites them with real
microseconds (same schema). Until then this script is their provenance:
it replays the exact op schedules of `rank_step`
(rust/src/train/mod.rs) for one R=4 factor-sharded ring epoch under the
three (overlap, stream) modes the bench traces, over a single-threaded
FIFO progress engine (rust/src/dist/pending.rs semantics), and reduces
the resulting spans with a line-for-line port of
`trace::overlap_stats` (rust/src/obs/trace.rs) — the fraction of
comm-span time covered by compute spans.

Schedules (one step; the epoch is 8 identical steps):

  overlap=0            blocking collectives — every comm span runs with
                       no compute span in flight, so nothing is hidden.
  overlap=1, stream=0  the PR-5 schedule: all per-layer stats gathers
                       are issued back to back after the backward
                       finishes; they hide only under the local
                       precond-prep compute between issue and drain,
                       plus the bucket-pipelined update exchange.
  overlap=1, stream=1  the ISSUE-9 schedule: layer l's gather is issued
                       from inside its backward hook, so it additionally
                       hides under the backward of layers l-1..0 — the
                       engine drains the queue while the rest of the
                       backward is still computing.

Durations are nominal microseconds, not measurements: per-layer
backward/gather costs proportional to the dist_scaling MLP's layer
sizes (seven 64x65 layers + one 8x65 head), with link service times in
a regime where one layer's gather fits under roughly two layers of
backward (comm ~40% of backward — the regime where issue order
matters). The *fractions* are the model's output; the structural claim
they encode — streamed issue strictly increases the hidden fraction,
because the same FIFO engine sees the same ops strictly earlier
relative to the same compute — holds for any positive durations.

Run: python3 tools/model_stream_overlap.py
Prints the three overlap_efficiency JSON rows and a summary.
"""

import json

WORLD = 4
STEPS = 8
FORWARD_US = 300
# Backward + gather-service cost per layer, reverse (issue) order:
# seven 64x65 hidden layers then the 8x65 head (backward runs last
# layer first).
BWD_US = [40] + [180] * 7  # head first: layers 7, 6, .., 0
GATHER_US = [60] + [95] * 7
PRECOND_PREP_US = 260  # local compute between gather issue and drain
BUCKETS = 4  # bucketed update exchange: compute then issue, pipelined
BUCKET_COMPUTE_US = 70
BUCKET_COMM_US = 85
UPDATE_APPLY_US = 120


def merge(intervals):
    out = []
    for a, b in sorted(intervals):
        if out and a <= out[-1][1]:
            out[-1][1] = max(out[-1][1], b)
        else:
            out.append([a, b])
    return out


def hidden(comm, compute):
    """Port of trace::overlap_stats: comm-span time under compute."""
    merged = merge(compute)
    total = sum(b - a for a, b in comm)
    hid = 0
    for a, b in comm:
        for ca, cb in merged:
            lo, hi = max(a, ca), min(b, cb)
            if lo < hi:
                hid += hi - lo
    return total, hid


def one_step(overlap, stream):
    """Spans of one rank_step; all ranks run the same symmetric ring
    schedule, so one rank's timeline is every rank's timeline."""
    compute, comm = [], []
    t = FORWARD_US  # forward done; backward begins
    issue = []  # (issue_time, service_us) per gather, issue order
    for bwd, g in zip(BWD_US, GATHER_US):
        t += bwd
        issue.append((t, g))  # streamed: issued the moment the layer ends
    backward_end = t
    compute.append((0, backward_end))  # the forward_backward span
    if not overlap:
        # Blocking: batched gather then update exchange, nothing in
        # flight during any compute span.
        t = backward_end
        for _, g in issue:
            comm.append((t, t + g))
            t += g
        compute.append((t, t + PRECOND_PREP_US))
        t += PRECOND_PREP_US
        for _ in range(BUCKETS):
            compute.append((t, t + BUCKET_COMPUTE_US))
            t += BUCKET_COMPUTE_US
            comm.append((t, t + BUCKET_COMM_US))
            t += BUCKET_COMM_US
        compute.append((t, t + UPDATE_APPLY_US))
        return compute, comm, t + UPDATE_APPLY_US
    # Overlapped: FIFO engine services gathers concurrently with compute.
    if not stream:
        issue = [(backward_end, g) for _, g in issue]
    engine_t = 0
    for at, g in issue:
        engine_t = max(engine_t, at)
        comm.append((engine_t, engine_t + g))
        engine_t += g
    drain = engine_t
    # Local precond prep overlaps the tail of the gather queue; the rank
    # then waits for the drain if the engine is still behind.
    prep_end = backward_end + PRECOND_PREP_US
    compute.append((backward_end, prep_end))
    t = max(prep_end, drain)
    # Bucketed update exchange: compute bucket k, issue it, compute k+1
    # while k is on the wire (the PR-5 issue-every-bucket-then-drain
    # schedule).
    engine_t = t
    for _ in range(BUCKETS):
        compute.append((t, t + BUCKET_COMPUTE_US))
        t += BUCKET_COMPUTE_US
        engine_t = max(engine_t, t)
        comm.append((engine_t, engine_t + BUCKET_COMM_US))
        engine_t += BUCKET_COMM_US
    t = max(t, engine_t)
    compute.append((t, t + UPDATE_APPLY_US))
    return compute, comm, t + UPDATE_APPLY_US


def epoch(overlap, stream):
    compute, comm = [], []
    t0 = 0
    for _ in range(STEPS):
        c, m, dur = one_step(overlap, stream)
        compute += [(a + t0, b + t0) for a, b in c]
        comm += [(a + t0, b + t0) for a, b in m]
        t0 += dur
    return hidden(comm, compute)


def main():
    rows = []
    for overlap, stream in ((False, False), (True, False), (True, True)):
        comm_us, hidden_us = epoch(overlap, stream)
        frac = hidden_us / comm_us if comm_us else 0.0
        rows.append(
            {
                "name": "traced epoch ranks=4 factor-sharded ring",
                "overlap": overlap,
                "stream": stream,
                "comm_us_by_rank": [comm_us] * WORLD,
                "hidden_us_by_rank": [hidden_us] * WORLD,
                "hidden_frac_by_rank": [round(frac, 4)] * WORLD,
                "mean_hidden_frac": round(frac, 4),
            }
        )
        print(
            f"overlap={int(overlap)} stream={int(stream)}: "
            f"comm {comm_us} us, hidden {hidden_us} us "
            f"({100.0 * frac:.1f}% hidden)"
        )
    off = next(r for r in rows if r["overlap"] and not r["stream"])
    on = next(r for r in rows if r["overlap"] and r["stream"])
    assert on["mean_hidden_frac"] > off["mean_hidden_frac"], (
        "streamed issue must strictly increase the hidden fraction"
    )
    print(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
