#!/usr/bin/env python3
"""Validate a SINGD trace directory (the `--trace-dir` / SINGD_TRACE output).

Usage: python3 tools/check_trace.py <trace-dir>

Checks, per rank `N` found in the directory:

  * `rN.jsonl` — one JSON object per line with the journal schema
    (`name`, `cat`, `ph`, `rank`, `tid`, `ts_us`, `dur_us`, `args`),
    `ph` in {"X", "i"}, integer non-negative timestamps, instants with
    `dur_us == 0`, and every event attributed to rank N.
  * `rN.trace.json` — loads as JSON, has a `traceEvents` list whose
    entries carry the Chrome trace_event keys (`name`, `cat`, `ph`,
    `pid`, `tid`, `ts`), so chrome://tracing / ui.perfetto.dev accept it.
  * The two files agree on the event count.

Additionally enforces the streaming nesting rule (determinism contract
8): every `layer_gather_issue` span — the streamed per-layer stats
gather issued from inside a layer's backward hook — must nest inside a
`forward_backward` span on the same rank. A violation means a gather
was issued outside any backward, which breaks the premise of the
backward↔comm fusion.

Then prints the per-rank overlap-efficiency summary — the fraction of
`cat == "comm"` span time hidden under `cat == "compute"` spans — the
Python twin of `trace::overlap_stats` in rust/src/obs/trace.rs.

Exits nonzero on any violation (including an empty directory).
"""

import json
import sys
from pathlib import Path

JOURNAL_KEYS = {"name", "cat", "ph", "rank", "tid", "ts_us", "dur_us", "args"}
CHROME_KEYS = {"name", "cat", "ph", "pid", "tid", "ts"}

errors = 0


def err(msg: str) -> None:
    global errors
    errors += 1
    print(f"check_trace: ERROR: {msg}", file=sys.stderr)


def check_journal(path: Path, rank: int):
    """Validate one journal; return its events as dicts."""
    events = []
    for i, line in enumerate(path.read_text().splitlines(), 1):
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            err(f"{path}:{i}: not a JSON object: {e}")
            continue
        missing = JOURNAL_KEYS - ev.keys()
        if missing:
            err(f"{path}:{i}: missing keys {sorted(missing)}")
            continue
        if ev["ph"] not in ("X", "i"):
            err(f"{path}:{i}: bad ph {ev['ph']!r} (want X or i)")
        if ev["rank"] != rank:
            err(f"{path}:{i}: rank {ev['rank']} in r{rank}.jsonl")
        for k in ("ts_us", "dur_us", "tid", "rank"):
            if not isinstance(ev[k], int) or ev[k] < 0:
                err(f"{path}:{i}: {k} must be a non-negative integer")
        if ev["ph"] == "i" and ev["dur_us"] != 0:
            err(f"{path}:{i}: instant with nonzero dur_us")
        if not isinstance(ev["args"], dict):
            err(f"{path}:{i}: args must be an object")
        events.append(ev)
    if not events:
        err(f"{path}: empty journal")
    return events


def check_chrome(path: Path, rank: int) -> int:
    """Validate one Chrome trace file; return its event count."""
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        err(f"{path}: not loadable JSON: {e}")
        return 0
    tev = doc.get("traceEvents")
    if not isinstance(tev, list):
        err(f"{path}: no traceEvents list")
        return 0
    for i, ev in enumerate(tev):
        missing = CHROME_KEYS - ev.keys()
        if missing:
            err(f"{path}: traceEvents[{i}]: missing keys {sorted(missing)}")
        elif ev["pid"] != rank:
            err(f"{path}: traceEvents[{i}]: pid {ev['pid']} in r{rank}.trace.json")
    return len(tev)


def merge(intervals):
    out = []
    for a, b in sorted(intervals):
        if out and a <= out[-1][1]:
            out[-1][1] = max(out[-1][1], b)
        else:
            out.append([a, b])
    return out


def check_stream_nesting(path: Path, events) -> None:
    """Every layer_gather_issue span must nest inside a forward_backward
    span (closed intervals: the issue is recorded strictly inside the
    backward, but the microsecond clock can tie at either edge)."""
    backward = [
        (e["ts_us"], e["ts_us"] + e["dur_us"])
        for e in events
        if e["ph"] == "X" and e["name"] == "forward_backward"
    ]
    for e in events:
        if e["ph"] != "X" or e["name"] != "layer_gather_issue":
            continue
        a, b = e["ts_us"], e["ts_us"] + e["dur_us"]
        if not any(fa <= a and b <= fb for fa, fb in backward):
            err(
                f"{path}: layer_gather_issue [{a},{b}] (layer "
                f"{e['args'].get('layer', '?')}) nests in no forward_backward span"
            )


def overlap_summary(rank: int, events) -> str:
    compute = merge(
        (e["ts_us"], e["ts_us"] + e["dur_us"])
        for e in events
        if e["ph"] == "X" and e["cat"] == "compute"
    )
    comm_us = hidden_us = 0
    for e in events:
        if e["ph"] != "X" or e["cat"] != "comm":
            continue
        a, b = e["ts_us"], e["ts_us"] + e["dur_us"]
        comm_us += b - a
        for ca, cb in compute:
            lo, hi = max(a, ca), min(b, cb)
            if lo < hi:
                hidden_us += hi - lo
    frac = hidden_us / comm_us if comm_us else 0.0
    return (
        f"r{rank}: {len(events)} events, comm {comm_us} us, "
        f"hidden {hidden_us} us ({100.0 * frac:.1f}% overlapped)"
    )


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    root = Path(sys.argv[1])
    journals = sorted(root.glob("r*.jsonl"))
    if not journals:
        err(f"no r*.jsonl journals under {root}")
        return 1
    for journal in journals:
        stem = journal.name[1 : -len(".jsonl")]
        if not stem.isdigit():
            err(f"{journal}: malformed rank in filename")
            continue
        rank = int(stem)
        events = check_journal(journal, rank)
        chrome = journal.with_name(f"r{rank}.trace.json")
        if chrome.exists():
            n = check_chrome(chrome, rank)
            if events and n != len(events):
                err(f"{chrome}: {n} events vs {len(events)} journal lines")
        else:
            err(f"missing {chrome}")
        if events:
            check_stream_nesting(journal, events)
            print(overlap_summary(rank, events))
    if errors:
        print(f"check_trace: FAILED ({errors} error(s))", file=sys.stderr)
        return 1
    print(f"check_trace: OK ({len(journals)} rank(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
