#!/usr/bin/env python3
"""Line-for-line Python port of the pipelined-ring / progress-engine logic.

Methodology record for EXPERIMENTS.md §Dist-Overlap (the container this
PR was authored in ships no Rust toolchain, so the new scheduling logic
was validated through this port; run `./ci.sh` for the in-repo gates
once cargo is available). Mirrors rust/src/dist/collectives.rs
(`ring_all_reduce_flat_pipelined`) and rust/src/dist/pending.rs.

Validates, with real threads and bounded (socket-buffer-like) links:

1. BITWISE: pipelined ring == blocking ring == star on random float32
   payloads, across worlds x lengths x stage counts (incl. empty, 1,
   < world, non-dividing, multi-stage).
2. NO DEADLOCK: every schedule terminates when each rank's collectives
   run on a FIFO progress-engine thread over capacity-1 duplex links
   (send blocks unless drained concurrently -- the duplex loop is
   load-bearing, as in SocketComm).
3. TRAFFIC MODEL: per-rank sent bytes of the blocking ring equal
   2*(R-1)*(HDR + chunk_bytes) for divisible payloads; the pipelined
   ring moves identical payload bytes + 2*(R-1) extra headers per
   additional stage.
4. ENGINE SEMANTICS: a blocking exchange issued after an unwaited
   istart lands after it in FIFO order on every rank; a dropped
   (never-waited) op still executes.
"""
import threading
import queue
import numpy as np

HDR = 17
DEPTH = 2


def row_shard_range(rows, world, rank):
    world = max(world, 1)
    q, rem = divmod(rows, world)
    start = rank * q + min(rank, rem)
    end = start + q + (1 if rank < rem else 0)
    return start, end


def tree_combine(parts):
    n = len(parts)
    if n == 0:
        return np.zeros(0, np.float32)
    if n == 1:
        return parts[0].copy()
    mid = (n + 1) // 2
    a = tree_combine(parts[:mid])
    b = tree_combine(parts[mid:])
    return (a + b).astype(np.float32)


class Links:
    """capacity-1 per-direction byte links (socket-buffer stand-in)."""

    def __init__(self, world, cap=1):
        self.q = {(f, t): queue.Queue(maxsize=cap)
                  for f in range(world) for t in range(world) if f != t}
        self.sent = [0] * world  # payload-frame bytes per rank


class Comm:
    def __init__(self, links, rank, world):
        self.links, self.rank, self.world = links, rank, world

    def send_recv(self, to, payload, frm):
        """duplex: progress both directions (try-send / try-recv loop)."""
        sent = False
        got = None
        sq, rq = self.links.q[(self.rank, to)], self.links.q[(frm, self.rank)]
        self.links.sent[self.rank] += HDR + payload.nbytes
        while not (sent and got is not None):
            if not sent:
                try:
                    sq.put_nowait(payload)
                    sent = True
                    continue
                except queue.Full:
                    pass
            if got is None:
                try:
                    got = rq.get(timeout=0.0005)
                    continue
                except queue.Empty:
                    pass
        return got


class Engine:
    """FIFO progress engine: one thread, ops in issue order."""

    def __init__(self):
        self.jobs = queue.Queue()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        while True:
            job = self.jobs.get()
            if job is None:
                return
            f, box = job
            box.append(f())
            box_done = job[2] if len(job) > 2 else None

    def submit(self, f):
        box = []
        done = threading.Event()

        def wrapped():
            r = f()
            done.set()
            return r
        self.jobs.put((wrapped, box))
        return box, done

    def close(self):
        self.jobs.put(None)
        self.t.join(timeout=30)
        assert not self.t.is_alive(), "engine leak"


def wait(op):
    box, done = op
    assert done.wait(timeout=30), "deadlock: op never completed"
    return box[0]


def ring_blocking(comm, flat):
    world, rank = comm.world, comm.rank
    total = len(flat)

    def chunk(c):
        return row_shard_range(total, world, c)

    my = chunk(rank)
    contrib = [None] * world
    contrib[rank] = flat[my[0]:my[1]].copy()
    for s in range(1, world):
        to = (rank + s) % world
        frm = (rank + world - s) % world
        got = comm.send_recv(to, flat[chunk(to)[0]:chunk(to)[1]].copy(), frm)
        contrib[frm] = got
    out = np.zeros(total, np.float32)
    reduced = tree_combine(contrib)
    out[my[0]:my[1]] = reduced
    right, left = (rank + 1) % world, (rank + world - 1) % world
    cursor = reduced
    for s in range(world - 1):
        ri = (rank + world - s - 1) % world
        cursor = comm.send_recv(right, cursor, left)
        out[chunk(ri)[0]:chunk(ri)[1]] = cursor
    return out


def ring_pipelined(comm, engine, flat, stages):
    world, rank = comm.world, comm.rank
    total = len(flat)
    stages = max(stages, 1)
    right, left = (rank + 1) % world, (rank + world - 1) % world

    def stage_rg(m):
        return row_shard_range(total, stages, m)

    def chunk(m, c):
        ms, me = stage_rg(m)
        s, e = row_shard_range(me - ms, world, c)
        return ms + s, ms + e

    def issue_p1(m):
        ops = []
        for s in range(1, world):
            to = (rank + s) % world
            frm = (rank + world - s) % world
            lo, hi = chunk(m, to)
            payload = flat[lo:hi].copy()
            ops.append(engine.submit(
                lambda p=payload, t=to, f=frm: comm.send_recv(t, p, f)))
        return ops

    out = np.zeros(total, np.float32)
    in_flight = [issue_p1(m) for m in range(min(DEPTH, stages))]
    for m in range(stages):
        if m + DEPTH < stages:
            in_flight.append(issue_p1(m + DEPTH))
        my = chunk(m, rank)
        contrib = [None] * world
        contrib[rank] = flat[my[0]:my[1]].copy()
        ops = in_flight.pop(0)
        for s, op in zip(range(1, world), ops):
            frm = (rank + world - s) % world
            contrib[frm] = wait(op)
        reduced = tree_combine(contrib)
        out[my[0]:my[1]] = reduced
        cursor = reduced
        for s in range(world - 1):
            ri = (rank + world - s - 1) % world
            cursor = wait(engine.submit(
                lambda c=cursor: comm.send_recv(right, c, left)))
            out[chunk(m, ri)[0]:chunk(m, ri)[1]] = cursor
    return out


def star(inputs):
    return tree_combine(inputs)


def run_world(world, fn):
    links = Links(world)
    outs = [None] * world
    errs = []

    def body(r):
        try:
            outs[r] = fn(r, links)
        except Exception as e:  # noqa
            errs.append((r, e))
    ts = [threading.Thread(target=body, args=(r,)) for r in range(world)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
        assert not t.is_alive(), "deadlock: rank thread hung"
    assert not errs, errs
    return outs, links


class InlineEngine:
    """the Rust cores' istart_* semantics: execute at issue, return a
    completed handle. Engine jobs MUST use this for nested collectives —
    submitting micro-ops back onto the engine that is executing the job
    deadlocks a single-threaded FIFO (this port's first draft did
    exactly that, which is why the core/wrapper split in
    rust/src/dist/{mod,transport}.rs is load-bearing)."""

    def submit(self, f):
        box = [f()]
        done = threading.Event()
        done.set()
        return box, done

    def close(self):
        pass


class Rendezvous:
    """two-phase barrier exchange (the star primitive)."""

    def __init__(self, world):
        self.world = world
        self.cv = threading.Condition()
        self.slots = [None] * world
        self.deposited = 0
        self.taken = 0
        self.reading = False

    def exchange(self, rank, payload):
        with self.cv:
            while self.reading or self.slots[rank] is not None:
                self.cv.wait(30)
            self.slots[rank] = payload
            self.deposited += 1
            if self.deposited == self.world:
                self.reading = True
                self.cv.notify_all()
            while not self.reading:
                assert self.cv.wait(30), "exchange deadlock"
            out = list(self.slots)
            self.taken += 1
            if self.taken == self.world:
                self.slots = [None] * self.world
                self.deposited = 0
                self.taken = 0
                self.reading = False
                self.cv.notify_all()
            return out


def rank_step_sim(rank, world, rv, comm, engine, stats, bucket_flat, overlap):
    """the overlapped rank_step op sequence: loss exchange, one gather
    per layer (vs one batched gather), pipelined bucket all-reduce,
    flag exchange — all through the FIFO engine when overlap is on."""
    if overlap:
        loss_op = engine.submit(lambda: rv.exchange(rank, ("loss", rank)))
        gather_ops = [engine.submit(lambda l=l: rv.exchange(rank, ("g", l, stats[l])))
                      for l in range(len(stats))]
        loss = wait(loss_op)
        gathered = [wait(op) for op in gather_ops]
        # istart_all_reduce_sum: the whole collective is ONE engine job;
        # inside it, micro-ops run inline on the core (InlineEngine).
        update = wait(engine.submit(
            lambda: ring_pipelined(comm, InlineEngine(), bucket_flat, 1)))
        flag = wait(engine.submit(lambda: rv.exchange(rank, ("flag", rank))))
    else:
        loss = rv.exchange(rank, ("loss", rank))
        batched = rv.exchange(rank, ("g", "all",
                                     np.concatenate(stats) if stats else
                                     np.zeros(0, np.float32)))
        gathered = batched
        update = ring_blocking(comm, bucket_flat)
        flag = rv.exchange(rank, ("flag", rank))
    # flatten gathered per-rank stats rows into one array per rank
    def rows(part):
        if overlap:
            return part  # list of per-layer exchanges, checked below
        return part
    return loss, gathered, update, flag


def validate_rank_step_schedule():
    rng = np.random.default_rng(11)
    world = 4
    n_layers = 5
    rounds = 3
    per_rank_stats = [[rng.standard_normal(6).astype(np.float32)
                       for _ in range(n_layers)] for _ in range(world)]
    bucket = [rng.standard_normal(32).astype(np.float32)
              for _ in range(world)]
    results = {}
    for overlap in (False, True):
        links = Links(world)
        rv = Rendezvous(world)
        outs = [None] * world

        def body(r):
            comm = Comm(links, r, world)
            engine = Engine() if overlap else None
            try:
                acc = []
                for _ in range(rounds):
                    acc.append(rank_step_sim(r, world, rv, comm, engine,
                                             per_rank_stats[r], bucket[r],
                                             overlap))
                return acc
            finally:
                if engine:
                    engine.close()
        ts = []
        for r in range(world):
            t = threading.Thread(target=lambda r=r: outs.__setitem__(r, body(r)))
            t.start()
            ts.append(t)
        for t in ts:
            t.join(timeout=60)
            assert not t.is_alive(), f"rank_step sim deadlock (overlap={overlap})"
        # updates must be bitwise equal across overlap modes
        results[overlap] = [[step[2] for step in outs[r]] for r in range(world)]
        # per-layer gathered stats must reconstruct the batched bytes
        for r in range(world):
            for step in outs[r]:
                g = step[1]
                if overlap:
                    per_layer = [[p[2] for p in g[l]] for l in range(n_layers)]
                    recon = [np.concatenate([per_layer[l][src]
                                             for l in range(n_layers)])
                             for src in range(world)]
                else:
                    recon = [p[2] for p in g]
                for src in range(world):
                    want = np.concatenate(per_rank_stats[src])
                    assert np.array_equal(recon[src], want), (overlap, r, src)
    for r in range(world):
        for a, b in zip(results[False][r], results[True][r]):
            assert np.array_equal(a, b), "overlap changed update bits"
    print("rank_step overlap schedule: bitwise + termination OK "
          f"({rounds} rounds x {world} ranks, persistent engines)")


def main():
    rng = np.random.default_rng(7)
    cases = 0
    for world in (2, 3, 4):
        for total in (0, 1, world - 1, 7, 3 * world, 12 * world + 5, 257):
            inputs = [rng.standard_normal(total).astype(np.float32)
                      for _ in range(world)]
            want = star(inputs)
            # blocking ring
            outs, links_b = run_world(
                world, lambda r, L: ring_blocking(Comm(L, r, world), inputs[r]))
            for r, o in enumerate(outs):
                assert np.array_equal(o, want), (world, total, r, "blocking")
            # traffic model (divisible case)
            if total % world == 0 and total > 0:
                per = 2 * (world - 1) * (HDR + 4 * total // world)
                assert links_b.sent == [per] * world, (links_b.sent, per)
            for stages in (1, 2, 3, 7):
                def body(r, L):
                    eng = Engine()
                    try:
                        return ring_pipelined(Comm(L, r, world), eng,
                                              inputs[r], stages)
                    finally:
                        eng.close()
                outs, links_p = run_world(world, body)
                for r, o in enumerate(outs):
                    assert np.array_equal(o, want), (world, total, r, stages)
                cases += 1
                # pipelined payload bytes == blocking payload bytes up
                # to chunk-boundary rounding; extra header bytes are
                # exactly 2*(R-1) per additional stage. Exact when every
                # stage length divides by R.
                if total > 0:
                    S = max(stages, 1)
                    hdr_delta = HDR * (S - 1) * 2 * (world - 1)
                    diff = links_p.sent[0] - links_b.sent[0]
                    if total % (S * world) == 0:
                        assert diff == hdr_delta, (world, total, stages, diff)
                    else:
                        slack = 4 * 2 * (world - 1) * S
                        assert abs(diff - hdr_delta) <= slack, (
                            world, total, stages, diff, hdr_delta)
    print(f"bitwise + traffic + termination OK ({cases} pipelined cases)")

    # Engine FIFO semantics: blocking-after-istart ordering + dropped op.
    world = 3

    def body(r, L):
        comm = Comm(L, r, world)
        eng = Engine()
        ran = []
        # "istart" a ring step, never wait it (dropped handle).
        eng.submit(lambda: ran.append(
            comm.send_recv((r + 1) % world, np.float32([r]), (r + world - 1) % world)))
        # blocking call routed through the engine (FIFO after the above).
        second = wait(eng.submit(lambda: comm.send_recv(
            (r + 1) % world, np.float32([10 + r]), (r + world - 1) % world)))
        eng.close()
        assert len(ran) == 1, "dropped op must still execute"
        return float(second[0])
    outs, _ = run_world(world, body)
    assert outs == [10 + (r + world - 1) % world for r in range(world)], outs
    print("engine FIFO + dropped-op semantics OK")
    validate_rank_step_schedule()


if __name__ == "__main__":
    main()
