//! Quickstart: train a small model with SINGD and compare against AdamW.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use singd::config::{Arch, JobConfig};
use singd::exp::{default_hyper, run_job};
use singd::optim::Method;
use singd::structured::Structure;
use singd::train::Schedule;

fn main() {
    println!("SINGD quickstart — MLP on synthetic CIFAR-100 (20 classes)\n");
    let base = JobConfig {
        arch: Arch::Mlp { hidden: vec![64, 32] },
        dataset: "cifar100".into(),
        classes: 10,
        n_train: 1000,
        n_test: 200,
        method: Method::AdamW,
        hyper: default_hyper(&Method::AdamW, false),
        schedule: Schedule::Cosine { total: 300 },
        epochs: 10,
        batch_size: 32,
        seed: 1,
        label: "quickstart".into(),
        ranks: 1,
        dist_strategy: singd::dist::DistStrategy::Replicated,
        transport: singd::dist::Transport::Local,
        algo: singd::dist::default_algo(),
        overlap: singd::dist::default_overlap(),
        wire_dtype: singd::dist::default_wire_dtype(),
        resume: None,
        ckpt: None,
        ckpt_every: 0,
        elastic: false,
        trace_dir: None,
        log: None,
    };

    for method in [
        Method::AdamW,
        Method::Singd { structure: Structure::Diagonal },
        Method::Singd { structure: Structure::Dense }, // = INGD
    ] {
        let mut cfg = base.clone();
        cfg.method = method.clone();
        cfg.hyper = default_hyper(&method, false);
        let res = run_job(&cfg);
        println!(
            "{:<14} final test err {:.3}  best {:.3}  optimizer state {:>8} bytes  ({:.1}s)",
            method.name(),
            res.final_test_err,
            res.best_test_err,
            res.optimizer_bytes,
            res.wall_secs
        );
    }
    println!("\nSINGD-Diag matches INGD's quality at a fraction of the state bytes;");
    println!("see `cargo bench --bench fig1_vgg_cifar` for the full Fig. 1 reproduction.");
}
