//! Fig. 7 (right): GCN node classification on the synthetic-Cora graph —
//! KFAC (fp32, the strong baseline suggested by Izadi et al. 2020) vs
//! AdamW vs SINGD variants.
//!
//! ```bash
//! cargo run --release --example gnn_cora
//! ```

use singd::exp::{default_hyper, run_gcn};
use singd::optim::Method;
use singd::structured::Structure;

fn main() {
    println!("GCN on synthetic Cora (300 nodes, 7 classes, SBM homophily 8×)\n");
    println!("{:<16} {:>10} {:>10}", "method", "test err", "diverged");
    println!("{}", "-".repeat(40));
    let mut curves = String::from("method,step,test_loss,test_err\n");
    for method in [
        Method::Sgd,
        Method::AdamW,
        Method::Kfac,
        Method::Singd { structure: Structure::Dense },
        Method::Singd { structure: Structure::Diagonal },
        Method::Singd { structure: Structure::Hierarchical { k1: 4, k2: 4 } },
    ] {
        let mut hp = default_hyper(&method, false);
        hp.lr *= 3.0; // constant-lr schedule on a small graph
        let (curve, diverged) = run_gcn(&method, &hp, 300, 7);
        let last = curve.last().unwrap();
        println!(
            "{:<16} {:>10.3} {:>10}",
            method.name(),
            last.2,
            if diverged { "YES" } else { "no" }
        );
        for (t, loss, err) in &curve {
            curves.push_str(&format!("{},{},{},{}\n", method.name(), t, loss, err));
        }
    }
    if let Ok(p) = singd::train::write_csv("gnn_cora_curves.csv", &curves) {
        println!("\nwrote {}", p.display());
    }
}
