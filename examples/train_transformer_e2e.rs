//! END-TO-END driver: the full three-layer stack on a real small workload.
//!
//! - **L1/L2** — `artifacts/transformer_lm.hlo.txt`: a causal transformer
//!   LM (Pallas linear kernels inside a JAX fwd/bwd graph), AOT-lowered
//!   once by `python/compile/aot.py`. Python is NOT running here.
//! - **Runtime** — `singd::runtime::Engine` loads the HLO text and
//!   compiles it on the PJRT CPU client.
//! - **L3** — this Rust loop owns all state: parameters, the SINGD
//!   optimizer (structured inverse-free preconditioner), the data stream,
//!   LR schedule, metrics and checkpointing.
//!
//! Trains on a second-order-Markov token stream for a few hundred steps
//! and logs the loss curve to `results/e2e_transformer_loss.csv`; the run
//! is recorded in EXPERIMENTS.md. The model must beat both the uniform
//! baseline `ln(V)` and the unigram entropy of the stream.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_transformer_e2e
//! ```

use singd::config::Toml;
use singd::data::TokenStream;
use singd::model::with_bias_col;
use singd::optim::{Hyper, KronStats, Method};
use singd::proptest::Pcg;
use singd::runtime::{artifact_path, Engine, MatInput};
use singd::structured::Structure;
use singd::tensor::Mat;
use singd::train::{save_checkpoint, Schedule};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let meta_path = artifact_path("meta.toml");
    let hlo_path = artifact_path("transformer_lm.hlo.txt");
    if !std::path::Path::new(&hlo_path).exists() {
        eprintln!("artifacts missing — run `make artifacts` first ({hlo_path})");
        std::process::exit(1);
    }
    let meta = Toml::parse(&std::fs::read_to_string(&meta_path)?)?;
    let vocab = meta.usize_or("lm.vocab", 32);
    let batch = meta.usize_or("lm.batch", 8);
    let seq = meta.usize_or("lm.seq", 16);
    let n_layers = meta.usize_or("lm.n_layers", 0);
    let shapes: Vec<(usize, usize)> = (0..n_layers)
        .map(|i| {
            (
                meta.usize_or(&format!("layer{i}.d_out"), 0),
                meta.usize_or(&format!("layer{i}.d_in1"), 0),
            )
        })
        .collect();
    let n_params: usize = shapes.iter().map(|&(o, i)| o * i).sum();
    println!("e2e transformer LM: vocab={vocab} batch={batch} seq={seq} layers={n_layers} params={n_params}");

    let engine = Engine::load(&hlo_path)?;
    println!("PJRT platform: {}", engine.platform());

    // L3 state: parameters (Kaiming-ish init, zero bias column).
    let mut rng = Pcg::new(1234);
    let mut params: Vec<Mat> = shapes
        .iter()
        .map(|&(o, i)| {
            let scale = (2.0 / (i - 1) as f32).sqrt();
            Mat::from_fn(o, i, |_, c| if c + 1 < i { rng.normal() * scale } else { 0.0 })
        })
        .collect();

    // SINGD with hierarchical structure — the paper's best memory/quality
    // trade-off for transformers (Fig. 6).
    let method = Method::Singd { structure: Structure::Hierarchical { k1: 4, k2: 4 } };
    let hp = Hyper {
        lr: 0.05,
        momentum: 0.9,
        weight_decay: 1e-3,
        damping: 0.1,
        precond_lr: 0.05,
        riem_momentum: 0.6,
        t_update: 2,
        update_clip: 0.05,
        ..Hyper::default()
    };
    let mut opt = method.build(&shapes, &hp);
    println!(
        "optimizer {} — state {} bytes (AdamW would be {} bytes)",
        method.name(),
        opt.state_bytes(),
        2 * n_params * 4
    );

    let stream = TokenStream::markov(&mut rng, vocab, 40_000, 0.15);
    let steps: usize = std::env::var("SINGD_E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3000);
    let schedule = Schedule::Cosine { total: steps };
    let mut csv = String::from("step,loss,lr,ms_per_step\n");
    let uniform = (vocab as f32).ln();

    let t_start = std::time::Instant::now();
    let mut ema_loss = None::<f32>;
    for step in 0..steps {
        let (tokens, targets) = stream.lm_batch(&mut rng, batch, seq);
        let t0 = std::time::Instant::now();
        let mut inputs = vec![MatInput::new(&tokens), MatInput::new(&targets)];
        for p in &params {
            inputs.push(MatInput::new(p));
        }
        let out = engine.run(&inputs)?;
        let loss = out[0][0];
        // Unpack per-layer (dW, A, G).
        let ms_rows = batch * seq;
        let mut grads = Vec::with_capacity(n_layers);
        let mut stats = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let (d_out, d_in1) = shapes[l];
            let dw = Mat::from_vec(d_out, d_in1, out[1 + 3 * l].clone());
            let a = Mat::from_vec(ms_rows, d_in1 - 1, out[2 + 3 * l].clone());
            let g = Mat::from_vec(ms_rows, d_out, out[3 + 3 * l].clone());
            grads.push(dw);
            // Bias column appended here (the JAX side exports raw inputs);
            // G rescaled to per-row gradients (KFAC convention).
            stats.push(KronStats { a: with_bias_col(&a), g: g.scale(ms_rows as f32) });
        }
        opt.set_lr(hp.lr * schedule.factor(step));
        opt.step(step, &mut params, &grads, &stats);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        ema_loss = Some(match ema_loss {
            None => loss,
            Some(e) => 0.95 * e + 0.05 * loss,
        });
        csv.push_str(&format!("{step},{loss:.6},{:.6},{ms:.1}\n", hp.lr * schedule.factor(step)));
        if step % 150 == 0 || step + 1 == steps {
            println!(
                "step {step:>4}  loss {loss:.4}  (ema {:.4}, uniform {:.4})  {ms:.0} ms/step",
                ema_loss.unwrap(),
                uniform
            );
        }
        if opt.diverged() || !loss.is_finite() {
            eprintln!("DIVERGED at step {step}");
            std::process::exit(1);
        }
    }
    let wall = t_start.elapsed().as_secs_f64();
    let final_ema = ema_loss.unwrap();
    println!(
        "\ndone: {} steps in {:.1}s ({:.0} tokens/s), final ema loss {:.4} vs uniform {:.4}",
        steps,
        wall,
        (steps * batch * seq) as f64 / wall,
        final_ema,
        uniform
    );
    singd::train::write_csv("e2e_transformer_loss.csv", &csv).ok();
    let ckpt = std::path::Path::new("results/e2e_transformer.ckpt");
    save_checkpoint(ckpt, &params)?;
    println!("checkpoint: {} ; curve: results/e2e_transformer_loss.csv", ckpt.display());

    // Success criterion: well below the uniform baseline (the stream's
    // conditional entropy is ≈ noise-dominated, far under ln V).
    if final_ema > 0.75 * uniform {
        eprintln!("WARN: loss {final_ema:.3} did not get well below uniform {uniform:.3}");
        std::process::exit(1);
    }
    Ok(())
}
