//! Figs. 5 & 8: the structure gallery — for every supported Kronecker
//! factor class, print the sparsity pattern of `K`, of `K Kᵀ` (the
//! approximate inverse-Hessian factor) and of `(K Kᵀ)⁻¹` (the approximate
//! Hessian factor), plus stored-parameter counts.
//!
//! ```bash
//! cargo run --release --example structures_gallery
//! ```

use singd::cli::print_structure;
use singd::structured::Structure;

fn main() {
    let d = 12;
    for s in [
        Structure::Dense,
        Structure::Diagonal,
        Structure::BlockDiag { k: 4 },
        Structure::Tril,
        Structure::RankKTril { k: 1 },
        Structure::RankKTril { k: 3 },
        Structure::Hierarchical { k1: 3, k2: 2 },
        Structure::TriuToeplitz,
    ] {
        print_structure(s, d);
        println!();
    }
    println!("Note (Fig. 8): rank-1 triangular K yields a diagonal-plus-rank-one");
    println!("K Kᵀ — a *dense* approximate inverse-Hessian from O(d) storage,");
    println!("which cannot be imposed directly on (S_K + λI)⁻¹.");
}
