//! The paper's stability headline (Fig. 1 left/center, §4): KFAC becomes
//! numerically unstable in BFloat16 because it must invert its damped
//! Kronecker factors, while the inverse-free methods (IKFAC / INGD /
//! SINGD) remain stable — their updates are multiplications only.
//!
//! This driver trains a small VGG on synthetic CIFAR-100 under
//! fp32 / bf16 / pure-bf16 with KFAC, IKFAC and SINGD-Diag and reports,
//! per cell: divergence (and the step it first bit), accumulated
//! Cholesky failures, the final/best error gap to the fp32 reference,
//! and the optimizer-state bytes (half-precision storage packs the
//! Kronecker factors as 2-byte [`singd::numerics::QMat`] payloads). A
//! second section isolates the end-to-end low-precision *wire*: the same
//! distributed job at an f32 vs bf16 wire dtype, with per-rank collective
//! bytes from `singd::dist::traffic`. Results land in
//! `BENCH_low_precision.json` alongside the printed table.
//!
//! ```bash
//! cargo run --release --example low_precision_stability
//! ```

use singd::config::{Arch, JobConfig};
use singd::dist::{traffic, DistStrategy};
use singd::exp::{default_hyper, run_job};
use singd::numerics::{Dtype, Policy};
use singd::optim::Method;
use singd::structured::Structure;
use singd::train::Schedule;

struct Cell {
    method: String,
    precision: &'static str,
    final_err: f32,
    best_err: f32,
    diverged: bool,
    /// First step whose log row carries the diverged flag (the run stops
    /// there under `stop_on_divergence`); `None` for stable runs.
    divergence_step: Option<usize>,
    chol_failures: usize,
    optimizer_bytes: usize,
    steps_run: usize,
    wall_secs: f64,
}

struct WireRow {
    wire: &'static str,
    ranks: usize,
    wire_bytes_by_rank: Vec<u64>,
}

/// Pull `chol_failures=N` out of the optimizer telemetry string.
fn parse_chol_failures(telemetry: &str) -> usize {
    telemetry
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("chol_failures="))
        .and_then(|n| n.parse().ok())
        .unwrap_or(0)
}

fn json_u64_array(xs: &[u64]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn write_json(cells: &[Cell], wires: &[WireRow]) {
    let mut out = String::from("{\n  \"bench\": \"low_precision\",\n  \"cases\": [\n");
    for (i, c) in cells.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"method\": \"{}\", \"precision\": \"{}\", \"final_err\": {:.4}, \"best_err\": {:.4}, \"diverged\": {}, \"divergence_step\": {}, \"chol_failures\": {}, \"optimizer_bytes\": {}, \"steps_run\": {}, \"wall_secs\": {:.2}}}",
            c.method,
            c.precision,
            c.final_err,
            c.best_err,
            c.diverged,
            c.divergence_step.map_or("null".to_string(), |s| s.to_string()),
            c.chol_failures,
            c.optimizer_bytes,
            c.steps_run,
            c.wall_secs,
        ));
        out.push_str(if i + 1 < cells.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"wire\": [\n");
    for (i, w) in wires.iter().enumerate() {
        let max = w.wire_bytes_by_rank.iter().max().copied().unwrap_or(0);
        out.push_str(&format!(
            "    {{\"wire\": \"{}\", \"ranks\": {}, \"wire_bytes_by_rank\": {}, \"max_rank_wire_bytes\": {}}}",
            w.wire,
            w.ranks,
            json_u64_array(&w.wire_bytes_by_rank),
            max,
        ));
        out.push_str(if i + 1 < wires.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_low_precision.json", &out) {
        Ok(()) => println!("\n-- wrote BENCH_low_precision.json"),
        Err(e) => eprintln!("\n-- failed to write BENCH_low_precision.json: {e}"),
    }
}

fn main() {
    let base = JobConfig {
        arch: Arch::Vgg { width: 8 },
        dataset: "cifar100".into(),
        classes: 20,
        n_train: 1200,
        n_test: 300,
        method: Method::Kfac,
        hyper: default_hyper(&Method::Kfac, false),
        schedule: Schedule::Step { every: 120, gamma: 0.5 },
        epochs: 8,
        batch_size: 32,
        seed: 17,
        label: "stability".into(),
        ranks: 1,
        dist_strategy: DistStrategy::Replicated,
        transport: singd::dist::Transport::Local,
        algo: singd::dist::default_algo(),
        overlap: singd::dist::default_overlap(),
        wire_dtype: singd::dist::default_wire_dtype(),
        resume: None,
        ckpt: None,
        ckpt_every: 0,
        elastic: false,
        trace_dir: None,
        log: None,
    };

    println!(
        "{:<16} {:<10} {:>9} {:>9} {:>10} {:>10} {:>6} {:>12}",
        "method", "precision", "final", "best", "diverged", "div_step", "chol", "state_bytes"
    );
    println!("{}", "-".repeat(92));
    let mut cells: Vec<Cell> = Vec::new();
    for method in [
        Method::Kfac,
        Method::Ikfac { structure: Structure::Dense },
        Method::Singd { structure: Structure::Diagonal },
    ] {
        for prec in ["fp32", "bf16", "bf16-pure"] {
            let mut cfg = base.clone();
            cfg.method = method.clone();
            cfg.hyper = default_hyper(&method, true);
            cfg.hyper.policy = Policy::parse(prec).unwrap();
            // Small damping stresses the inversion exactly as large-scale
            // training does (damping ≲ bf16's 2⁻⁸ entrywise rounding of S).
            if matches!(method, Method::Kfac | Method::Ikfac { .. }) {
                cfg.hyper.damping = 2e-3;
                cfg.hyper.precond_lr = 0.1;
            }
            let res = run_job(&cfg);
            let cell = Cell {
                method: method.name(),
                precision: prec,
                final_err: res.final_test_err,
                best_err: res.best_test_err,
                diverged: res.diverged,
                divergence_step: res.rows.iter().find(|r| r.diverged).map(|r| r.step),
                chol_failures: parse_chol_failures(&res.telemetry),
                optimizer_bytes: res.optimizer_bytes,
                steps_run: res.steps_run,
                wall_secs: res.wall_secs,
            };
            println!(
                "{:<16} {:<10} {:>9.3} {:>9.3} {:>10} {:>10} {:>6} {:>12}",
                cell.method,
                cell.precision,
                cell.final_err,
                cell.best_err,
                if cell.diverged { "YES" } else { "no" },
                cell.divergence_step.map_or("-".to_string(), |s| s.to_string()),
                cell.chol_failures,
                cell.optimizer_bytes,
            );
            cells.push(cell);
        }
    }

    // The wire leg: the same small SINGD job data-parallel at ranks=4,
    // once per wire dtype. Bulk collective frames carry dtype-sized
    // elements, so the bf16 wire moves ~half the per-rank bytes; the f64
    // control plane and checkpoint gathers stay exact either way.
    println!("\nwire dtype    ranks   max B/rank");
    let mut wires: Vec<WireRow> = Vec::new();
    for wire in [Dtype::F32, Dtype::Bf16] {
        let mut cfg = base.clone();
        cfg.method = Method::Singd { structure: Structure::Diagonal };
        cfg.hyper = default_hyper(&cfg.method, false);
        cfg.arch = Arch::Mlp { hidden: vec![64, 32] };
        cfg.n_train = 320;
        cfg.n_test = 64;
        cfg.epochs = 1;
        cfg.ranks = 4;
        cfg.dist_strategy = DistStrategy::FactorSharded;
        cfg.wire_dtype = wire;
        traffic::reset();
        let res = run_job(&cfg);
        assert!(!res.diverged, "wire leg diverged at {}", wire.name());
        let row = WireRow {
            wire: wire.name(),
            ranks: cfg.ranks,
            wire_bytes_by_rank: traffic::sent_by_rank(cfg.ranks),
        };
        println!(
            "{:<13} {:>5} {:>12}",
            row.wire,
            row.ranks,
            row.wire_bytes_by_rank.iter().max().copied().unwrap_or(0),
        );
        wires.push(row);
    }

    write_json(&cells, &wires);

    println!("\nExpected shape (paper Fig. 1): KFAC's bf16 runs hit Cholesky failures");
    println!("(its damped factors lose positive-definiteness to rounding) and degrade,");
    println!("while the inverse-free methods (IKFAC / SINGD) match their fp32 quality");
    println!("in bf16 with no failures — at half the factor bytes — and the bf16 wire");
    println!("halves the per-rank collective bytes on top. The hard-NaN regime is");
    println!("exercised by `cargo test bf16_cholesky` and `cargo test kfac_bf16`.");
}
