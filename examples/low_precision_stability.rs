//! The paper's stability headline (Fig. 1 left/center, §4): KFAC becomes
//! numerically unstable in BFloat16 because it must invert its damped
//! Kronecker factors, while the inverse-free methods (IKFAC / INGD /
//! SINGD) remain stable — their updates are multiplications only.
//!
//! This driver trains a small VGG on synthetic CIFAR-100 under
//! fp32 / bf16 / pure-bf16 with KFAC, IKFAC and SINGD-Diag, and reports
//! divergences and Cholesky failures.
//!
//! ```bash
//! cargo run --release --example low_precision_stability
//! ```

use singd::config::{Arch, JobConfig};
use singd::exp::{default_hyper, run_job};
use singd::numerics::Policy;
use singd::optim::Method;
use singd::structured::Structure;
use singd::train::Schedule;

fn main() {
    let base = JobConfig {
        arch: Arch::Vgg { width: 8 },
        dataset: "cifar100".into(),
        classes: 20,
        n_train: 1200,
        n_test: 300,
        method: Method::Kfac,
        hyper: default_hyper(&Method::Kfac, false),
        schedule: Schedule::Step { every: 120, gamma: 0.5 },
        epochs: 8,
        batch_size: 32,
        seed: 17,
        label: "stability".into(),
        ranks: 1,
        dist_strategy: singd::dist::DistStrategy::Replicated,
        transport: singd::dist::Transport::Local,
    };

    println!("{:<16} {:<10} {:>9} {:>9} {:>10}  {}", "method", "precision", "final", "best", "diverged", "telemetry");
    println!("{}", "-".repeat(72));
    for method in [
        Method::Kfac,
        Method::Ikfac { structure: Structure::Dense },
        Method::Singd { structure: Structure::Diagonal },
    ] {
        for prec in ["fp32", "bf16", "bf16-pure"] {
            let mut cfg = base.clone();
            cfg.method = method.clone();
            cfg.hyper = default_hyper(&method, true);
            cfg.hyper.policy = Policy::parse(prec).unwrap();
            // Small damping stresses the inversion exactly as large-scale
            // training does (damping ≲ bf16's 2⁻⁸ entrywise rounding of S).
            if matches!(method, Method::Kfac | Method::Ikfac { .. }) {
                cfg.hyper.damping = 2e-3;
                cfg.hyper.precond_lr = 0.1;
            }
            let res = run_job(&cfg);
            println!(
                "{:<16} {:<10} {:>9.3} {:>9.3} {:>10}  {}",
                method.name(),
                prec,
                res.final_test_err,
                res.best_test_err,
                if res.diverged { "YES" } else { "no" },
                res.telemetry
            );
        }
    }
    println!("\nExpected shape (paper Fig. 1): KFAC's bf16 runs hit Cholesky failures");
    println!("(its damped factors lose positive-definiteness to rounding) and degrade,");
    println!("while the inverse-free methods (IKFAC / SINGD) match their fp32 quality");
    println!("in bf16 with no failures. The hard-NaN regime is exercised by");
    println!("`cargo test bf16_cholesky` and `cargo test kfac_bf16`.");
}
