//! Synthetic datasets standing in for CIFAR-100 / ImageWoof-10 / Cora and
//! a tiny-corpus token stream (DESIGN.md §3 documents each substitution).
//!
//! All generators are seeded through [`Pcg`] so every experiment is
//! bit-reproducible from its config. Datasets are *learnable but not
//! trivial*: class-prototype structure with controllable signal-to-noise
//! plus nuisance transforms, so optimizer orderings (the quantity the
//! paper's figures compare) are observable at CPU scale.

use crate::model::cnn::ImgShape;
use crate::model::gcn::Graph;
use crate::model::Batch;
use crate::proptest::Pcg;
use crate::tensor::Mat;

/// A fixed train/test image dataset in flattened `C×H×W` layout.
#[derive(Clone)]
pub struct Dataset {
    pub shape: ImgShape,
    pub classes: usize,
    pub train_x: Mat,
    pub train_y: Vec<usize>,
    pub test_x: Mat,
    pub test_y: Vec<usize>,
}

impl Dataset {
    /// Iterate shuffled train minibatches for one epoch.
    pub fn epoch_batches<'a>(&'a self, rng: &mut Pcg, batch: usize) -> Vec<Batch> {
        let n = self.train_y.len();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        order
            .chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|chunk| Batch {
                x: Mat::from_fn(chunk.len(), self.train_x.cols(), |r, c| {
                    self.train_x.at(chunk[r], c)
                }),
                y: chunk.iter().map(|&i| self.train_y[i]).collect(),
            })
            .collect()
    }

    /// The whole test set as one batch.
    pub fn test_batch(&self) -> Batch {
        Batch { x: self.test_x.clone(), y: self.test_y.clone() }
    }
}

/// Class-prototype image generator.
///
/// Each class has a random smooth prototype image; a sample is
/// `signal·shift(prototype) + noise` with a random ±2px cyclic shift (the
/// nuisance transform that makes convs/attention genuinely useful).
pub fn prototype_images(
    rng: &mut Pcg,
    shape: ImgShape,
    classes: usize,
    n_train: usize,
    n_test: usize,
    signal: f32,
) -> Dataset {
    // Smooth prototypes: low-frequency cosine mixtures.
    let protos: Vec<Mat> = (0..classes)
        .map(|_| {
            let mut img = Mat::zeros(1, shape.len());
            for _ in 0..6 {
                let (fy, fx) = (1.0 + rng.uniform() * 3.0, 1.0 + rng.uniform() * 3.0);
                let (py, px) = (rng.uniform() * 6.28, rng.uniform() * 6.28);
                let ch = rng.below(shape.c);
                let amp = rng.normal();
                for y in 0..shape.h {
                    for x in 0..shape.w {
                        *img.at_mut(0, (ch * shape.h + y) * shape.w + x) += amp
                            * ((fy * y as f32 / shape.h as f32 * 6.28 + py).cos()
                                * (fx * x as f32 / shape.w as f32 * 6.28 + px).cos());
                    }
                }
            }
            img
        })
        .collect();

    let mut sample = |rng: &mut Pcg, y: usize| -> Vec<f32> {
        let (dy, dx) = (rng.below(5) as isize - 2, rng.below(5) as isize - 2);
        let mut v = vec![0.0f32; shape.len()];
        for c in 0..shape.c {
            for yy in 0..shape.h {
                for xx in 0..shape.w {
                    let sy = (yy as isize + dy).rem_euclid(shape.h as isize) as usize;
                    let sx = (xx as isize + dx).rem_euclid(shape.w as isize) as usize;
                    v[(c * shape.h + yy) * shape.w + xx] =
                        signal * protos[y].at(0, (c * shape.h + sy) * shape.w + sx) + rng.normal();
                }
            }
        }
        v
    };

    let gen = |rng: &mut Pcg, n: usize, sample: &mut dyn FnMut(&mut Pcg, usize) -> Vec<f32>| {
        let y: Vec<usize> = (0..n).map(|i| i % classes).collect();
        let mut x = Mat::zeros(n, shape.len());
        for (i, &yi) in y.iter().enumerate() {
            let v = sample(rng, yi);
            x.row_mut(i).copy_from_slice(&v);
        }
        (x, y)
    };

    let (train_x, train_y) = gen(rng, n_train, &mut sample);
    let (test_x, test_y) = gen(rng, n_test, &mut sample);
    Dataset { shape, classes, train_x, train_y, test_x, test_y }
}

/// Synthetic CIFAR-100 stand-in: 3×16×16, many classes, moderate SNR.
pub fn cifar100(rng: &mut Pcg, classes: usize, n_train: usize, n_test: usize) -> Dataset {
    prototype_images(rng, ImgShape { c: 3, h: 16, w: 16 }, classes, n_train, n_test, 1.2)
}

/// Synthetic ImageWoof-10 stand-in: 10 fine-grained (low-SNR) classes.
pub fn imagewoof(rng: &mut Pcg, n_train: usize, n_test: usize) -> Dataset {
    prototype_images(rng, ImgShape { c: 3, h: 16, w: 16 }, 10, n_train, n_test, 0.7)
}

/// Synthetic Cora stand-in: a stochastic-block-model citation graph with
/// class-correlated bag-of-words features, symmetric-normalized adjacency
/// with self-loops, and train/test node splits.
pub fn cora(rng: &mut Pcg, n: usize, features: usize, classes: usize, homophily: f32) -> Graph {
    let y: Vec<usize> = (0..n).map(|i| i % classes).collect();
    // SBM edges: intra-class probability `homophily×` the inter-class one.
    let p_inter = 2.0 / n as f32;
    let p_intra = (p_inter * homophily).min(0.9);
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            let p = if y[i] == y[j] { p_intra } else { p_inter };
            if rng.uniform() < p {
                a.set(i, j, 1.0);
                a.set(j, i, 1.0);
            }
        }
    }
    // Â = D^{-1/2} (A + I) D^{-1/2}.
    a.add_diag(1.0);
    let deg: Vec<f32> = (0..n).map(|i| a.row(i).iter().sum::<f32>()).collect();
    let adj = Mat::from_fn(n, n, |i, j| a.at(i, j) / (deg[i] * deg[j]).sqrt());

    // Features: class topic vector + noise (bag-of-words-ish, nonneg).
    let topics: Vec<Vec<f32>> =
        (0..classes).map(|_| (0..features).map(|_| rng.uniform() * 2.0).collect()).collect();
    let x = Mat::from_fn(n, features, |i, f| {
        (topics[y[i]][f] + 0.8 * rng.normal()).max(0.0)
    });

    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let n_train = (n as f32 * 0.3) as usize;
    Graph {
        adj,
        x,
        y,
        train_mask: order[..n_train].to_vec(),
        test_mask: order[n_train..].to_vec(),
    }
}

/// Tiny-corpus token stream for the LM example: a second-order Markov
/// chain over `vocab` tokens (deterministic-ish transitions + noise), so a
/// causal transformer can reach low perplexity while an order-0 model
/// cannot.
pub struct TokenStream {
    pub vocab: usize,
    tokens: Vec<usize>,
}

impl TokenStream {
    pub fn markov(rng: &mut Pcg, vocab: usize, len: usize, noise: f32) -> Self {
        // Transition table: (prev2, prev1) → preferred next token.
        let table: Vec<usize> = (0..vocab * vocab).map(|_| rng.below(vocab)).collect();
        let mut tokens = vec![rng.below(vocab), rng.below(vocab)];
        for _ in 2..len {
            let (p2, p1) = (tokens[tokens.len() - 2], tokens[tokens.len() - 1]);
            let next = if rng.uniform() < noise {
                rng.below(vocab)
            } else {
                table[p2 * vocab + p1]
            };
            tokens.push(next);
        }
        TokenStream { vocab, tokens }
    }

    /// Sample `m` windows of length `seq`; `y[b]` is the continuation
    /// token after the window (used as the final-position LM target).
    pub fn batch(&self, rng: &mut Pcg, m: usize, seq: usize) -> Batch {
        let mut x = Mat::zeros(m, seq);
        let mut y = Vec::with_capacity(m);
        for b in 0..m {
            let start = rng.below(self.tokens.len() - seq - 1);
            for t in 0..seq {
                *x.at_mut(b, t) = self.tokens[start + t] as f32;
            }
            y.push(self.tokens[start + seq]);
        }
        Batch { x, y }
    }

    /// Sample `m` (tokens, next-tokens) window pairs of length `seq` for
    /// per-position LM training (the e2e PJRT driver's input layout).
    pub fn lm_batch(&self, rng: &mut Pcg, m: usize, seq: usize) -> (Mat, Mat) {
        let mut x = Mat::zeros(m, seq);
        let mut t = Mat::zeros(m, seq);
        for b in 0..m {
            let start = rng.below(self.tokens.len() - seq - 1);
            for i in 0..seq {
                *x.at_mut(b, i) = self.tokens[start + i] as f32;
                *t.at_mut(b, i) = self.tokens[start + i + 1] as f32;
            }
        }
        (x, t)
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shapes_and_balance() {
        let mut rng = Pcg::new(61);
        let ds = cifar100(&mut rng, 20, 200, 60);
        assert_eq!(ds.train_x.shape(), (200, 3 * 16 * 16));
        assert_eq!(ds.test_y.len(), 60);
        // Balanced classes.
        let count0 = ds.train_y.iter().filter(|&&y| y == 0).count();
        assert_eq!(count0, 10);
    }

    #[test]
    fn epoch_batches_cover_and_shuffle() {
        let mut rng = Pcg::new(62);
        let ds = cifar100(&mut rng, 4, 64, 16);
        let b1 = ds.epoch_batches(&mut rng, 16);
        assert_eq!(b1.len(), 4);
        let b2 = ds.epoch_batches(&mut rng, 16);
        // Different shuffles with overwhelming probability.
        assert!(b1[0].y != b2[0].y || b1[0].x != b2[0].x);
    }

    #[test]
    fn prototype_signal_is_learnable() {
        // Same-class samples must correlate more than cross-class ones.
        let mut rng = Pcg::new(63);
        let ds = prototype_images(&mut rng, ImgShape { c: 1, h: 8, w: 8 }, 2, 40, 2, 2.0);
        let dot = |a: usize, b: usize| -> f32 {
            ds.train_x.row(a).iter().zip(ds.train_x.row(b)).map(|(x, y)| x * y).sum()
        };
        // rows alternate classes (i % classes)
        let same: f32 = (0..10).map(|i| dot(2 * i, 2 * i + 2)).sum::<f32>() / 10.0;
        let cross: f32 = (0..10).map(|i| dot(2 * i, 2 * i + 1)).sum::<f32>() / 10.0;
        assert!(same > cross, "same {same} cross {cross}");
    }

    #[test]
    fn cora_adjacency_normalized_symmetric() {
        let mut rng = Pcg::new(64);
        let g = cora(&mut rng, 50, 12, 5, 5.0);
        for i in 0..50 {
            for j in 0..50 {
                assert!((g.adj.at(i, j) - g.adj.at(j, i)).abs() < 1e-6);
            }
            assert!(g.adj.at(i, i) > 0.0, "self loop");
        }
        assert_eq!(g.train_mask.len() + g.test_mask.len(), 50);
    }

    #[test]
    fn markov_stream_is_predictable() {
        let mut rng = Pcg::new(65);
        let ts = TokenStream::markov(&mut rng, 8, 5000, 0.1);
        // Empirical check: the mode of next|{prev2,prev1} predicts ≈90%.
        let mut counts = vec![[0usize; 8]; 64];
        for w in ts.tokens.windows(3) {
            counts[w[0] * 8 + w[1]][w[2]] += 1;
        }
        let (mut hit, mut tot) = (0usize, 0usize);
        for w in ts.tokens.windows(3) {
            let c = &counts[w[0] * 8 + w[1]];
            let mode = (0..8).max_by_key(|&k| c[k]).unwrap();
            hit += (mode == w[2]) as usize;
            tot += 1;
        }
        assert!(hit as f32 / tot as f32 > 0.8, "predictability {}", hit as f32 / tot as f32);
    }

    #[test]
    fn token_batch_windows_are_consistent() {
        let mut rng = Pcg::new(66);
        let ts = TokenStream::markov(&mut rng, 6, 500, 0.2);
        let b = ts.batch(&mut rng, 4, 10);
        assert_eq!(b.x.shape(), (4, 10));
        for r in 0..4 {
            for t in 0..10 {
                let v = b.x.at(r, t);
                assert!(v >= 0.0 && v < 6.0 && v.fract() == 0.0);
            }
        }
    }
}
