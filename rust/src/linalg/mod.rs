//! Dense linear algebra: Cholesky, triangular solves, SPD inversion, and the
//! truncated matrix exponential.
//!
//! The KFAC baseline (Fig. 3, left) requires a real matrix inversion of the
//! damped Kronecker factors every preconditioner update. We implement it
//! via Cholesky + two triangular solves. Crucially, [`cholesky_policy`]
//! carries a [`Policy`] so every intermediate is rounded to the training
//! format — this is the code path that becomes unstable in bf16 and
//! motivates the paper. IKFAC/INGD/SINGD never call into this module on
//! their hot paths (they are "inverse-free").

use crate::numerics::Policy;
use crate::tensor::{matmul, Mat};

/// Cholesky factorization `S = L Lᵀ` in full f32 precision.
///
/// Returns `None` if `S` is not (numerically) positive definite.
pub fn cholesky(s: &Mat) -> Option<Mat> {
    cholesky_policy(s, &Policy::fp32())
}

/// Cholesky factorization under a precision policy.
///
/// Every arithmetic result is rounded to `policy.compute`, and each stored
/// `L` entry is rounded to `policy.store` — mirroring what a half-precision
/// kernel would do. With bf16's 8-bit mantissa, ill-conditioned inputs make
/// the pivot `s_ii − Σ l_ik²` go non-positive and the factorization fails:
/// this is the paper's KFAC-in-BFP16 instability.
pub fn cholesky_policy(s: &Mat, policy: &Policy) -> Option<Mat> {
    assert_eq!(s.rows(), s.cols(), "cholesky: not square");
    let n = s.rows();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut acc = 0.0f32;
            for k in 0..j {
                acc = policy.qc(acc + policy.qc(l.at(i, k) * l.at(j, k)));
            }
            if i == j {
                let d = policy.qc(s.at(i, i) - acc);
                if d <= 0.0 || !d.is_finite() {
                    return None;
                }
                l.set(i, i, policy.q(d.sqrt()));
            } else {
                let ljj = l.at(j, j);
                if ljj == 0.0 || !ljj.is_finite() {
                    return None;
                }
                l.set(i, j, policy.q(policy.qc(s.at(i, j) - acc) / ljj));
            }
        }
    }
    Some(l)
}

/// Solve `L x = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = b[i];
        for k in 0..i {
            acc -= l.at(i, k) * x[k];
        }
        x[i] = acc / l.at(i, i);
    }
    x
}

/// Solve `Lᵀ x = b` for lower-triangular `L` (backward substitution).
pub fn solve_lower_t(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut acc = b[i];
        for k in (i + 1)..n {
            acc -= l.at(k, i) * x[k];
        }
        x[i] = acc / l.at(i, i);
    }
    x
}

/// Invert an SPD matrix via Cholesky. Returns `None` if not SPD under the
/// given policy. This is KFAC's `(S + λI)⁻¹` step.
pub fn spd_inverse_policy(s: &Mat, policy: &Policy) -> Option<Mat> {
    let l = cholesky_policy(s, policy)?;
    let n = s.rows();
    let mut inv = Mat::zeros(n, n);
    // Solve S x = e_i column by column.
    let mut e = vec![0.0f32; n];
    for i in 0..n {
        e[i] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for r in 0..n {
            inv.set(r, i, policy.q(x[r]));
        }
        e[i] = 0.0;
    }
    Some(inv)
}

/// Full-precision SPD inverse.
pub fn spd_inverse(s: &Mat) -> Option<Mat> {
    spd_inverse_policy(s, &Policy::fp32())
}

/// Truncated matrix exponential `I + N + N²/2 + … + N^order/order!`.
///
/// `order = 1` is the first-order truncation the paper uses throughout
/// (`Expm(N) ≈ I + N`); `order = 2` is the non-singularity-preserving
/// variant mentioned in footnote 1.
pub fn expm_truncated(n_mat: &Mat, order: usize) -> Mat {
    assert_eq!(n_mat.rows(), n_mat.cols());
    let d = n_mat.rows();
    let mut out = Mat::eye(d);
    let mut term = Mat::eye(d);
    let mut fact = 1.0f32;
    for k in 1..=order {
        term = matmul(&term, n_mat);
        fact *= k as f32;
        out.axpy(1.0 / fact, &term);
    }
    out
}

/// General matrix inverse via LU with partial pivoting.
///
/// Used to emulate what `torch.linalg.inv` does when KFAC's damped factor
/// has lost positive-definiteness to low-precision rounding: the inverse
/// *succeeds* but has enormous / wrong-signed entries, which is precisely
/// how KFAC destabilizes in bf16 (rather than erroring out cleanly).
/// Returns `None` only for exactly-singular pivots.
pub fn lu_inverse(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut lu = a.clone();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // Partial pivoting.
        let mut pmax = k;
        let mut vmax = lu.at(k, k).abs();
        for r in (k + 1)..n {
            if lu.at(r, k).abs() > vmax {
                vmax = lu.at(r, k).abs();
                pmax = r;
            }
        }
        if vmax == 0.0 || !vmax.is_finite() {
            return None;
        }
        if pmax != k {
            for c in 0..n {
                let tmp = lu.at(k, c);
                lu.set(k, c, lu.at(pmax, c));
                lu.set(pmax, c, tmp);
            }
            piv.swap(k, pmax);
        }
        let inv_pivot = 1.0 / lu.at(k, k);
        for r in (k + 1)..n {
            let f = lu.at(r, k) * inv_pivot;
            lu.set(r, k, f);
            for c in (k + 1)..n {
                *lu.at_mut(r, c) -= f * lu.at(k, c);
            }
        }
    }
    // Solve A X = I column by column.
    let mut inv = Mat::zeros(n, n);
    let mut b = vec![0.0f32; n];
    for col in 0..n {
        for (r, bv) in b.iter_mut().enumerate() {
            *bv = if piv[r] == col { 1.0 } else { 0.0 };
        }
        // Forward (unit lower).
        for i in 0..n {
            let mut acc = b[i];
            for k in 0..i {
                acc -= lu.at(i, k) * b[k];
            }
            b[i] = acc;
        }
        // Backward (upper).
        for i in (0..n).rev() {
            let mut acc = b[i];
            for k in (i + 1)..n {
                acc -= lu.at(i, k) * b[k];
            }
            b[i] = acc / lu.at(i, i);
        }
        for r in 0..n {
            inv.set(r, col, b[r]);
        }
    }
    Some(inv)
}

/// Condition-number estimate via a few rounds of power iteration on `S` and
/// `S⁻¹` (SPD input). Used to characterize Kronecker-factor conditioning in
/// the stability experiments.
pub fn spd_condition_estimate(s: &Mat, iters: usize) -> Option<f32> {
    let inv = spd_inverse(s)?;
    Some(power_iter_sym(s, iters) * power_iter_sym(&inv, iters))
}

/// Largest-eigenvalue estimate of a symmetric matrix by power iteration.
pub fn power_iter_sym(s: &Mat, iters: usize) -> f32 {
    let n = s.rows();
    let mut v = vec![1.0f32; n];
    let mut lambda = 0.0f32;
    for _ in 0..iters {
        let mut w = vec![0.0f32; n];
        for i in 0..n {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += s.at(i, j) * v[j];
            }
            w[i] = acc;
        }
        lambda = (w.iter().map(|x| (x * x) as f64).sum::<f64>() as f32).sqrt();
        if lambda == 0.0 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / lambda;
        }
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_mat_close, forall, Pcg};

    #[test]
    fn cholesky_identity() {
        let l = cholesky(&Mat::eye(4)).unwrap();
        assert_mat_close(&l, &Mat::eye(4), 1e-6, "chol(I)");
    }

    #[test]
    fn cholesky_reconstructs() {
        forall(21, 20, |rng, _| {
            let n = 2 + rng.below(12);
            let s = rng.spd_mat(n, 0.5);
            let l = cholesky(&s).expect("SPD input must factor");
            let recon = matmul(&l, &l.transpose());
            assert_mat_close(&recon, &s, 1e-4, "L Lᵀ = S");
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let s = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&s).is_none());
    }

    #[test]
    fn spd_inverse_is_inverse() {
        forall(22, 15, |rng, _| {
            let n = 2 + rng.below(10);
            let s = rng.spd_mat(n, 1.0);
            let inv = spd_inverse(&s).unwrap();
            assert_mat_close(&matmul(&s, &inv), &Mat::eye(n), 1e-3, "S S⁻¹ = I");
        });
    }

    #[test]
    fn triangular_solves() {
        let l = Mat::from_vec(2, 2, vec![2.0, 0.0, 1.0, 3.0]);
        let x = solve_lower(&l, &[4.0, 10.0]);
        assert_eq!(x, vec![2.0, 8.0 / 3.0]);
        let y = solve_lower_t(&l, &[2.0, 3.0]);
        // Lᵀ = [[2,1],[0,3]]; solve: y1=1, y0=(2-1)/2=0.5
        assert!((y[1] - 1.0).abs() < 1e-6 && (y[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn expm_first_order_is_i_plus_n() {
        let n = Mat::from_vec(2, 2, vec![0.0, 0.1, -0.1, 0.0]);
        let e = expm_truncated(&n, 1);
        assert_mat_close(&e, &Mat::eye(2).add(&n), 1e-7, "expm order 1");
    }

    #[test]
    fn expm_converges_to_scalar_exp_on_diagonal() {
        let n = Mat::diag(&[0.3, -0.2]);
        let e = expm_truncated(&n, 12);
        assert!((e.at(0, 0) - 0.3f32.exp()).abs() < 1e-6);
        assert!((e.at(1, 1) - (-0.2f32).exp()).abs() < 1e-6);
    }

    /// The heart of the paper: bf16 Cholesky fails on SPD matrices whose
    /// *correlation structure* is ill-conditioned (min eigenvalue below
    /// bf16's ~2⁻⁸ entrywise rounding scale) while fp32 handles them fine.
    /// This is the realistic NN case — strongly correlated activations.
    #[test]
    fn bf16_cholesky_fails_on_ill_conditioned() {
        let mut rng = Pcg::new(5);
        let n = 24;
        let mut failures_bf16 = 0;
        let mut failures_f32 = 0;
        for _ in 0..8 {
            // Condition ≈ 3000: min eig 1e-3, max 3. Entrywise bf16
            // rounding perturbs eigenvalues by ~4e-3·‖S‖ ≫ 1e-3.
            let s = rng.spd_with_spectrum(n, 1e-3, 3.0);
            if cholesky_policy(&s, &Policy::fp32()).is_none() {
                failures_f32 += 1;
            }
            if cholesky_policy(&s, &Policy::bf16_pure()).is_none() {
                failures_bf16 += 1;
            }
        }
        assert_eq!(failures_f32, 0, "fp32 should factor all trials");
        assert!(failures_bf16 >= 4, "bf16 should fail most trials, failed {failures_bf16}/8");
    }

    #[test]
    fn lu_inverse_matches_spd_inverse() {
        forall(23, 12, |rng, _| {
            let n = 2 + rng.below(10);
            let s = rng.spd_mat(n, 1.0);
            let a = spd_inverse(&s).unwrap();
            let b = lu_inverse(&s).unwrap();
            assert_mat_close(&a, &b, 1e-3, "spd vs lu inverse");
        });
    }

    #[test]
    fn lu_inverse_handles_indefinite() {
        // Indefinite but nonsingular: Cholesky refuses, LU succeeds.
        let s = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(cholesky(&s).is_none());
        let inv = lu_inverse(&s).unwrap();
        assert_mat_close(&matmul(&s, &inv), &Mat::eye(2), 1e-5, "indefinite inverse");
    }

    #[test]
    fn lu_inverse_rejects_singular() {
        let s = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(lu_inverse(&s).is_none());
    }

    #[test]
    fn power_iteration_top_eigenvalue() {
        let s = Mat::diag(&[5.0, 2.0, 1.0]);
        let l = power_iter_sym(&s, 50);
        assert!((l - 5.0).abs() < 1e-3, "{l}");
    }
}
