//! Typed experiment configuration + a minimal TOML-subset parser.
//!
//! No serde offline, so we parse the subset of TOML the configs need:
//! `[section]` headers, `key = value` with string / integer / float /
//! boolean values, `#` comments. The typed layer ([`JobConfig`]) validates
//! against the model zoo / optimizer registry and produces everything the
//! trainer needs.
//!
//! Example (see `configs/` in the repo root):
//!
//! ```toml
//! [model]
//! arch = "vgg"        # mlp | vgg | convmixer | vit | gcn
//! width = 8
//!
//! [data]
//! dataset = "cifar100" # cifar100 | imagewoof | cora
//! classes = 20
//! n_train = 2000
//!
//! [optim]
//! method = "singd:diag"
//! lr = 0.1
//! precision = "bf16"
//!
//! [train]
//! epochs = 20
//! batch_size = 64
//! schedule = "cosine:600"
//! seed = 7
//! ckpt = "run.ckpt"            # periodic checkpoint path (atomic writes + .prev)
//! ckpt_every = 50              # checkpoint cadence in steps (0 = never)
//! resume = "run.ckpt"          # resume bitwise from a checkpoint
//! accum_steps = 4              # gradient-accumulation micro-batches (1 = off)
//!
//! [dist]
//! ranks = 4                    # default: SINGD_RANKS env, else 1
//! strategy = "factor-sharded"  # replicated | factor-sharded
//! transport = "socket"         # local | socket (default: SINGD_TRANSPORT env, else local)
//! algo = "ring"                # star | ring (default: SINGD_ALGO env, else ring)
//! overlap = true               # comm/compute overlap (default: SINGD_OVERLAP env, else on)
//! stream = true                # layer-streamed backward↔comm fusion: issue each
//!                              # layer's stats gather from inside its backward hook
//!                              # (default: SINGD_STREAM env, else on; needs overlap)
//! wire_dtype = "bf16"          # f32 | bf16 | fp16 collective payload dtype
//!                              # (default: SINGD_WIRE_DTYPE env, else f32)
//! elastic = true               # survive worker death / admit joiners (socket only;
//!                              # requires ckpt + ckpt_every >= 1)
//!
//! [obs]
//! trace_dir = "traces/run1"    # per-rank span journal + Chrome trace
//!                              # (default: SINGD_TRACE env, else off)
//! log = "debug"                # error | warn | info | debug
//!                              # (default: SINGD_LOG env, else info)
//! ```

use crate::dist::{self, Algo, DistStrategy, Transport};
use crate::obs::log::Level;
use crate::numerics::{Dtype, Policy};
use crate::optim::{Hyper, Method};
use crate::train::Schedule;
use std::collections::BTreeMap;

/// A parsed TOML-subset document: `section.key → value`.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    values: BTreeMap<String, Value>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        match self {
            Value::Float(f) => Some(*f as f32),
            Value::Int(i) => Some(*i as f32),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Error with line context.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Toml {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Toml, ParseError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or(ParseError { line: ln + 1, msg: "unterminated section".into() })?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(ParseError { line: ln + 1, msg: "empty section name".into() });
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or(ParseError { line: ln + 1, msg: "expected key = value".into() })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ParseError { line: ln + 1, msg: "empty key".into() });
            }
            let value = parse_value(val.trim())
                .ok_or(ParseError { line: ln + 1, msg: format!("bad value: {}", val.trim()) })?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, value);
        }
        Ok(Toml { values })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.as_f32()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(stripped) = s.strip_prefix('"') {
        return stripped.strip_suffix('"').map(|inner| Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

/// Model architecture selector.
#[derive(Clone, Debug, PartialEq)]
pub enum Arch {
    Mlp { hidden: Vec<usize> },
    Vgg { width: usize },
    ConvMixer { patch: usize, width: usize, depth: usize },
    Vit { dim: usize, depth: usize, patch: usize },
    Gcn { hidden: usize },
}

/// Fully-resolved training job.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub arch: Arch,
    pub dataset: String,
    pub classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub method: Method,
    pub hyper: Hyper,
    pub schedule: Schedule,
    pub epochs: usize,
    pub batch_size: usize,
    pub seed: u64,
    pub label: String,
    /// Data-parallel world size (`[dist] ranks`; defaults to the
    /// `SINGD_RANKS` env contract, else 1 = serial).
    pub ranks: usize,
    /// Optimizer-state layout across ranks (`[dist] strategy`).
    pub dist_strategy: DistStrategy,
    /// Communicator backend (`[dist] transport`; defaults to the
    /// `SINGD_TRANSPORT` env contract, else in-process `local`).
    pub transport: Transport,
    /// Collective algorithm (`[dist] algo`; defaults to the `SINGD_ALGO`
    /// env contract, else the bandwidth-optimal `ring`).
    pub algo: Algo,
    /// Comm/compute overlap (`[dist] overlap`; defaults to the
    /// `SINGD_OVERLAP` env contract, else on). Bitwise-neutral by the
    /// overlap-invariance contract; the knob trades progress-engine
    /// overhead for hidden collective latency.
    pub overlap: bool,
    /// Layer-streamed backward↔comm fusion (`[dist] stream`; defaults to
    /// the `SINGD_STREAM` env contract, else on). When on (and overlap
    /// is on), each layer's stats gather is issued from inside that
    /// layer's backward hook so it overlaps the backward of earlier
    /// layers. Bitwise-neutral by the stream-invariance contract
    /// (determinism contract 8).
    pub stream: bool,
    /// Collective payload dtype (`[dist] wire_dtype`; defaults to the
    /// `SINGD_WIRE_DTYPE` env contract, else exact `f32`). Half wire
    /// dtypes halve the per-rank bytes of the stats gather and update
    /// all-reduce; runs stay bitwise deterministic across transport ×
    /// algo × overlap at any fixed wire dtype.
    pub wire_dtype: Dtype,
    /// Resume from this checkpoint (`[train] resume` / `--resume`); the
    /// continued run is bitwise identical to an uninterrupted one.
    pub resume: Option<String>,
    /// Periodic checkpoint path (`[train] ckpt` / `--ckpt`); writes are
    /// atomic (tmp + fsync + rename) with a `.prev` last-good sibling.
    pub ckpt: Option<String>,
    /// Checkpoint cadence in optimizer steps (`[train] ckpt_every`;
    /// 0 = never).
    pub ckpt_every: usize,
    /// Gradient-accumulation micro-batch count (`[train] accum_steps`;
    /// 0/1 = off). Each optimizer step splits its batch into `k`
    /// contiguous micro-batches and folds their Kronecker stats back
    /// together; bitwise identical to the unsplit step when every
    /// micro-batch height is a power of two (see
    /// [`crate::optim::accum`]).
    pub accum_steps: usize,
    /// Elastic fault tolerance (`[dist] elastic` / `--elastic`): socket
    /// transport only, requires `ckpt` + `ckpt_every >= 1` + `ranks >= 2`.
    pub elastic: bool,
    /// Structured-trace output directory (`[obs] trace_dir` /
    /// `--trace-dir`; defaults to the `SINGD_TRACE` env contract, else
    /// off). Each rank writes `r<N>.jsonl` + `r<N>.trace.json` there;
    /// tracing never changes training math (the non-interference
    /// contract of [`crate::obs`]).
    pub trace_dir: Option<String>,
    /// Log-level override (`[obs] log`; defaults to the `SINGD_LOG` env
    /// contract — see [`crate::obs::log`]).
    pub log: Option<Level>,
}

impl JobConfig {
    /// Build from a parsed TOML document, validating every field.
    pub fn from_toml(t: &Toml) -> Result<JobConfig, String> {
        let arch = match t.str_or("model.arch", "mlp") {
            "mlp" => Arch::Mlp {
                hidden: vec![t.usize_or("model.width", 64), t.usize_or("model.width", 64) / 2],
            },
            "vgg" => Arch::Vgg { width: t.usize_or("model.width", 8) },
            "convmixer" => Arch::ConvMixer {
                patch: t.usize_or("model.patch", 4),
                width: t.usize_or("model.width", 16),
                depth: t.usize_or("model.depth", 3),
            },
            "vit" => Arch::Vit {
                dim: t.usize_or("model.width", 24),
                depth: t.usize_or("model.depth", 2),
                patch: t.usize_or("model.patch", 4),
            },
            "gcn" => Arch::Gcn { hidden: t.usize_or("model.width", 16) },
            other => return Err(format!("unknown model.arch '{other}'")),
        };
        let method = Method::parse(t.str_or("optim.method", "sgd"))
            .ok_or_else(|| format!("unknown optim.method '{}'", t.str_or("optim.method", "")))?;
        let policy = Policy::parse(t.str_or("optim.precision", "fp32"))
            .ok_or_else(|| format!("unknown optim.precision '{}'", t.str_or("optim.precision", "")))?;
        let hyper = Hyper {
            lr: t.f32_or("optim.lr", 0.05),
            momentum: t.f32_or("optim.momentum", 0.9),
            weight_decay: t.f32_or("optim.weight_decay", 1e-4),
            damping: t.f32_or("optim.damping", 1e-3),
            precond_lr: t.f32_or("optim.precond_lr", 0.05),
            riem_momentum: t.f32_or("optim.riem_momentum", 0.9),
            t_update: t.usize_or("optim.t_update", 5),
            policy,
            eps: t.f32_or("optim.eps", 1e-8),
            precond_clip: t.f32_or("optim.precond_clip", 1.0),
            update_clip: t.f32_or("optim.update_clip", 0.1),
        };
        let schedule = Schedule::parse(t.str_or("train.schedule", "constant"))
            .ok_or_else(|| format!("unknown train.schedule '{}'", t.str_or("train.schedule", "")))?;
        let ranks = t.usize_or("dist.ranks", dist::default_ranks()).max(1);
        let dist_strategy = DistStrategy::parse(t.str_or("dist.strategy", "replicated"))
            .ok_or_else(|| format!("unknown dist.strategy '{}'", t.str_or("dist.strategy", "")))?;
        let default_tr = dist::default_transport();
        let transport = Transport::parse(t.str_or("dist.transport", default_tr.name()))
            .ok_or_else(|| format!("unknown dist.transport '{}'", t.str_or("dist.transport", "")))?;
        let default_algo = dist::default_algo();
        let algo = Algo::parse(t.str_or("dist.algo", default_algo.name()))
            .ok_or_else(|| format!("unknown dist.algo '{}'", t.str_or("dist.algo", "")))?;
        let default_wire = dist::default_wire_dtype();
        let wire_dtype = Dtype::parse(t.str_or("dist.wire_dtype", default_wire.name()))
            .ok_or_else(|| {
                format!("unknown dist.wire_dtype '{}'", t.str_or("dist.wire_dtype", ""))
            })?;
        // `overlap = true|false` (TOML bool) or a string form accepted by
        // dist::parse_overlap; anything else is rejected, not ignored.
        let overlap = match t.get("dist.overlap") {
            None => dist::default_overlap(),
            Some(Value::Bool(b)) => *b,
            Some(v) => v
                .as_str()
                .and_then(dist::parse_overlap)
                .ok_or_else(|| format!("bad dist.overlap value {v:?} (true | false)"))?,
        };
        // `stream = true|false` (TOML bool) or a string form accepted by
        // dist::parse_overlap; anything else is rejected, not ignored.
        let stream = match t.get("dist.stream") {
            None => dist::default_stream(),
            Some(Value::Bool(b)) => *b,
            Some(v) => v
                .as_str()
                .and_then(dist::parse_overlap)
                .ok_or_else(|| format!("bad dist.stream value {v:?} (true | false)"))?,
        };
        let resume = match t.get("train.resume") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| format!("bad train.resume value {v:?} (expected a string path)"))?
                    .to_string(),
            ),
        };
        let ckpt = match t.get("train.ckpt") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| format!("bad train.ckpt value {v:?} (expected a string path)"))?
                    .to_string(),
            ),
        };
        let ckpt_every = match t.get("train.ckpt_every") {
            None => 0,
            Some(v) => v.as_usize().ok_or_else(|| {
                format!("bad train.ckpt_every value {v:?} (expected a non-negative integer)")
            })?,
        };
        let accum_steps = match t.get("train.accum_steps") {
            None => 1,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| {
                    format!("bad train.accum_steps value {v:?} (expected a non-negative integer)")
                })?
                .max(1),
        };
        let elastic = match t.get("dist.elastic") {
            None => false,
            Some(Value::Bool(b)) => *b,
            Some(v) => return Err(format!("bad dist.elastic value {v:?} (true | false)")),
        };
        let trace_dir = match t.get("obs.trace_dir") {
            None => std::env::var("SINGD_TRACE").ok().filter(|v| !v.is_empty()),
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        format!("bad obs.trace_dir value {v:?} (expected a string path)")
                    })?
                    .to_string(),
            ),
        };
        let log = match t.get("obs.log") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .and_then(Level::parse)
                    .ok_or_else(|| {
                        format!("bad obs.log value {v:?} (error | warn | info | debug)")
                    })?,
            ),
        };
        if elastic {
            if transport != Transport::Socket {
                return Err(
                    "dist.elastic requires dist.transport = \"socket\" (the in-process \
                     local transport has no processes to lose)"
                        .into(),
                );
            }
            if ckpt.is_none() {
                return Err(
                    "dist.elastic requires train.ckpt (recovery reloads the last checkpoint)"
                        .into(),
                );
            }
            if ckpt_every == 0 {
                return Err("dist.elastic requires train.ckpt_every >= 1 (the checkpoint \
                            cadence bounds the work lost to a failure)"
                    .into());
            }
            if ranks < 2 {
                return Err(format!(
                    "dist.elastic requires dist.ranks >= 2 (got {ranks}); a single rank has \
                     no peers to survive"
                ));
            }
        }
        Ok(JobConfig {
            arch,
            dataset: t.str_or("data.dataset", "cifar100").to_string(),
            classes: t.usize_or("data.classes", 20),
            n_train: t.usize_or("data.n_train", 1000),
            n_test: t.usize_or("data.n_test", 200),
            method,
            hyper,
            schedule,
            epochs: t.usize_or("train.epochs", 10),
            batch_size: t.usize_or("train.batch_size", 32),
            seed: t.get("train.seed").and_then(|v| v.as_u64()).unwrap_or(0),
            label: t.str_or("label", "job").to_string(),
            ranks,
            dist_strategy,
            transport,
            algo,
            overlap,
            stream,
            wire_dtype,
            resume,
            ckpt,
            ckpt_every,
            accum_steps,
            elastic,
            trace_dir,
            log,
        })
    }

    pub fn from_str_toml(text: &str) -> Result<JobConfig, String> {
        let t = Toml::parse(text).map_err(|e| e.to_string())?;
        Self::from_toml(&t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# experiment config
label = "fig1-vgg"

[model]
arch = "vgg"
width = 8

[data]
dataset = "cifar100"
classes = 20

[optim]
method = "singd:diag"
lr = 0.1
precision = "bf16"
damping = 0.001

[train]
epochs = 20
batch_size = 64
schedule = "cosine:600"
seed = 7
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = Toml::parse(EXAMPLE).unwrap();
        assert_eq!(t.get("model.arch"), Some(&Value::Str("vgg".into())));
        assert_eq!(t.get("model.width"), Some(&Value::Int(8)));
        assert_eq!(t.get("optim.damping"), Some(&Value::Float(0.001)));
        assert_eq!(t.get("label"), Some(&Value::Str("fig1-vgg".into())));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let t = Toml::parse("# only a comment\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(t.get("x"), Some(&Value::Int(1)));
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = Toml::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(t.get("s"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Toml::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn job_config_resolves() {
        let cfg = JobConfig::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.arch, Arch::Vgg { width: 8 });
        assert_eq!(cfg.method.name(), "singd:diag");
        assert_eq!(cfg.hyper.policy, Policy::bf16_mixed());
        assert_eq!(cfg.epochs, 20);
        assert!(matches!(cfg.schedule, Schedule::Cosine { total: 600 }));
        assert_eq!(cfg.label, "fig1-vgg");
    }

    #[test]
    fn job_config_rejects_unknown_method() {
        let bad = EXAMPLE.replace("singd:diag", "frobnicate");
        assert!(JobConfig::from_str_toml(&bad).is_err());
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.batch_size, 32);
        assert_eq!(cfg.method.name(), "sgd");
        assert_eq!(cfg.dist_strategy, DistStrategy::Replicated);
        assert!(cfg.ranks >= 1);
    }

    #[test]
    fn dist_section_parses_ranks_and_strategy() {
        let toml = "[dist]\nranks = 4\nstrategy = \"factor-sharded\"\n";
        let cfg = JobConfig::from_str_toml(toml).unwrap();
        assert_eq!(cfg.ranks, 4);
        assert_eq!(cfg.dist_strategy, DistStrategy::FactorSharded);
        // ranks = 0 is clamped to 1 (serial), bad strategies rejected.
        assert_eq!(JobConfig::from_str_toml("[dist]\nranks = 0\n").unwrap().ranks, 1);
        assert!(JobConfig::from_str_toml("[dist]\nstrategy = \"bogus\"\n").is_err());
    }

    #[test]
    fn dist_section_parses_transport() {
        let cfg = JobConfig::from_str_toml("[dist]\ntransport = \"socket\"\n").unwrap();
        assert_eq!(cfg.transport, Transport::Socket);
        let cfg = JobConfig::from_str_toml("[dist]\ntransport = \"local\"\n").unwrap();
        assert_eq!(cfg.transport, Transport::Local);
        // Default follows the SINGD_TRANSPORT env contract.
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.transport, dist::default_transport());
        assert!(JobConfig::from_str_toml("[dist]\ntransport = \"pigeon\"\n").is_err());
    }

    #[test]
    fn dist_section_parses_overlap() {
        let cfg = JobConfig::from_str_toml("[dist]\noverlap = false\n").unwrap();
        assert!(!cfg.overlap);
        let cfg = JobConfig::from_str_toml("[dist]\noverlap = true\n").unwrap();
        assert!(cfg.overlap);
        // String forms ride the shared parser.
        let cfg = JobConfig::from_str_toml("[dist]\noverlap = \"off\"\n").unwrap();
        assert!(!cfg.overlap);
        // Default follows the SINGD_OVERLAP env contract (on when unset).
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.overlap, dist::default_overlap());
        assert!(JobConfig::from_str_toml("[dist]\noverlap = \"sideways\"\n").is_err());
        assert!(JobConfig::from_str_toml("[dist]\noverlap = 2\n").is_err());
    }

    #[test]
    fn dist_section_parses_stream() {
        let cfg = JobConfig::from_str_toml("[dist]\nstream = false\n").unwrap();
        assert!(!cfg.stream);
        let cfg = JobConfig::from_str_toml("[dist]\nstream = true\n").unwrap();
        assert!(cfg.stream);
        // String forms ride the shared parser.
        let cfg = JobConfig::from_str_toml("[dist]\nstream = \"off\"\n").unwrap();
        assert!(!cfg.stream);
        // Default follows the SINGD_STREAM env contract (on when unset).
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.stream, dist::default_stream());
        assert!(JobConfig::from_str_toml("[dist]\nstream = \"sideways\"\n").is_err());
        assert!(JobConfig::from_str_toml("[dist]\nstream = 2\n").is_err());
    }

    #[test]
    fn train_section_parses_accum_steps() {
        let cfg = JobConfig::from_str_toml("[train]\naccum_steps = 4\n").unwrap();
        assert_eq!(cfg.accum_steps, 4);
        // 0 is clamped to 1 (off), the default is 1, wrong types rejected.
        let cfg = JobConfig::from_str_toml("[train]\naccum_steps = 0\n").unwrap();
        assert_eq!(cfg.accum_steps, 1);
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.accum_steps, 1);
        assert!(JobConfig::from_str_toml("[train]\naccum_steps = \"four\"\n").is_err());
        assert!(JobConfig::from_str_toml("[train]\naccum_steps = -2\n").is_err());
    }

    #[test]
    fn train_section_parses_checkpoint_keys() {
        let toml = "[train]\nckpt = \"run.ckpt\"\nckpt_every = 10\nresume = \"old.ckpt\"\n";
        let cfg = JobConfig::from_str_toml(toml).unwrap();
        assert_eq!(cfg.ckpt.as_deref(), Some("run.ckpt"));
        assert_eq!(cfg.ckpt_every, 10);
        assert_eq!(cfg.resume.as_deref(), Some("old.ckpt"));
        // Defaults: no checkpointing, no resume, not elastic.
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.ckpt, None);
        assert_eq!(cfg.ckpt_every, 0);
        assert_eq!(cfg.resume, None);
        assert!(!cfg.elastic);
        // Wrong types are rejected loudly, not defaulted.
        assert!(JobConfig::from_str_toml("[train]\nckpt = 3\n").is_err());
        assert!(JobConfig::from_str_toml("[train]\nckpt_every = \"ten\"\n").is_err());
        assert!(JobConfig::from_str_toml("[train]\nresume = true\n").is_err());
    }

    #[test]
    fn elastic_requires_socket_ckpt_cadence_and_ranks() {
        let good = "[train]\nckpt = \"e.ckpt\"\nckpt_every = 2\n\
                    [dist]\nranks = 4\ntransport = \"socket\"\nelastic = true\n";
        let cfg = JobConfig::from_str_toml(good).unwrap();
        assert!(cfg.elastic);
        // Each precondition missing in turn → a loud, specific error.
        let no_sock = good.replace("transport = \"socket\"", "transport = \"local\"");
        assert!(JobConfig::from_str_toml(&no_sock).unwrap_err().contains("socket"));
        let no_ckpt = good.replace("ckpt = \"e.ckpt\"\n", "");
        assert!(JobConfig::from_str_toml(&no_ckpt).unwrap_err().contains("train.ckpt"));
        let no_cadence = good.replace("ckpt_every = 2", "ckpt_every = 0");
        assert!(JobConfig::from_str_toml(&no_cadence).unwrap_err().contains("ckpt_every"));
        let one_rank = good.replace("ranks = 4", "ranks = 1");
        assert!(JobConfig::from_str_toml(&one_rank).unwrap_err().contains("ranks"));
        assert!(JobConfig::from_str_toml("[dist]\nelastic = \"sideways\"\n").is_err());
    }

    #[test]
    fn obs_section_parses_trace_dir_and_log() {
        let cfg =
            JobConfig::from_str_toml("[obs]\ntrace_dir = \"traces/t1\"\nlog = \"debug\"\n")
                .unwrap();
        assert_eq!(cfg.trace_dir.as_deref(), Some("traces/t1"));
        assert_eq!(cfg.log, Some(Level::Debug));
        // Defaults: log unset (env contract applies at run time). The
        // trace_dir default reads SINGD_TRACE, which tests must not set
        // process-wide, so only the explicit-key paths are pinned here.
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.log, None);
        // Wrong types / unknown levels are rejected loudly.
        assert!(JobConfig::from_str_toml("[obs]\ntrace_dir = 3\n").is_err());
        assert!(JobConfig::from_str_toml("[obs]\nlog = \"loud\"\n").is_err());
        assert!(JobConfig::from_str_toml("[obs]\nlog = 2\n").is_err());
    }

    #[test]
    fn dist_section_parses_wire_dtype() {
        let cfg = JobConfig::from_str_toml("[dist]\nwire_dtype = \"bf16\"\n").unwrap();
        assert_eq!(cfg.wire_dtype, Dtype::Bf16);
        let cfg = JobConfig::from_str_toml("[dist]\nwire_dtype = \"fp16\"\n").unwrap();
        assert_eq!(cfg.wire_dtype, Dtype::Fp16);
        let cfg = JobConfig::from_str_toml("[dist]\nwire_dtype = \"f32\"\n").unwrap();
        assert_eq!(cfg.wire_dtype, Dtype::F32);
        // Default follows the SINGD_WIRE_DTYPE env contract (f32 when unset).
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.wire_dtype, dist::default_wire_dtype());
        assert!(JobConfig::from_str_toml("[dist]\nwire_dtype = \"int4\"\n").is_err());
    }

    #[test]
    fn dist_section_parses_algo() {
        let cfg = JobConfig::from_str_toml("[dist]\nalgo = \"star\"\n").unwrap();
        assert_eq!(cfg.algo, Algo::Star);
        let cfg = JobConfig::from_str_toml("[dist]\nalgo = \"ring\"\n").unwrap();
        assert_eq!(cfg.algo, Algo::Ring);
        // Default follows the SINGD_ALGO env contract (ring when unset).
        let cfg = JobConfig::from_str_toml("[model]\narch = \"mlp\"\n").unwrap();
        assert_eq!(cfg.algo, dist::default_algo());
        assert!(JobConfig::from_str_toml("[dist]\nalgo = \"mesh\"\n").is_err());
    }
}
