//! Dependency-free stand-in for the PJRT runtime (default build).
//!
//! Mirrors the API of the `pjrt`-gated backend so the benches, examples
//! and experiment drivers compile unchanged; loading always fails with a
//! descriptive error. Callers already guard on the artifact file existing,
//! so in practice this path is only reached when artifacts were built but
//! the crate was not compiled with `--features pjrt`.

use crate::tensor::Mat;

/// Error produced by the stub runtime.
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// A compiled PJRT executable (stub: never constructible via `load`).
pub struct Engine {
    path: String,
}

impl Engine {
    /// Always fails in the stub build.
    pub fn load(path: &str) -> Result<Engine, RuntimeError> {
        Err(RuntimeError(format!(
            "PJRT runtime not compiled in (artifact: {path}); add vendored \
             `xla` and `anyhow` crates to [dependencies] in Cargo.toml (they \
             are intentionally undeclared so offline builds resolve), then \
             rebuild with `cargo build --features pjrt`"
        )))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Unreachable in practice (`load` never succeeds).
    pub fn run(&self, _inputs: &[MatInput<'_>]) -> Result<Vec<Vec<f32>>, RuntimeError> {
        Err(RuntimeError("PJRT runtime not compiled in".to_string()))
    }
}

/// An input tensor: a matrix with an optional reshape to higher rank.
pub struct MatInput<'a> {
    pub mat: &'a Mat,
    /// Target dims (defaults to `[rows, cols]`).
    pub dims: Option<Vec<i64>>,
}

impl<'a> MatInput<'a> {
    pub fn new(mat: &'a Mat) -> Self {
        MatInput { mat, dims: None }
    }

    pub fn with_dims(mat: &'a Mat, dims: Vec<i64>) -> Self {
        MatInput { mat, dims: Some(dims) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_context() {
        let err = Engine::load("artifacts/smoke.hlo.txt").err().expect("stub must fail");
        assert!(err.to_string().contains("smoke.hlo.txt"));
    }

    #[test]
    fn mat_input_carries_dims() {
        let m = Mat::ones(2, 3);
        assert!(MatInput::new(&m).dims.is_none());
        assert_eq!(MatInput::with_dims(&m, vec![1, 6]).dims, Some(vec![1, 6]));
    }
}
