//! The real PJRT backend (`pjrt` feature): compiles HLO-text artifacts on
//! the PJRT CPU client via the `xla` crate. See the parent module for the
//! stub that replaces it in dependency-free builds.

use crate::tensor::Mat;
use anyhow::{Context, Result};

/// A compiled PJRT executable plus its client.
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    path: String,
}

impl Engine {
    /// Load an HLO-text artifact and compile it on the CPU PJRT client.
    pub fn load(path: &str) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text at {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compile {path}"))?;
        Ok(Engine { client, exe, path: path.to_string() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Execute with `Mat` inputs; outputs are the flattened elements of the
    /// result tuple, one `Vec<f32>` per tuple element.
    ///
    /// The artifact must have been lowered with `return_tuple=True` (see
    /// `python/compile/aot.py`).
    pub fn run(&self, inputs: &[MatInput<'_>]) -> Result<Vec<Vec<f32>>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for inp in inputs {
            lits.push(inp.to_literal()?);
        }
        let mut result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // Tuple outputs: decompose.
        let elems = result.decompose_tuple().unwrap_or_else(|_| vec![]);
        if elems.is_empty() {
            return Ok(vec![result.to_vec::<f32>().unwrap_or_default()]);
        }
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>().context("tuple element to f32 vec")?);
        }
        Ok(out)
    }
}

/// An input tensor: a matrix with an optional reshape to higher rank.
pub struct MatInput<'a> {
    pub mat: &'a Mat,
    /// Target dims (defaults to `[rows, cols]`).
    pub dims: Option<Vec<i64>>,
}

impl<'a> MatInput<'a> {
    pub fn new(mat: &'a Mat) -> Self {
        MatInput { mat, dims: None }
    }

    pub fn with_dims(mat: &'a Mat, dims: Vec<i64>) -> Self {
        MatInput { mat, dims: Some(dims) }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(self.mat.data());
        let dims = self
            .dims
            .clone()
            .unwrap_or_else(|| vec![self.mat.rows() as i64, self.mat.cols() as i64]);
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_path;

    /// These tests need the artifacts built (`make artifacts`); they are
    /// skipped gracefully otherwise so `cargo test` stays green pre-AOT.
    fn engine(name: &str) -> Option<Engine> {
        let p = artifact_path(name);
        if !std::path::Path::new(&p).exists() {
            eprintln!("skipping: {p} not built (run `make artifacts`)");
            return None;
        }
        Some(Engine::load(&p).expect("load+compile artifact"))
    }

    #[test]
    fn smoke_artifact_executes() {
        let Some(eng) = engine("smoke.hlo.txt") else { return };
        // smoke: f(x, y) = (x @ y + 2,) over f32[2,2].
        let x = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = Mat::ones(2, 2);
        let out = eng.run(&[MatInput::new(&x), MatInput::new(&y)]).unwrap();
        assert_eq!(out[0], vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn mlp_artifact_matches_native_model() {
        let Some(eng) = engine("mlp_fwdbwd.hlo.txt") else { return };
        // The artifact computes (loss, dW1, dW2) for a fixed-shape MLP —
        // python/tests/test_model.py pins the same shapes.
        let mut rng = crate::proptest::Pcg::new(5);
        let x = rng.normal_mat(8, 16, 1.0);
        let y_onehot = Mat::from_fn(8, 4, |r, c| if c == r % 4 { 1.0 } else { 0.0 });
        let w1 = rng.normal_mat(32, 17, 0.3);
        let w2 = rng.normal_mat(4, 33, 0.3);
        let out = eng
            .run(&[MatInput::new(&x), MatInput::new(&y_onehot), MatInput::new(&w1), MatInput::new(&w2)])
            .unwrap();
        assert!(out[0].len() == 1, "loss is a scalar");
        let loss = out[0][0];
        assert!(loss.is_finite() && loss > 0.0);

        // Cross-check against the native Rust model: same weights → same loss.
        let mut mlp = crate::model::Mlp::new(&mut crate::proptest::Pcg::new(1), &[16, 32, 4]);
        mlp.params_mut()[0] = w1.clone();
        mlp.params_mut()[1] = w2.clone();
        use crate::model::Model;
        let batch = crate::model::Batch { x: x.clone(), y: (0..8).map(|r| r % 4).collect() };
        let (native_loss, _) = mlp.evaluate(&batch);
        assert!(
            (native_loss - loss).abs() < 1e-3 * (1.0 + native_loss.abs()),
            "native {native_loss} vs pjrt {loss}"
        );
        // And the gradients must match shape & values.
        let res = mlp.forward_backward(&batch);
        let dw1 = &out[1];
        assert_eq!(dw1.len(), 32 * 17);
        let max_diff = dw1
            .iter()
            .zip(res.grads[0].data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "grad mismatch {max_diff}");
    }
}
