//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and execute them from Rust — Python is never on
//! the training path.
//!
//! The real backend lives in [`pjrt`] and needs the `xla` and `anyhow`
//! crates, which are not available in offline/CI builds — so it is gated
//! behind the (default-off) `pjrt` cargo feature, and the default build
//! compiles a dependency-free stub with the same API whose `Engine::load`
//! returns a descriptive error. Every caller already guards on the
//! artifact file existing, so default builds and tests skip gracefully.
//!
//! Interchange format is HLO *text*: the image's xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos (64-bit instruction ids), while the
//! text parser reassigns ids (see /opt/xla-example/README.md).

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, MatInput};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, MatInput, RuntimeError};

/// Resolve an artifact path relative to the repo's `artifacts/` directory,
/// honoring `SINGD_ARTIFACTS` when set.
pub fn artifact_path(name: &str) -> String {
    let dir = std::env::var("SINGD_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    format!("{dir}/{name}")
}
