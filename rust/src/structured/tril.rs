//! Lower-triangular Kronecker factor (Table 1, row 1).
//!
//! Packed row-major storage of the lower triangle: `d(d+1)/2` floats —
//! half the memory of the dense factor, and the class is closed under
//! multiplication (triangular matrices form an associative subalgebra,
//! paper footnote 4).

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct TrilF {
    pub d: usize,
    /// Packed rows: row r contributes entries (r,0..=r).
    pub data: Vec<f32>,
}

#[inline]
fn idx(r: usize, c: usize) -> usize {
    debug_assert!(c <= r);
    r * (r + 1) / 2 + c
}

impl TrilF {
    pub fn identity(d: usize) -> Self {
        let mut t = TrilF { d, data: vec![0.0; d * (d + 1) / 2] };
        for i in 0..d {
            t.data[idx(i, i)] = 1.0;
        }
        t
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        if c > r {
            0.0
        } else {
            self.data[idx(r, c)]
        }
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.d);
        for r in 0..self.d {
            for c in 0..=r {
                m.set(r, c, self.data[idx(r, c)]);
            }
        }
        m
    }

    pub fn axpy(&mut self, alpha: f32, other: &TrilF) {
        assert_eq!(self.d, other.d);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Triangular × triangular: result is triangular;
    /// `(AB)[r][c] = Σ_{p=c..=r} A[r][p] B[p][c]`.
    pub fn matmul(&self, other: &TrilF) -> TrilF {
        assert_eq!(self.d, other.d);
        let d = self.d;
        let mut out = TrilF { d, data: vec![0.0; d * (d + 1) / 2] };
        for r in 0..d {
            for c in 0..=r {
                let mut acc = 0.0f32;
                for p in c..=r {
                    acc += self.data[idx(r, p)] * other.data[idx(p, c)];
                }
                out.data[idx(r, c)] = acc;
            }
        }
        out
    }

    /// `X @ K` / `X @ Kᵀ`.
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let d = self.d;
        let mut out = Mat::zeros(m, d);
        for r in 0..m {
            let xr = x.row(r);
            let or = out.row_mut(r);
            if !transpose {
                // out[j] = Σ_i x[i] K[i][j], K lower: i >= j
                for i in 0..d {
                    let xi = xr[i];
                    if xi == 0.0 {
                        continue;
                    }
                    let row = &self.data[i * (i + 1) / 2..i * (i + 1) / 2 + i + 1];
                    for (j, kij) in row.iter().enumerate() {
                        or[j] += xi * kij;
                    }
                }
            } else {
                // out[j] = Σ_i x[i] K[j][i], K lower: i <= j
                for j in 0..d {
                    let row = &self.data[j * (j + 1) / 2..j * (j + 1) / 2 + j + 1];
                    let mut acc = 0.0f32;
                    for (i, kji) in row.iter().enumerate() {
                        acc += xr[i] * kji;
                    }
                    or[j] = acc;
                }
            }
        }
        out
    }

    /// `K @ X` / `Kᵀ @ X`.
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let n = x.cols();
        let d = self.d;
        let mut out = Mat::zeros(d, n);
        if !transpose {
            // out[r] = Σ_{p<=r} K[r][p] x[p]
            for r in 0..d {
                let krow = &self.data[r * (r + 1) / 2..r * (r + 1) / 2 + r + 1];
                let orow = out.row_mut(r);
                for (p, kv) in krow.iter().enumerate() {
                    if *kv == 0.0 {
                        continue;
                    }
                    let xrow = x.row(p);
                    for c in 0..n {
                        orow[c] += kv * xrow[c];
                    }
                }
            }
        } else {
            // out[r] = Σ_{p>=r} K[p][r] x[p]
            for p in 0..d {
                let krow = &self.data[p * (p + 1) / 2..p * (p + 1) / 2 + p + 1];
                let xrow = x.row(p);
                for (r, kv) in krow.iter().enumerate() {
                    if *kv == 0.0 {
                        continue;
                    }
                    let orow = out.row_mut(r);
                    for c in 0..n {
                        orow[c] += kv * xrow[c];
                    }
                }
            }
        }
        out
    }

    /// `Π̂(scale · BᵀB)`: lower triangle with sub-diagonal entries doubled
    /// (Table 1, row 1 — the weighted extraction map).
    pub fn gram_project(&self, b: &Mat, scale: f32) -> TrilF {
        let gram = crate::tensor::matmul_at_b(b, b);
        let d = self.d;
        let mut out = TrilF { d, data: vec![0.0; d * (d + 1) / 2] };
        for r in 0..d {
            for c in 0..=r {
                let w = if c == r { 1.0 } else { 2.0 };
                out.data[idx(r, c)] = scale * w * gram.at(r, c);
            }
        }
        out
    }

    pub fn trace(&self) -> f32 {
        (0..self.d).map(|i| self.data[idx(i, i)]).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_indexing() {
        let mut t = TrilF::identity(3);
        t.data[idx(2, 1)] = 5.0;
        assert_eq!(t.at(2, 1), 5.0);
        assert_eq!(t.at(1, 2), 0.0);
        let d = t.to_dense();
        assert_eq!(d.at(2, 1), 5.0);
        assert_eq!(d.at(1, 2), 0.0);
    }

    #[test]
    fn tril_matmul_is_tril() {
        let mut a = TrilF::identity(4);
        a.data[idx(3, 0)] = 2.0;
        let b = a.clone();
        let p = a.matmul(&b);
        assert_eq!(p.at(3, 0), 4.0); // I·2 + 2·I
        assert_eq!(p.at(0, 0), 1.0);
    }
}
