//! Lower-triangular Kronecker factor (Table 1, row 1).
//!
//! Packed row-major storage of the lower triangle: `d(d+1)/2` floats —
//! half the memory of the dense factor, and the class is closed under
//! multiplication (triangular matrices form an associative subalgebra,
//! paper footnote 4).
//!
//! Output rows are independent in every op here, so the expensive ones
//! (`matmul`, `right_mul`, `left_mul`) shard output rows across the
//! persistent worker pool above [`super::PAR_WORK`]; per-row accumulation
//! order is fixed (`p` ascending), so pooled and serial results are
//! bitwise identical.

use crate::tensor::{pool, Mat};

#[derive(Clone, Debug)]
pub struct TrilF {
    pub d: usize,
    /// Packed rows: row r contributes entries (r,0..=r).
    pub data: Vec<f32>,
}

#[inline]
fn idx(r: usize, c: usize) -> usize {
    debug_assert!(c <= r);
    r * (r + 1) / 2 + c
}

impl TrilF {
    pub fn identity(d: usize) -> Self {
        let mut t = TrilF { d, data: vec![0.0; d * (d + 1) / 2] };
        for i in 0..d {
            t.data[idx(i, i)] = 1.0;
        }
        t
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        if c > r {
            0.0
        } else {
            self.data[idx(r, c)]
        }
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.d);
        for r in 0..self.d {
            for c in 0..=r {
                m.set(r, c, self.data[idx(r, c)]);
            }
        }
        m
    }

    pub fn axpy(&mut self, alpha: f32, other: &TrilF) {
        assert_eq!(self.d, other.d);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Triangular × triangular: result is triangular;
    /// `(AB)[r][c] = Σ_{p=c..=r} A[r][p] B[p][c]`. Output rows are
    /// independent; large factors shard contiguous packed row ranges
    /// across the pool.
    pub fn matmul(&self, other: &TrilF) -> TrilF {
        assert_eq!(self.d, other.d);
        let d = self.d;
        let mut out = TrilF { d, data: vec![0.0; d * (d + 1) / 2] };
        let rows_fn = |r0: usize, r1: usize, dst: &mut [f32]| {
            // dst holds packed rows [r0, r1).
            let base = idx(r0, 0);
            for r in r0..r1 {
                for c in 0..=r {
                    let mut acc = 0.0f32;
                    for p in c..=r {
                        acc += self.data[idx(r, p)] * other.data[idx(p, c)];
                    }
                    dst[idx(r, c) - base] = acc;
                }
            }
        };
        // ~d³/3 flops; row cost grows quadratically, so shard row *ranges*
        // with balanced packed sizes rather than equal row counts.
        if d * d * d / 3 < super::PAR_WORK || pool::current_threads() <= 1 {
            rows_fn(0, d, &mut out.data);
            return out;
        }
        let nt = pool::current_threads().min(d);
        let total = out.data.len();
        let mut bounds = Vec::with_capacity(nt + 1);
        bounds.push(0usize);
        for t in 1..nt {
            // Row r such that packed prefix ≈ t/nt of total.
            let target = total * t / nt;
            let mut r = *bounds.last().unwrap();
            while r < d && idx(r, 0) < target {
                r += 1;
            }
            bounds.push(r.min(d));
        }
        bounds.push(d);
        let rf = &rows_fn;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nt);
        let mut rest = out.data.as_mut_slice();
        let mut consumed = 0usize;
        for w in bounds.windows(2) {
            let (r0, r1) = (w[0], w[1]);
            if r0 == r1 {
                continue;
            }
            let len = idx(r1, 0) - idx(r0, 0);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            consumed += len;
            jobs.push(Box::new(move || rf(r0, r1, chunk)));
        }
        debug_assert_eq!(consumed, total);
        pool::run_jobs(jobs);
        out
    }

    /// `X @ K` / `X @ Kᵀ`, sharded by rows of `X`.
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let d = self.d;
        let mut out = Mat::zeros(m, d);
        if m == 0 || d == 0 {
            return out;
        }
        let xd = x.data();
        let min_rows = if m * d * d / 2 < super::PAR_WORK { m } else { 1 };
        pool::parallel_chunks_mut(out.data_mut(), d, min_rows, |row0, chunk| {
            for (li, or) in chunk.chunks_mut(d).enumerate() {
                let xr = &xd[(row0 + li) * d..(row0 + li + 1) * d];
                if !transpose {
                    // out[j] = Σ_i x[i] K[i][j], K lower: i >= j
                    for (i, &xi) in xr.iter().enumerate() {
                        if xi == 0.0 {
                            continue;
                        }
                        let row = &self.data[i * (i + 1) / 2..i * (i + 1) / 2 + i + 1];
                        for (o, kij) in or.iter_mut().zip(row.iter()) {
                            *o += xi * kij;
                        }
                    }
                } else {
                    // out[j] = Σ_i x[i] K[j][i], K lower: i <= j
                    for (j, o) in or.iter_mut().enumerate() {
                        let row = &self.data[j * (j + 1) / 2..j * (j + 1) / 2 + j + 1];
                        let mut acc = 0.0f32;
                        for (xv, kji) in xr.iter().zip(row.iter()) {
                            acc += xv * kji;
                        }
                        *o = acc;
                    }
                }
            }
        });
        out
    }

    /// `K @ X` / `Kᵀ @ X`, sharded by output rows (both orientations are
    /// written row-at-a-time with `p` ascending, so sharding preserves the
    /// serial accumulation order exactly).
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let n = x.cols();
        let d = self.d;
        let mut out = Mat::zeros(d, n);
        if n == 0 || d == 0 {
            return out;
        }
        let min_rows = if d * d * n / 2 < super::PAR_WORK { d } else { 1 };
        pool::parallel_chunks_mut(out.data_mut(), n, min_rows, |row0, chunk| {
            for (li, orow) in chunk.chunks_mut(n).enumerate() {
                let r = row0 + li;
                if !transpose {
                    // out[r] = Σ_{p<=r} K[r][p] x[p]
                    let krow = &self.data[r * (r + 1) / 2..r * (r + 1) / 2 + r + 1];
                    for (p, kv) in krow.iter().enumerate() {
                        if *kv == 0.0 {
                            continue;
                        }
                        let xrow = x.row(p);
                        for (ov, xv) in orow.iter_mut().zip(xrow.iter()) {
                            *ov += kv * xv;
                        }
                    }
                } else {
                    // out[r] = Σ_{p>=r} K[p][r] x[p]
                    for p in r..d {
                        let kv = self.data[idx(p, r)];
                        if kv == 0.0 {
                            continue;
                        }
                        let xrow = x.row(p);
                        for (ov, xv) in orow.iter_mut().zip(xrow.iter()) {
                            *ov += kv * xv;
                        }
                    }
                }
            }
        });
        out
    }

    /// `Π̂(scale · BᵀB)`: lower triangle with sub-diagonal entries doubled
    /// (Table 1, row 1 — the weighted extraction map).
    pub fn gram_project(&self, b: &Mat, scale: f32) -> TrilF {
        let gram = crate::tensor::matmul_at_b(b, b);
        let d = self.d;
        let mut out = TrilF { d, data: vec![0.0; d * (d + 1) / 2] };
        for r in 0..d {
            for c in 0..=r {
                let w = if c == r { 1.0 } else { 2.0 };
                out.data[idx(r, c)] = scale * w * gram.at(r, c);
            }
        }
        out
    }

    pub fn trace(&self) -> f32 {
        (0..self.d).map(|i| self.data[idx(i, i)]).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_indexing() {
        let mut t = TrilF::identity(3);
        t.data[idx(2, 1)] = 5.0;
        assert_eq!(t.at(2, 1), 5.0);
        assert_eq!(t.at(1, 2), 0.0);
        let d = t.to_dense();
        assert_eq!(d.at(2, 1), 5.0);
        assert_eq!(d.at(1, 2), 0.0);
    }

    #[test]
    fn tril_matmul_is_tril() {
        let mut a = TrilF::identity(4);
        a.data[idx(3, 0)] = 2.0;
        let b = a.clone();
        let p = a.matmul(&b);
        assert_eq!(p.at(3, 0), 4.0); // I·2 + 2·I
        assert_eq!(p.at(0, 0), 1.0);
    }
}
