//! Upper-triangular Toeplitz Kronecker factor (Table 1, row 5).
//!
//! `K[i][j] = coef[j - i]` for `j >= i`, zero below the diagonal. Storage
//! `O(d)`. Upper-triangular Toeplitz matrices form a *commutative*
//! subalgebra (they are polynomials in the shift matrix), so the class is
//! closed under multiplication; the product is coefficient convolution.

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct ToepF {
    pub d: usize,
    /// `coef[j]` is the value of the j-th superdiagonal; `coef[0]` the diagonal.
    pub coef: Vec<f32>,
}

impl ToepF {
    pub fn identity(d: usize) -> Self {
        let mut coef = vec![0.0; d];
        if d > 0 {
            coef[0] = 1.0;
        }
        ToepF { d, coef }
    }

    pub fn to_dense(&self) -> Mat {
        Mat::from_fn(self.d, self.d, |r, c| if c >= r { self.coef[c - r] } else { 0.0 })
    }

    pub fn axpy(&mut self, alpha: f32, o: &ToepF) {
        assert_eq!(self.d, o.d);
        for (a, b) in self.coef.iter_mut().zip(&o.coef) {
            *a += alpha * b;
        }
    }

    /// Crossover below which the direct `O(d²)` path beats the FFT one
    /// (measured in §Perf iteration 4).
    const FFT_MIN_D: usize = 64;

    /// Coefficient convolution truncated at `d`: the paper's `O(d log d)`
    /// Toeplitz claim (Table 2). Direct `O(d²)` below the crossover.
    pub fn matmul(&self, o: &ToepF) -> ToepF {
        assert_eq!(self.d, o.d);
        if self.d >= Self::FFT_MIN_D {
            let coef = crate::tensor::fft::convolve_trunc(&self.coef, &o.coef, self.d);
            return ToepF { d: self.d, coef };
        }
        let mut coef = vec![0.0f32; self.d];
        for (j, c) in coef.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for i in 0..=j {
                acc += self.coef[i] * o.coef[j - i];
            }
            *c = acc;
        }
        ToepF { d: self.d, coef }
    }

    /// `X @ K` / `X @ Kᵀ` in `O(m d²)` (each output entry touches a band).
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let d = self.d;
        let mut out = Mat::zeros(m, d);
        for r in 0..m {
            let xr = x.row(r);
            let or = out.row_mut(r);
            if !transpose {
                // out[j] = Σ_{i<=j} x[i]·coef[j-i]
                for j in 0..d {
                    let mut acc = 0.0f32;
                    for i in 0..=j {
                        acc += xr[i] * self.coef[j - i];
                    }
                    or[j] = acc;
                }
            } else {
                // Kᵀ[i][j] = coef[i-j] for i>=j: out[j] = Σ_{i>=j} x[i]·coef[i-j]
                for j in 0..d {
                    let mut acc = 0.0f32;
                    for i in j..d {
                        acc += xr[i] * self.coef[i - j];
                    }
                    or[j] = acc;
                }
            }
        }
        out
    }

    /// `K @ X` / `Kᵀ @ X`.
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let n = x.cols();
        let d = self.d;
        let mut out = Mat::zeros(d, n);
        for r in 0..d {
            let orow_idx = r;
            if !transpose {
                // out[r] = Σ_{p>=r} coef[p-r]·x[p]
                for p in r..d {
                    let v = self.coef[p - r];
                    if v == 0.0 {
                        continue;
                    }
                    let xrow = x.row(p);
                    let orow = out.row_mut(orow_idx);
                    for c in 0..n {
                        orow[c] += v * xrow[c];
                    }
                }
            } else {
                // Kᵀ lower-Toeplitz: out[r] = Σ_{p<=r} coef[r-p]·x[p]
                for p in 0..=r {
                    let v = self.coef[r - p];
                    if v == 0.0 {
                        continue;
                    }
                    let xrow = x.row(p);
                    let orow = out.row_mut(orow_idx);
                    for c in 0..n {
                        orow[c] += v * xrow[c];
                    }
                }
            }
        }
        out
    }

    /// `Π̂(scale·BᵀB)`: Toeplitz projection with diagonal averaging
    /// (Table 1, row 5):
    /// `b_j = (1/(d-j)) Σ_k G[k][k+j]`, stored as `coef[0] = b_0`,
    /// `coef[j] = 2 b_j` for `j ≥ 1`.
    pub fn gram_project(&self, b: &Mat, scale: f32) -> ToepF {
        let d = self.d;
        let m = b.rows();
        // Diagonal-sum of the Gram matrix: Σ_k Σ_r B[r][k]·B[r][k+j] — a
        // batched truncated autocorrelation. FFT path: one forward
        // transform per row + one inverse for the whole batch,
        // O(m d log d) (§Perf iteration 4); direct O(m d²) below the
        // crossover.
        let sums: Vec<f32> = if d >= Self::FFT_MIN_D {
            crate::tensor::fft::batched_autocorr((0..m).map(|r| b.row(r)), d)
        } else {
            let mut s = vec![0.0f32; d];
            for r in 0..m {
                let br = b.row(r);
                for (j, sj) in s.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for k in 0..d - j {
                        acc += br[k] * br[k + j];
                    }
                    *sj += acc;
                }
            }
            s
        };
        let mut coef = vec![0.0f32; d];
        for j in 0..d {
            let avg = sums[j] / (d - j) as f32;
            coef[j] = scale * avg * if j == 0 { 1.0 } else { 2.0 };
        }
        ToepF { d, coef }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_mat_close, forall, Pcg};
    use crate::structured::{proj, Structure};

    /// Random well-scaled coefficient vector (geometric band decay so
    /// convolutions stay O(1)).
    fn random_coef(d: usize, rng: &mut Pcg) -> Vec<f32> {
        (0..d).map(|j| rng.normal() * 0.5f32.powi(j.min(12) as i32)).collect()
    }

    #[test]
    fn identity_dense() {
        assert_eq!(ToepF::identity(4).to_dense(), Mat::eye(4));
    }

    #[test]
    fn matmul_is_convolution() {
        // K = I + N (N = shift), K² = I + 2N + N².
        let mut k = ToepF::identity(4);
        k.coef[1] = 1.0;
        let sq = k.matmul(&k);
        assert_eq!(sq.coef, vec![1.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn commutes() {
        let a = ToepF { d: 5, coef: vec![1.0, 0.5, 0.2, 0.0, 0.1] };
        let b = ToepF { d: 5, coef: vec![2.0, -0.3, 0.0, 0.4, 0.0] };
        assert_eq!(a.matmul(&b).coef, b.matmul(&a).coef);
    }

    /// The FFT matmul path (d ≥ FFT_MIN_D) must agree with the direct
    /// truncated convolution it replaces, across the crossover boundary.
    #[test]
    fn fft_matmul_matches_direct_convolution_across_crossover() {
        forall(61, 6, |rng, case| {
            for d in [ToepF::FFT_MIN_D - 1, ToepF::FFT_MIN_D, ToepF::FFT_MIN_D + 33] {
                let a = ToepF { d, coef: random_coef(d, rng) };
                let b = ToepF { d, coef: random_coef(d, rng) };
                let got = a.matmul(&b);
                // Direct reference, written out independently.
                let mut want = vec![0.0f32; d];
                for (j, w) in want.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for i in 0..=j {
                        acc += a.coef[i] as f64 * b.coef[j - i] as f64;
                    }
                    *w = acc as f32;
                }
                for (j, (g, w)) in got.coef.iter().zip(&want).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                        "case {case} d {d} coef {j}: {g} vs {w}"
                    );
                }
            }
        });
    }

    /// The FFT gram path (batched autocorrelation) must agree with the
    /// direct O(m d²) loop across the crossover.
    #[test]
    fn fft_gram_project_matches_direct_across_crossover() {
        forall(62, 4, |rng, case| {
            for d in [ToepF::FFT_MIN_D - 1, ToepF::FFT_MIN_D + 8] {
                let m = 3 + rng.below(6);
                let b = rng.normal_mat(m, d, 1.0);
                let k = ToepF::identity(d);
                let got = k.gram_project(&b, 0.7);
                // Direct reference via the dense projection map.
                let gram = crate::tensor::matmul_at_b(&b, &b).scale(0.7);
                let want = proj::proj(Structure::TriuToeplitz, &gram);
                assert_mat_close(
                    &got.to_dense(),
                    &want.to_dense(),
                    2e-3,
                    &format!("case {case} d {d}"),
                );
            }
        });
    }

    /// A 0-row batch gram-projects to exactly zero on BOTH the direct
    /// and the FFT path (empty autocorrelation batch).
    #[test]
    fn zero_row_gram_is_exactly_zero_on_both_paths() {
        for d in [8usize, ToepF::FFT_MIN_D + 1] {
            let k = ToepF::identity(d);
            let out = k.gram_project(&Mat::zeros(0, d), 1.3);
            assert!(out.coef.iter().all(|&c| c == 0.0), "d {d}: {:?}", &out.coef[..4]);
        }
    }

    /// `left_mul`'s zero-skip fast path: coefficient vectors with exact
    /// zeros must produce the same result as the dense reference (the
    /// skipped terms are exact zeros, so this is bitwise).
    #[test]
    fn left_mul_zero_skip_matches_dense_bitwise() {
        let mut rng = Pcg::new(63);
        let d = 9;
        // Sparse band: only the diagonal and two superdiagonals.
        let mut coef = vec![0.0f32; d];
        coef[0] = rng.normal();
        coef[3] = rng.normal();
        coef[5] = rng.normal();
        let k = ToepF { d, coef };
        let kd = k.to_dense();
        let x = rng.normal_mat(d, 4, 1.0);
        for transpose in [false, true] {
            let got = k.left_mul(&x, transpose);
            // Scalar reference in the same (row-major, ascending-p)
            // accumulation order, without the zero skip.
            let mut want = Mat::zeros(d, 4);
            for r in 0..d {
                for p in 0..d {
                    let v = if transpose { kd.at(p, r) } else { kd.at(r, p) };
                    for c in 0..4 {
                        *want.at_mut(r, c) += v * x.at(p, c);
                    }
                }
            }
            assert_eq!(
                got.data(),
                want.data(),
                "transpose {transpose}: zero-skip changed the bits"
            );
        }
    }

    /// Transposed products against the dense reference (the transpose
    /// legs had no toeplitz-local coverage).
    #[test]
    fn transpose_products_match_dense_reference() {
        forall(64, 6, |rng, case| {
            let d = 4 + rng.below(20);
            let k = ToepF { d, coef: random_coef(d, rng) };
            let kd = k.to_dense();
            let x = rng.normal_mat(5, d, 1.0);
            let y = rng.normal_mat(d, 5, 1.0);
            assert_mat_close(
                &k.right_mul(&x, true),
                &crate::tensor::matmul_a_bt(&x, &kd),
                1e-4,
                &format!("case {case} right-T"),
            );
            assert_mat_close(
                &k.left_mul(&y, true),
                &crate::tensor::matmul_at_b(&kd, &y),
                1e-4,
                &format!("case {case} left-T"),
            );
        });
    }
}
