//! Structured Kronecker factors — the paper's core contribution (§3.2).
//!
//! SINGD replaces the dense Kronecker factors `K ∈ R^{d×d}` of INGD with
//! members of *matrix Lie (sub)groups* that are closed under the operations
//! the update needs — matrix multiplication, subtraction, scalar
//! multiplication — so the multiplicative update `K ← K(I − β/2 Π̂(m))`
//! never leaves the class and the dense log-space matrix `m` is never
//! materialized.
//!
//! Supported structures (paper Table 1 / Figs. 5, 8):
//!
//! | variant            | storage   | class                                |
//! |--------------------|-----------|--------------------------------------|
//! | [`SMat::Dense`]    | `O(d²)`   | general linear (INGD)                |
//! | [`SMat::Diag`]     | `O(d)`    | diagonal                             |
//! | [`SMat::Block`]    | `O(kd)`   | block-diagonal, block size `k`       |
//! | [`SMat::Tril`]     | `O(d²/2)` | lower triangular                     |
//! | [`SMat::RankK`]    | `O(kd)`   | rank-k triangular `[[A,B],[0,D]]`    |
//! | [`SMat::Hier`]     | `O(kd)`   | hierarchical (Table 1, row 3)        |
//! | [`SMat::Toep`]     | `O(d)`    | upper-triangular Toeplitz            |
//!
//! Each structure implements:
//!
//! - the **subspace projection map** `Π̂` (Table 1) via [`SMat::gram_project`]
//!   (computing `Π̂(s·BᵀB)` *directly from* `B` without forming the dense
//!   Gram matrix — this is where the memory/runtime win comes from) and the
//!   dense-reference [`proj`] used in tests;
//! - closed **structured × structured** multiplication ([`SMat::matmul`]);
//! - **structured × dense** products ([`SMat::right_mul`], [`SMat::left_mul`])
//!   for computing `B = A K` and the preconditioned gradient `C Cᵀ G K Kᵀ`;
//! - elementwise log-space arithmetic (`scale`, `axpy`) for the Riemannian
//!   momentum buffer;
//! - memory accounting ([`SMat::bytes`], Table 3).
//!
//! The expensive structured ops (`gram_project`, `matmul`,
//! `right_mul`/`left_mul` — and through them `kkt_left`/`kkt_right`) run
//! on the persistent worker pool in [`crate::tensor::pool`] once their
//! work clears [`PAR_WORK`]; sharding is arranged so pooled and serial
//! runs produce identical results (see `rust/tests/parallel.rs`).

mod blockdiag;
mod hier;
pub mod proj;
mod rankk;
mod toeplitz;
mod tril;

pub use blockdiag::BlockDiagF;
pub use hier::HierF;
pub use rankk::RankKF;
pub use toeplitz::ToepF;
pub use tril::TrilF;

use crate::numerics::Policy;
use crate::tensor::Mat;

/// Approximate scalar-op threshold above which a structured op fans out
/// across the worker pool (below it, sharding overhead dominates).
pub(crate) const PAR_WORK: usize = 1 << 18;

/// Fixed shard count for the batched `gram_project` reductions. Fixed —
/// rather than derived from the thread count — so the floating-point
/// reduction tree, and therefore the result, is a function of the problem
/// alone; idle workers are the price of bitwise serial/pooled parity.
pub(crate) const GRAM_SHARDS: usize = 4;

/// Structure class selector (config-level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Structure {
    /// Dense factors — SINGD-Dense ≡ INGD.
    Dense,
    /// Diagonal factors — SINGD-Diag.
    Diagonal,
    /// Block-diagonal with block size `k`.
    BlockDiag { k: usize },
    /// Lower triangular.
    Tril,
    /// Rank-k triangular `[[A11, A12], [0, D22]]`, `A11 ∈ R^{k×k}`, `D22` diagonal.
    RankKTril { k: usize },
    /// Hierarchical `[[A11, A12, A13], [0, D22, 0], [0, A32, A33]]`,
    /// `A11 ∈ R^{k1×k1}`, `A33 ∈ R^{k2×k2}`, `D22` diagonal.
    Hierarchical { k1: usize, k2: usize },
    /// Upper-triangular Toeplitz.
    TriuToeplitz,
}

impl Structure {
    /// Parse a config string like `"dense"`, `"diag"`, `"block:32"`,
    /// `"tril"`, `"rankk:8"`, `"hier:16"`, `"toeplitz"`.
    pub fn parse(s: &str) -> Option<Structure> {
        let s = s.to_ascii_lowercase();
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, a.parse::<usize>().ok()),
            None => (s.as_str(), None),
        };
        match head {
            "dense" | "ingd" => Some(Structure::Dense),
            "diag" | "diagonal" => Some(Structure::Diagonal),
            "block" | "blockdiag" | "block-diag" => Some(Structure::BlockDiag { k: arg.unwrap_or(32) }),
            "tril" | "triangular" => Some(Structure::Tril),
            "rankk" | "rank-k" | "rank1" => Some(Structure::RankKTril { k: arg.unwrap_or(1) }),
            "hier" | "hierarchical" => {
                let k = arg.unwrap_or(16);
                Some(Structure::Hierarchical { k1: k / 2, k2: k - k / 2 })
            }
            "toeplitz" | "toepl" | "triu-toepl" => Some(Structure::TriuToeplitz),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Structure::Dense => "dense".into(),
            Structure::Diagonal => "diag".into(),
            Structure::BlockDiag { k } => format!("block:{k}"),
            Structure::Tril => "tril".into(),
            Structure::RankKTril { k } => format!("rankk:{k}"),
            Structure::Hierarchical { k1, k2 } => format!("hier:{}", k1 + k2),
            Structure::TriuToeplitz => "toeplitz".into(),
        }
    }
}

/// A structured square matrix (a Kronecker factor `K`/`C`, or a log-space
/// momentum element `m_K`/`m_C` — both live in the same class).
#[derive(Clone, Debug)]
pub enum SMat {
    Dense(Mat),
    Diag(Vec<f32>),
    Block(BlockDiagF),
    Tril(TrilF),
    RankK(RankKF),
    Hier(HierF),
    Toep(ToepF),
}

impl SMat {
    /// The identity element of the class.
    pub fn identity(s: Structure, d: usize) -> SMat {
        match s {
            Structure::Dense => SMat::Dense(Mat::eye(d)),
            Structure::Diagonal => SMat::Diag(vec![1.0; d]),
            Structure::BlockDiag { k } => SMat::Block(BlockDiagF::identity(d, k)),
            Structure::Tril => SMat::Tril(TrilF::identity(d)),
            Structure::RankKTril { k } => SMat::RankK(RankKF::identity(d, k)),
            Structure::Hierarchical { k1, k2 } => SMat::Hier(HierF::identity(d, k1, k2)),
            Structure::TriuToeplitz => SMat::Toep(ToepF::identity(d)),
        }
    }

    /// The zero element of the class (additive identity of the log space).
    pub fn zeros(s: Structure, d: usize) -> SMat {
        let mut z = SMat::identity(s, d);
        z.scale_inplace(0.0);
        z
    }

    /// Which structure class this element belongs to.
    pub fn structure(&self) -> Structure {
        match self {
            SMat::Dense(_) => Structure::Dense,
            SMat::Diag(_) => Structure::Diagonal,
            SMat::Block(b) => Structure::BlockDiag { k: b.k },
            SMat::Tril(_) => Structure::Tril,
            SMat::RankK(r) => Structure::RankKTril { k: r.k },
            SMat::Hier(h) => Structure::Hierarchical { k1: h.k1, k2: h.k2 },
            SMat::Toep(_) => Structure::TriuToeplitz,
        }
    }

    /// Matrix dimension `d`.
    pub fn dim(&self) -> usize {
        match self {
            SMat::Dense(m) => m.rows(),
            SMat::Diag(d) => d.len(),
            SMat::Block(b) => b.d,
            SMat::Tril(t) => t.d,
            SMat::RankK(r) => r.d,
            SMat::Hier(h) => h.d,
            SMat::Toep(t) => t.d,
        }
    }

    /// Materialize as a dense matrix (tests, gallery, dense fallbacks).
    pub fn to_dense(&self) -> Mat {
        match self {
            SMat::Dense(m) => m.clone(),
            SMat::Diag(d) => Mat::diag(d),
            SMat::Block(b) => b.to_dense(),
            SMat::Tril(t) => t.to_dense(),
            SMat::RankK(r) => r.to_dense(),
            SMat::Hier(h) => h.to_dense(),
            SMat::Toep(t) => t.to_dense(),
        }
    }

    /// Scale all stored entries in place.
    pub fn scale_inplace(&mut self, s: f32) {
        self.for_each_mut(|x| *x *= s);
    }

    /// `self += alpha * other` (same structure and dim required).
    pub fn axpy(&mut self, alpha: f32, other: &SMat) {
        match (self, other) {
            (SMat::Dense(a), SMat::Dense(b)) => a.axpy(alpha, b),
            (SMat::Diag(a), SMat::Diag(b)) => {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += alpha * y;
                }
            }
            (SMat::Block(a), SMat::Block(b)) => a.axpy(alpha, b),
            (SMat::Tril(a), SMat::Tril(b)) => a.axpy(alpha, b),
            (SMat::RankK(a), SMat::RankK(b)) => a.axpy(alpha, b),
            (SMat::Hier(a), SMat::Hier(b)) => a.axpy(alpha, b),
            (SMat::Toep(a), SMat::Toep(b)) => a.axpy(alpha, b),
            _ => panic!("axpy: structure mismatch"),
        }
    }

    /// Closed structured multiplication `self @ other`.
    pub fn matmul(&self, other: &SMat) -> SMat {
        match (self, other) {
            (SMat::Dense(a), SMat::Dense(b)) => SMat::Dense(crate::tensor::matmul(a, b)),
            (SMat::Diag(a), SMat::Diag(b)) => {
                SMat::Diag(a.iter().zip(b).map(|(x, y)| x * y).collect())
            }
            (SMat::Block(a), SMat::Block(b)) => SMat::Block(a.matmul(b)),
            (SMat::Tril(a), SMat::Tril(b)) => SMat::Tril(a.matmul(b)),
            (SMat::RankK(a), SMat::RankK(b)) => SMat::RankK(a.matmul(b)),
            (SMat::Hier(a), SMat::Hier(b)) => SMat::Hier(a.matmul(b)),
            (SMat::Toep(a), SMat::Toep(b)) => SMat::Toep(a.matmul(b)),
            _ => panic!("matmul: structure mismatch"),
        }
    }

    /// Dense product `X @ K` (or `X @ Kᵀ` when `transpose`).
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        assert_eq!(x.cols(), self.dim(), "right_mul: dim mismatch");
        match self {
            SMat::Dense(k) => {
                if transpose {
                    crate::tensor::matmul_a_bt(x, k)
                } else {
                    crate::tensor::matmul(x, k)
                }
            }
            SMat::Diag(d) => {
                let mut out = x.clone();
                for r in 0..out.rows() {
                    for (v, s) in out.row_mut(r).iter_mut().zip(d.iter()) {
                        *v *= s;
                    }
                }
                out
            }
            SMat::Block(b) => b.right_mul(x, transpose),
            SMat::Tril(t) => t.right_mul(x, transpose),
            SMat::RankK(r) => r.right_mul(x, transpose),
            SMat::Hier(h) => h.right_mul(x, transpose),
            SMat::Toep(t) => t.right_mul(x, transpose),
        }
    }

    /// Dense product `K @ X` (or `Kᵀ @ X` when `transpose`).
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        assert_eq!(x.rows(), self.dim(), "left_mul: dim mismatch");
        match self {
            SMat::Dense(k) => {
                if transpose {
                    crate::tensor::matmul_at_b(k, x)
                } else {
                    crate::tensor::matmul(k, x)
                }
            }
            SMat::Diag(d) => {
                let mut out = x.clone();
                for r in 0..out.rows() {
                    let s = d[r];
                    for v in out.row_mut(r) {
                        *v *= s;
                    }
                }
                out
            }
            SMat::Block(b) => b.left_mul(x, transpose),
            SMat::Tril(t) => t.left_mul(x, transpose),
            SMat::RankK(r) => r.left_mul(x, transpose),
            SMat::Hier(h) => h.left_mul(x, transpose),
            SMat::Toep(t) => t.left_mul(x, transpose),
        }
    }

    /// `X @ K @ Kᵀ` — the K-side of the preconditioned gradient
    /// `m_μ = C Cᵀ vec⁻¹(g) K Kᵀ` (Fig. 4 step 2).
    pub fn kkt_right(&self, x: &Mat) -> Mat {
        let xk = self.right_mul(x, false);
        self.right_mul(&xk, true)
    }

    /// `K Kᵀ @ X` — the C-side of the preconditioned gradient.
    pub fn kkt_left(&self, x: &Mat) -> Mat {
        let ktx = self.left_mul(x, true);
        self.left_mul(&ktx, false)
    }

    /// `Π̂(scale · BᵀB)` computed directly from `B ∈ R^{m×d}` without
    /// forming the dense `d×d` Gram matrix (except for classes whose
    /// support is `O(d²)` anyway).
    ///
    /// With `B = A K` this yields `Π̂(H_K)`; with `B = K` (densified) it
    /// yields `Π̂(KᵀK)`.
    pub fn gram_project(&self, b: &Mat, scale: f32) -> SMat {
        assert_eq!(b.cols(), self.dim(), "gram_project: dim mismatch");
        match self {
            SMat::Dense(_) => {
                SMat::Dense(crate::tensor::matmul_at_b(b, b).scale(scale))
            }
            SMat::Diag(_) => {
                let d = self.dim();
                let mut out = vec![0.0f32; d];
                for r in 0..b.rows() {
                    for (o, v) in out.iter_mut().zip(b.row(r)) {
                        *o += v * v;
                    }
                }
                for o in &mut out {
                    *o *= scale;
                }
                SMat::Diag(out)
            }
            SMat::Block(bl) => SMat::Block(bl.gram_project(b, scale)),
            SMat::Tril(t) => SMat::Tril(t.gram_project(b, scale)),
            SMat::RankK(r) => SMat::RankK(r.gram_project(b, scale)),
            SMat::Hier(h) => SMat::Hier(h.gram_project(b, scale)),
            SMat::Toep(t) => SMat::Toep(t.gram_project(b, scale)),
        }
    }

    /// `Π̂(scale · KᵀK)` for this factor itself (the damping term of
    /// Fig. 4). Fast path for diagonal; dense-materialized otherwise for
    /// classes that need cross terms.
    pub fn self_gram_project(&self, scale: f32) -> SMat {
        match self {
            SMat::Diag(d) => SMat::Diag(d.iter().map(|x| scale * x * x).collect()),
            _ => {
                let dense = self.to_dense();
                self.gram_project(&dense, scale)
            }
        }
    }

    /// `Tr(KᵀK) = ‖K‖²_F` over the stored support.
    pub fn fro_sq(&self) -> f32 {
        let mut acc = 0.0f64;
        self.for_each(|x| acc += (x as f64) * (x as f64));
        // Structured storage never aliases entries except Toeplitz, where a
        // coefficient appears on a whole (shrinking) diagonal.
        if let SMat::Toep(t) = self {
            let mut s = 0.0f64;
            for (j, &c) in t.coef.iter().enumerate() {
                s += (t.d - j) as f64 * (c as f64) * (c as f64);
            }
            return s as f32;
        }
        acc as f32
    }

    /// Trace of the factor itself.
    pub fn trace(&self) -> f32 {
        match self {
            SMat::Dense(m) => m.trace(),
            SMat::Diag(d) => d.iter().sum(),
            SMat::Block(b) => b.trace(),
            SMat::Tril(t) => t.trace(),
            SMat::RankK(r) => r.trace(),
            SMat::Hier(h) => h.trace(),
            SMat::Toep(t) => t.coef[0] * t.d as f32,
        }
    }

    /// Number of stored parameters.
    pub fn nnz(&self) -> usize {
        let mut n = 0usize;
        self.for_each(|_| n += 1);
        n
    }

    /// Bytes of storage under a precision policy (paper Table 3 / Fig. 1R).
    pub fn bytes(&self, policy: &Policy) -> usize {
        self.nnz() * policy.store.bytes()
    }

    /// Round all stored entries to the policy's storage format.
    pub fn quantize(&mut self, policy: &Policy) {
        if policy.store == crate::numerics::Dtype::F32 {
            return;
        }
        let p = *policy;
        self.for_each_mut(|x| *x = p.q(*x));
    }

    /// Stored coefficients in the deterministic `for_each` iteration
    /// order — the flat wire format used by checkpoint v2.
    pub fn coeffs(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.nnz());
        self.for_each(|x| v.push(x));
        v
    }

    /// Overwrite the stored coefficients from [`SMat::coeffs`] order.
    /// Panics on a length mismatch (the caller validates blob sizes).
    pub fn set_coeffs(&mut self, coeffs: &[f32]) {
        let mut it = coeffs.iter();
        self.for_each_mut(|x| *x = *it.next().expect("set_coeffs: too few coefficients"));
        assert!(it.next().is_none(), "set_coeffs: too many coefficients");
    }

    /// Max absolute stored entry (∞-norm proxy used for the log-space
    /// trust region in [`crate::optim::Singd`]).
    pub fn max_abs(&self) -> f32 {
        let mut m = 0.0f32;
        self.for_each(|x| m = m.max(x.abs()));
        m
    }

    /// True if any stored entry is NaN/Inf.
    pub fn has_nonfinite(&self) -> bool {
        let mut bad = false;
        self.for_each(|x| bad |= !x.is_finite());
        bad
    }

    fn for_each(&self, mut f: impl FnMut(f32)) {
        match self {
            SMat::Dense(m) => m.data().iter().for_each(|&x| f(x)),
            SMat::Diag(d) => d.iter().for_each(|&x| f(x)),
            SMat::Block(b) => b.for_each(&mut f),
            SMat::Tril(t) => t.data.iter().for_each(|&x| f(x)),
            SMat::RankK(r) => r.for_each(&mut f),
            SMat::Hier(h) => h.for_each(&mut f),
            SMat::Toep(t) => t.coef.iter().for_each(|&x| f(x)),
        }
    }

    fn for_each_mut(&mut self, mut f: impl FnMut(&mut f32)) {
        match self {
            SMat::Dense(m) => m.data_mut().iter_mut().for_each(&mut f),
            SMat::Diag(d) => d.iter_mut().for_each(&mut f),
            SMat::Block(b) => b.for_each_mut(&mut f),
            SMat::Tril(t) => t.data.iter_mut().for_each(&mut f),
            SMat::RankK(r) => r.for_each_mut(&mut f),
            SMat::Hier(h) => h.for_each_mut(&mut f),
            SMat::Toep(t) => t.coef.iter_mut().for_each(&mut f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_mat_close, forall, Pcg};

    pub(crate) const ALL: &[Structure] = &[
        Structure::Dense,
        Structure::Diagonal,
        Structure::BlockDiag { k: 4 },
        Structure::Tril,
        Structure::RankKTril { k: 3 },
        Structure::Hierarchical { k1: 3, k2: 2 },
        Structure::TriuToeplitz,
    ];

    /// Random element of a structure class: project a random symmetric
    /// matrix, then shift by identity to keep it well-conditioned.
    pub(crate) fn random_smat(s: Structure, d: usize, rng: &mut Pcg) -> SMat {
        let m = rng.normal_mat(d, d, 0.3).symmetrize();
        let mut x = proj::proj(s, &m);
        let id = SMat::identity(s, d);
        x.axpy(1.0, &id);
        x
    }

    #[test]
    fn identity_is_dense_identity() {
        for &s in ALL {
            let id = SMat::identity(s, 13);
            assert_mat_close(&id.to_dense(), &Mat::eye(13), 1e-7, &format!("{s:?}"));
        }
    }

    #[test]
    fn matmul_matches_dense_reference() {
        forall(31, 12, |rng, case| {
            let d = 6 + rng.below(14);
            for &s in ALL {
                let a = random_smat(s, d, rng);
                let b = random_smat(s, d, rng);
                let prod = a.matmul(&b);
                // closure: result must be in the same class
                assert_eq!(prod.structure(), a.structure(), "case {case} {s:?}");
                let dense_ref = crate::tensor::matmul(&a.to_dense(), &b.to_dense());
                assert_mat_close(&prod.to_dense(), &dense_ref, 1e-4, &format!("{s:?}"));
            }
        });
    }

    #[test]
    fn right_left_mul_match_dense() {
        forall(32, 10, |rng, _| {
            let d = 5 + rng.below(12);
            let m = 3 + rng.below(9);
            let x_right = rng.normal_mat(m, d, 1.0);
            let x_left = rng.normal_mat(d, m, 1.0);
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let kd = k.to_dense();
                assert_mat_close(
                    &k.right_mul(&x_right, false),
                    &crate::tensor::matmul(&x_right, &kd),
                    1e-4,
                    &format!("{s:?} right"),
                );
                assert_mat_close(
                    &k.right_mul(&x_right, true),
                    &crate::tensor::matmul_a_bt(&x_right, &kd),
                    1e-4,
                    &format!("{s:?} right-T"),
                );
                assert_mat_close(
                    &k.left_mul(&x_left, false),
                    &crate::tensor::matmul(&kd, &x_left),
                    1e-4,
                    &format!("{s:?} left"),
                );
                assert_mat_close(
                    &k.left_mul(&x_left, true),
                    &crate::tensor::matmul_at_b(&kd, &x_left),
                    1e-4,
                    &format!("{s:?} left-T"),
                );
            }
        });
    }

    #[test]
    fn kkt_products_match_dense() {
        forall(33, 8, |rng, _| {
            let d = 4 + rng.below(10);
            let x = rng.normal_mat(3, d, 1.0);
            let y = rng.normal_mat(d, 3, 1.0);
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let kd = k.to_dense();
                let kkt = crate::tensor::matmul_a_bt(&kd, &kd);
                assert_mat_close(
                    &k.kkt_right(&x),
                    &crate::tensor::matmul(&x, &kkt),
                    1e-4,
                    &format!("{s:?} X K Kᵀ"),
                );
                assert_mat_close(
                    &k.kkt_left(&y),
                    &crate::tensor::matmul(&kkt, &y),
                    1e-4,
                    &format!("{s:?} K Kᵀ Y"),
                );
            }
        });
    }

    #[test]
    fn gram_project_matches_dense_proj() {
        forall(34, 10, |rng, _| {
            let d = 5 + rng.below(11);
            let m = 4 + rng.below(8);
            let b = rng.normal_mat(m, d, 1.0);
            let gram = crate::tensor::matmul_at_b(&b, &b).scale(0.7);
            for &s in ALL {
                let k = SMat::identity(s, d);
                let got = k.gram_project(&b, 0.7);
                let want = proj::proj(s, &gram);
                assert_mat_close(&got.to_dense(), &want.to_dense(), 1e-4, &format!("{s:?}"));
            }
        });
    }

    #[test]
    fn self_gram_project_matches() {
        forall(35, 8, |rng, _| {
            let d = 5 + rng.below(9);
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let kd = k.to_dense();
                let gram = crate::tensor::matmul_at_b(&kd, &kd).scale(1.3);
                let want = proj::proj(s, &gram);
                let got = k.self_gram_project(1.3);
                assert_mat_close(&got.to_dense(), &want.to_dense(), 1e-4, &format!("{s:?}"));
            }
        });
    }

    #[test]
    fn fro_sq_matches_dense() {
        forall(36, 8, |rng, _| {
            let d = 4 + rng.below(12);
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let dense = k.to_dense();
                let want = dense.fro_norm().powi(2);
                let got = k.fro_sq();
                assert!((got - want).abs() <= 1e-3 * (1.0 + want), "{s:?}: {got} vs {want}");
            }
        });
    }

    #[test]
    fn trace_matches_dense() {
        forall(37, 8, |rng, _| {
            let d = 4 + rng.below(12);
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let want = k.to_dense().trace();
                assert!((k.trace() - want).abs() < 1e-4 * (1.0 + want.abs()), "{s:?}");
            }
        });
    }

    #[test]
    fn nnz_and_bytes_scaling() {
        let d = 64;
        let p = Policy::fp32();
        let dense = SMat::identity(Structure::Dense, d).bytes(&p);
        let diag = SMat::identity(Structure::Diagonal, d).bytes(&p);
        let block = SMat::identity(Structure::BlockDiag { k: 8 }, d).bytes(&p);
        let toep = SMat::identity(Structure::TriuToeplitz, d).bytes(&p);
        assert_eq!(dense, d * d * 4);
        assert_eq!(diag, d * 4);
        assert_eq!(block, d * 8 * 4);
        assert_eq!(toep, d * 4);
        // bf16 halves everything
        let pb = Policy::bf16_mixed();
        assert_eq!(SMat::identity(Structure::Dense, d).bytes(&pb), d * d * 2);
    }

    #[test]
    fn axpy_and_scale_match_dense() {
        forall(38, 6, |rng, _| {
            let d = 5 + rng.below(9);
            for &s in ALL {
                let mut a = random_smat(s, d, rng);
                let b = random_smat(s, d, rng);
                let want = a.to_dense().scale(0.5).add(&b.to_dense().scale(2.0));
                a.scale_inplace(0.5);
                a.axpy(2.0, &b);
                assert_mat_close(&a.to_dense(), &want, 1e-5, &format!("{s:?}"));
            }
        });
    }

    #[test]
    fn quantize_bf16_changes_entries_representably() {
        let mut rng = Pcg::new(40);
        for &s in ALL {
            let mut k = random_smat(s, 10, &mut rng);
            k.quantize(&Policy::bf16_mixed());
            k.for_each(|x| {
                assert_eq!(x, crate::numerics::Dtype::Bf16.round(x), "{s:?} not bf16-representable");
            });
        }
    }

    #[test]
    fn coeffs_roundtrip_every_structure() {
        let mut rng = Pcg::new(42);
        for &s in ALL {
            let k = random_smat(s, 11, &mut rng);
            let mut z = SMat::zeros(s, 11);
            let c = k.coeffs();
            assert_eq!(c.len(), k.nnz(), "{s:?}");
            z.set_coeffs(&c);
            assert_eq!(z.to_dense().data(), k.to_dense().data(), "{s:?}");
        }
    }

    /// Exact (no-tolerance) matrix comparison for the conformance grid.
    /// Values must agree bitwise up to IEEE's `-0.0 == 0.0`
    /// identification; NaN anywhere fails.
    pub(crate) fn assert_mat_bitwise(a: &Mat, b: &Mat, ctx: &str) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{ctx}: shape mismatch");
        for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
            assert!(
                x == y,
                "{ctx}: entry {i}: {x:e} ({:#010x}) != {y:e} ({:#010x})",
                x.to_bits(),
                y.to_bits()
            );
        }
    }

    /// Conformance grid: products with an exact identity operand must be
    /// BITWISE equal to the dense reference, for every structure class
    /// and every op (`matmul`, `right_mul`, `left_mul`, both transpose
    /// legs). With an identity operand every output entry is one exact
    /// coefficient plus exact-zero terms, so any summation order — the
    /// scalar loops, the blocked dense kernel on extracted blocks, the
    /// pooled shards — must reproduce the coefficient exactly; any
    /// indexing or accumulation bug shows up as a bit flip, not as noise
    /// hidden under a tolerance.
    #[test]
    fn conformance_identity_products_bitwise_equal_dense() {
        // d stays below the Toeplitz FFT crossover so every class runs
        // its direct path; the FFT leg has its own tolerance cells in
        // `toeplitz.rs`.
        forall(51, 10, |rng, case| {
            let d = 1 + rng.below(32);
            let eye = Mat::eye(d);
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let kd = k.to_dense();
                let kdt = kd.transpose();
                let id = SMat::identity(s, d);
                let ctx = format!("case {case} d {d} {s:?}");
                assert_mat_bitwise(&k.matmul(&id).to_dense(), &kd, &format!("{ctx} K@I"));
                assert_mat_bitwise(&id.matmul(&k).to_dense(), &kd, &format!("{ctx} I@K"));
                assert_mat_bitwise(&k.right_mul(&eye, false), &kd, &format!("{ctx} I·K"));
                assert_mat_bitwise(&k.right_mul(&eye, true), &kdt, &format!("{ctx} I·Kᵀ"));
                assert_mat_bitwise(&k.left_mul(&eye, false), &kd, &format!("{ctx} K·I"));
                assert_mat_bitwise(&k.left_mul(&eye, true), &kdt, &format!("{ctx} Kᵀ·I"));
            }
        });
    }

    /// Conformance grid, non-square operands: one-hot selector matrices
    /// (`m ≠ d`) pick rows/columns of `K`, so the expected output is an
    /// exact gather from the dense form — bitwise, like the identity
    /// cells, but through the rectangular code paths.
    #[test]
    fn conformance_one_hot_selectors_bitwise_gather_rows_and_cols() {
        forall(52, 10, |rng, case| {
            let d = 2 + rng.below(30);
            let m = 1 + rng.below(2 * d); // freely non-square, both m<d and m>d
            let picks: Vec<usize> = (0..m).map(|_| rng.below(d)).collect();
            // X ∈ R^{m×d} with exactly one 1.0 per row.
            let mut x = Mat::zeros(m, d);
            for (r, &p) in picks.iter().enumerate() {
                x.set(r, p, 1.0);
            }
            let xt = x.transpose(); // d×m, one 1.0 per column
            for &s in ALL {
                let k = random_smat(s, d, rng);
                let kd = k.to_dense();
                let ctx = format!("case {case} d {d} m {m} {s:?}");
                // X@K gathers rows of K; X@Kᵀ gathers rows of Kᵀ.
                let want_rows = Mat::from_fn(m, d, |r, c| kd.at(picks[r], c));
                let want_rows_t = Mat::from_fn(m, d, |r, c| kd.at(c, picks[r]));
                assert_mat_bitwise(&k.right_mul(&x, false), &want_rows, &format!("{ctx} right"));
                assert_mat_bitwise(
                    &k.right_mul(&x, true),
                    &want_rows_t,
                    &format!("{ctx} right-T"),
                );
                // K@Xᵀ gathers columns of K; Kᵀ@Xᵀ gathers columns of Kᵀ.
                let want_cols = Mat::from_fn(d, m, |r, c| kd.at(r, picks[c]));
                let want_cols_t = Mat::from_fn(d, m, |r, c| kd.at(picks[c], r));
                assert_mat_bitwise(&k.left_mul(&xt, false), &want_cols, &format!("{ctx} left"));
                assert_mat_bitwise(
                    &k.left_mul(&xt, true),
                    &want_cols_t,
                    &format!("{ctx} left-T"),
                );
            }
        });
    }

    /// Conformance grid, degenerate shapes: a 0-row batch must
    /// gram-project to the exact zero element, and 1×1 factors must run
    /// every op exactly (single-coefficient arithmetic has no rounding
    /// freedom).
    #[test]
    fn conformance_zero_row_and_one_by_one_shapes() {
        let mut rng = Pcg::new(53);
        for &s in ALL {
            // 0-row batch: Π̂(scale · BᵀB) with B ∈ R^{0×d} is exactly 0.
            let d = 7;
            let k = random_smat(s, d, &mut rng);
            let b0 = Mat::zeros(0, d);
            let got = k.gram_project(&b0, 1.3);
            assert_eq!(got.structure(), k.structure(), "{s:?} 0-row closure");
            assert_mat_bitwise(
                &got.to_dense(),
                &Mat::zeros(d, d),
                &format!("{s:?} 0-row gram"),
            );
            // right_mul with a 0-row operand: a 0×d result, no panic.
            let empty = k.right_mul(&b0, false);
            assert_eq!((empty.rows(), empty.cols()), (0, d), "{s:?} 0-row right_mul");

            // 1×1: every class degenerates to scalar arithmetic.
            let k1 = random_smat(s, 1, &mut rng);
            let v = k1.to_dense().at(0, 0);
            let x = rng.normal_mat(3, 1, 1.0);
            let want = Mat::from_fn(3, 1, |r, _| x.at(r, 0) * v);
            assert_mat_bitwise(&k1.right_mul(&x, false), &want, &format!("{s:?} 1×1 right"));
            assert_mat_bitwise(&k1.right_mul(&x, true), &want, &format!("{s:?} 1×1 right-T"));
            let prod = k1.matmul(&k1).to_dense().at(0, 0);
            assert!(prod == v * v, "{s:?} 1×1 matmul: {prod:e} != {:e}", v * v);
            // Single-row batch: the gram is one product, so every
            // accumulation strategy must hit the same bits (scale 0.5 is
            // a power of two — exact).
            let b = rng.normal_mat(1, 1, 1.0);
            let want_gram = b.at(0, 0) * b.at(0, 0) * 0.5;
            let got_gram = k1.gram_project(&b, 0.5).to_dense().at(0, 0);
            assert!(
                got_gram == want_gram,
                "{s:?} 1×1 gram: {got_gram:e} != {want_gram:e}"
            );
        }
    }

    /// Conformance grid, scheduling axis: every structured op must be
    /// BITWISE identical between a serial run and a pooled run, at a
    /// shape big enough that the pooled path actually shards
    /// (`PAR_WORK`-crossing matmul/gram work). This is the property the
    /// optimizer determinism contracts stand on — a tolerance here would
    /// let scheduling-dependent reductions leak into the digests.
    #[test]
    fn conformance_serial_and_pooled_runs_bitwise_identical() {
        for (d, m) in [(12usize, 8usize), (96, 72)] {
            // Build inputs OUTSIDE with_threads so both runs see the
            // identical bits.
            let mut rng = Pcg::new(54 + d as u64);
            let x_right = rng.normal_mat(m, d, 1.0);
            let x_left = rng.normal_mat(d, m, 1.0);
            for &s in ALL {
                let a = random_smat(s, d, &mut rng);
                let b = random_smat(s, d, &mut rng);
                let run = || {
                    (
                        a.matmul(&b).to_dense(),
                        a.right_mul(&x_right, false),
                        a.right_mul(&x_right, true),
                        a.left_mul(&x_left, false),
                        a.left_mul(&x_left, true),
                        a.gram_project(&x_right, 0.7).to_dense(),
                        a.self_gram_project(1.3).to_dense(),
                    )
                };
                let serial = crate::tensor::pool::with_threads(1, run);
                let pooled = crate::tensor::pool::with_threads(4, run);
                let ctx = format!("d {d} {s:?}");
                assert_mat_bitwise(&serial.0, &pooled.0, &format!("{ctx} matmul"));
                assert_mat_bitwise(&serial.1, &pooled.1, &format!("{ctx} right"));
                assert_mat_bitwise(&serial.2, &pooled.2, &format!("{ctx} right-T"));
                assert_mat_bitwise(&serial.3, &pooled.3, &format!("{ctx} left"));
                assert_mat_bitwise(&serial.4, &pooled.4, &format!("{ctx} left-T"));
                assert_mat_bitwise(&serial.5, &pooled.5, &format!("{ctx} gram"));
                assert_mat_bitwise(&serial.6, &pooled.6, &format!("{ctx} self-gram"));
            }
        }
    }

    #[test]
    fn structure_parse_roundtrip() {
        for &s in ALL {
            let parsed = Structure::parse(&s.name()).unwrap();
            // hier collapses k1/k2 to k1+k2; compare via name
            assert_eq!(parsed.name(), s.name());
        }
        assert_eq!(Structure::parse("block:16"), Some(Structure::BlockDiag { k: 16 }));
        assert!(Structure::parse("bogus").is_none());
    }
}
