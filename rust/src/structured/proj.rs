//! Dense-reference subspace projection maps `Π̂` (paper Table 1).
//!
//! [`proj`] takes a dense *symmetric* matrix `m` (an element of the matrix
//! logarithm space) and returns its weighted projection onto the chosen
//! structure's Lie subalgebra. These are the reference semantics; the
//! production path computes the same quantity directly from factored inputs
//! via [`SMat::gram_project`](super::SMat::gram_project) without forming `m`.
//!
//! The weights (off-support entries folded into their mirrored on-support
//! partner with factor 2, Toeplitz diagonals averaged) are exactly the ones
//! that satisfy the local orthonormalization condition of the Fisher block,
//! `F(m_K)|_{m_K=0} = I`, in the subspace — verified by the
//! `orthonormalization_*` tests below, which check that `Π̂` is the adjoint
//! of the inclusion with respect to the inner product
//! `⟨u, v⟩ = ½ Tr(uᵀv + u v)` induced by the dense log space on symmetric
//! inputs (equivalently: `Tr(Π̂(m)ᵀ s) = Tr(m s)` for every *symmetric* `m`
//! and every structured direction `s`).

use super::{HierF, RankKF, SMat, Structure, ToepF, TrilF};
use crate::tensor::Mat;

/// Apply the Table-1 projection map `Π̂` to a dense symmetric matrix.
pub fn proj(s: Structure, m: &Mat) -> SMat {
    assert_eq!(m.rows(), m.cols(), "proj: not square");
    let d = m.rows();
    match s {
        Structure::Dense => SMat::Dense(m.clone()),
        Structure::Diagonal => SMat::Diag(m.diagonal()),
        Structure::BlockDiag { k: _ } => {
            let mut out = match SMat::identity(s, d) {
                SMat::Block(b) => b,
                _ => unreachable!(),
            };
            let mut off = 0;
            for blk in &mut out.blocks {
                let sz = blk.rows();
                for r in 0..sz {
                    for c in 0..sz {
                        blk.set(r, c, m.at(off + r, off + c));
                    }
                }
                off += sz;
            }
            SMat::Block(out)
        }
        Structure::Tril => {
            let mut out = TrilF::identity(d);
            for r in 0..d {
                for c in 0..=r {
                    let w = if r == c { 1.0 } else { 2.0 };
                    out.data[r * (r + 1) / 2 + c] = w * m.at(r, c);
                }
            }
            SMat::Tril(out)
        }
        Structure::RankKTril { k } => {
            let k = k.min(d);
            let mut out = RankKF::identity(d, k);
            out.a11 = Mat::from_fn(k, k, |r, c| m.at(r, c));
            out.a12 = Mat::from_fn(k, d - k, |r, c| 2.0 * m.at(r, k + c));
            out.d22 = (k..d).map(|i| m.at(i, i)).collect();
            SMat::RankK(out)
        }
        Structure::Hierarchical { k1, k2 } => {
            let k1 = k1.min(d);
            let k2 = k2.min(d - k1);
            let dm = d - k1 - k2;
            let mut out = HierF::identity(d, k1, k2);
            out.a11 = Mat::from_fn(k1, k1, |r, c| m.at(r, c));
            out.a12 = Mat::from_fn(k1, dm, |r, c| 2.0 * m.at(r, k1 + c));
            out.a13 = Mat::from_fn(k1, k2, |r, c| 2.0 * m.at(r, k1 + dm + c));
            out.d22 = (0..dm).map(|i| m.at(k1 + i, k1 + i)).collect();
            out.a32 = Mat::from_fn(k2, dm, |r, c| 2.0 * m.at(k1 + dm + r, k1 + c));
            out.a33 = Mat::from_fn(k2, k2, |r, c| m.at(k1 + dm + r, k1 + dm + c));
            SMat::Hier(out)
        }
        Structure::TriuToeplitz => {
            let mut coef = vec![0.0f32; d];
            for (j, c) in coef.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for k in 0..d - j {
                    acc += m.at(k, k + j) as f64;
                }
                let avg = (acc / (d - j) as f64) as f32;
                *c = avg * if j == 0 { 1.0 } else { 2.0 };
            }
            SMat::Toep(ToepF { d, coef })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, Pcg};

    const ALL: &[Structure] = &[
        Structure::Dense,
        Structure::Diagonal,
        Structure::BlockDiag { k: 3 },
        Structure::Tril,
        Structure::RankKTril { k: 2 },
        Structure::Hierarchical { k1: 2, k2: 3 },
        Structure::TriuToeplitz,
    ];

    #[test]
    fn proj_is_linear() {
        forall(51, 8, |rng, _| {
            let d = 5 + rng.below(8);
            let a = rng.normal_mat(d, d, 1.0).symmetrize();
            let b = rng.normal_mat(d, d, 1.0).symmetrize();
            let combo = a.scale(0.3).add(&b.scale(-1.7));
            for &s in ALL {
                let mut lhs = proj(s, &a);
                lhs.scale_inplace(0.3);
                lhs.axpy(-1.7, &proj(s, &b));
                let rhs = proj(s, &combo);
                crate::proptest::assert_mat_close(
                    &lhs.to_dense(),
                    &rhs.to_dense(),
                    1e-4,
                    &format!("{s:?} linearity"),
                );
            }
        });
    }

    #[test]
    fn proj_of_identity_is_identity() {
        for &s in ALL {
            let d = 9;
            let p = proj(s, &Mat::eye(d));
            crate::proptest::assert_mat_close(
                &p.to_dense(),
                &Mat::eye(d),
                1e-6,
                &format!("{s:?} Π̂(I)=I"),
            );
        }
    }

    #[test]
    fn proj_idempotent_on_diagonal_structures() {
        // For structures whose support contains the diagonal of the input,
        // projecting a matrix already in the (symmetrized) image should act
        // predictably: Π̂(D) = D for diagonal D on every structure.
        let mut rng = Pcg::new(3);
        let d = 8;
        let entries: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let diag = Mat::diag(&entries);
        for &s in ALL {
            if s == Structure::TriuToeplitz {
                // Toeplitz collapses the diagonal to its mean.
                let mean = entries.iter().sum::<f32>() / d as f32;
                let p = proj(s, &diag).to_dense();
                crate::proptest::assert_mat_close(
                    &p,
                    &Mat::eye_scaled(d, mean),
                    1e-5,
                    "toeplitz on diag",
                );
                continue;
            }
            let p = proj(s, &diag);
            crate::proptest::assert_mat_close(&p.to_dense(), &diag, 1e-5, &format!("{s:?} on diag"));
        }
    }

    /// The orthonormalization condition (§3.2), in its variational form:
    /// the weighted map `Π̂` of Table 1 is exactly the map for which
    /// `sym(Π̂(m))` is the *orthogonal projection* of the symmetric
    /// log-space element `m` onto `sym(class)` — equivalently, the residual
    /// `sym(Π̂(m)) − m` is Frobenius-orthogonal to every symmetrized
    /// structured direction:
    ///
    /// `⟨sym(Π̂(m)) − m, sym(E)⟩_F = 0   ∀ structured E`,
    ///
    /// with `sym(A) = (A + Aᵀ)/2`. This single identity forces the factor-2
    /// weights on one-sidedly stored off-diagonal entries and the
    /// diagonal-averaging of the Toeplitz class, and is what makes the NGD
    /// step in the subspace a plain (Euclidean) gradient step.
    #[test]
    fn orthonormalization_projection_property() {
        forall(52, 8, |rng, _| {
            let d = 6 + rng.below(6);
            let m = rng.normal_mat(d, d, 1.0).symmetrize();
            for &s in ALL {
                let p = proj(s, &m).to_dense();
                let sym_p = p.symmetrize();
                let resid = sym_p.sub(&m);
                // Test orthogonality against a batch of random structured
                // directions (spans the subspace with overwhelming
                // probability across cases).
                for _ in 0..4 {
                    let dir = super::super::tests::random_smat(s, d, rng);
                    let sym_dir = dir.to_dense().symmetrize();
                    let ip: f64 = resid
                        .data()
                        .iter()
                        .zip(sym_dir.data())
                        .map(|(&a, &b)| (a as f64) * (b as f64))
                        .sum();
                    let scale = 1.0 + resid.fro_norm() as f64 * sym_dir.fro_norm() as f64;
                    assert!(
                        ip.abs() <= 1e-3 * scale,
                        "{s:?}: residual not orthogonal to subspace: ⟨r, sym(E)⟩ = {ip}"
                    );
                }
            }
        });
    }

    /// Toeplitz variant of the adjoint property: each coefficient direction
    /// `e_j` (ones on superdiagonal j) must satisfy
    /// `coef_j(Π̂(m)) · ⟨e_j, e_j⟩ = ⟨m, e_j + e_jᵀ⟩` appropriately scaled;
    /// concretely Table 1 gives coef_j = (2−δ_j0)·mean(diag_j(m)).
    #[test]
    fn toeplitz_projection_coefficients() {
        let mut rng = Pcg::new(53);
        let d = 7;
        let m = rng.normal_mat(d, d, 1.0).symmetrize();
        if let SMat::Toep(t) = proj(Structure::TriuToeplitz, &m) {
            for j in 0..d {
                let mean: f32 =
                    (0..d - j).map(|k| m.at(k, k + j)).sum::<f32>() / (d - j) as f32;
                let want = mean * if j == 0 { 1.0 } else { 2.0 };
                assert!((t.coef[j] - want).abs() < 1e-5);
            }
        } else {
            panic!("wrong variant");
        }
    }
}
