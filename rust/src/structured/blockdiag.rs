//! Block-diagonal Kronecker factor (Table 1, row 2).
//!
//! `K = blockdiag(A₁, …, A_q)` with `A_b ∈ R^{k×k}` (last block may be
//! smaller when `k ∤ d`). Storage `O(kd)`; every op is per-block and costs
//! `O(k)` per matrix element touched, which yields the `O(k m d)` iteration
//! cost of paper Table 2.

use crate::tensor::{matmul, Mat};

#[derive(Clone, Debug)]
pub struct BlockDiagF {
    pub d: usize,
    pub k: usize,
    /// Diagonal blocks; `blocks[b]` covers rows/cols `[b*k, b*k + blocks[b].rows())`.
    pub blocks: Vec<Mat>,
}

impl BlockDiagF {
    pub fn identity(d: usize, k: usize) -> Self {
        let k = k.max(1).min(d.max(1));
        let mut blocks = Vec::new();
        let mut off = 0;
        while off < d {
            let sz = k.min(d - off);
            blocks.push(Mat::eye(sz));
            off += sz;
        }
        BlockDiagF { d, k, blocks }
    }

    /// Block start offsets.
    fn offsets(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let mut off = 0;
        self.blocks.iter().map(move |b| {
            let cur = off;
            off += b.rows();
            (cur, b.rows())
        })
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.d);
        let mut off = 0;
        for b in &self.blocks {
            for r in 0..b.rows() {
                for c in 0..b.cols() {
                    m.set(off + r, off + c, b.at(r, c));
                }
            }
            off += b.rows();
        }
        m
    }

    pub fn axpy(&mut self, alpha: f32, other: &BlockDiagF) {
        assert_eq!(self.d, other.d);
        assert_eq!(self.k, other.k);
        for (a, b) in self.blocks.iter_mut().zip(&other.blocks) {
            a.axpy(alpha, b);
        }
    }

    pub fn matmul(&self, other: &BlockDiagF) -> BlockDiagF {
        assert_eq!(self.d, other.d);
        assert_eq!(self.k, other.k);
        BlockDiagF {
            d: self.d,
            k: self.k,
            blocks: self.blocks.iter().zip(&other.blocks).map(|(a, b)| matmul(a, b)).collect(),
        }
    }

    /// `X @ K` or `X @ Kᵀ`.
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let mut out = Mat::zeros(m, self.d);
        for (off, sz) in self.offsets() {
            let blk = &self.blocks[off / self.k];
            for r in 0..m {
                let xr = &x.row(r)[off..off + sz];
                let or = &mut out.row_mut(r)[off..off + sz];
                for j in 0..sz {
                    let mut acc = 0.0f32;
                    for i in 0..sz {
                        let kij = if transpose { blk.at(j, i) } else { blk.at(i, j) };
                        acc += xr[i] * kij;
                    }
                    or[j] = acc;
                }
            }
        }
        out
    }

    /// `K @ X` or `Kᵀ @ X`.
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let n = x.cols();
        let mut out = Mat::zeros(self.d, n);
        for (off, sz) in self.offsets() {
            let blk = &self.blocks[off / self.k];
            for i in 0..sz {
                let orow = out.row_mut(off + i);
                for p in 0..sz {
                    let kip = if transpose { blk.at(p, i) } else { blk.at(i, p) };
                    if kip == 0.0 {
                        continue;
                    }
                    let xrow = x.row(off + p);
                    for c in 0..n {
                        orow[c] += kip * xrow[c];
                    }
                }
            }
        }
        out
    }

    /// `Π̂(scale · BᵀB)`: extract each diagonal block of the Gram matrix,
    /// computed blockwise from `B` in `O(m d k)`.
    pub fn gram_project(&self, b: &Mat, scale: f32) -> BlockDiagF {
        let m = b.rows();
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (off, sz) in self.offsets() {
            let mut g = Mat::zeros(sz, sz);
            for r in 0..m {
                let br = &b.row(r)[off..off + sz];
                for i in 0..sz {
                    let bi = br[i];
                    if bi == 0.0 {
                        continue;
                    }
                    for j in 0..sz {
                        *g.at_mut(i, j) += bi * br[j];
                    }
                }
            }
            blocks.push(g.scale(scale));
        }
        BlockDiagF { d: self.d, k: self.k, blocks }
    }

    pub fn trace(&self) -> f32 {
        self.blocks.iter().map(|b| b.trace()).sum()
    }

    pub fn for_each(&self, f: &mut impl FnMut(f32)) {
        for b in &self.blocks {
            b.data().iter().for_each(|&x| f(x));
        }
    }

    pub fn for_each_mut(&mut self, f: &mut impl FnMut(&mut f32)) {
        for b in &mut self.blocks {
            b.data_mut().iter_mut().for_each(&mut *f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_blocks() {
        let b = BlockDiagF::identity(10, 4); // blocks 4,4,2
        assert_eq!(b.blocks.len(), 3);
        assert_eq!(b.blocks[2].rows(), 2);
        assert_eq!(b.to_dense(), Mat::eye(10));
    }

    #[test]
    fn k_larger_than_d_clamps() {
        let b = BlockDiagF::identity(3, 100);
        assert_eq!(b.blocks.len(), 1);
        assert_eq!(b.blocks[0].rows(), 3);
    }
}
