//! Block-diagonal Kronecker factor (Table 1, row 2).
//!
//! `K = blockdiag(A₁, …, A_q)` with `A_b ∈ R^{k×k}` (last block may be
//! smaller when `k ∤ d`). Storage `O(kd)`; every op is per-block and costs
//! `O(k)` per matrix element touched, which yields the `O(k m d)` iteration
//! cost of paper Table 2.
//!
//! Blocks are independent, so the expensive ops (`matmul`,
//! `gram_project`, `left_mul`) fan their per-block work out across the
//! persistent worker pool when the total work clears
//! [`super::PAR_WORK`]; `right_mul` shards by rows of `X` instead (all
//! blocks touch every row). Each parallel unit owns a disjoint slice of
//! the output and per-element accumulation order is independent of the
//! sharding, so pooled and serial results are bitwise identical.

use crate::tensor::{matmul_into, pool, Mat};

#[derive(Clone, Debug)]
pub struct BlockDiagF {
    pub d: usize,
    pub k: usize,
    /// Diagonal blocks; `blocks[b]` covers rows/cols `[b*k, b*k + blocks[b].rows())`.
    pub blocks: Vec<Mat>,
}

impl BlockDiagF {
    pub fn identity(d: usize, k: usize) -> Self {
        let k = k.max(1).min(d.max(1));
        let mut blocks = Vec::new();
        let mut off = 0;
        while off < d {
            let sz = k.min(d - off);
            blocks.push(Mat::eye(sz));
            off += sz;
        }
        BlockDiagF { d, k, blocks }
    }

    /// Block start offsets.
    fn offsets(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let mut off = 0;
        self.blocks.iter().map(move |b| {
            let cur = off;
            off += b.rows();
            (cur, b.rows())
        })
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.d);
        let mut off = 0;
        for b in &self.blocks {
            for r in 0..b.rows() {
                for c in 0..b.cols() {
                    m.set(off + r, off + c, b.at(r, c));
                }
            }
            off += b.rows();
        }
        m
    }

    pub fn axpy(&mut self, alpha: f32, other: &BlockDiagF) {
        assert_eq!(self.d, other.d);
        assert_eq!(self.k, other.k);
        for (a, b) in self.blocks.iter_mut().zip(&other.blocks) {
            a.axpy(alpha, b);
        }
    }

    pub fn matmul(&self, other: &BlockDiagF) -> BlockDiagF {
        assert_eq!(self.d, other.d);
        assert_eq!(self.k, other.k);
        // 2k³ flops per block.
        if 2 * self.k * self.k * self.d < super::PAR_WORK || self.blocks.len() < 2 {
            return BlockDiagF {
                d: self.d,
                k: self.k,
                blocks: self
                    .blocks
                    .iter()
                    .zip(&other.blocks)
                    .map(|(a, b)| crate::tensor::matmul(a, b))
                    .collect(),
            };
        }
        let mut blocks: Vec<Mat> =
            self.blocks.iter().map(|b| Mat::zeros(b.rows(), b.cols())).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = blocks
            .iter_mut()
            .zip(self.blocks.iter().zip(&other.blocks))
            .map(|(dst, (a, b))| {
                Box::new(move || matmul_into(a, b, dst, false)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_jobs(jobs);
        BlockDiagF { d: self.d, k: self.k, blocks }
    }

    /// `X @ K` or `X @ Kᵀ`, sharded by rows of `X`.
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let mut out = Mat::zeros(m, self.d);
        if m == 0 || self.d == 0 {
            return out;
        }
        let d = self.d;
        let xd = x.data();
        let min_rows = if m * self.k * d < super::PAR_WORK { m } else { 1 };
        pool::parallel_chunks_mut(out.data_mut(), d, min_rows, |row0, chunk| {
            for (li, or) in chunk.chunks_mut(d).enumerate() {
                let xr = &xd[(row0 + li) * d..(row0 + li + 1) * d];
                self.right_mul_row(xr, or, transpose);
            }
        });
        out
    }

    fn right_mul_row(&self, xr: &[f32], or: &mut [f32], transpose: bool) {
        let mut off = 0;
        for blk in &self.blocks {
            let sz = blk.rows();
            let xs = &xr[off..off + sz];
            let os = &mut or[off..off + sz];
            for (j, o) in os.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (i, &xv) in xs.iter().enumerate() {
                    let kij = if transpose { blk.at(j, i) } else { blk.at(i, j) };
                    acc += xv * kij;
                }
                *o = acc;
            }
            off += sz;
        }
    }

    /// `K @ X` or `Kᵀ @ X`: block `b` owns the contiguous output rows
    /// `[off_b, off_b + sz_b)`, so blocks fan out as independent jobs.
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let n = x.cols();
        let mut out = Mat::zeros(self.d, n);
        if n == 0 || self.d == 0 {
            return out;
        }
        let parallel =
            self.k * self.d * n >= super::PAR_WORK && self.blocks.len() >= 2;
        let offsets: Vec<(usize, usize)> = self.offsets().collect();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(self.blocks.len());
        let mut rest = out.data_mut();
        for (bi, &(off, sz)) in offsets.iter().enumerate() {
            let (chunk, tail) = rest.split_at_mut(sz * n);
            rest = tail;
            let blk = &self.blocks[bi];
            let job = move || {
                for i in 0..sz {
                    let orow = &mut chunk[i * n..(i + 1) * n];
                    for p in 0..sz {
                        let kip = if transpose { blk.at(p, i) } else { blk.at(i, p) };
                        if kip == 0.0 {
                            continue;
                        }
                        let xrow = x.row(off + p);
                        for (ov, &xv) in orow.iter_mut().zip(xrow.iter()) {
                            *ov += kip * xv;
                        }
                    }
                }
            };
            if parallel {
                jobs.push(Box::new(job));
            } else {
                job();
            }
        }
        pool::run_jobs(jobs);
        out
    }

    /// `Π̂(scale · BᵀB)`: extract each diagonal block of the Gram matrix,
    /// computed blockwise from `B` in `O(m d k)`, one pool job per block.
    pub fn gram_project(&self, b: &Mat, scale: f32) -> BlockDiagF {
        let m = b.rows();
        let offsets: Vec<(usize, usize)> = self.offsets().collect();
        let mut blocks: Vec<Mat> =
            offsets.iter().map(|&(_, sz)| Mat::zeros(sz, sz)).collect();
        let parallel = m * self.k * self.d >= super::PAR_WORK && blocks.len() >= 2;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(blocks.len());
        for (g, &(off, sz)) in blocks.iter_mut().zip(offsets.iter()) {
            let job = move || {
                for r in 0..m {
                    let br = &b.row(r)[off..off + sz];
                    for (i, &bi) in br.iter().enumerate() {
                        if bi == 0.0 {
                            continue;
                        }
                        for (j, &bj) in br.iter().enumerate() {
                            *g.at_mut(i, j) += bi * bj;
                        }
                    }
                }
                if scale != 1.0 {
                    for v in g.data_mut() {
                        *v *= scale;
                    }
                }
            };
            if parallel {
                jobs.push(Box::new(job));
            } else {
                job();
            }
        }
        pool::run_jobs(jobs);
        BlockDiagF { d: self.d, k: self.k, blocks }
    }

    pub fn trace(&self) -> f32 {
        self.blocks.iter().map(|b| b.trace()).sum()
    }

    pub fn for_each(&self, f: &mut impl FnMut(f32)) {
        for b in &self.blocks {
            b.data().iter().for_each(|&x| f(x));
        }
    }

    pub fn for_each_mut(&mut self, f: &mut impl FnMut(&mut f32)) {
        for b in &mut self.blocks {
            b.data_mut().iter_mut().for_each(&mut *f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_blocks() {
        let b = BlockDiagF::identity(10, 4); // blocks 4,4,2
        assert_eq!(b.blocks.len(), 3);
        assert_eq!(b.blocks[2].rows(), 2);
        assert_eq!(b.to_dense(), Mat::eye(10));
    }

    #[test]
    fn k_larger_than_d_clamps() {
        let b = BlockDiagF::identity(3, 100);
        assert_eq!(b.blocks.len(), 1);
        assert_eq!(b.blocks[0].rows(), 3);
    }
}
