//! Hierarchical Kronecker factor (Table 1, row 3).
//!
//! ```text
//!     [ A11  A12  A13 ]     A11 ∈ R^{k1×k1},  A33 ∈ R^{k2×k2} dense,
//! K = [  0   D22   0  ]     D22 ∈ R^{dm×dm} diagonal, dm = d - k1 - k2,
//!     [  0   A32  A33 ]     A12 ∈ R^{k1×dm}, A13 ∈ R^{k1×k2}, A32 ∈ R^{k2×dm}.
//! ```
//!
//! The paper constructs it from the rank-k triangular class by replacing
//! the trailing diagonal with another rank-k triangular block (Table 1
//! caption); storage is `O((k1+k2)·d)`. Closure under multiplication:
//!
//! ```text
//! P11 = A11·B11            P12 = A11·B12 + A12·D22' + A13·B32   P13 = A11·B13 + A13·B33
//! P22 = D22·D22' (diag)    P32 = A32·D22' + A33·B32             P33 = A33·B33
//! ```

use crate::tensor::{matmul, pool, Mat};

#[derive(Clone, Debug)]
pub struct HierF {
    pub d: usize,
    pub k1: usize,
    pub k2: usize,
    pub a11: Mat,
    /// `k1 × dm`
    pub a12: Mat,
    /// `k1 × k2`
    pub a13: Mat,
    /// diagonal, length `dm`
    pub d22: Vec<f32>,
    /// `k2 × dm`
    pub a32: Mat,
    pub a33: Mat,
}

impl HierF {
    pub fn identity(d: usize, k1: usize, k2: usize) -> Self {
        // Clamp so k1 + k2 <= d.
        let k1 = k1.min(d);
        let k2 = k2.min(d - k1);
        let dm = d - k1 - k2;
        HierF {
            d,
            k1,
            k2,
            a11: Mat::eye(k1),
            a12: Mat::zeros(k1, dm),
            a13: Mat::zeros(k1, k2),
            d22: vec![1.0; dm],
            a32: Mat::zeros(k2, dm),
            a33: Mat::eye(k2),
        }
    }

    #[inline]
    pub fn dm(&self) -> usize {
        self.d - self.k1 - self.k2
    }

    pub fn to_dense(&self) -> Mat {
        let (k1, k2, dm) = (self.k1, self.k2, self.dm());
        let mut m = Mat::zeros(self.d, self.d);
        for r in 0..k1 {
            for c in 0..k1 {
                m.set(r, c, self.a11.at(r, c));
            }
            for c in 0..dm {
                m.set(r, k1 + c, self.a12.at(r, c));
            }
            for c in 0..k2 {
                m.set(r, k1 + dm + c, self.a13.at(r, c));
            }
        }
        for i in 0..dm {
            m.set(k1 + i, k1 + i, self.d22[i]);
        }
        for r in 0..k2 {
            for c in 0..dm {
                m.set(k1 + dm + r, k1 + c, self.a32.at(r, c));
            }
            for c in 0..k2 {
                m.set(k1 + dm + r, k1 + dm + c, self.a33.at(r, c));
            }
        }
        m
    }

    pub fn axpy(&mut self, alpha: f32, o: &HierF) {
        assert_eq!((self.d, self.k1, self.k2), (o.d, o.k1, o.k2));
        self.a11.axpy(alpha, &o.a11);
        self.a12.axpy(alpha, &o.a12);
        self.a13.axpy(alpha, &o.a13);
        for (a, b) in self.d22.iter_mut().zip(&o.d22) {
            *a += alpha * b;
        }
        self.a32.axpy(alpha, &o.a32);
        self.a33.axpy(alpha, &o.a33);
    }

    pub fn matmul(&self, o: &HierF) -> HierF {
        assert_eq!((self.d, self.k1, self.k2), (o.d, o.k1, o.k2));
        let dm = self.dm();
        let a11 = matmul(&self.a11, &o.a11);
        // P12 = A11 B12 + A12 ⊙ d22' + A13 B32
        let mut a12 = matmul(&self.a11, &o.a12);
        for r in 0..self.k1 {
            for c in 0..dm {
                *a12.at_mut(r, c) += self.a12.at(r, c) * o.d22[c];
            }
        }
        a12 = a12.add(&matmul(&self.a13, &o.a32));
        // P13 = A11 B13 + A13 B33
        let a13 = matmul(&self.a11, &o.a13).add(&matmul(&self.a13, &o.a33));
        let d22 = self.d22.iter().zip(&o.d22).map(|(x, y)| x * y).collect();
        // P32 = A32 ⊙ d22' + A33 B32
        let mut a32 = matmul(&self.a33, &o.a32);
        for r in 0..self.k2 {
            for c in 0..dm {
                *a32.at_mut(r, c) += self.a32.at(r, c) * o.d22[c];
            }
        }
        let a33 = matmul(&self.a33, &o.a33);
        HierF { d: self.d, k1: self.k1, k2: self.k2, a11, a12, a13, d22, a32, a33 }
    }

    /// Dense products via the block formulas, `O((k1+k2)·d·m)`; rows of
    /// `X` are independent and shard across the worker pool.
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let d = self.d;
        let mut out = Mat::zeros(m, d);
        if m == 0 || d == 0 {
            return out;
        }
        let xd = x.data();
        let min_rows =
            if m * (self.k1 + self.k2 + 1) * d < super::PAR_WORK { m } else { 1 };
        pool::parallel_chunks_mut(out.data_mut(), d, min_rows, |row0, chunk| {
            for (li, or) in chunk.chunks_mut(d).enumerate() {
                let xr = &xd[(row0 + li) * d..(row0 + li + 1) * d];
                self.right_mul_row(xr, or, transpose);
            }
        });
        out
    }

    fn right_mul_row(&self, xr: &[f32], or: &mut [f32], transpose: bool) {
        let (k1, k2, dm) = (self.k1, self.k2, self.dm());
        {
            if !transpose {
                // out1 = x1 A11; out2 = x1 A12 + x2 ⊙ d22 + x3 A32; out3 = x1 A13 + x3 A33
                for i in 0..k1 {
                    let xi = xr[i];
                    if xi == 0.0 {
                        continue;
                    }
                    for j in 0..k1 {
                        or[j] += xi * self.a11.at(i, j);
                    }
                    for j in 0..dm {
                        or[k1 + j] += xi * self.a12.at(i, j);
                    }
                    for j in 0..k2 {
                        or[k1 + dm + j] += xi * self.a13.at(i, j);
                    }
                }
                for j in 0..dm {
                    or[k1 + j] += xr[k1 + j] * self.d22[j];
                }
                for i in 0..k2 {
                    let xi = xr[k1 + dm + i];
                    if xi == 0.0 {
                        continue;
                    }
                    for j in 0..dm {
                        or[k1 + j] += xi * self.a32.at(i, j);
                    }
                    for j in 0..k2 {
                        or[k1 + dm + j] += xi * self.a33.at(i, j);
                    }
                }
            } else {
                // (X Kᵀ)[j] = Σ_i x[i] K[j][i] = dot(x, row j of K).
                for j in 0..k1 {
                    let mut acc = 0.0f32;
                    for i in 0..k1 {
                        acc += xr[i] * self.a11.at(j, i);
                    }
                    for i in 0..dm {
                        acc += xr[k1 + i] * self.a12.at(j, i);
                    }
                    for i in 0..k2 {
                        acc += xr[k1 + dm + i] * self.a13.at(j, i);
                    }
                    or[j] = acc;
                }
                // Row k1+j of K has only the diagonal entry d22[j].
                for j in 0..dm {
                    or[k1 + j] = xr[k1 + j] * self.d22[j];
                }
                // Row k1+dm+j of K = (0, A32[j,:], A33[j,:]).
                for j in 0..k2 {
                    let mut acc = 0.0f32;
                    for i in 0..dm {
                        acc += xr[k1 + i] * self.a32.at(j, i);
                    }
                    for i in 0..k2 {
                        acc += xr[k1 + dm + i] * self.a33.at(j, i);
                    }
                    or[k1 + dm + j] = acc;
                }
            }
        }
    }

    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        // K @ X = (Xᵀ @ Kᵀ)ᵀ — reuse right_mul with flipped transpose.
        let xt = x.transpose();
        self.right_mul(&xt, !transpose).transpose()
    }

    /// `Π̂(scale·BᵀB) = [[M11, 2M12, 2M13],[0, Diag(M22), 0],[0, 2M32, M33]]`
    /// computed from `B` in `O(m (k1+k2) d)` (Table 1, row 3).
    ///
    /// Large batches split into [`super::GRAM_SHARDS`] row shards whose
    /// partial projections are reduced in shard order; the shard count
    /// depends only on the problem size (never the thread count), so
    /// pooled and serial runs produce identical results.
    pub fn gram_project(&self, b: &Mat, scale: f32) -> HierF {
        let m = b.rows();
        let (k1, k2) = (self.k1, self.k2);
        let zeros_like = || {
            let mut z = HierF::identity(self.d, k1, k2);
            z.a11 = Mat::zeros(k1, k1);
            z.a13 = Mat::zeros(k1, k2);
            z.d22 = vec![0.0; z.dm()];
            z.a33 = Mat::zeros(k2, k2);
            z
        };
        let shards = if m * (k1 + k2 + 1) * self.d >= super::PAR_WORK {
            super::GRAM_SHARDS.min(m.max(1))
        } else {
            1
        };
        if shards <= 1 {
            let mut out = zeros_like();
            Self::gram_accumulate(&mut out, b, 0, m);
            out.for_each_mut(&mut |x| *x *= scale);
            return out;
        }
        let rows_per = m.div_ceil(shards);
        let mut partials: Vec<HierF> = (0..shards).map(|_| zeros_like()).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = partials
            .iter_mut()
            .enumerate()
            .map(|(s, part)| {
                Box::new(move || {
                    let r0 = s * rows_per;
                    let r1 = m.min(r0 + rows_per);
                    Self::gram_accumulate(part, b, r0, r1);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_jobs(jobs);
        let mut out = zeros_like();
        for part in &partials {
            out.axpy(1.0, part);
        }
        out.for_each_mut(&mut |x| *x *= scale);
        out
    }

    /// Accumulate the unscaled projection of rows `[r0, r1)` of `B` into
    /// `out` (the per-shard body of [`Self::gram_project`]).
    fn gram_accumulate(out: &mut HierF, b: &Mat, r0: usize, r1: usize) {
        let (k1, k2, dm) = (out.k1, out.k2, out.dm());
        for r in r0..r1 {
            let br = b.row(r);
            let (b1, rest) = br.split_at(k1);
            let (b2, b3) = rest.split_at(dm);
            for i in 0..k1 {
                let bi = b1[i];
                if bi == 0.0 {
                    continue;
                }
                for j in 0..k1 {
                    *out.a11.at_mut(i, j) += bi * b1[j];
                }
                for j in 0..dm {
                    *out.a12.at_mut(i, j) += 2.0 * bi * b2[j];
                }
                for j in 0..k2 {
                    *out.a13.at_mut(i, j) += 2.0 * bi * b3[j];
                }
            }
            for j in 0..dm {
                out.d22[j] += b2[j] * b2[j];
            }
            for i in 0..k2 {
                let bi = b3[i];
                if bi == 0.0 {
                    continue;
                }
                for j in 0..dm {
                    *out.a32.at_mut(i, j) += 2.0 * bi * b2[j];
                }
                for j in 0..k2 {
                    *out.a33.at_mut(i, j) += bi * b3[j];
                }
            }
        }
    }

    pub fn trace(&self) -> f32 {
        self.a11.trace() + self.d22.iter().sum::<f32>() + self.a33.trace()
    }

    pub fn for_each(&self, f: &mut impl FnMut(f32)) {
        self.a11.data().iter().for_each(|&x| f(x));
        self.a12.data().iter().for_each(|&x| f(x));
        self.a13.data().iter().for_each(|&x| f(x));
        self.d22.iter().for_each(|&x| f(x));
        self.a32.data().iter().for_each(|&x| f(x));
        self.a33.data().iter().for_each(|&x| f(x));
    }

    pub fn for_each_mut(&mut self, f: &mut impl FnMut(&mut f32)) {
        self.a11.data_mut().iter_mut().for_each(&mut *f);
        self.a12.data_mut().iter_mut().for_each(&mut *f);
        self.a13.data_mut().iter_mut().for_each(&mut *f);
        self.d22.iter_mut().for_each(&mut *f);
        self.a32.data_mut().iter_mut().for_each(&mut *f);
        self.a33.data_mut().iter_mut().for_each(&mut *f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_identity() {
        assert_eq!(HierF::identity(9, 3, 2).to_dense(), Mat::eye(9));
    }

    #[test]
    fn degenerate_middle_block() {
        // k1 + k2 == d leaves dm == 0.
        let h = HierF::identity(5, 3, 2);
        assert_eq!(h.dm(), 0);
        assert_eq!(h.to_dense(), Mat::eye(5));
    }
}
