//! Rank-k triangular Kronecker factor (Table 1, row 4; Fig. 8).
//!
//! ```text
//! K = [ A11  A12 ]      A11 ∈ R^{k×k} dense,
//!     [  0   D22 ]      D22 ∈ R^{(d-k)×(d-k)} diagonal.
//! ```
//!
//! Storage `O(kd)`. The class is closed under multiplication:
//! `[[A,B],[0,D]]·[[A',B'],[0,D']] = [[AA', AB' + BD'],[0, DD']]` and `DD'`
//! stays diagonal. With `k = 1` this gives the diagonal-plus-rank-one
//! structure of `K Kᵀ` shown in Fig. 8.

use crate::tensor::{matmul, pool, Mat};

#[derive(Clone, Debug)]
pub struct RankKF {
    pub d: usize,
    pub k: usize,
    /// Top-left dense block, `k×k`.
    pub a11: Mat,
    /// Top-right dense block, `k×(d-k)`.
    pub a12: Mat,
    /// Trailing diagonal, length `d-k`.
    pub d22: Vec<f32>,
}

impl RankKF {
    pub fn identity(d: usize, k: usize) -> Self {
        let k = k.min(d);
        RankKF { d, k, a11: Mat::eye(k), a12: Mat::zeros(k, d - k), d22: vec![1.0; d - k] }
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.d);
        for r in 0..self.k {
            for c in 0..self.k {
                m.set(r, c, self.a11.at(r, c));
            }
            for c in 0..self.d - self.k {
                m.set(r, self.k + c, self.a12.at(r, c));
            }
        }
        for i in 0..self.d - self.k {
            m.set(self.k + i, self.k + i, self.d22[i]);
        }
        m
    }

    pub fn axpy(&mut self, alpha: f32, o: &RankKF) {
        assert_eq!((self.d, self.k), (o.d, o.k));
        self.a11.axpy(alpha, &o.a11);
        self.a12.axpy(alpha, &o.a12);
        for (a, b) in self.d22.iter_mut().zip(&o.d22) {
            *a += alpha * b;
        }
    }

    pub fn matmul(&self, o: &RankKF) -> RankKF {
        assert_eq!((self.d, self.k), (o.d, o.k));
        // [[A,B],[0,D]]·[[A',B'],[0,D']] = [[AA', AB' + B·D'],[0, DD']]
        let a11 = matmul(&self.a11, &o.a11);
        let mut a12 = matmul(&self.a11, &o.a12);
        for r in 0..self.k {
            for c in 0..self.d - self.k {
                *a12.at_mut(r, c) += self.a12.at(r, c) * o.d22[c];
            }
        }
        let d22 = self.d22.iter().zip(&o.d22).map(|(x, y)| x * y).collect();
        RankKF { d: self.d, k: self.k, a11, a12, d22 }
    }

    /// `X @ K` / `X @ Kᵀ` in `O(m k d)`; rows of `X` are independent and
    /// shard across the worker pool.
    pub fn right_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let m = x.rows();
        let d = self.d;
        let mut out = Mat::zeros(m, d);
        if m == 0 || d == 0 {
            return out;
        }
        let xd = x.data();
        let min_rows = if m * (self.k + 1) * d < super::PAR_WORK { m } else { 1 };
        pool::parallel_chunks_mut(out.data_mut(), d, min_rows, |row0, chunk| {
            for (li, or) in chunk.chunks_mut(d).enumerate() {
                let xr = &xd[(row0 + li) * d..(row0 + li + 1) * d];
                self.right_mul_row(xr, or, transpose);
            }
        });
        out
    }

    fn right_mul_row(&self, xr: &[f32], or: &mut [f32], transpose: bool) {
        let (d, k) = (self.d, self.k);
        {
            if !transpose {
                // out[0..k] = x[0..k] @ A11 ; out[k..] = x[0..k] @ A12 + x[k..] ⊙ d22
                for i in 0..k {
                    let xi = xr[i];
                    if xi == 0.0 {
                        continue;
                    }
                    for j in 0..k {
                        or[j] += xi * self.a11.at(i, j);
                    }
                    for j in 0..d - k {
                        or[k + j] += xi * self.a12.at(i, j);
                    }
                }
                for j in 0..d - k {
                    or[k + j] += xr[k + j] * self.d22[j];
                }
            } else {
                // Kᵀ = [[A11ᵀ, 0],[A12ᵀ, D]]
                // out[0..k] = x[0..k] @ A11ᵀ + x[k..] @ A12ᵀ ; out[k..] = x[k..] ⊙ d22
                for j in 0..k {
                    let mut acc = 0.0f32;
                    for i in 0..k {
                        acc += xr[i] * self.a11.at(j, i);
                    }
                    for i in 0..d - k {
                        acc += xr[k + i] * self.a12.at(j, i);
                    }
                    or[j] = acc;
                }
                for j in 0..d - k {
                    or[k + j] = xr[k + j] * self.d22[j];
                }
            }
        }
    }

    /// `K @ X` / `Kᵀ @ X` in `O(k d n)` — light enough (`k ≪ d`) that it
    /// stays on the caller; the dominant per-step cost for this class is
    /// `right_mul`/`gram_project`, which do shard.
    pub fn left_mul(&self, x: &Mat, transpose: bool) -> Mat {
        let n = x.cols();
        let (d, k) = (self.d, self.k);
        let mut out = Mat::zeros(d, n);
        if !transpose {
            // rows 0..k: A11 x[0..k] + A12 x[k..]; rows k..: d22 ⊙ x[k..]
            for r in 0..k {
                let orow = out.row_mut(r);
                for p in 0..k {
                    let v = self.a11.at(r, p);
                    if v == 0.0 {
                        continue;
                    }
                    let xrow = x.row(p);
                    for c in 0..n {
                        orow[c] += v * xrow[c];
                    }
                }
                for p in 0..d - k {
                    let v = self.a12.at(r, p);
                    if v == 0.0 {
                        continue;
                    }
                    let xrow = x.row(k + p);
                    for c in 0..n {
                        orow[c] += v * xrow[c];
                    }
                }
            }
            for i in 0..d - k {
                let v = self.d22[i];
                let xrow = x.row(k + i);
                let orow = out.row_mut(k + i);
                for c in 0..n {
                    orow[c] = v * xrow[c];
                }
            }
        } else {
            // Kᵀ rows 0..k: A11ᵀ x[0..k]; rows k..: A12ᵀ x[0..k] + d22 ⊙ x[k..]
            for p in 0..k {
                let xrow = x.row(p);
                for r in 0..k {
                    let v = self.a11.at(p, r);
                    if v == 0.0 {
                        continue;
                    }
                    let orow = out.row_mut(r);
                    for c in 0..n {
                        orow[c] += v * xrow[c];
                    }
                }
                for r in 0..d - k {
                    let v = self.a12.at(p, r);
                    if v == 0.0 {
                        continue;
                    }
                    let orow = out.row_mut(k + r);
                    for c in 0..n {
                        orow[c] += v * xrow[c];
                    }
                }
            }
            for i in 0..d - k {
                let v = self.d22[i];
                let xrow = x.row(k + i);
                let orow = out.row_mut(k + i);
                for c in 0..n {
                    orow[c] += v * xrow[c];
                }
            }
        }
        out
    }

    /// `Π̂(scale · BᵀB) = [[M11, 2·M12],[0, Diag(M22)]]` computed from `B`
    /// in `O(m k d)` (Table 1, row 4).
    ///
    /// Large batches split into [`super::GRAM_SHARDS`] row shards whose
    /// partials are reduced in shard order; the shard count depends only
    /// on the problem size, so pooled and serial runs match exactly.
    pub fn gram_project(&self, b: &Mat, scale: f32) -> RankKF {
        let m = b.rows();
        let (d, k) = (self.d, self.k);
        let zeros_like = || RankKF {
            d,
            k,
            a11: Mat::zeros(k, k),
            a12: Mat::zeros(k, d - k),
            d22: vec![0.0f32; d - k],
        };
        let shards = if m * (k + 1) * d >= super::PAR_WORK {
            super::GRAM_SHARDS.min(m.max(1))
        } else {
            1
        };
        let mut out = zeros_like();
        if shards <= 1 {
            Self::gram_accumulate(&mut out, b, 0, m);
        } else {
            let rows_per = m.div_ceil(shards);
            let mut partials: Vec<RankKF> = (0..shards).map(|_| zeros_like()).collect();
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = partials
                .iter_mut()
                .enumerate()
                .map(|(s, part)| {
                    Box::new(move || {
                        let r0 = s * rows_per;
                        let r1 = m.min(r0 + rows_per);
                        Self::gram_accumulate(part, b, r0, r1);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool::run_jobs(jobs);
            for part in &partials {
                out.axpy(1.0, part);
            }
        }
        out.for_each_mut(&mut |x| *x *= scale);
        out
    }

    /// Accumulate the unscaled projection of rows `[r0, r1)` of `B` into
    /// `out` (the per-shard body of [`Self::gram_project`]).
    fn gram_accumulate(out: &mut RankKF, b: &Mat, r0: usize, r1: usize) {
        let (d, k) = (out.d, out.k);
        for r in r0..r1 {
            let br = b.row(r);
            for i in 0..k {
                let bi = br[i];
                if bi != 0.0 {
                    for j in 0..k {
                        *out.a11.at_mut(i, j) += bi * br[j];
                    }
                    for j in 0..d - k {
                        *out.a12.at_mut(i, j) += 2.0 * bi * br[k + j];
                    }
                }
            }
            for j in 0..d - k {
                out.d22[j] += br[k + j] * br[k + j];
            }
        }
    }

    pub fn trace(&self) -> f32 {
        self.a11.trace() + self.d22.iter().sum::<f32>()
    }

    pub fn for_each(&self, f: &mut impl FnMut(f32)) {
        self.a11.data().iter().for_each(|&x| f(x));
        self.a12.data().iter().for_each(|&x| f(x));
        self.d22.iter().for_each(|&x| f(x));
    }

    pub fn for_each_mut(&mut self, f: &mut impl FnMut(&mut f32)) {
        self.a11.data_mut().iter_mut().for_each(&mut *f);
        self.a12.data_mut().iter_mut().for_each(&mut *f);
        self.d22.iter_mut().for_each(&mut *f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_identity() {
        assert_eq!(RankKF::identity(6, 2).to_dense(), Mat::eye(6));
    }

    #[test]
    fn closure_blocks() {
        let mut a = RankKF::identity(5, 2);
        a.a12.set(0, 1, 3.0);
        a.d22[1] = 2.0;
        let p = a.matmul(&a);
        // (0, 2+1=3): A11·A12 + A12·D22 → 3 + 3·2 = 9
        assert_eq!(p.to_dense().at(0, 3), 9.0);
        assert_eq!(p.d22[1], 4.0);
    }
}
