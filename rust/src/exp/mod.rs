//! Experiment drivers — one per paper table/figure (DESIGN.md §5 maps each
//! experiment id to the bench target that regenerates it).
//!
//! The drivers glue [`JobConfig`] → dataset → model → [`crate::train::train_dist`]
//! and provide the comparison loops (method × precision grids) that the
//! `rust/benches/fig*` targets print.

use crate::config::{Arch, JobConfig};
use crate::data::{self, Dataset};
use crate::model::cnn::{Cnn, ImgShape};
use crate::model::transformer::{Embed, Transformer, TransformerCfg};
use crate::model::{Mlp, Model};
use crate::optim::{Hyper, Method};
use crate::proptest::Pcg;
use crate::train::{train_dist, DistCfg, RunResult, Schedule, TrainCfg};

/// Instantiate the dataset a job asks for.
pub fn build_dataset(cfg: &JobConfig, rng: &mut Pcg) -> Dataset {
    match cfg.dataset.as_str() {
        "imagewoof" => data::imagewoof(rng, cfg.n_train, cfg.n_test),
        // default: synthetic CIFAR-100 stand-in
        _ => data::cifar100(rng, cfg.classes, cfg.n_train, cfg.n_test),
    }
}

/// Instantiate the model a job asks for (image models only; GCN has its own
/// driver below).
pub fn build_model(cfg: &JobConfig, shape: ImgShape, classes: usize, rng: &mut Pcg) -> Box<dyn Model> {
    match &cfg.arch {
        Arch::Mlp { hidden } => {
            let mut dims = vec![shape.len()];
            dims.extend_from_slice(hidden);
            dims.push(classes);
            Box::new(Mlp::new(rng, &dims))
        }
        Arch::Vgg { width } => Box::new(Cnn::vgg(rng, shape, *width, classes)),
        Arch::ConvMixer { patch, width, depth } => {
            Box::new(Cnn::convmixer(rng, shape, *patch, *width, *depth, classes))
        }
        Arch::Vit { dim, depth, patch } => Box::new(Transformer::new(
            rng,
            TransformerCfg {
                embed: Embed::Patch { img: shape, patch: *patch },
                dim: *dim,
                depth: *depth,
                mlp_ratio: 2,
                out: classes,
                causal_lm: false,
            },
        )),
        Arch::Gcn { .. } => panic!("GCN uses run_gcn, not build_model"),
    }
}

/// Run one image-classification job end to end. Jobs with `ranks > 1`
/// run under the deterministic data-parallel driver
/// ([`crate::train::train_dist`]) over the configured transport
/// (in-process `local` or multi-process `socket`); `ranks = 1` is the
/// serial path.
pub fn run_job(cfg: &JobConfig) -> RunResult {
    // `[obs] log` overrides the SINGD_LOG / worker-default level for the
    // whole process — observability config, never training math.
    if let Some(level) = cfg.log {
        crate::obs::log::set_level(level);
    }
    let mut rng = Pcg::with_stream(cfg.seed, 0xda7a);
    let ds = build_dataset(cfg, &mut rng);
    let mut model = build_model(cfg, ds.shape, ds.classes, &mut rng);
    let tc = TrainCfg {
        method: cfg.method.clone(),
        hyper: cfg.hyper.clone(),
        schedule: cfg.schedule.clone(),
        epochs: cfg.epochs,
        batch_size: cfg.batch_size,
        seed: cfg.seed,
        eval_every: 0,
        stop_on_divergence: true,
        resume: cfg.resume.as_ref().map(std::path::PathBuf::from),
        ckpt: cfg.ckpt.as_ref().map(std::path::PathBuf::from),
        ckpt_every: cfg.ckpt_every,
        accum_steps: cfg.accum_steps,
        trace_dir: cfg.trace_dir.as_ref().map(std::path::PathBuf::from),
    };
    let dc = DistCfg {
        ranks: cfg.ranks,
        strategy: cfg.dist_strategy,
        transport: cfg.transport,
        algo: cfg.algo,
        overlap: cfg.overlap,
        stream: cfg.stream,
        wire_dtype: cfg.wire_dtype,
        elastic: cfg.elastic,
    };
    train_dist(model.as_mut(), &ds, &tc, &dc)
}

/// A (method, precision) comparison grid over a shared dataset/model —
/// the shape of Figs. 1, 6 and 7. Returns `(label, RunResult)` per cell.
pub fn run_grid(
    base: &JobConfig,
    methods: &[(Method, Hyper)],
    precisions: &[&str],
) -> Vec<(String, RunResult)> {
    let mut out = Vec::new();
    for (method, hyper) in methods {
        for prec in precisions {
            let mut cfg = base.clone();
            cfg.method = method.clone();
            cfg.hyper = hyper.clone();
            cfg.hyper.policy = crate::numerics::Policy::parse(prec).expect("precision");
            let label = format!("{}-{}", method.name(), prec);
            let res = run_job(&cfg);
            crate::obs_info!(
                "{label:<28} final_err={:.3} best={:.3} diverged={} bytes={} wall={:.1}s {}",
                res.final_test_err,
                res.best_test_err,
                res.diverged,
                res.optimizer_bytes,
                res.wall_secs,
                res.telemetry
            );
            out.push((label, res));
        }
    }
    out
}

/// GCN node-classification driver (Fig. 7, right).
pub fn run_gcn(
    method: &Method,
    hyper: &Hyper,
    steps: usize,
    seed: u64,
) -> (Vec<(usize, f32, f32)>, bool) {
    use crate::model::gcn::Gcn;
    let mut rng = Pcg::with_stream(seed, 0xc04a);
    let g = data::cora(&mut rng, 300, 32, 7, 8.0);
    let mut net = Gcn::new(&mut rng, g.x.cols(), 16, 7);
    let mut opt = method.build(&net.shapes(), hyper);
    let mut curve = Vec::new();
    let mut diverged = false;
    for t in 0..steps {
        let res = net.forward_backward_graph(&g, &g.train_mask);
        opt.step(t, net.params_mut(), &res.grads, &res.stats);
        diverged |= !res.loss.is_finite() || opt.diverged();
        if t % 10 == 0 || t + 1 == steps {
            let (test_loss, correct) = net.evaluate_graph(&g, &g.test_mask);
            let err = 1.0 - correct as f32 / g.test_mask.len() as f32;
            curve.push((t, test_loss, err));
        }
        if diverged {
            curve.push((t, f32::NAN, 1.0));
            break;
        }
    }
    (curve, diverged)
}

/// Default hyper-parameters per method family, scaled for the synthetic
/// workloads (stand-ins for the paper's random-search winners, Table 4).
pub fn default_hyper(method: &Method, policy_eps_scale: bool) -> Hyper {
    let mut hp = match method {
        Method::Sgd => Hyper { lr: 0.05, momentum: 0.9, weight_decay: 1e-4, ..Hyper::default() },
        Method::AdamW => Hyper {
            lr: 3e-3,
            momentum: 0.9,
            precond_lr: 0.02,
            weight_decay: 1e-4,
            eps: 1e-8,
            ..Hyper::default()
        },
        // Second-order defaults: the random-search winners on the synthetic
        // workloads land at large damping (λ ≈ 0.1 is inside the paper's
        // Table-4 search range) with a modest lr and an RMS update clip —
        // see EXPERIMENTS.md §Tuning for the probe log.
        Method::Kfac => Hyper {
            lr: 0.01,
            momentum: 0.9,
            precond_lr: 0.1,
            damping: 0.1,
            weight_decay: 1e-2,
            t_update: 5,
            update_clip: 0.05,
            ..Hyper::default()
        },
        Method::Ikfac { .. } => Hyper {
            lr: 0.01,
            momentum: 0.9,
            precond_lr: 0.05,
            damping: 0.1,
            weight_decay: 1e-2,
            t_update: 5,
            update_clip: 0.05,
            ..Hyper::default()
        },
        Method::Singd { .. } => Hyper {
            lr: 0.01,
            momentum: 0.9,
            precond_lr: 0.05,
            riem_momentum: 0.6,
            damping: 0.1,
            weight_decay: 1e-2,
            t_update: 5,
            update_clip: 0.05,
            ..Hyper::default()
        },
        // Sketched KFAC shares the KFAC-family winners: the Woodbury core
        // inverts through the same λ, so the same heavy damping applies.
        Method::RkFac { .. } => Hyper {
            lr: 0.01,
            momentum: 0.9,
            precond_lr: 0.1,
            damping: 0.1,
            weight_decay: 1e-2,
            t_update: 5,
            update_clip: 0.05,
            ..Hyper::default()
        },
        // MAC behaves first-order in all directions orthogonal to the mean
        // activation, so it tunes like SGD with a curvature damping knob.
        Method::Mac => Hyper {
            lr: 0.05,
            momentum: 0.9,
            precond_lr: 0.1,
            damping: 0.1,
            weight_decay: 1e-4,
            t_update: 5,
            ..Hyper::default()
        },
    };
    if policy_eps_scale {
        // Half precision cannot resolve damping below the rounding scale.
        hp.damping = hp.damping.max(1e-3);
    }
    hp
}

/// The standard figure schedule: cosine over the run.
pub fn cosine_for(epochs: usize, n_train: usize, batch: usize) -> Schedule {
    Schedule::Cosine { total: epochs * (n_train / batch.max(1)).max(1) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structured::Structure;

    fn tiny_job(method: Method) -> JobConfig {
        JobConfig {
            arch: Arch::Mlp { hidden: vec![24] },
            dataset: "cifar100".into(),
            classes: 4,
            n_train: 160,
            n_test: 48,
            method: method.clone(),
            hyper: default_hyper(&method, false),
            schedule: Schedule::Constant,
            epochs: 3,
            batch_size: 32,
            seed: 3,
            label: "test".into(),
            ranks: 1,
            dist_strategy: crate::dist::DistStrategy::Replicated,
            transport: crate::dist::Transport::Local,
            algo: crate::dist::default_algo(),
            overlap: crate::dist::default_overlap(),
            stream: crate::dist::default_stream(),
            wire_dtype: crate::numerics::Dtype::F32,
            resume: None,
            ckpt: None,
            ckpt_every: 0,
            accum_steps: 1,
            elastic: false,
            trace_dir: None,
            log: None,
        }
    }

    #[test]
    fn run_job_with_ranks_matches_serial_bitwise() {
        // The exp-level rank-invariance check (full suite in
        // rust/tests/dist.rs): same job, ranks 1 vs 4, identical curves.
        let mut serial = tiny_job(Method::Singd { structure: Structure::Diagonal });
        serial.epochs = 2;
        let mut dist4 = serial.clone();
        dist4.ranks = 4;
        let a = run_job(&serial);
        let b = run_job(&dist4);
        assert_eq!(a.rows.len(), b.rows.len());
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits(), "step {}", ra.step);
            assert_eq!(ra.test_err.to_bits(), rb.test_err.to_bits(), "step {}", ra.step);
        }
    }

    #[test]
    fn run_job_mlp_improves() {
        let res = run_job(&tiny_job(Method::Sgd));
        assert!(!res.diverged);
        assert!(res.rows.last().unwrap().test_err < 0.8);
    }

    #[test]
    fn run_grid_produces_all_cells() {
        let base = tiny_job(Method::Sgd);
        let methods = vec![
            (Method::Sgd, default_hyper(&Method::Sgd, false)),
            (
                Method::Singd { structure: Structure::Diagonal },
                default_hyper(&Method::Singd { structure: Structure::Diagonal }, false),
            ),
        ];
        let grid = run_grid(&base, &methods, &["fp32", "bf16"]);
        assert_eq!(grid.len(), 4);
        assert!(grid.iter().any(|(l, _)| l == "singd:diag-bf16"));
    }

    #[test]
    fn run_gcn_learns() {
        let m = Method::Sgd;
        let hp = Hyper { lr: 0.3, momentum: 0.9, ..Hyper::default() };
        let (curve, diverged) = run_gcn(&m, &hp, 120, 5);
        assert!(!diverged);
        let first = curve.first().unwrap().2;
        let last = curve.last().unwrap().2;
        assert!(last < first, "gcn err {first} -> {last}");
    }
}
