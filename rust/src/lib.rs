//! # SINGD — Structured Inverse-Free Natural Gradient Descent
//!
//! A Rust + JAX + Pallas reproduction of *"Structured Inverse-Free Natural
//! Gradient: Memory-Efficient & Numerically-Stable KFAC for Large Neural
//! Nets"* (Lin et al., 2023).
//!
//! The crate is organised as a small training framework (the Layer-3
//! coordinator of the three-layer architecture):
//!
//! - [`tensor`] — dense `f32` matrix substrate (BLAS-free, blocked matmul).
//! - [`numerics`] — software BF16/FP16 emulation and precision policies;
//!   the numeric-format substrate that reproduces the paper's
//!   half-precision (in)stability results.
//! - [`linalg`] — Cholesky, triangular solves, inversion, truncated matrix
//!   exponential (the KFAC baseline needs real inversion; SINGD does not).
//! - [`structured`] — the paper's Lie-group structure classes for Kronecker
//!   factors (Table 1, Figs. 5/8) and their subspace projection maps.
//! - [`optim`] — SGD, AdamW, KFAC, IKFAC, INGD and SINGD (Figs. 3/4/9).
//! - [`model`] — pure-Rust reference models (MLP, CNN, transformer, GCN)
//!   whose backward pass also emits per-layer Kronecker factors `(U, G)`.
//! - [`data`] — synthetic dataset generators (class-prototype images,
//!   stochastic-block-model graphs, token streams) and a PCG RNG.
//! - [`dist`] — deterministic in-process collectives (fixed reduction
//!   trees, bitwise rank-invariance) and ZeRO-style sharding of the
//!   Kronecker factors across ranks.
//! - [`runtime`] — PJRT client wrapper that loads AOT-compiled HLO-text
//!   artifacts (produced by `python/compile/aot.py`) and executes them.
//! - [`train`] — training-loop driver, LR schedules, metrics, checkpoints,
//!   memory accounting.
//! - [`obs`] — observability: leveled logging, a process-wide metrics
//!   registry, and a per-rank span tracer (JSONL + Chrome `trace_event`
//!   export) under a strict non-interference contract.
//! - [`config`] — typed configuration + minimal TOML-subset parser.
//! - [`sweep`] — random hyperparameter search (paper Table 4).
//! - [`exp`] — one driver per paper table/figure.
//! - [`bench`] — a small statistics-reporting benchmark harness (criterion
//!   is unavailable offline).
//! - [`proptest`] — seeded randomized property-testing helpers.

pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod dist;
pub mod exp;
pub mod linalg;
pub mod model;
pub mod numerics;
pub mod obs;
pub mod optim;
pub mod proptest;
pub mod runtime;
pub mod structured;
pub mod sweep;
pub mod tensor;
pub mod train;

pub use tensor::Mat;
