//! Two-layer graph convolutional network (Kipf & Welling, 2016) for node
//! classification — the paper's "GNN on Cora" model (Fig. 7, right).
//!
//! `Z₁ = Â X W₁ᵀ, H₁ = ReLU(Z₁), Z₂ = Â H₁ W₂ᵀ`, softmax-CE on the
//! training-mask nodes. `Â = D^{-1/2}(A + I)D^{-1/2}` is precomputed by
//! [`crate::data::cora`]. Nodes act as the batch dimension for the
//! Kronecker statistics.

use super::{
    layer_backward_span, relu, relu_bwd, softmax_xent, BackwardResult, Batch, LayerEvent,
    LayerHook, Linear, Model,
};
use crate::proptest::Pcg;
use crate::tensor::{matmul, Mat};

/// A node-classification graph dataset.
#[derive(Clone)]
pub struct Graph {
    /// Symmetric-normalized adjacency with self loops, `n × n`.
    pub adj: Mat,
    /// Node features, `n × f`.
    pub x: Mat,
    /// Node labels, length `n`.
    pub y: Vec<usize>,
    /// Training node indices.
    pub train_mask: Vec<usize>,
    /// Test node indices.
    pub test_mask: Vec<usize>,
}

pub struct Gcn {
    params: Vec<Mat>,
    shapes: Vec<(usize, usize)>,
}

impl Gcn {
    pub fn new(rng: &mut Pcg, features: usize, hidden: usize, classes: usize) -> Self {
        let params = vec![Linear::init(rng, hidden, features), Linear::init(rng, classes, hidden)];
        let shapes = vec![(hidden, features + 1), (classes, hidden + 1)];
        Gcn { params, shapes }
    }

    fn forward_cached(&self, g: &Graph) -> (Mat, Mat, Mat, Mat, Mat) {
        // agg0 = Â X; Z1 = lin1(agg0); H1 = relu(Z1); agg1 = Â H1; Z2 = lin2(agg1)
        let agg0 = matmul(&g.adj, &g.x);
        let (z1, xb1) = Linear::forward(&self.params[0], &agg0);
        let h1 = relu(&z1);
        let agg1 = matmul(&g.adj, &h1);
        let (z2, xb2) = Linear::forward(&self.params[1], &agg1);
        (xb1, z1, xb2, z2, agg1)
    }

    /// Full-graph forward/backward with masked loss
    /// ([`Gcn::forward_backward_graph_hooked`] with a no-op hook).
    pub fn forward_backward_graph(&self, g: &Graph, mask: &[usize]) -> BackwardResult {
        self.forward_backward_graph_hooked(g, mask, &mut |_| {})
    }

    /// Full-graph forward/backward with masked loss, delivering each
    /// layer's completion through `hook` (the graph counterpart of
    /// [`Model::forward_backward_hooked`]; same bitwise-transparency
    /// contract).
    pub fn forward_backward_graph_hooked(
        &self,
        g: &Graph,
        mask: &[usize],
        hook: &mut LayerHook<'_>,
    ) -> BackwardResult {
        let (xb1, z1, xb2, z2, _agg1) = self.forward_cached(g);
        // Masked CE: gather masked logits, scatter gradients back.
        let mm = mask.len();
        let logits = Mat::from_fn(mm, z2.cols(), |r, c| z2.at(mask[r], c));
        let labels: Vec<usize> = mask.iter().map(|&i| g.y[i]).collect();
        let (loss_sum, correct, dmasked) = super::softmax_xent_sum(&logits, &labels);
        let loss = (loss_sum / mm.max(1) as f64) as f32;
        let mut dz2 = Mat::zeros(z2.rows(), z2.cols());
        for (r, &node) in mask.iter().enumerate() {
            for c in 0..z2.cols() {
                *dz2.at_mut(node, c) = dmasked.at(r, c);
            }
        }
        let lb = layer_backward_span(1);
        let (g2, dagg1, st2) = Linear::backward(&self.params[1], &xb2, &dz2);
        hook(LayerEvent { layer_id: 1, grad: &g2, kron_stats: &st2 });
        drop(lb);
        // dH1 = Âᵀ dagg1 (Â symmetric).
        let dh1 = matmul(&g.adj, &dagg1);
        let dz1 = relu_bwd(&z1, &dh1);
        let lb = layer_backward_span(0);
        let (g1, _dx, st1) = Linear::backward(&self.params[0], &xb1, &dz1);
        hook(LayerEvent { layer_id: 0, grad: &g1, kron_stats: &st1 });
        drop(lb);
        BackwardResult {
            loss,
            correct,
            grads: vec![g1, g2],
            stats: vec![st1, st2],
            loss_sum,
            loss_rows: mm,
        }
    }

    pub fn evaluate_graph(&self, g: &Graph, mask: &[usize]) -> (f32, usize) {
        let (_, _, _, z2, _) = self.forward_cached(g);
        let logits = Mat::from_fn(mask.len(), z2.cols(), |r, c| z2.at(mask[r], c));
        let labels: Vec<usize> = mask.iter().map(|&i| g.y[i]).collect();
        let (loss, correct, _) = softmax_xent(&logits, &labels);
        (loss, correct)
    }
}

impl Model for Gcn {
    fn shapes(&self) -> Vec<(usize, usize)> {
        self.shapes.clone()
    }

    fn params_mut(&mut self) -> &mut Vec<Mat> {
        &mut self.params
    }

    fn params(&self) -> &Vec<Mat> {
        &self.params
    }

    /// The generic [`Model`] entry points are not used for graphs (the
    /// graph does not fit the flat [`Batch`] layout); the Fig. 7 driver
    /// calls [`Gcn::forward_backward_graph`] /
    /// [`Gcn::forward_backward_graph_hooked`].
    fn forward_backward_hooked(&self, _batch: &Batch, _hook: &mut LayerHook<'_>) -> BackwardResult {
        unimplemented!("use forward_backward_graph_hooked");
    }

    fn evaluate(&self, _batch: &Batch) -> (f32, usize) {
        unimplemented!("use evaluate_graph");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_graph(rng: &mut Pcg) -> Graph {
        crate::data::cora(rng, 90, 12, 3, 6.0)
    }

    #[test]
    fn gcn_gradcheck_masked() {
        let mut rng = Pcg::new(31);
        let g = toy_graph(&mut rng);
        let mut net = Gcn::new(&mut rng, g.x.cols(), 6, 3);
        let res = net.forward_backward_graph(&g, &g.train_mask);
        // FD check a few entries.
        let eps = 1e-2f32;
        for &(l, idx) in &[(0usize, 3usize), (0, 10), (1, 5), (1, 12)] {
            let orig = net.params[l].data()[idx];
            net.params[l].data_mut()[idx] = orig + eps;
            let (lp, _) = net.evaluate_graph(&g, &g.train_mask);
            net.params[l].data_mut()[idx] = orig - eps;
            let (lm, _) = net.evaluate_graph(&g, &g.train_mask);
            net.params[l].data_mut()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = res.grads[l].data()[idx];
            assert!((fd - an).abs() < 3e-2 * (1.0 + fd.abs()), "({l},{idx}): {fd} vs {an}");
        }
    }

    #[test]
    fn gcn_hook_events_are_final_reverse_ordered_and_bitwise() {
        let mut rng = Pcg::new(33);
        let g = toy_graph(&mut rng);
        let net = Gcn::new(&mut rng, g.x.cols(), 6, 3);
        let mut order = Vec::new();
        let mut captured: Vec<Option<(Mat, crate::optim::KronStats)>> = vec![None, None];
        let hooked = net.forward_backward_graph_hooked(&g, &g.train_mask, &mut |ev| {
            assert_eq!(ev.grad.shape(), net.shapes[ev.layer_id], "layer {} grad shape", ev.layer_id);
            assert_eq!(ev.kron_stats.a.rows(), ev.kron_stats.g.rows());
            order.push(ev.layer_id);
            captured[ev.layer_id] = Some((ev.grad.clone(), ev.kron_stats.clone()));
        });
        assert_eq!(order, vec![1, 0], "head layer backward completes first");
        let plain = net.forward_backward_graph(&g, &g.train_mask);
        assert_eq!(plain.loss_sum.to_bits(), hooked.loss_sum.to_bits());
        for l in 0..2 {
            let (eg, est) = captured[l].as_ref().unwrap();
            assert_eq!(eg.data(), hooked.grads[l].data(), "layer {l}: event grad final");
            assert_eq!(est.a.data(), hooked.stats[l].a.data(), "layer {l}: event A final");
            assert_eq!(plain.grads[l].data(), hooked.grads[l].data(), "layer {l}: hook-free bitwise");
            assert_eq!(plain.stats[l].g.data(), hooked.stats[l].g.data(), "layer {l}: G bitwise");
        }
    }

    #[test]
    fn gcn_trains_on_sbm() {
        let mut rng = Pcg::new(32);
        let g = toy_graph(&mut rng);
        let mut net = Gcn::new(&mut rng, g.x.cols(), 8, 3);
        let hp = crate::optim::Hyper { lr: 0.3, momentum: 0.9, weight_decay: 1e-4, ..Default::default() };
        let mut opt = crate::optim::Method::Sgd.build(&net.shapes(), &hp);
        for t in 0..150 {
            let res = net.forward_backward_graph(&g, &g.train_mask);
            opt.step(t, &mut net.params, &res.grads, &res.stats);
        }
        let (_, correct) = net.evaluate_graph(&g, &g.test_mask);
        let acc = correct as f32 / g.test_mask.len() as f32;
        assert!(acc > 0.6, "test acc {acc}");
    }
}
