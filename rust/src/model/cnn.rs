//! Convolutional models on flattened `C×H×W` inputs, built on an im2col
//! substrate so every conv is a (weight-shared) linear layer with proper
//! KFAC-expand Kronecker statistics.
//!
//! Two architectures used by the Fig. 1 / Fig. 7 reproductions:
//!
//! - [`Cnn::vgg`] — a small VGG-style stack: 3×3 convs + ReLU + 2×2 average
//!   pooling, then a linear classifier.
//! - [`Cnn::convmixer`] — a ConvMixer-style stack: patch embedding followed
//!   by 1×1 (pointwise) mixing convs, global average pool, classifier
//!   (depthwise convs replaced by pointwise mixing — the structural point
//!   is the patch-embed + isotropic-conv topology, see DESIGN.md §3).

use super::{
    layer_backward_span, relu_bwd, softmax_xent, BackwardResult, Batch, LayerEvent, LayerHook,
    Linear, Model,
};
use crate::optim::KronStats;
use crate::proptest::Pcg;
use crate::tensor::Mat;

/// Image geometry of a conv stage.
#[derive(Clone, Copy, Debug)]
pub struct ImgShape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl ImgShape {
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// im2col: images `(m × C·H·W)` → patches `(m·H'·W' × C·k·k)`, stride `s`,
/// zero padding `p`.
pub fn im2col(x: &Mat, shape: ImgShape, k: usize, s: usize, p: usize) -> Mat {
    let (ho, wo) = out_hw(shape, k, s, p);
    let m = x.rows();
    let mut out = Mat::zeros(m * ho * wo, shape.c * k * k);
    for b in 0..m {
        let row = x.row(b);
        for oy in 0..ho {
            for ox in 0..wo {
                let orow = out.row_mut((b * ho + oy) * wo + ox);
                let mut idx = 0usize;
                for c in 0..shape.c {
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * s + ky) as isize - p as isize;
                            let ix = (ox * s + kx) as isize - p as isize;
                            orow[idx] = if iy >= 0
                                && ix >= 0
                                && (iy as usize) < shape.h
                                && (ix as usize) < shape.w
                            {
                                row[(c * shape.h + iy as usize) * shape.w + ix as usize]
                            } else {
                                0.0
                            };
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

/// col2im: scatter-add patch gradients back to image gradients.
pub fn col2im(dpatch: &Mat, m: usize, shape: ImgShape, k: usize, s: usize, p: usize) -> Mat {
    let (ho, wo) = out_hw(shape, k, s, p);
    let mut dx = Mat::zeros(m, shape.len());
    for b in 0..m {
        for oy in 0..ho {
            for ox in 0..wo {
                let prow = dpatch.row((b * ho + oy) * wo + ox);
                let drow = dx.row_mut(b);
                let mut idx = 0usize;
                for c in 0..shape.c {
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * s + ky) as isize - p as isize;
                            let ix = (ox * s + kx) as isize - p as isize;
                            if iy >= 0
                                && ix >= 0
                                && (iy as usize) < shape.h
                                && (ix as usize) < shape.w
                            {
                                drow[(c * shape.h + iy as usize) * shape.w + ix as usize] +=
                                    prow[idx];
                            }
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    dx
}

pub fn out_hw(shape: ImgShape, k: usize, s: usize, p: usize) -> (usize, usize) {
    (((shape.h + 2 * p - k) / s) + 1, ((shape.w + 2 * p - k) / s) + 1)
}

/// Patch rows `(m·H·W × C_out)` → image layout `(m × C_out·H·W)`.
fn rows_to_chw(y: &Mat, m: usize, c_out: usize, ho: usize, wo: usize) -> Mat {
    let mut out = Mat::zeros(m, c_out * ho * wo);
    for b in 0..m {
        for oy in 0..ho {
            for ox in 0..wo {
                let src = y.row((b * ho + oy) * wo + ox);
                for c in 0..c_out {
                    *out.at_mut(b, (c * ho + oy) * wo + ox) = src[c];
                }
            }
        }
    }
    out
}

/// Image layout gradient → patch-row layout.
fn chw_to_rows(dy: &Mat, m: usize, c_out: usize, ho: usize, wo: usize) -> Mat {
    let mut out = Mat::zeros(m * ho * wo, c_out);
    for b in 0..m {
        for oy in 0..ho {
            for ox in 0..wo {
                let dst = out.row_mut((b * ho + oy) * wo + ox);
                for c in 0..c_out {
                    dst[c] = dy.at(b, (c * ho + oy) * wo + ox);
                }
            }
        }
    }
    out
}

/// 2×2 average pooling on `(m × C·H·W)` (H, W even).
pub fn avgpool2(x: &Mat, shape: ImgShape) -> Mat {
    let (h2, w2) = (shape.h / 2, shape.w / 2);
    let m = x.rows();
    let mut out = Mat::zeros(m, shape.c * h2 * w2);
    for b in 0..m {
        for c in 0..shape.c {
            for y in 0..h2 {
                for xx in 0..w2 {
                    let mut acc = 0.0;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            acc += x.at(b, (c * shape.h + 2 * y + dy) * shape.w + 2 * xx + dx);
                        }
                    }
                    *out.at_mut(b, (c * h2 + y) * w2 + xx) = acc * 0.25;
                }
            }
        }
    }
    out
}

pub fn avgpool2_bwd(dout: &Mat, shape: ImgShape) -> Mat {
    let (h2, w2) = (shape.h / 2, shape.w / 2);
    let m = dout.rows();
    let mut dx = Mat::zeros(m, shape.len());
    for b in 0..m {
        for c in 0..shape.c {
            for y in 0..h2 {
                for xx in 0..w2 {
                    let g = dout.at(b, (c * h2 + y) * w2 + xx) * 0.25;
                    for dy in 0..2 {
                        for dxx in 0..2 {
                            *dx.at_mut(b, (c * shape.h + 2 * y + dy) * shape.w + 2 * xx + dxx) = g;
                        }
                    }
                }
            }
        }
    }
    dx
}

/// One stage of the CNN.
#[derive(Clone, Debug)]
enum Stage {
    /// 3×3 (or k×k) conv + ReLU; weight index into `params`.
    Conv { k: usize, s: usize, p: usize, c_out: usize },
    /// 2×2 average pool (no params).
    Pool,
    /// Global average pool over spatial dims (no params).
    GlobalPool,
}

/// A conv net = conv/pool stages + linear classifier.
pub struct Cnn {
    input: ImgShape,
    stages: Vec<Stage>,
    #[allow(dead_code)]
    classes: usize,
    params: Vec<Mat>,
    shapes: Vec<(usize, usize)>,
}

impl Cnn {
    fn build(rng: &mut Pcg, input: ImgShape, stages: Vec<Stage>, classes: usize) -> Self {
        let mut params = Vec::new();
        let mut shapes = Vec::new();
        let mut cur = input;
        for st in &stages {
            match *st {
                Stage::Conv { k, s, p, c_out } => {
                    let d_in = cur.c * k * k;
                    params.push(Linear::init(rng, c_out, d_in));
                    shapes.push((c_out, d_in + 1));
                    let (ho, wo) = out_hw(cur, k, s, p);
                    cur = ImgShape { c: c_out, h: ho, w: wo };
                }
                Stage::Pool => {
                    cur = ImgShape { c: cur.c, h: cur.h / 2, w: cur.w / 2 };
                }
                Stage::GlobalPool => {
                    cur = ImgShape { c: cur.c, h: 1, w: 1 };
                }
            }
        }
        let feat = cur.len();
        params.push(Linear::init(rng, classes, feat));
        shapes.push((classes, feat + 1));
        Cnn { input, stages, classes, params, shapes }
    }

    /// Small VGG-style net for `input` images (e.g. 3×16×16, paper Fig. 1).
    pub fn vgg(rng: &mut Pcg, input: ImgShape, width: usize, classes: usize) -> Self {
        let stages = vec![
            Stage::Conv { k: 3, s: 1, p: 1, c_out: width },
            Stage::Conv { k: 3, s: 1, p: 1, c_out: width },
            Stage::Pool,
            Stage::Conv { k: 3, s: 1, p: 1, c_out: 2 * width },
            Stage::Pool,
            Stage::Conv { k: 3, s: 1, p: 1, c_out: 2 * width },
            Stage::Pool,
        ];
        Self::build(rng, input, stages, classes)
    }

    /// ConvMixer-style: patch embed (k=s=patch) then pointwise convs, then
    /// global average pooling.
    pub fn convmixer(
        rng: &mut Pcg,
        input: ImgShape,
        patch: usize,
        width: usize,
        depth: usize,
        classes: usize,
    ) -> Self {
        let mut stages = vec![Stage::Conv { k: patch, s: patch, p: 0, c_out: width }];
        for _ in 0..depth {
            stages.push(Stage::Conv { k: 1, s: 1, p: 0, c_out: width });
        }
        stages.push(Stage::GlobalPool);
        Self::build(rng, input, stages, classes)
    }

    /// Forward caching everything needed for backward.
    #[allow(clippy::type_complexity)]
    fn forward_cached(
        &self,
        x: &Mat,
    ) -> (Vec<(Mat, Mat, ImgShape, usize)>, Vec<ImgShape>, Mat, Mat) {
        // conv caches: (biased patch matrix, pre-activation rows, in-shape, param idx)
        let m = x.rows();
        let mut conv_caches = Vec::new();
        let mut shapes_seen = Vec::new();
        let mut cur = x.clone();
        let mut cur_shape = self.input;
        let mut pi = 0usize;
        for st in &self.stages {
            shapes_seen.push(cur_shape);
            match *st {
                Stage::Conv { k, s, p, c_out } => {
                    let patches = im2col(&cur, cur_shape, k, s, p);
                    let (z_rows, xb) = Linear::forward(&self.params[pi], &patches);
                    let a_rows = super::relu(&z_rows);
                    let (ho, wo) = out_hw(cur_shape, k, s, p);
                    cur = rows_to_chw(&a_rows, m, c_out, ho, wo);
                    conv_caches.push((xb, z_rows, cur_shape, pi));
                    cur_shape = ImgShape { c: c_out, h: ho, w: wo };
                    pi += 1;
                }
                Stage::Pool => {
                    cur = avgpool2(&cur, cur_shape);
                    cur_shape = ImgShape { c: cur_shape.c, h: cur_shape.h / 2, w: cur_shape.w / 2 };
                }
                Stage::GlobalPool => {
                    let mut pooled = Mat::zeros(m, cur_shape.c);
                    let inv = 1.0 / (cur_shape.h * cur_shape.w) as f32;
                    for b in 0..m {
                        for c in 0..cur_shape.c {
                            let mut acc = 0.0;
                            for i in 0..cur_shape.h * cur_shape.w {
                                acc += cur.at(b, c * cur_shape.h * cur_shape.w + i);
                            }
                            *pooled.at_mut(b, c) = acc * inv;
                        }
                    }
                    cur = pooled;
                    cur_shape = ImgShape { c: cur_shape.c, h: 1, w: 1 };
                }
            }
        }
        // Classifier.
        let (logits, head_xb) = Linear::forward(&self.params[pi], &cur);
        (conv_caches, shapes_seen, head_xb, logits)
    }
}

impl Model for Cnn {
    fn shapes(&self) -> Vec<(usize, usize)> {
        self.shapes.clone()
    }

    fn params_mut(&mut self) -> &mut Vec<Mat> {
        &mut self.params
    }

    fn params(&self) -> &Vec<Mat> {
        &self.params
    }

    fn forward_backward_hooked(&self, batch: &Batch, hook: &mut LayerHook<'_>) -> BackwardResult {
        let m = batch.x.rows();
        let (conv_caches, shapes_seen, head_xb, logits) = self.forward_cached(&batch.x);
        let (loss_sum, correct, dz) = super::softmax_xent_sum(&logits, &batch.y);
        let n = self.params.len();
        let mut grads = vec![Mat::zeros(1, 1); n];
        let mut stats: Vec<Option<KronStats>> = (0..n).map(|_| None).collect();

        // Head backward.
        let head_idx = n - 1;
        let lb = layer_backward_span(head_idx);
        let (g, mut dcur, st) = Linear::backward(&self.params[head_idx], &head_xb, &dz);
        hook(LayerEvent { layer_id: head_idx, grad: &g, kron_stats: &st });
        drop(lb);
        grads[head_idx] = g;
        stats[head_idx] = Some(st);

        // Walk stages in reverse.
        let mut ci = conv_caches.len();
        for (si, st) in self.stages.iter().enumerate().rev() {
            let in_shape = shapes_seen[si];
            match *st {
                Stage::Conv { k, s, p, c_out } => {
                    ci -= 1;
                    let (ref xb, ref z_rows, cache_shape, pi) = conv_caches[ci];
                    debug_assert_eq!(cache_shape.len(), in_shape.len());
                    let lb = layer_backward_span(pi);
                    let (ho, wo) = out_hw(in_shape, k, s, p);
                    let dy_rows = chw_to_rows(&dcur, m, c_out, ho, wo);
                    let dz_rows = relu_bwd(z_rows, &dy_rows);
                    let (g, dpatch, st) = Linear::backward(&self.params[pi], xb, &dz_rows);
                    hook(LayerEvent { layer_id: pi, grad: &g, kron_stats: &st });
                    drop(lb);
                    grads[pi] = g;
                    stats[pi] = Some(st);
                    dcur = col2im(&dpatch, m, in_shape, k, s, p);
                }
                Stage::Pool => {
                    dcur = avgpool2_bwd(&dcur, in_shape);
                }
                Stage::GlobalPool => {
                    let inv = 1.0 / (in_shape.h * in_shape.w) as f32;
                    let mut dx = Mat::zeros(m, in_shape.len());
                    for b in 0..m {
                        for c in 0..in_shape.c {
                            let g = dcur.at(b, c) * inv;
                            for i in 0..in_shape.h * in_shape.w {
                                *dx.at_mut(b, c * in_shape.h * in_shape.w + i) = g;
                            }
                        }
                    }
                    dcur = dx;
                }
            }
        }

        BackwardResult {
            loss: (loss_sum / batch.y.len().max(1) as f64) as f32,
            correct,
            grads,
            stats: stats.into_iter().map(|s| s.unwrap()).collect(),
            loss_sum,
            loss_rows: batch.y.len(),
        }
    }

    fn evaluate(&self, batch: &Batch) -> (f32, usize) {
        let (_, _, _, logits) = self.forward_cached(&batch.x);
        let (loss, correct, _) = softmax_xent(&logits, &batch.y);
        (loss, correct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil;

    #[test]
    fn im2col_identity_kernel_roundtrip() {
        // 1×1 conv with stride 1 and no padding is a permutation.
        let shape = ImgShape { c: 2, h: 3, w: 3 };
        let mut rng = Pcg::new(9);
        let x = rng.normal_mat(2, shape.len(), 1.0);
        let p = im2col(&x, shape, 1, 1, 0);
        assert_eq!(p.shape(), (2 * 9, 2));
        // patch row (b, y, x) column c == x[b][(c,y,x)]
        assert_eq!(p.at(4, 1), x.at(0, 9 + 4));
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // ⟨im2col(x), P⟩ = ⟨x, col2im(P)⟩ — adjointness (required for
        // correct conv backward).
        let shape = ImgShape { c: 2, h: 4, w: 4 };
        let mut rng = Pcg::new(10);
        let x = rng.normal_mat(3, shape.len(), 1.0);
        let fwd = im2col(&x, shape, 3, 1, 1);
        let p = rng.normal_mat(fwd.rows(), fwd.cols(), 1.0);
        let lhs: f64 = fwd.data().iter().zip(p.data()).map(|(&a, &b)| (a * b) as f64).sum();
        let back = col2im(&p, 3, shape, 3, 1, 1);
        let rhs: f64 = x.data().iter().zip(back.data()).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-2 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn avgpool_roundtrip_shapes_and_values() {
        let shape = ImgShape { c: 1, h: 4, w: 4 };
        let x = Mat::from_fn(1, 16, |_, i| i as f32);
        let p = avgpool2(&x, shape);
        assert_eq!(p.cols(), 4);
        // top-left 2×2 block of 0,1,4,5 → 2.5
        assert_eq!(p.at(0, 0), 2.5);
    }

    #[test]
    fn vgg_gradcheck() {
        let mut rng = Pcg::new(11);
        let shape = ImgShape { c: 2, h: 8, w: 8 };
        let mut net = Cnn::vgg(&mut rng, shape, 4, 3);
        let batch = Batch { x: rng.normal_mat(3, shape.len(), 1.0), y: vec![0, 1, 2] };
        testutil::check_grads(&mut net, &batch, 25, 5e-2);
    }

    #[test]
    fn vgg_stats_reproduce_grads() {
        let mut rng = Pcg::new(12);
        let shape = ImgShape { c: 2, h: 8, w: 8 };
        let net = Cnn::vgg(&mut rng, shape, 4, 3);
        let batch = Batch { x: rng.normal_mat(3, shape.len(), 1.0), y: vec![0, 1, 2] };
        testutil::check_stats_consistency(&net, &batch, 1e-3);
    }

    #[test]
    fn convmixer_gradcheck() {
        let mut rng = Pcg::new(13);
        let shape = ImgShape { c: 2, h: 8, w: 8 };
        let mut net = Cnn::convmixer(&mut rng, shape, 4, 6, 2, 3);
        let batch = Batch { x: rng.normal_mat(3, shape.len(), 1.0), y: vec![0, 1, 2] };
        testutil::check_grads(&mut net, &batch, 25, 5e-2);
    }

    #[test]
    fn vgg_hook_events_are_final_reverse_ordered_and_bitwise() {
        let mut rng = Pcg::new(15);
        let shape = ImgShape { c: 2, h: 8, w: 8 };
        let net = Cnn::vgg(&mut rng, shape, 4, 3);
        let batch = Batch { x: rng.normal_mat(3, shape.len(), 1.0), y: vec![0, 1, 2] };
        // Head first, then the conv stack last-to-first.
        let n = net.shapes().len();
        let want: Vec<usize> = (0..n).rev().collect();
        assert_eq!(testutil::check_hook_events(&net, &batch), want);
    }

    #[test]
    fn convmixer_hooked_gradcheck_and_stats() {
        let mut rng = Pcg::new(16);
        let shape = ImgShape { c: 2, h: 8, w: 8 };
        let mut net = Cnn::convmixer(&mut rng, shape, 4, 6, 2, 3);
        let batch = Batch { x: rng.normal_mat(3, shape.len(), 1.0), y: vec![0, 1, 2] };
        testutil::check_hook_events(&net, &batch);
        testutil::check_grads_hooked(&mut net, &batch, 25, 5e-2);
        testutil::check_stats_consistency_hooked(&net, &batch, 1e-3);
    }

    #[test]
    fn conv_shapes_follow_stages() {
        let mut rng = Pcg::new(14);
        let shape = ImgShape { c: 3, h: 16, w: 16 };
        let net = Cnn::vgg(&mut rng, shape, 8, 10);
        let shapes = net.shapes();
        assert_eq!(shapes[0], (8, 3 * 9 + 1));
        assert_eq!(shapes[1], (8, 8 * 9 + 1));
        // Classifier: 16 channels at 2×2 after three pools.
        assert_eq!(*shapes.last().unwrap(), (10, 16 * 2 * 2 + 1));
    }
}
