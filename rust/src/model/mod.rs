//! Pure-Rust reference models whose backward pass emits per-layer
//! Kronecker statistics.
//!
//! These models serve three roles:
//!
//! 1. **Experiment substrate** — the Fig. 1/6/7 reproductions train them
//!    natively with every optimizer under every precision policy (fully
//!    deterministic, no PJRT required).
//! 2. **Oracle for the AOT path** — the JAX/Pallas models in
//!    `python/compile/` implement the same architectures; the PJRT runtime
//!    executes those, and the e2e example cross-checks losses.
//! 3. **Stats provider** — every (generalized) linear layer reports
//!    [`KronStats`] in KFAC-*expand* form: weight-sharing locations
//!    (conv patches, tokens, graph nodes) are treated as extra batch rows
//!    (Eschenhagen et al., 2023).
//!
//! Architectures: [`Mlp`], VGG-ish [`cnn::Cnn`], ConvMixer-ish pointwise
//! CNN, ViT-ish [`transformer::Transformer`] (also a causal LM mode), and
//! a 2-layer [`gcn::Gcn`].

pub mod cnn;
pub mod gcn;
pub mod transformer;

use crate::optim::KronStats;
use crate::proptest::Pcg;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Mat};

/// A minibatch of flattened inputs with integer labels.
#[derive(Clone, Debug)]
pub struct Batch {
    /// `m × input_dim`.
    pub x: Mat,
    /// Length `m`.
    pub y: Vec<usize>,
}

/// Output of one forward/backward pass.
pub struct BackwardResult {
    pub loss: f32,
    pub correct: usize,
    /// Per-trainable-layer gradient of the *mean* loss.
    pub grads: Vec<Mat>,
    /// Per-trainable-layer Kronecker statistics.
    pub stats: Vec<KronStats>,
    /// Tree-ordered f64 sum of per-row losses (`loss` = `loss_sum /
    /// loss_rows`). The distributed driver combines shard partials with
    /// the same halving tree, making the global loss bitwise independent
    /// of the rank count (see [`crate::dist::collectives::tree_sum_f64`]).
    pub loss_sum: f64,
    /// Number of loss rows behind `loss_sum` (batch rows, or tokens for
    /// a causal LM, or masked nodes for the GCN).
    pub loss_rows: usize,
}

/// One layer's backward completion, delivered through
/// [`Model::forward_backward_hooked`] the moment that layer's
/// `(grad, stats)` pair exists — while earlier layers are still being
/// differentiated. The borrows point at the exact matrices that end up
/// in the [`BackwardResult`], so a consumer that clones them (e.g. the
/// streaming distributed driver issuing a per-layer gather) sees the
/// same bits the batched path would.
pub struct LayerEvent<'a> {
    /// Index into [`Model::shapes`] / `BackwardResult::grads`.
    pub layer_id: usize,
    /// Gradient of the *mean* loss for this layer, `d_out × d_in`.
    pub grad: &'a Mat,
    /// This layer's Kronecker statistics (KFAC-expand form).
    pub kron_stats: &'a KronStats,
}

/// Per-layer backward callback (see [`LayerEvent`]).
pub type LayerHook<'h> = dyn FnMut(LayerEvent<'_>) + 'h;

/// A `layer_backward` compute span covering one layer's backward
/// (gradient + stats production and hook delivery). Nested inside the
/// driver's `forward_backward` span; a streaming consumer's
/// `layer_gather_issue` span nests inside this one.
pub(crate) fn layer_backward_span(layer_id: usize) -> crate::obs::trace::Span {
    let mut sp = crate::obs::trace::span("layer_backward", "compute");
    if sp.is_recording() {
        sp.arg("layer", crate::obs::trace::ArgVal::U(layer_id as u64));
    }
    sp
}

/// Common model interface consumed by [`crate::train::Trainer`].
///
/// `Sync` so the distributed training driver can run its SPMD rank
/// bodies against one shared model instance (`forward_backward` takes
/// `&self`; parameters are only mutated between steps).
pub trait Model: Sync {
    /// `(d_out, d_in)` of every trainable layer, in `params` order.
    fn shapes(&self) -> Vec<(usize, usize)>;

    /// Trainable weight matrices (optimizer mutates these in place).
    fn params_mut(&mut self) -> &mut Vec<Mat>;

    fn params(&self) -> &Vec<Mat>;

    /// Forward + backward on a batch, invoking `hook` once per trainable
    /// layer as soon as that layer's gradient and Kronecker statistics
    /// are final (reverse-topological order; each `layer_id` exactly
    /// once). The hook is an observation seam: implementations perform
    /// the identical floating-point operations in the identical order as
    /// [`Model::forward_backward`], so the returned result is bitwise
    /// the same whether or not a hook consumes the events.
    fn forward_backward_hooked(&self, batch: &Batch, hook: &mut LayerHook<'_>) -> BackwardResult;

    /// Forward + backward on a batch ([`Model::forward_backward_hooked`]
    /// with a no-op hook).
    fn forward_backward(&self, batch: &Batch) -> BackwardResult {
        self.forward_backward_hooked(batch, &mut |_| {})
    }

    /// Forward only: mean loss and #correct (eval).
    fn evaluate(&self, batch: &Batch) -> (f32, usize);

    /// Total parameter count.
    fn num_params(&self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }
}

/// Softmax cross-entropy over logits `z (m×C)`; returns
/// `(mean loss, #correct, dL/dz of the mean loss)`.
pub fn softmax_xent(z: &Mat, y: &[usize]) -> (f32, usize, Mat) {
    let (loss_sum, correct, dz) = softmax_xent_sum(z, y);
    ((loss_sum / z.rows().max(1) as f64) as f32, correct, dz)
}

/// [`softmax_xent`] exposing the raw f64 per-row loss *sum* (the mean is
/// `loss_sum / m`). The sum is accumulated with the fixed halving tree of
/// [`crate::dist::collectives::tree_sum_f64`], so contiguous batch shards
/// produce exact subtrees of the full-batch reduction — the property the
/// distributed driver's bitwise rank-invariance rests on.
pub fn softmax_xent_sum(z: &Mat, y: &[usize]) -> (f64, usize, Mat) {
    let m = z.rows();
    assert_eq!(y.len(), m);
    let probs = z.softmax_rows();
    let mut row_losses = Vec::with_capacity(m);
    let mut correct = 0usize;
    let mut dz = probs.clone();
    for r in 0..m {
        let p = probs.at(r, y[r]).max(1e-12);
        row_losses.push(-(p as f64).ln());
        *dz.at_mut(r, y[r]) -= 1.0;
        let argmax = (0..z.cols()).max_by(|&a, &b| {
            probs.at(r, a).partial_cmp(&probs.at(r, b)).unwrap_or(std::cmp::Ordering::Equal)
        });
        if argmax == Some(y[r]) {
            correct += 1;
        }
    }
    let dz = dz.scale(1.0 / m as f32);
    (crate::dist::collectives::tree_sum_f64(&row_losses), correct, dz)
}

/// Append a constant-1 column (homogeneous bias coordinate).
pub fn with_bias_col(x: &Mat) -> Mat {
    let (m, d) = x.shape();
    Mat::from_fn(m, d + 1, |r, c| if c < d { x.at(r, c) } else { 1.0 })
}

/// A trainable linear layer `y = [x, 1] Wᵀ` with the bias folded into the
/// weight's last column (so optimizers see one matrix per layer).
pub struct Linear;

impl Linear {
    /// Kaiming-ish init for a `(d_out, d_in+1)` weight (bias column zero).
    pub fn init(rng: &mut Pcg, d_out: usize, d_in: usize) -> Mat {
        let scale = (2.0 / d_in as f32).sqrt();
        Mat::from_fn(d_out, d_in + 1, |_, c| if c < d_in { rng.normal() * scale } else { 0.0 })
    }

    /// Forward: returns `(output m×d_out, cached biased input)`.
    pub fn forward(w: &Mat, x: &Mat) -> (Mat, Mat) {
        let xb = with_bias_col(x);
        (matmul_a_bt(&xb, w), xb)
    }

    /// Backward: given `dy = dL/dy (m×d_out)` and the cached biased input,
    /// returns `(dL/dW, dL/dx, KronStats)`.
    pub fn backward(w: &Mat, xb: &Mat, dy: &Mat) -> (Mat, Mat, KronStats) {
        let m = xb.rows() as f32;
        let grad = matmul_at_b(dy, xb); // d_out × (d_in+1)
        let dxb = matmul(dy, w); // m × (d_in+1)
        // Drop the bias column of dx.
        let d_in = xb.cols() - 1;
        let dx = Mat::from_fn(dxb.rows(), d_in, |r, c| dxb.at(r, c));
        // Stats: inputs as-is; per-sample/location output grads (undo the
        // 1/m of the mean loss so the scale matches classic KFAC).
        let stats = KronStats { a: xb.clone(), g: dy.scale(m) };
        (grad, dx, stats)
    }
}

/// ReLU.
pub fn relu(x: &Mat) -> Mat {
    x.map(|v| v.max(0.0))
}

/// ReLU backward given the pre-activation and upstream gradient.
pub fn relu_bwd(x: &Mat, dy: &Mat) -> Mat {
    x.zip(dy, |xv, dv| if xv > 0.0 { dv } else { 0.0 })
}

/// A plain multilayer perceptron with ReLU activations.
pub struct Mlp {
    dims: Vec<usize>,
    params: Vec<Mat>,
}

impl Mlp {
    /// `dims = [input, hidden…, classes]`.
    pub fn new(rng: &mut Pcg, dims: &[usize]) -> Self {
        assert!(dims.len() >= 2);
        let params = dims.windows(2).map(|w| Linear::init(rng, w[1], w[0])).collect();
        Mlp { dims: dims.to_vec(), params }
    }

    fn forward_cached(&self, x: &Mat) -> (Vec<Mat>, Vec<Mat>, Mat) {
        // (pre-activations per layer, biased inputs per layer, logits)
        let mut pre = Vec::new();
        let mut cached = Vec::new();
        let mut cur = x.clone();
        for (i, w) in self.params.iter().enumerate() {
            let (z, xb) = Linear::forward(w, &cur);
            cached.push(xb);
            if i + 1 < self.params.len() {
                cur = relu(&z);
            }
            pre.push(z);
        }
        let logits = pre.last().unwrap().clone();
        (pre, cached, logits)
    }
}

impl Model for Mlp {
    fn shapes(&self) -> Vec<(usize, usize)> {
        self.dims.windows(2).map(|w| (w[1], w[0] + 1)).collect()
    }

    fn params_mut(&mut self) -> &mut Vec<Mat> {
        &mut self.params
    }

    fn params(&self) -> &Vec<Mat> {
        &self.params
    }

    fn forward_backward_hooked(&self, batch: &Batch, hook: &mut LayerHook<'_>) -> BackwardResult {
        let (pre, cached, logits) = self.forward_cached(&batch.x);
        let (loss_sum, correct, mut dz) = softmax_xent_sum(&logits, &batch.y);
        let loss_rows = batch.y.len();
        let n = self.params.len();
        let mut grads = vec![Mat::zeros(1, 1); n];
        let mut stats: Vec<Option<KronStats>> = (0..n).map(|_| None).collect();
        for i in (0..n).rev() {
            let lb = layer_backward_span(i);
            let (g, dx, st) = Linear::backward(&self.params[i], &cached[i], &dz);
            hook(LayerEvent { layer_id: i, grad: &g, kron_stats: &st });
            drop(lb);
            grads[i] = g;
            stats[i] = Some(st);
            if i > 0 {
                dz = relu_bwd(&pre[i - 1], &dx);
            }
        }
        BackwardResult {
            loss: (loss_sum / loss_rows.max(1) as f64) as f32,
            correct,
            grads,
            stats: stats.into_iter().map(|s| s.unwrap()).collect(),
            loss_sum,
            loss_rows,
        }
    }

    fn evaluate(&self, batch: &Batch) -> (f32, usize) {
        let (_, _, logits) = self.forward_cached(&batch.x);
        let (loss, correct, _) = softmax_xent(&logits, &batch.y);
        (loss, correct)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Finite-difference check of `forward_backward` gradients.
    pub fn check_grads<M: Model>(model: &mut M, batch: &Batch, n_checks: usize, tol: f32) {
        let res = model.forward_backward(batch);
        let mut rng = Pcg::new(777);
        let eps = 1e-2f32;
        let nl = model.params().len();
        for _ in 0..n_checks {
            let l = rng.below(nl);
            let idx = rng.below(model.params()[l].len());
            let orig = model.params()[l].data()[idx];
            model.params_mut()[l].data_mut()[idx] = orig + eps;
            let (lp, _) = model.evaluate(batch);
            model.params_mut()[l].data_mut()[idx] = orig - eps;
            let (lm, _) = model.evaluate(batch);
            model.params_mut()[l].data_mut()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = res.grads[l].data()[idx];
            assert!(
                (fd - an).abs() <= tol * (1.0 + fd.abs().max(an.abs())),
                "layer {l} idx {idx}: fd {fd} vs analytic {an}"
            );
        }
    }

    /// The stats outer product must reproduce the gradient:
    /// `∇W = Gᵀ A / m` — the consistency KFAC assumes.
    pub fn check_stats_consistency<M: Model>(model: &M, batch: &Batch, tol: f32) {
        let res = model.forward_backward(batch);
        for l in 0..res.grads.len() {
            let st = &res.stats[l];
            let m = st.a.rows() as f32;
            let rebuilt = crate::tensor::matmul_at_b(&st.g, &st.a).scale(1.0 / m);
            crate::proptest::assert_mat_close(&rebuilt, &res.grads[l], tol, &format!("layer {l}"));
        }
    }

    /// The hook-seam contract, checked for one `(model, batch)` pair:
    ///
    /// * exactly one [`LayerEvent`] per trainable layer, each `layer_id`
    ///   once, shapes matching [`Model::shapes`];
    /// * every event's `grad`/`kron_stats` bits equal the corresponding
    ///   entries of the returned [`BackwardResult`] (the event *is* the
    ///   final value, not a draft);
    /// * the hooked result is bitwise identical to the hook-free
    ///   [`Model::forward_backward`] path.
    ///
    /// Returns the `layer_id` emission order so callers can pin each
    /// model's reverse-topological ordering.
    pub fn check_hook_events<M: Model>(model: &M, batch: &Batch) -> Vec<usize> {
        let shapes = model.shapes();
        let mut order = Vec::new();
        let mut captured: Vec<Option<(Mat, KronStats)>> = (0..shapes.len()).map(|_| None).collect();
        let hooked = model.forward_backward_hooked(batch, &mut |ev: LayerEvent<'_>| {
            assert!(ev.layer_id < shapes.len(), "layer_id {} out of range", ev.layer_id);
            assert!(captured[ev.layer_id].is_none(), "layer {} emitted twice", ev.layer_id);
            assert_eq!(ev.grad.shape(), shapes[ev.layer_id], "layer {} grad shape", ev.layer_id);
            assert_eq!(ev.kron_stats.a.cols(), shapes[ev.layer_id].1, "layer {} A cols", ev.layer_id);
            assert_eq!(ev.kron_stats.g.cols(), shapes[ev.layer_id].0, "layer {} G cols", ev.layer_id);
            assert_eq!(ev.kron_stats.a.rows(), ev.kron_stats.g.rows(), "layer {} A/G rows", ev.layer_id);
            order.push(ev.layer_id);
            captured[ev.layer_id] = Some((ev.grad.clone(), ev.kron_stats.clone()));
        });
        assert_eq!(order.len(), shapes.len(), "one event per trainable layer");
        for (l, cap) in captured.iter().enumerate() {
            let (g, st) = cap.as_ref().expect("every layer emitted");
            assert_eq!(g.data(), hooked.grads[l].data(), "layer {l}: event grad == result grad");
            assert_eq!(st.a.data(), hooked.stats[l].a.data(), "layer {l}: event A == result A");
            assert_eq!(st.g.data(), hooked.stats[l].g.data(), "layer {l}: event G == result G");
        }
        let plain = model.forward_backward(batch);
        assert_eq!(plain.loss_sum.to_bits(), hooked.loss_sum.to_bits(), "loss_sum bitwise");
        assert_eq!(plain.loss_rows, hooked.loss_rows);
        assert_eq!(plain.correct, hooked.correct);
        for l in 0..shapes.len() {
            assert_eq!(plain.grads[l].data(), hooked.grads[l].data(), "layer {l}: grads bitwise");
            assert_eq!(plain.stats[l].a.data(), hooked.stats[l].a.data(), "layer {l}: A bitwise");
            assert_eq!(plain.stats[l].g.data(), hooked.stats[l].g.data(), "layer {l}: G bitwise");
        }
        order
    }

    /// [`check_grads`] driven through the hook path: the finite-difference
    /// reference is compared against the *event* gradients, so the seam —
    /// not just the batched result — is what the check covers.
    pub fn check_grads_hooked<M: Model>(model: &mut M, batch: &Batch, n_checks: usize, tol: f32) {
        let n = model.params().len();
        let mut grads: Vec<Option<Mat>> = (0..n).map(|_| None).collect();
        model.forward_backward_hooked(batch, &mut |ev: LayerEvent<'_>| {
            grads[ev.layer_id] = Some(ev.grad.clone());
        });
        let grads: Vec<Mat> = grads.into_iter().map(|g| g.expect("layer emitted")).collect();
        let mut rng = Pcg::new(777);
        let eps = 1e-2f32;
        for _ in 0..n_checks {
            let l = rng.below(n);
            let idx = rng.below(model.params()[l].len());
            let orig = model.params()[l].data()[idx];
            model.params_mut()[l].data_mut()[idx] = orig + eps;
            let (lp, _) = model.evaluate(batch);
            model.params_mut()[l].data_mut()[idx] = orig - eps;
            let (lm, _) = model.evaluate(batch);
            model.params_mut()[l].data_mut()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads[l].data()[idx];
            assert!(
                (fd - an).abs() <= tol * (1.0 + fd.abs().max(an.abs())),
                "hooked layer {l} idx {idx}: fd {fd} vs analytic {an}"
            );
        }
    }

    /// [`check_stats_consistency`] driven through the hook path: each
    /// event's stats outer product must reproduce that event's gradient.
    pub fn check_stats_consistency_hooked<M: Model>(model: &M, batch: &Batch, tol: f32) {
        model.forward_backward_hooked(batch, &mut |ev: LayerEvent<'_>| {
            let m = ev.kron_stats.a.rows() as f32;
            let rebuilt =
                crate::tensor::matmul_at_b(&ev.kron_stats.g, &ev.kron_stats.a).scale(1.0 / m);
            crate::proptest::assert_mat_close(
                &rebuilt,
                ev.grad,
                tol,
                &format!("hooked layer {}", ev.layer_id),
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(rng: &mut Pcg, m: usize, d: usize, c: usize) -> Batch {
        Batch { x: rng.normal_mat(m, d, 1.0), y: (0..m).map(|i| i % c).collect() }
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let z = Mat::zeros(4, 10);
        let y = vec![0, 1, 2, 3];
        let (loss, _, dz) = softmax_xent(&z, &y);
        assert!((loss - (10f32).ln()).abs() < 1e-5);
        for r in 0..4 {
            let s: f32 = dz.row(r).iter().sum();
            assert!(s.abs() < 1e-6, "gradient rows must sum to zero");
        }
    }

    #[test]
    fn mlp_gradcheck() {
        let mut rng = Pcg::new(1);
        let mut mlp = Mlp::new(&mut rng, &[5, 7, 4]);
        let batch = toy_batch(&mut rng, 6, 5, 4);
        testutil::check_grads(&mut mlp, &batch, 30, 2e-2);
    }

    #[test]
    fn mlp_stats_reproduce_grads() {
        let mut rng = Pcg::new(2);
        let mlp = Mlp::new(&mut rng, &[5, 8, 3]);
        let batch = toy_batch(&mut rng, 9, 5, 3);
        testutil::check_stats_consistency(&mlp, &batch, 1e-4);
    }

    #[test]
    fn mlp_hook_events_are_final_reverse_ordered_and_bitwise() {
        let mut rng = Pcg::new(21);
        let mlp = Mlp::new(&mut rng, &[5, 7, 6, 4]);
        let batch = toy_batch(&mut rng, 8, 5, 4);
        // An MLP differentiates strictly last-to-first.
        assert_eq!(testutil::check_hook_events(&mlp, &batch), vec![2, 1, 0]);
    }

    #[test]
    fn mlp_hooked_gradcheck_and_stats() {
        let mut rng = Pcg::new(22);
        let mut mlp = Mlp::new(&mut rng, &[5, 7, 4]);
        let batch = toy_batch(&mut rng, 6, 5, 4);
        testutil::check_grads_hooked(&mut mlp, &batch, 30, 2e-2);
        testutil::check_stats_consistency_hooked(&mlp, &batch, 1e-4);
    }

    #[test]
    fn mlp_trains_on_separable_data() {
        let mut rng = Pcg::new(3);
        let mut mlp = Mlp::new(&mut rng, &[4, 16, 3]);
        let make = |rng: &mut Pcg| -> Batch {
            let m = 30;
            let y: Vec<usize> = (0..m).map(|_| rng.below(3)).collect();
            let x = Mat::from_fn(m, 4, |r, c| if c == y[r] { 4.0 } else { 0.0 } + rng.normal());
            Batch { x, y }
        };
        let hp = crate::optim::Hyper { lr: 0.2, momentum: 0.9, ..Default::default() };
        let mut opt = crate::optim::Method::Sgd.build(&mlp.shapes(), &hp);
        for t in 0..100 {
            let b = make(&mut rng);
            let res = mlp.forward_backward(&b);
            opt.step(t, &mut mlp.params, &res.grads, &res.stats);
        }
        let b = make(&mut rng);
        let (_, correct) = mlp.evaluate(&b);
        assert!(correct as f32 / b.y.len() as f32 > 0.8, "acc {correct}/30");
    }

    #[test]
    fn bias_column_is_learnable() {
        // A constant-label problem solvable only through the bias.
        let mut rng = Pcg::new(4);
        let mut mlp = Mlp::new(&mut rng, &[2, 2]);
        let batch = Batch { x: Mat::zeros(8, 2), y: vec![1; 8] };
        let hp = crate::optim::Hyper { lr: 0.5, momentum: 0.0, ..Default::default() };
        let mut opt = crate::optim::Method::Sgd.build(&mlp.shapes(), &hp);
        for t in 0..50 {
            let res = mlp.forward_backward(&batch);
            opt.step(t, &mut mlp.params, &res.grads, &res.stats);
        }
        let (loss, correct) = mlp.evaluate(&batch);
        assert_eq!(correct, 8);
        assert!(loss < 0.1, "loss {loss}");
    }
}
