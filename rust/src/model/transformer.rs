//! A ViT-style transformer (Fig. 6 models) that doubles as a causal
//! language model (the end-to-end example).
//!
//! Architecture: embedding (patch-linear for images, one-hot-linear for
//! tokens) → `depth` pre-LN blocks (LN → single-head self-attention →
//! residual → LN → 2-layer MLP → residual) → final LN → head
//! (mean-pool classifier, or per-token LM logits with causal masking).
//!
//! All trainable layers are generalized linear layers with bias folded in;
//! tokens are treated as extra batch rows for the Kronecker statistics
//! (KFAC-expand, Eschenhagen et al., 2023). LayerNorm carries no learnable
//! affine so the optimizer interface stays uniform (see DESIGN.md §3).

use super::cnn::ImgShape;
use super::{
    layer_backward_span, relu, relu_bwd, softmax_xent, BackwardResult, Batch, LayerEvent,
    LayerHook, Linear, Model,
};
use crate::optim::KronStats;
use crate::proptest::Pcg;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Mat};

/// Input embedding mode.
#[derive(Clone, Debug)]
pub enum Embed {
    /// Non-overlapping `patch×patch` image patches, linearly projected.
    Patch { img: ImgShape, patch: usize },
    /// Token ids (stored as f32 in `Batch::x`, one row per sequence),
    /// one-hot embedded through a linear layer.
    Token { vocab: usize },
}

#[derive(Clone, Debug)]
pub struct TransformerCfg {
    pub embed: Embed,
    /// Model width `d`.
    pub dim: usize,
    /// Number of blocks.
    pub depth: usize,
    /// MLP expansion factor.
    pub mlp_ratio: usize,
    /// Output classes (classifier) or vocabulary (LM).
    pub out: usize,
    /// Causal attention + per-token LM loss.
    pub causal_lm: bool,
}

const LN_EPS: f32 = 1e-5;

/// Row-wise LayerNorm (no affine). Returns (y, inv_std per row, centered x).
fn layernorm(x: &Mat) -> (Mat, Vec<f32>, Mat) {
    let (m, d) = x.shape();
    let mut y = Mat::zeros(m, d);
    let mut inv_std = vec![0.0f32; m];
    let mut centered = Mat::zeros(m, d);
    for r in 0..m {
        let row = x.row(r);
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let is = 1.0 / (var + LN_EPS).sqrt();
        inv_std[r] = is;
        for c in 0..d {
            let cent = row[c] - mean;
            *centered.at_mut(r, c) = cent;
            *y.at_mut(r, c) = cent * is;
        }
    }
    (y, inv_std, centered)
}

/// LayerNorm backward.
fn layernorm_bwd(dy: &Mat, inv_std: &[f32], centered: &Mat) -> Mat {
    let (m, d) = dy.shape();
    let mut dx = Mat::zeros(m, d);
    for r in 0..m {
        let is = inv_std[r];
        let dyr = dy.row(r);
        let cr = centered.row(r);
        let mean_dy: f32 = dyr.iter().sum::<f32>() / d as f32;
        let mean_dy_xhat: f32 =
            dyr.iter().zip(cr).map(|(g, c)| g * c * is).sum::<f32>() / d as f32;
        for c in 0..d {
            let xhat = cr[c] * is;
            *dx.at_mut(r, c) = is * (dyr[c] - mean_dy - xhat * mean_dy_xhat);
        }
    }
    dx
}

/// Per-layer parameter indices of one block.
struct BlockIdx {
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    w1: usize,
    w2: usize,
}

pub struct Transformer {
    pub cfg: TransformerCfg,
    params: Vec<Mat>,
    shapes: Vec<(usize, usize)>,
    blocks: Vec<BlockIdx>,
    embed_idx: usize,
    head_idx: usize,
    /// Tokens per sequence.
    seq: usize,
    /// Embedding input dim (patch dim or vocab).
    #[allow(dead_code)]
    in_dim: usize,
}

struct BlockCache {
    ln1: (Mat, Vec<f32>, Mat),
    q_xb: Mat,
    k_xb: Mat,
    v_xb: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    /// Per-sample softmax attention probabilities.
    probs: Vec<Mat>,
    #[allow(dead_code)]
    att_out: Mat,
    o_xb: Mat,
    after_att: Mat,
    ln2: (Mat, Vec<f32>, Mat),
    m1_xb: Mat,
    m1_pre: Mat,
    m2_xb: Mat,
}

struct Cache {
    embed_xb: Mat,
    blocks: Vec<BlockCache>,
    final_ln: (Mat, Vec<f32>, Mat),
    pooled: Option<Mat>,
    head_xb: Mat,
    logits: Mat,
    m: usize,
}

impl Transformer {
    pub fn new(rng: &mut Pcg, cfg: TransformerCfg) -> Self {
        let (in_dim, seq) = match &cfg.embed {
            Embed::Patch { img, patch } => {
                assert!(img.h % patch == 0 && img.w % patch == 0, "patch must divide image");
                (img.c * patch * patch, (img.h / patch) * (img.w / patch))
            }
            Embed::Token { vocab } => (*vocab, 0), // seq comes from the batch
        };
        let d = cfg.dim;
        let mut params = Vec::new();
        let mut shapes = Vec::new();
        let push = |rng: &mut Pcg, o: usize, i: usize, params: &mut Vec<Mat>, shapes: &mut Vec<(usize, usize)>| -> usize {
            params.push(Linear::init(rng, o, i));
            shapes.push((o, i + 1));
            params.len() - 1
        };
        let embed_idx = push(rng, d, in_dim, &mut params, &mut shapes);
        let mut blocks = Vec::new();
        for _ in 0..cfg.depth {
            let wq = push(rng, d, d, &mut params, &mut shapes);
            let wk = push(rng, d, d, &mut params, &mut shapes);
            let wv = push(rng, d, d, &mut params, &mut shapes);
            let wo = push(rng, d, d, &mut params, &mut shapes);
            let w1 = push(rng, d * cfg.mlp_ratio, d, &mut params, &mut shapes);
            let w2 = push(rng, d, d * cfg.mlp_ratio, &mut params, &mut shapes);
            blocks.push(BlockIdx { wq, wk, wv, wo, w1, w2 });
        }
        let head_idx = push(rng, cfg.out, d, &mut params, &mut shapes);
        Transformer { cfg, params, shapes, blocks, embed_idx, head_idx, seq, in_dim }
    }

    /// Sequence length for a given batch.
    fn seq_len(&self, batch: &Batch) -> usize {
        match &self.cfg.embed {
            Embed::Patch { .. } => self.seq,
            Embed::Token { .. } => batch.x.cols(),
        }
    }

    /// Build the `(m·s) × in_dim` embedding input rows.
    fn embed_rows(&self, batch: &Batch) -> Mat {
        match &self.cfg.embed {
            Embed::Patch { img, patch } => {
                // Cut non-overlapping patches (a strided im2col).
                super::cnn::im2col(&batch.x, *img, *patch, *patch, 0)
            }
            Embed::Token { vocab } => {
                let (m, s) = batch.x.shape();
                let mut rows = Mat::zeros(m * s, *vocab);
                for b in 0..m {
                    for t in 0..s {
                        let tok = batch.x.at(b, t) as usize;
                        assert!(tok < *vocab, "token id out of range");
                        *rows.at_mut(b * s + t, tok) = 1.0;
                    }
                }
                rows
            }
        }
    }

    fn forward_cached(&self, batch: &Batch) -> Cache {
        let m = batch.x.rows();
        let s = self.seq_len(batch);
        let d = self.cfg.dim;
        let scale = 1.0 / (d as f32).sqrt();

        let emb_in = self.embed_rows(batch);
        let (mut h, embed_xb) = Linear::forward(&self.params[self.embed_idx], &emb_in);

        let mut block_caches = Vec::new();
        for blk in &self.blocks {
            let ln1 = layernorm(&h);
            let (q, q_xb) = Linear::forward(&self.params[blk.wq], &ln1.0);
            let (k, k_xb) = Linear::forward(&self.params[blk.wk], &ln1.0);
            let (v, v_xb) = Linear::forward(&self.params[blk.wv], &ln1.0);
            // Attention per sample.
            let mut att = Mat::zeros(m * s, d);
            let mut probs = Vec::with_capacity(m);
            for b in 0..m {
                let qb = Mat::from_fn(s, d, |r, c| q.at(b * s + r, c));
                let kb = Mat::from_fn(s, d, |r, c| k.at(b * s + r, c));
                let vb = Mat::from_fn(s, d, |r, c| v.at(b * s + r, c));
                let mut scores = matmul_a_bt(&qb, &kb).scale(scale);
                if self.cfg.causal_lm {
                    for r in 0..s {
                        for c in (r + 1)..s {
                            scores.set(r, c, f32::NEG_INFINITY);
                        }
                    }
                }
                let p = scores.softmax_rows();
                let ob = matmul(&p, &vb);
                for r in 0..s {
                    for c in 0..d {
                        *att.at_mut(b * s + r, c) = ob.at(r, c);
                    }
                }
                probs.push(p);
            }
            let (proj, o_xb) = Linear::forward(&self.params[blk.wo], &att);
            let after_att = h.add(&proj); // residual
            let ln2 = layernorm(&after_att);
            let (m1_pre, m1_xb) = Linear::forward(&self.params[blk.w1], &ln2.0);
            let m1_act = relu(&m1_pre);
            let (m2, m2_xb) = Linear::forward(&self.params[blk.w2], &m1_act);
            let out = after_att.add(&m2); // residual
            block_caches.push(BlockCache {
                ln1,
                q_xb,
                k_xb,
                v_xb,
                q,
                k,
                v,
                probs,
                att_out: att,
                o_xb,
                after_att,
                ln2,
                m1_xb,
                m1_pre,
                m2_xb,
            });
            h = out;
        }

        let final_ln = layernorm(&h);
        let (pooled, head_in) = if self.cfg.causal_lm {
            (None, final_ln.0.clone())
        } else {
            // Mean-pool tokens per sample.
            let mut pooled = Mat::zeros(m, d);
            for b in 0..m {
                for t in 0..s {
                    for c in 0..d {
                        *pooled.at_mut(b, c) += final_ln.0.at(b * s + t, c);
                    }
                }
            }
            let pooled = pooled.scale(1.0 / s as f32);
            (Some(pooled.clone()), pooled)
        };
        let (logits, head_xb) = Linear::forward(&self.params[self.head_idx], &head_in);
        Cache { embed_xb, blocks: block_caches, final_ln, pooled, head_xb, logits, m }
    }

    /// LM targets: next-token labels, flattened `(m·s)`; the final position
    /// of each sequence predicts `batch.y[b]` (continuation token).
    fn lm_labels(&self, batch: &Batch) -> Vec<usize> {
        let (m, s) = batch.x.shape();
        let mut labels = Vec::with_capacity(m * s);
        for b in 0..m {
            for t in 0..s {
                if t + 1 < s {
                    labels.push(batch.x.at(b, t + 1) as usize);
                } else {
                    labels.push(batch.y[b]);
                }
            }
        }
        labels
    }
}

impl Model for Transformer {
    fn shapes(&self) -> Vec<(usize, usize)> {
        self.shapes.clone()
    }

    fn params_mut(&mut self) -> &mut Vec<Mat> {
        &mut self.params
    }

    fn params(&self) -> &Vec<Mat> {
        &self.params
    }

    fn forward_backward_hooked(&self, batch: &Batch, hook: &mut LayerHook<'_>) -> BackwardResult {
        let cache = self.forward_cached(batch);
        let m = cache.m;
        let s = self.seq_len(batch);
        let d = self.cfg.dim;
        let scale = 1.0 / (d as f32).sqrt();

        let labels: Vec<usize> =
            if self.cfg.causal_lm { self.lm_labels(batch) } else { batch.y.clone() };
        let (loss_sum, correct, dlogits) = super::softmax_xent_sum(&cache.logits, &labels);
        let loss_rows = labels.len();

        let n = self.params.len();
        let mut grads = vec![Mat::zeros(1, 1); n];
        let mut stats: Vec<Option<KronStats>> = (0..n).map(|_| None).collect();

        // Head.
        let lb = layer_backward_span(self.head_idx);
        let (g, dhead_in, st) = Linear::backward(&self.params[self.head_idx], &cache.head_xb, &dlogits);
        hook(LayerEvent { layer_id: self.head_idx, grad: &g, kron_stats: &st });
        drop(lb);
        grads[self.head_idx] = g;
        stats[self.head_idx] = Some(st);

        // Un-pool.
        let dln_final = if self.cfg.causal_lm {
            dhead_in
        } else {
            let _ = cache.pooled;
            let mut dtok = Mat::zeros(m * s, d);
            let inv = 1.0 / s as f32;
            for b in 0..m {
                for t in 0..s {
                    for c in 0..d {
                        *dtok.at_mut(b * s + t, c) = dhead_in.at(b, c) * inv;
                    }
                }
            }
            dtok
        };
        let mut dh = layernorm_bwd(&dln_final, &cache.final_ln.1, &cache.final_ln.2);

        // Blocks in reverse.
        for (bi, blk) in self.blocks.iter().enumerate().rev() {
            let bc = &cache.blocks[bi];
            // out = after_att + mlp(ln2(after_att))
            let dm2 = dh.clone();
            let lb = layer_backward_span(blk.w2);
            let (g2, dm1_act, st2) = Linear::backward(&self.params[blk.w2], &bc.m2_xb, &dm2);
            hook(LayerEvent { layer_id: blk.w2, grad: &g2, kron_stats: &st2 });
            drop(lb);
            grads[blk.w2] = g2;
            stats[blk.w2] = Some(st2);
            let dm1_pre = relu_bwd(&bc.m1_pre, &dm1_act);
            let lb = layer_backward_span(blk.w1);
            let (g1, dln2_out, st1) = Linear::backward(&self.params[blk.w1], &bc.m1_xb, &dm1_pre);
            hook(LayerEvent { layer_id: blk.w1, grad: &g1, kron_stats: &st1 });
            drop(lb);
            grads[blk.w1] = g1;
            stats[blk.w1] = Some(st1);
            let dafter_att_mlp = layernorm_bwd(&dln2_out, &bc.ln2.1, &bc.ln2.2);
            let dafter_att = dh.add(&dafter_att_mlp);

            // after_att = h + proj(att)
            let lb = layer_backward_span(blk.wo);
            let (go, datt, sto) = Linear::backward(&self.params[blk.wo], &bc.o_xb, &dafter_att);
            hook(LayerEvent { layer_id: blk.wo, grad: &go, kron_stats: &sto });
            drop(lb);
            grads[blk.wo] = go;
            stats[blk.wo] = Some(sto);

            // Attention backward per sample.
            let mut dq = Mat::zeros(m * s, d);
            let mut dk = Mat::zeros(m * s, d);
            let mut dv = Mat::zeros(m * s, d);
            for b in 0..m {
                let p = &bc.probs[b];
                let vb = Mat::from_fn(s, d, |r, c| bc.v.at(b * s + r, c));
                let qb = Mat::from_fn(s, d, |r, c| bc.q.at(b * s + r, c));
                let kb = Mat::from_fn(s, d, |r, c| bc.k.at(b * s + r, c));
                let dob = Mat::from_fn(s, d, |r, c| datt.at(b * s + r, c));
                let dp = matmul_a_bt(&dob, &vb); // s×s
                let dvb = matmul_at_b(p, &dob); // s×d
                // Softmax backward row-wise: ds_ij = p_ij (dp_ij − Σ_k dp_ik p_ik)
                let mut ds = Mat::zeros(s, s);
                for r in 0..s {
                    let dot: f32 = (0..s).map(|c| dp.at(r, c) * p.at(r, c)).sum();
                    for c in 0..s {
                        ds.set(r, c, p.at(r, c) * (dp.at(r, c) - dot));
                    }
                }
                let dqb = matmul(&ds, &kb).scale(scale);
                let dkb = matmul_at_b(&ds, &qb).scale(scale);
                for r in 0..s {
                    for c in 0..d {
                        *dq.at_mut(b * s + r, c) = dqb.at(r, c);
                        *dk.at_mut(b * s + r, c) = dkb.at(r, c);
                        *dv.at_mut(b * s + r, c) = dvb.at(r, c);
                    }
                }
            }
            let _ = &bc.att_out;

            let lb = layer_backward_span(blk.wq);
            let (gq, dln1_q, stq) = Linear::backward(&self.params[blk.wq], &bc.q_xb, &dq);
            hook(LayerEvent { layer_id: blk.wq, grad: &gq, kron_stats: &stq });
            drop(lb);
            let lb = layer_backward_span(blk.wk);
            let (gk, dln1_k, stk) = Linear::backward(&self.params[blk.wk], &bc.k_xb, &dk);
            hook(LayerEvent { layer_id: blk.wk, grad: &gk, kron_stats: &stk });
            drop(lb);
            let lb = layer_backward_span(blk.wv);
            let (gv, dln1_v, stv) = Linear::backward(&self.params[blk.wv], &bc.v_xb, &dv);
            hook(LayerEvent { layer_id: blk.wv, grad: &gv, kron_stats: &stv });
            drop(lb);
            grads[blk.wq] = gq;
            stats[blk.wq] = Some(stq);
            grads[blk.wk] = gk;
            stats[blk.wk] = Some(stk);
            grads[blk.wv] = gv;
            stats[blk.wv] = Some(stv);
            let dln1_out = dln1_q.add(&dln1_k).add(&dln1_v);
            let dh_ln = layernorm_bwd(&dln1_out, &bc.ln1.1, &bc.ln1.2);
            dh = dafter_att.add(&dh_ln);
        }

        // Embedding.
        let lb = layer_backward_span(self.embed_idx);
        let (ge, _demb, ste) = Linear::backward(&self.params[self.embed_idx], &cache.embed_xb, &dh);
        hook(LayerEvent { layer_id: self.embed_idx, grad: &ge, kron_stats: &ste });
        drop(lb);
        grads[self.embed_idx] = ge;
        stats[self.embed_idx] = Some(ste);

        BackwardResult {
            loss: (loss_sum / loss_rows.max(1) as f64) as f32,
            correct,
            grads,
            stats: stats.into_iter().map(|s| s.unwrap()).collect(),
            loss_sum,
            loss_rows,
        }
    }

    fn evaluate(&self, batch: &Batch) -> (f32, usize) {
        let cache = self.forward_cached(batch);
        let labels: Vec<usize> =
            if self.cfg.causal_lm { self.lm_labels(batch) } else { batch.y.clone() };
        let (loss, correct, _) = softmax_xent(&cache.logits, &labels);
        (loss, correct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil;

    fn vit(rng: &mut Pcg) -> Transformer {
        Transformer::new(
            rng,
            TransformerCfg {
                embed: Embed::Patch { img: ImgShape { c: 2, h: 8, w: 8 }, patch: 4 },
                dim: 10,
                depth: 2,
                mlp_ratio: 2,
                out: 3,
                causal_lm: false,
            },
        )
    }

    #[test]
    fn layernorm_rows_standardized() {
        let mut rng = Pcg::new(21);
        let x = rng.normal_mat(5, 16, 3.0);
        let (y, _, _) = layernorm(&x);
        for r in 0..5 {
            let mean: f32 = y.row(r).iter().sum::<f32>() / 16.0;
            let var: f32 = y.row(r).iter().map(|v| v * v).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_bwd_fd() {
        // Check d/dx of sum(w ⊙ LN(x)) against finite differences.
        let mut rng = Pcg::new(22);
        let x = rng.normal_mat(3, 8, 1.0);
        let w = rng.normal_mat(3, 8, 1.0);
        let (_, inv_std, centered) = layernorm(&x);
        let dx = layernorm_bwd(&w, &inv_std, &centered);
        let eps = 1e-2f32;
        for idx in [0usize, 5, 13, 23] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let f = |m: &Mat| -> f32 {
                layernorm(m).0.data().iter().zip(w.data()).map(|(a, b)| a * b).sum()
            };
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((fd - dx.data()[idx]).abs() < 2e-2 * (1.0 + fd.abs()), "idx {idx}: {fd} vs {}", dx.data()[idx]);
        }
    }

    #[test]
    fn vit_gradcheck() {
        let mut rng = Pcg::new(23);
        let mut t = vit(&mut rng);
        let batch = Batch { x: rng.normal_mat(2, 2 * 8 * 8, 1.0), y: vec![0, 2] };
        testutil::check_grads(&mut t, &batch, 30, 6e-2);
    }

    #[test]
    fn vit_stats_reproduce_grads() {
        let mut rng = Pcg::new(24);
        let t = vit(&mut rng);
        let batch = Batch { x: rng.normal_mat(2, 2 * 8 * 8, 1.0), y: vec![1, 2] };
        testutil::check_stats_consistency(&t, &batch, 1e-3);
    }

    #[test]
    fn vit_hook_events_follow_block_reverse_order() {
        let mut rng = Pcg::new(28);
        let t = vit(&mut rng);
        let batch = Batch { x: rng.normal_mat(2, 2 * 8 * 8, 1.0), y: vec![0, 2] };
        let order = testutil::check_hook_events(&t, &batch);
        // Head first, blocks in reverse with per-block order w2, w1, wo,
        // wq, wk, wv, embedding last. depth=2: layers 1..6 are block 0,
        // 7..12 block 1, 13 the head, 0 the embedding.
        assert_eq!(order, vec![13, 12, 11, 10, 7, 8, 9, 6, 5, 4, 1, 2, 3, 0]);
    }

    #[test]
    fn causal_lm_hooked_gradcheck_and_stats() {
        let mut rng = Pcg::new(29);
        let mut t = Transformer::new(
            &mut rng,
            TransformerCfg {
                embed: Embed::Token { vocab: 7 },
                dim: 8,
                depth: 1,
                mlp_ratio: 2,
                out: 7,
                causal_lm: true,
            },
        );
        let x = Mat::from_fn(2, 5, |_, _| rng.below(7) as f32);
        let batch = Batch { x, y: vec![3, 4] };
        testutil::check_hook_events(&t, &batch);
        testutil::check_grads_hooked(&mut t, &batch, 20, 6e-2);
        testutil::check_stats_consistency_hooked(&t, &batch, 1e-3);
    }

    #[test]
    fn causal_lm_gradcheck() {
        let mut rng = Pcg::new(25);
        let mut t = Transformer::new(
            &mut rng,
            TransformerCfg {
                embed: Embed::Token { vocab: 7 },
                dim: 8,
                depth: 1,
                mlp_ratio: 2,
                out: 7,
                causal_lm: true,
            },
        );
        let x = Mat::from_fn(2, 5, |_, _| rng.below(7) as f32);
        let batch = Batch { x, y: vec![3, 4] };
        testutil::check_grads(&mut t, &batch, 20, 6e-2);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // Changing a *future* token must not change the logits at an
        // earlier position.
        let mut rng = Pcg::new(26);
        let t = Transformer::new(
            &mut rng,
            TransformerCfg {
                embed: Embed::Token { vocab: 5 },
                dim: 6,
                depth: 2,
                mlp_ratio: 2,
                out: 5,
                causal_lm: true,
            },
        );
        let x1 = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let x2 = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 0.0]); // last token differs
        let c1 = t.forward_cached(&Batch { x: x1, y: vec![0] });
        let c2 = t.forward_cached(&Batch { x: x2, y: vec![0] });
        for pos in 0..3 {
            for c in 0..5 {
                assert!(
                    (c1.logits.at(pos, c) - c2.logits.at(pos, c)).abs() < 1e-5,
                    "position {pos} saw the future"
                );
            }
        }
    }

    #[test]
    fn vit_trains_on_prototype_images() {
        let mut rng = Pcg::new(27);
        let mut t = vit(&mut rng);
        let protos: Vec<Mat> = (0..3).map(|_| rng.normal_mat(1, 2 * 8 * 8, 1.0)).collect();
        let make = |rng: &mut Pcg| -> Batch {
            let m = 12;
            let y: Vec<usize> = (0..m).map(|_| rng.below(3)).collect();
            let x = Mat::from_fn(m, 2 * 8 * 8, |r, c| protos[y[r]].at(0, c) * 2.0 + rng.normal() * 0.3);
            Batch { x, y }
        };
        let hp = crate::optim::Hyper { lr: 0.1, momentum: 0.9, ..Default::default() };
        let mut opt = crate::optim::Method::AdamW.build(&t.shapes(), &hp);
        let hp2 = crate::optim::Hyper { lr: 0.01, ..hp };
        let _ = hp2;
        for step in 0..60 {
            let b = make(&mut rng);
            let res = t.forward_backward(&b);
            opt.step(step, &mut t.params, &res.grads, &res.stats);
        }
        let b = make(&mut rng);
        let (_, correct) = t.evaluate(&b);
        assert!(correct >= 9, "acc {correct}/12");
    }
}
