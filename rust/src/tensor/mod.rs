//! Dense `f32` matrix substrate.
//!
//! The whole framework is built on this BLAS-free matrix type: row-major
//! storage, packed/tiled matmul kernels for the hot path ([`matmul`]), a
//! persistent worker pool that all parallel kernels share ([`pool`]), and
//! the handful of elementwise / reduction ops the optimizers and models
//! need.
//!
//! The structured Kronecker-factor classes in [`crate::structured`] avoid
//! materializing dense matrices; `Mat` is used for activations, gradients,
//! dense factors, and as the interchange type at module boundaries.

pub mod fft;
mod matmul;
mod ops;
pub mod pool;

pub use matmul::{matmul, matmul_a_bt, matmul_a_wb, matmul_at_b, matmul_into, matmul_wa_b};

/// A dense row-major `f32` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat({}x{})", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for r in 0..rmax {
            write!(f, "  [")?;
            for c in 0..cmax {
                write!(f, "{:9.4} ", self.at(r, c))?;
            }
            writeln!(f, "{}]", if cmax < self.cols { "…" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// All-ones matrix.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Scaled identity `s * I`.
    pub fn eye_scaled(n: usize, s: f32) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = s;
        }
        m
    }

    /// Build from a row-major `Vec` (length must equal `rows*cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: length mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f32]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// A row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Consume into the backing vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying (element count must match).
    pub fn reshape(mut self, rows: usize, cols: usize) -> Self {
        assert_eq!(rows * cols, self.data.len(), "reshape: element count mismatch");
        self.rows = rows;
        self.cols = cols;
        self
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        if self.data.is_empty() {
            return t;
        }
        // Blocked for cache friendliness; large matrices shard the
        // destination rows across the worker pool (disjoint writes, so the
        // result is identical to the serial pass).
        const B: usize = 32;
        let src = &self.data;
        let (rows, cols) = (self.rows, self.cols);
        pool::parallel_chunks_mut(&mut t.data, rows, 256, |c0, chunk| {
            let h = chunk.len() / rows;
            for rb in (0..rows).step_by(B) {
                for cb in (0..h).step_by(B) {
                    for r in rb..(rb + B).min(rows) {
                        for c in cb..(cb + B).min(h) {
                            chunk[c * rows + r] = src[r * cols + c0 + c];
                        }
                    }
                }
            }
        });
        t
    }

    /// Bytes of backing storage (f32).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_and_at() {
        let m = Mat::eye(3);
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(0, 1), 0.0);
        assert_eq!(m.shape(), (3, 3));
    }

    #[test]
    fn from_fn_indexing() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(5, 7, |r, c| (r * 7 + c) as f32);
        let t = m.transpose();
        assert_eq!(t.shape(), (7, 5));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn reshape_preserves_data() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let r = m.clone().reshape(3, 2);
        assert_eq!(r.at(2, 1), 6.0);
        assert_eq!(r.data(), m.data());
    }

    #[test]
    #[should_panic]
    fn from_vec_len_mismatch_panics() {
        let _ = Mat::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn diag_constructor() {
        let d = Mat::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(d.at(1, 1), 2.0);
        assert_eq!(d.at(0, 1), 0.0);
    }
}
