//! Elementwise and reduction operations on [`Mat`].

use super::Mat;

impl Mat {
    /// Elementwise map, returning a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise binary zip: `f(self[i], other[i])`.
    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!(self.shape(), other.shape(), "zip: shape mismatch");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a + b)
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a - b)
    }

    /// Hadamard (elementwise) product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a * b)
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f32) -> Mat {
        self.map(|x| x * s)
    }

    /// `self += alpha * other` in place (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// `self = beta*self + alpha*other` in place (scaled EMA step).
    pub fn ema(&mut self, beta: f32, alpha: f32, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "ema: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = beta * *a + alpha * b;
        }
    }

    /// Add `s` to each diagonal entry (square matrices).
    pub fn add_diag(&mut self, s: f32) {
        assert_eq!(self.rows, self.cols, "add_diag: not square");
        for i in 0..self.rows {
            self.data[i * self.cols + i] += s;
        }
    }

    /// Trace (sum of diagonal).
    pub fn trace(&self) -> f32 {
        assert_eq!(self.rows, self.cols, "trace: not square");
        (0..self.rows).map(|i| self.data[i * self.cols + i] as f64).sum::<f64>() as f32
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        self.sum() / self.data.len() as f32
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// True if any entry is NaN or infinite.
    pub fn has_nonfinite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// Column means as a `1 x cols` matrix.
    pub fn col_mean(&self) -> Mat {
        let mut out = Mat::zeros(1, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c] += self.data[r * self.cols + c];
            }
        }
        let inv = 1.0 / self.rows as f32;
        for v in &mut out.data {
            *v *= inv;
        }
        out
    }

    /// Row-wise softmax (used by attention and classification losses).
    ///
    /// Rows are independent, so large batches shard across the worker
    /// pool; per-row arithmetic is unchanged, keeping results identical to
    /// the serial pass.
    pub fn softmax_rows(&self) -> Mat {
        let mut out = self.clone();
        if out.data.is_empty() {
            return out;
        }
        let cols = self.cols;
        super::pool::parallel_chunks_mut(&mut out.data, cols, 64, |_, chunk| {
            for row in chunk.chunks_mut(cols) {
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    z += *v;
                }
                let inv = 1.0 / z;
                for v in row.iter_mut() {
                    *v *= inv;
                }
            }
        });
        out
    }

    /// Broadcast-add a `1 x cols` row vector to every row.
    pub fn add_row_broadcast(&self, row: &Mat) -> Mat {
        assert_eq!(row.rows(), 1);
        assert_eq!(row.cols(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[r * self.cols + c] += row.data[c];
            }
        }
        out
    }

    /// Extract the main diagonal.
    pub fn diagonal(&self) -> Vec<f32> {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + i]).collect()
    }

    /// Symmetrize: `(A + Aᵀ)/2`.
    pub fn symmetrize(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            for c in (r + 1)..self.cols {
                let v = 0.5 * (self.at(r, c) + self.at(c, r));
                out.set(r, c, v);
                out.set(c, r, v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::eye(2);
        assert_eq!(a.add(&b).at(0, 0), 2.0);
        assert_eq!(a.sub(&b).at(1, 1), 3.0);
        assert_eq!(a.hadamard(&a).at(1, 0), 9.0);
        assert_eq!(a.scale(2.0).at(0, 1), 4.0);
    }

    #[test]
    fn trace_and_norms() {
        let a = Mat::from_vec(2, 2, vec![3., 0., 0., 4.]);
        assert_eq!(a.trace(), 7.0);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn ema_step() {
        let mut a = Mat::ones(1, 2);
        let b = Mat::from_vec(1, 2, vec![3.0, 5.0]);
        a.ema(0.5, 0.5, &b);
        assert_eq!(a.data(), &[2.0, 3.0]);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., -1., 0., 1.]);
        let s = a.softmax_rows();
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        assert!(s.at(0, 2) > s.at(0, 1));
    }

    #[test]
    fn symmetrize_is_symmetric() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 4., 3.]);
        let s = a.symmetrize();
        assert_eq!(s.at(0, 1), s.at(1, 0));
        assert_eq!(s.at(0, 1), 3.0);
    }

    #[test]
    fn nonfinite_detection() {
        let mut a = Mat::ones(2, 2);
        assert!(!a.has_nonfinite());
        a.set(0, 1, f32::NAN);
        assert!(a.has_nonfinite());
    }

    #[test]
    fn add_diag_and_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(2.5);
        assert_eq!(a.diagonal(), vec![2.5, 2.5, 2.5]);
    }

    #[test]
    fn col_mean_values() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let m = a.col_mean();
        assert_eq!(m.data(), &[2.0, 3.0]);
    }
}
