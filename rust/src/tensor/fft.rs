//! Radix-2 FFT substrate.
//!
//! Powers the `O(d log d)` Toeplitz-factor operations of paper Table 2:
//! coefficient convolution (Toeplitz × Toeplitz) and batched
//! autocorrelation (the Toeplitz `Π̂(BᵀB)` projection). §Perf iteration 4.

/// In-place iterative radix-2 complex FFT (`invert` = inverse transform,
/// including the 1/n scaling). `re.len()` must be a power of two.
pub fn fft(re: &mut [f32], im: &mut [f32], invert: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft: length must be a power of two");
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    // Butterflies.
    let mut len = 2usize;
    while len <= n {
        let ang = 2.0 * std::f64::consts::PI / len as f64 * if invert { 1.0 } else { -1.0 };
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cur_r, mut cur_i) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k] as f64, im[i + k] as f64);
                let (vr0, vi0) = (re[i + k + len / 2] as f64, im[i + k + len / 2] as f64);
                let vr = vr0 * cur_r - vi0 * cur_i;
                let vi = vr0 * cur_i + vi0 * cur_r;
                re[i + k] = (ur + vr) as f32;
                im[i + k] = (ui + vi) as f32;
                re[i + k + len / 2] = (ur - vr) as f32;
                im[i + k + len / 2] = (ui - vi) as f32;
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
            i += len;
        }
        len <<= 1;
    }
    if invert {
        let inv = 1.0 / n as f32;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }
}

/// Truncated linear convolution: `out[j] = Σ_{i≤j} a[i] b[j−i]` for
/// `j < d`, via FFT of size `≥ 2d`.
pub fn convolve_trunc(a: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let n = (2 * d).next_power_of_two();
    let mut ar = vec![0.0f32; n];
    let mut ai = vec![0.0f32; n];
    let mut br = vec![0.0f32; n];
    let mut bi = vec![0.0f32; n];
    ar[..a.len().min(d)].copy_from_slice(&a[..a.len().min(d)]);
    br[..b.len().min(d)].copy_from_slice(&b[..b.len().min(d)]);
    fft(&mut ar, &mut ai, false);
    fft(&mut br, &mut bi, false);
    for i in 0..n {
        let (x, y) = (ar[i], ai[i]);
        ar[i] = x * br[i] - y * bi[i];
        ai[i] = x * bi[i] + y * br[i];
    }
    fft(&mut ar, &mut ai, true);
    ar.truncate(d);
    ar
}

/// Batched autocorrelation: given rows `rows` (each of length `d`),
/// returns `s[j] = Σ_rows Σ_k row[k]·row[k+j]` for `j = 0..d-1`,
/// computed as `IFFT( Σ_rows |FFT(row)|² )` — one inverse transform for
/// the whole batch.
pub fn batched_autocorr(rows: impl Iterator<Item = impl AsRef<[f32]>>, d: usize) -> Vec<f32> {
    let n = (2 * d).next_power_of_two();
    let mut acc_r = vec![0.0f32; n];
    let mut re = vec![0.0f32; n];
    let mut im = vec![0.0f32; n];
    let mut any = false;
    for row in rows {
        let row = row.as_ref();
        any = true;
        re[..d].copy_from_slice(&row[..d]);
        re[d..].fill(0.0);
        im.fill(0.0);
        fft(&mut re, &mut im, false);
        for i in 0..n {
            acc_r[i] += re[i] * re[i] + im[i] * im[i];
        }
    }
    if !any {
        return vec![0.0; d];
    }
    let mut acc_i = vec![0.0f32; n];
    fft(&mut acc_r, &mut acc_i, true);
    acc_r.truncate(d);
    acc_r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, Pcg};

    #[test]
    fn fft_roundtrip() {
        let mut rng = Pcg::new(91);
        let n = 64;
        let orig: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut re = orig.clone();
        let mut im = vec![0.0f32; n];
        fft(&mut re, &mut im, false);
        fft(&mut re, &mut im, true);
        for (a, b) in re.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for v in &im {
            assert!(v.abs() < 1e-4);
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut re = vec![0.0f32; 8];
        let mut im = vec![0.0f32; 8];
        re[0] = 1.0;
        fft(&mut re, &mut im, false);
        for i in 0..8 {
            assert!((re[i] - 1.0).abs() < 1e-6 && im[i].abs() < 1e-6);
        }
    }

    #[test]
    fn convolve_matches_direct() {
        forall(92, 10, |rng, _| {
            let d = 1 + rng.below(40);
            let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let got = convolve_trunc(&a, &b, d);
            for j in 0..d {
                let want: f32 = (0..=j).map(|i| a[i] * b[j - i]).sum();
                assert!((got[j] - want).abs() < 1e-3 * (1.0 + want.abs()), "j={j}: {} vs {want}", got[j]);
            }
        });
    }

    #[test]
    fn batched_autocorr_matches_direct() {
        forall(93, 8, |rng, _| {
            let d = 2 + rng.below(24);
            let m = 1 + rng.below(6);
            let rows: Vec<Vec<f32>> = (0..m).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
            let got = batched_autocorr(rows.iter(), d);
            for j in 0..d {
                let want: f32 = rows
                    .iter()
                    .map(|r| (0..d - j).map(|k| r[k] * r[k + j]).sum::<f32>())
                    .sum();
                assert!(
                    (got[j] - want).abs() < 2e-3 * (1.0 + want.abs()),
                    "j={j}: {} vs {want}",
                    got[j]
                );
            }
        });
    }
}
