//! Matrix multiplication kernels.
//!
//! The framework's Rust-side hot path (model fwd/bwd for the native models,
//! and every optimizer's preconditioner algebra) bottoms out here. The
//! kernels are BLAS-free but shaped like a real BLAS:
//!
//! - **Panel packing + register tiling.** Tiles of `A` and panels of `B`
//!   are repacked into contiguous, zero-padded strips ([`pack_a`] /
//!   [`pack_b`]) and consumed by a fixed-width `4×16` microkernel
//!   ([`microkernel_4x16`]) whose inner loops have compile-time trip
//!   counts, so the autovectorizer keeps the 4×16 accumulator tile in
//!   vector registers and emits FMA streams. Measured on the reference
//!   machine this roughly doubles single-thread GFLOP/s over the previous
//!   unpacked 2-row kernel (EXPERIMENTS.md §Perf, iterations 6–7).
//! - **Persistent pool sharding.** Large products are sharded by row
//!   blocks of `C` across the lazily-initialized worker pool in
//!   [`super::pool`] — no per-call thread spawns anywhere in `tensor::`.
//!   Sharding is over disjoint `C` row blocks and the per-element
//!   accumulation order never depends on the partition, so pooled and
//!   serial runs are bitwise identical (`rust/tests/parallel.rs`).
//! - **`AᵀB` without the transpose.** [`matmul_at_b`] (the per-step
//!   Kronecker-statistics product `Xᵀ X`) reuses the same blocked +
//!   packed + pooled regime via a transposed `A`-packing ([`pack_at`]);
//!   it is no longer a serial unblocked loop.
//!
//! Tile sizes: `MC×KC` tiles of `A` and `KC×NC` panels of `B` (L1/L2
//! resident), strips of `MR = 4` rows × `NR = 16` columns for the
//! microkernel. Tiny products (< [`TINY_FLOPS`]) skip packing entirely.
//!
//! Benchmarked in `rust/benches/hotpath.rs`; see EXPERIMENTS.md §Perf for
//! the naive → blocked → packed → pooled iteration log.

use super::pool;
use super::Mat;

/// Tile sizes (empirically tuned on the target CPU; see §Perf).
const MC: usize = 64; // rows of A per tile
const KC: usize = 256; // inner dimension per tile
const NC: usize = 256; // cols of B per tile

/// Microkernel register-tile shape: MR rows × NR columns of `C`.
const MR: usize = 4;
const NR: usize = 16;

/// FLOP threshold above which matmul shards across the worker pool
/// (§Perf iteration 2: below this, sharding overhead dominates — the
/// persistent pool lowered the crossover vs. spawned threads, but small
/// products still belong on the caller's core).
const PAR_FLOPS: usize = 1 << 20;

/// FLOP threshold below which the pack-free scalar loop wins (packing a
/// panel costs more than the whole product for ~16³ and under).
const TINY_FLOPS: usize = 8192;

/// Element source for panel packing: plain `f32` slices, or dtype-narrowed
/// `u16` words widened through a conversion function *at pack time*. The
/// packers copy into contiguous zero-padded strips anyway, so the u16→f32
/// conversion rides that copy and the microkernel always accumulates f32 —
/// half-precision operands cost one extra convert per packed element,
/// nothing on the FMA stream.
#[derive(Clone, Copy)]
enum Src<'a> {
    F32(&'a [f32]),
    U16(&'a [u16], fn(u16) -> f32),
}

impl Src<'_> {
    #[inline]
    fn at(self, idx: usize) -> f32 {
        match self {
            Src::F32(s) => s[idx],
            Src::U16(s, widen) => widen(s[idx]),
        }
    }
}

/// `C = A @ B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c, false);
    c
}

/// `C = A @ B` where `A` is `m×k` of dtype-narrowed `u16` words, widened
/// through `widen` at pack time. Bitwise identical to widening the whole
/// operand into an `f32` matrix first (same blocking, same accumulation
/// order) at every size — without materializing the 4-byte copy.
pub fn matmul_wa_b(ad: &[u16], widen: fn(u16) -> f32, m: usize, k: usize, b: &Mat) -> Mat {
    assert_eq!(ad.len(), m * k, "matmul_wa_b: payload len");
    assert_eq!(k, b.rows(), "matmul_wa_b: inner dims {m}x{k} @ {}x{}", b.rows(), b.cols());
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    let flops = 2 * m * k * n;
    if flops < TINY_FLOPS {
        // Below the packing threshold there is no pack to ride; widening
        // into a scratch operand is the same arithmetic on the same values.
        let wa = Mat::from_vec(m, k, ad.iter().map(|&u| widen(u)).collect());
        matmul_into(&wa, b, &mut c, false);
        return c;
    }
    let a_src = Src::U16(ad, widen);
    let b_src = Src::F32(b.data());
    if flops < PAR_FLOPS {
        gemm_rows(a_src, b_src, c.data_mut(), 0, m, k, n, k, false);
        return c;
    }
    pool::parallel_chunks_mut(c.data_mut(), n, MR, |row0, chunk| {
        let rows = chunk.len() / n;
        gemm_rows(a_src, b_src, chunk, row0, rows, k, n, k, false);
    });
    c
}

/// `C = A @ B` where `B` is `k×n` of dtype-narrowed `u16` words, widened
/// through `widen` at pack time (see [`matmul_wa_b`]).
pub fn matmul_a_wb(a: &Mat, bd: &[u16], widen: fn(u16) -> f32, k: usize, n: usize) -> Mat {
    assert_eq!(bd.len(), k * n, "matmul_a_wb: payload len");
    assert_eq!(a.cols(), k, "matmul_a_wb: inner dims {}x{} @ {k}x{n}", a.rows(), a.cols());
    let m = a.rows();
    let mut c = Mat::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    let flops = 2 * m * k * n;
    if flops < TINY_FLOPS {
        let wb = Mat::from_vec(k, n, bd.iter().map(|&u| widen(u)).collect());
        matmul_into(a, &wb, &mut c, false);
        return c;
    }
    let a_src = Src::F32(a.data());
    let b_src = Src::U16(bd, widen);
    if flops < PAR_FLOPS {
        gemm_rows(a_src, b_src, c.data_mut(), 0, m, k, n, k, false);
        return c;
    }
    pool::parallel_chunks_mut(c.data_mut(), n, MR, |row0, chunk| {
        let rows = chunk.len() / n;
        gemm_rows(a_src, b_src, chunk, row0, rows, k, n, k, false);
    });
    c
}

/// `C (+)= A @ B`. If `accumulate` is false, `c` is overwritten.
///
/// Large products are sharded by row-blocks of `C` across the persistent
/// worker pool (each shard owns a disjoint slice of `C`, so no
/// synchronization is needed); small products stay on the caller.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if !accumulate {
        c.data_mut().fill(0.0);
    }
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let ad = a.data();
    let bd = b.data();
    let flops = 2 * m * k * n;
    if flops < TINY_FLOPS {
        matmul_tiny(ad, bd, c.data_mut(), m, k, n);
        return;
    }
    if flops < PAR_FLOPS {
        gemm_rows(Src::F32(ad), Src::F32(bd), c.data_mut(), 0, m, k, n, k, false);
        return;
    }
    pool::parallel_chunks_mut(c.data_mut(), n, MR, |row0, chunk| {
        let rows = chunk.len() / n;
        gemm_rows(Src::F32(ad), Src::F32(bd), chunk, row0, rows, k, n, k, false);
    });
}

/// `C = Aᵀ @ B` without materializing the transpose.
///
/// Used for Kronecker-factor statistics `U = Xᵀ X / m` where `X` is a
/// `(batch, d)` activation matrix — a per-optimizer-step product, now under
/// the same blocked + packed + pooled regime as [`matmul_into`] (rows of
/// `C` index *columns* of `A`; [`pack_at`] reads them contiguously).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: row mismatch");
    let (m, ka) = (a.rows(), a.cols());
    let n = b.cols();
    let mut c = Mat::zeros(ka, n);
    if m == 0 || ka == 0 || n == 0 {
        return c;
    }
    let ad = a.data();
    let bd = b.data();
    let flops = 2 * m * ka * n;
    if flops < TINY_FLOPS {
        at_b_tiny(ad, bd, c.data_mut(), m, ka, n);
        return c;
    }
    if flops < PAR_FLOPS {
        gemm_rows(Src::F32(ad), Src::F32(bd), c.data_mut(), 0, ka, m, n, ka, true);
        return c;
    }
    pool::parallel_chunks_mut(c.data_mut(), n, MR, |row0, chunk| {
        let rows = chunk.len() / n;
        gemm_rows(Src::F32(ad), Src::F32(bd), chunk, row0, rows, m, n, ka, true);
    });
    c
}

/// `C = A @ Bᵀ` without materializing the transpose.
///
/// Row-dot formulation: both operands are traversed along contiguous rows,
/// with an 8-lane accumulator dot product ([`dot8`]) so the FP adds
/// pipeline and vectorize; sharded across the pool by rows of `A`.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: col mismatch");
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    let mut c = Mat::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    let ad = a.data();
    let bd = b.data();
    let flops = 2 * m * k * n;
    if flops < PAR_FLOPS {
        a_bt_rows(ad, bd, c.data_mut(), 0, m, k, n);
        return c;
    }
    pool::parallel_chunks_mut(c.data_mut(), n, 1, |row0, chunk| {
        let rows = chunk.len() / n;
        a_bt_rows(ad, bd, chunk, row0, rows, k, n);
    });
    c
}

/// Pack-free fallback for tiny products (`i-k-j` order, zero-skip).
fn matmul_tiny(ad: &[f32], bd: &[f32], cd: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// Pack-free fallback for tiny `AᵀB` (`p`-outer order).
fn at_b_tiny(ad: &[f32], bd: &[f32], cd: &mut [f32], m: usize, ka: usize, n: usize) {
    for p in 0..m {
        let arow = &ad[p * ka..(p + 1) * ka];
        let brow = &bd[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..i * n + n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// Blocked, panel-packed kernel over `rows` rows of `C` starting at
/// absolute row `row0` (`cd` holds exactly those rows; `C` has `n` cols).
///
/// `k` is the shared inner dimension. When `transpose_a` is false, `A` is
/// row-major with leading dimension `lda == k` and `C` rows index `A`
/// rows; when true, `A` is `k × lda` row-major and `C` rows index `A`
/// *columns* (computing `AᵀB`).
///
/// Determinism: for every `C` element the contribution order is `p`
/// ascending (registers accumulate `p` within each `KC` block, blocks are
/// visited in order), independent of `row0`/`rows` — so any row-sharding
/// of `C` is bitwise identical to the serial pass.
fn gemm_rows(
    ad: Src,
    bd: Src,
    cd: &mut [f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    lda: usize,
    transpose_a: bool,
) {
    let kc_max = KC.min(k);
    let mc_max = MC.min(rows);
    let ncp = NC.min(n).next_multiple_of(NR);
    let mcp = mc_max.next_multiple_of(MR);
    let mut pb = vec![0.0f32; kc_max * ncp];
    let mut pa = vec![0.0f32; mcp * kc_max];
    for kb in (0..k).step_by(KC) {
        let kc = kc_max.min(k - kb);
        for jb in (0..n).step_by(NC) {
            let nc = NC.min(n - jb);
            pack_b(bd, &mut pb, kb, kc, jb, nc, n);
            for ib in (0..rows).step_by(MC) {
                let mc = mc_max.min(rows - ib);
                if transpose_a {
                    pack_at(ad, &mut pa, row0 + ib, mc, kb, kc, lda);
                } else {
                    pack_a(ad, &mut pa, row0 + ib, mc, kb, kc, lda);
                }
                let mut is = 0;
                while is < mc {
                    let mr = MR.min(mc - is);
                    let pa_strip = &pa[(is / MR) * kc * MR..(is / MR + 1) * kc * MR];
                    let mut js = 0;
                    while js < nc {
                        let nr = NR.min(nc - js);
                        let pb_strip = &pb[(js / NR) * kc * NR..(js / NR + 1) * kc * NR];
                        microkernel_4x16(
                            pa_strip,
                            pb_strip,
                            &mut cd[(ib + is) * n + jb + js..],
                            n,
                            mr,
                            nr,
                        );
                        js += NR;
                    }
                    is += MR;
                }
            }
        }
    }
}

/// Pack a `kc × nc` panel of `B` (row-major, `n` cols wide) into
/// contiguous `NR`-wide column strips: strip `s` holds, for each `p`, the
/// `NR` values `B[kb+p][jb + s·NR ..]`, zero-padded past the panel edge so
/// the microkernel never needs a column-fringe path.
fn pack_b(bd: Src, pb: &mut [f32], kb: usize, kc: usize, jb: usize, nc: usize, n: usize) {
    for s in 0..nc.div_ceil(NR) {
        let j0 = jb + s * NR;
        let w = NR.min(jb + nc - j0);
        let dst = &mut pb[s * kc * NR..(s + 1) * kc * NR];
        for p in 0..kc {
            let base = (kb + p) * n + j0;
            let drow = &mut dst[p * NR..(p + 1) * NR];
            match bd {
                Src::F32(src) => drow[..w].copy_from_slice(&src[base..base + w]),
                Src::U16(src, widen) => {
                    for (x, &u) in drow[..w].iter_mut().zip(&src[base..base + w]) {
                        *x = widen(u);
                    }
                }
            }
            for x in &mut drow[w..] {
                *x = 0.0;
            }
        }
    }
}

/// Pack an `mc × kc` tile of row-major `A` (leading dim `lda`) into
/// `MR`-high row strips: strip `s` holds, for each `p`, the `MR` values
/// `A[r0 + s·MR ..][kb+p]`, zero-padded past the tile edge. Padded rows
/// multiply real `B` values but land in accumulator rows that are never
/// stored, so they cost nothing and corrupt nothing.
fn pack_a(ad: Src, pa: &mut [f32], r0: usize, mc: usize, kb: usize, kc: usize, lda: usize) {
    for s in 0..mc.div_ceil(MR) {
        let base = r0 + s * MR;
        let h = MR.min(mc - s * MR);
        let dst = &mut pa[s * kc * MR..(s + 1) * kc * MR];
        for p in 0..kc {
            let drow = &mut dst[p * MR..(p + 1) * MR];
            for (i, x) in drow.iter_mut().enumerate() {
                *x = if i < h { ad.at((base + i) * lda + kb + p) } else { 0.0 };
            }
        }
    }
}

/// Like [`pack_a`] but for `AᵀB`: strip rows are *columns* of the
/// `k × lda` row-major `A`, so for each `p` the `MR` values
/// `A[kb+p][c0 + s·MR ..]` are a contiguous read.
fn pack_at(ad: Src, pa: &mut [f32], c0: usize, mc: usize, kb: usize, kc: usize, lda: usize) {
    for s in 0..mc.div_ceil(MR) {
        let base = c0 + s * MR;
        let h = MR.min(mc - s * MR);
        let dst = &mut pa[s * kc * MR..(s + 1) * kc * MR];
        for p in 0..kc {
            let base_idx = (kb + p) * lda + base;
            let drow = &mut dst[p * MR..(p + 1) * MR];
            match ad {
                Src::F32(src) => drow[..h].copy_from_slice(&src[base_idx..base_idx + h]),
                Src::U16(src, widen) => {
                    for (x, &u) in drow[..h].iter_mut().zip(&src[base_idx..base_idx + h]) {
                        *x = widen(u);
                    }
                }
            }
            for x in &mut drow[h..] {
                *x = 0.0;
            }
        }
    }
}

/// The `MR×NR = 4×16` register-tile microkernel.
///
/// `pa` is one packed `A` strip (`kc·MR` values), `pb` one packed `B`
/// strip (`kc·NR` values). Four separate fixed-width accumulator rows with
/// compile-time trip counts are what the autovectorizer needs to keep the
/// whole tile in vector registers (a 2-D `[[f32; NR]; MR]` array spills —
/// §Perf iteration 6). Only the `mr × nr` in-bounds corner is added to
/// `C`; the zero-padded lanes accumulate garbage-free zeros.
#[inline]
fn microkernel_4x16(pa: &[f32], pb: &[f32], cd: &mut [f32], ldc: usize, mr: usize, nr: usize) {
    let mut acc0 = [0.0f32; NR];
    let mut acc1 = [0.0f32; NR];
    let mut acc2 = [0.0f32; NR];
    let mut acc3 = [0.0f32; NR];
    for (av, bv) in pa.chunks_exact(MR).zip(pb.chunks_exact(NR)) {
        let (v0, v1, v2, v3) = (av[0], av[1], av[2], av[3]);
        for j in 0..NR {
            acc0[j] += v0 * bv[j];
            acc1[j] += v1 * bv[j];
            acc2[j] += v2 * bv[j];
            acc3[j] += v3 * bv[j];
        }
    }
    let accs: [&[f32; NR]; MR] = [&acc0, &acc1, &acc2, &acc3];
    for (i, acc) in accs.iter().enumerate().take(mr) {
        let crow = &mut cd[i * ldc..i * ldc + nr];
        for (cv, &av) in crow.iter_mut().zip(acc.iter()) {
            *cv += av;
        }
    }
}

/// Serial `A @ Bᵀ` over `rows` rows of `C` starting at `row0`.
fn a_bt_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for i in 0..rows {
        let arow = &ad[(row0 + i) * k..(row0 + i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            cd[i * n + j] = dot8(arow, brow);
        }
    }
}

/// Dot product with 8 independent accumulator lanes (one vector register
/// at f32x8). Operand lengths must match — a silent truncation here would
/// corrupt every `A Bᵀ` product downstream.
#[inline]
fn dot8(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot8: length mismatch");
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let xr = xc.remainder();
    let yr = yc.remainder();
    let mut acc = [0.0f32; 8];
    for (xs, ys) in xc.zip(yc) {
        for j in 0..8 {
            acc[j] += xs[j] * ys[j];
        }
    }
    let mut tail = 0.0f32;
    for (&xv, &yv) in xr.iter().zip(yr.iter()) {
        tail += xv * yv;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Pcg;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::ones(2, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut rng = Pcg::new(7);
        for _ in 0..10 {
            let m = 1 + (rng.next_u32() % 70) as usize;
            let k = 1 + (rng.next_u32() % 70) as usize;
            let n = 1 + (rng.next_u32() % 70) as usize;
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_blocked_crosses_tile_boundaries() {
        let mut rng = Pcg::new(3);
        let a = Mat::from_fn(MC + 3, KC + 5, |_, _| rng.normal());
        let b = Mat::from_fn(KC + 5, NC + 2, |_, _| rng.normal());
        assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
    }

    #[test]
    fn matmul_crosses_microkernel_fringes() {
        // Shapes straddling every MR/NR strip boundary around one tile.
        let mut rng = Pcg::new(19);
        for (m, k, n) in [(MR + 1, 9, NR + 1), (2 * MR - 1, KC + 1, NR - 1), (1, 3, NR + 3)] {
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg::new(11);
        let a = Mat::from_fn(17, 9, |_, _| rng.normal());
        let b = Mat::from_fn(17, 13, |_, _| rng.normal());
        assert_close(&matmul_at_b(&a, &b), &matmul(&a.transpose(), &b), 1e-5);
    }

    #[test]
    fn at_b_matches_explicit_transpose_blocked_sizes() {
        let mut rng = Pcg::new(23);
        let a = Mat::from_fn(KC + 9, MC + 5, |_, _| rng.normal());
        let b = Mat::from_fn(KC + 9, NR * 3 + 2, |_, _| rng.normal());
        assert_close(&matmul_at_b(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg::new(13);
        let a = Mat::from_fn(8, 21, |_, _| rng.normal());
        let b = Mat::from_fn(5, 21, |_, _| rng.normal());
        assert_close(&matmul_a_bt(&a, &b), &matmul(&a, &b.transpose()), 1e-5);
    }

    #[test]
    fn widened_matmul_matches_prewidened_bitwise() {
        // The u16 entry points must be bitwise identical to widening the
        // operand first, across the tiny / serial-blocked / pooled
        // dispatch tiers (the pack-time conversion feeds the microkernel
        // the exact same panel values).
        fn widen_half(bits: u16) -> f32 {
            // A stand-in conversion with the same shape as bf16 widening.
            f32::from_bits((bits as u32) << 16)
        }
        let mut rng = Pcg::new(41);
        for (m, k, n) in [(3usize, 5usize, 4usize), (40, 60, 50), (MC + 3, KC + 5, NC + 2)] {
            let a_bits: Vec<u16> =
                (0..m * k).map(|_| (rng.next_u32() >> 16) as u16 & 0x7f7f).collect();
            let b_bits: Vec<u16> =
                (0..k * n).map(|_| (rng.next_u32() >> 16) as u16 & 0x7f7f).collect();
            let aw = Mat::from_vec(m, k, a_bits.iter().map(|&u| widen_half(u)).collect());
            let bw = Mat::from_vec(k, n, b_bits.iter().map(|&u| widen_half(u)).collect());
            let c_wa = matmul_wa_b(&a_bits, widen_half, m, k, &bw);
            assert_eq!(c_wa, matmul(&aw, &bw), "wa {m}x{k}x{n}");
            let c_wb = matmul_a_wb(&aw, &b_bits, widen_half, k, n);
            assert_eq!(c_wb, matmul(&aw, &bw), "wb {m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_into_accumulates() {
        let a = Mat::eye(3);
        let b = Mat::ones(3, 3);
        let mut c = Mat::ones(3, 3);
        matmul_into(&a, &b, &mut c, true);
        assert_eq!(c.at(0, 0), 2.0);
        matmul_into(&a, &b, &mut c, false);
        assert_eq!(c.at(0, 0), 1.0);
    }
}
