//! Matrix multiplication kernels.
//!
//! The framework's Rust-side hot path (model fwd/bwd for the native models,
//! and every optimizer's preconditioner algebra) bottoms out here. We keep a
//! simple, portable blocked kernel: pack-free, row-major, `i-k-j` loop order
//! with a tiled outer structure so panels of `b` stay in L1/L2.
//!
//! Benchmarked in `rust/benches/hotpath.rs`; see EXPERIMENTS.md §Perf for
//! the naive → blocked → parallel iteration log.

use super::Mat;

/// Tile sizes (empirically tuned on the target CPU; see §Perf).
const MC: usize = 64; // rows of A per tile
const KC: usize = 256; // inner dimension per tile
const NC: usize = 256; // cols of B per tile

/// FLOP threshold above which matmul fans out across threads (§Perf
/// iteration 2: below this, thread spawn overhead dominates).
const PAR_FLOPS: usize = 4 << 20;

/// `C = A @ B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c, false);
    c
}

/// Worker count for parallel kernels (respects `SINGD_THREADS`).
pub(crate) fn num_threads() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("SINGD_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// `C (+)= A @ B`. If `accumulate` is false, `c` is overwritten.
///
/// Large products are sharded by row-blocks across `std::thread::scope`
/// workers (each worker owns a disjoint slice of `C`, so no synchronization
/// is needed); small products stay single-threaded.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if !accumulate {
        c.data_mut().fill(0.0);
    }
    let nt = num_threads();
    let flops = 2 * m * k * n;
    if nt <= 1 || flops < PAR_FLOPS || m < 2 {
        matmul_rows(a.data(), b.data(), c.data_mut(), 0, m, k, n);
        return;
    }
    let nt = nt.min(m);
    let rows_per = m.div_ceil(nt);
    let ad = a.data();
    let bd = b.data();
    let chunks: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|scope| {
        for (ci, chunk) in chunks.into_iter().enumerate() {
            let row0 = ci * rows_per;
            let rows = chunk.len() / n;
            scope.spawn(move || {
                matmul_rows(ad, bd, chunk, row0, rows, k, n);
            });
        }
    });
}

/// Serial blocked kernel over `rows` rows of `C` starting at `row0` (the
/// `cd` slice holds exactly those rows).
fn matmul_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for ib in (0..rows).step_by(MC) {
            let iend = (ib + MC).min(rows);
            for jb in (0..n).step_by(NC) {
                let jend = (jb + NC).min(n);
                let width = jend - jb;
                // 2-row microkernel: each B panel load feeds two C rows
                // (§Perf iteration 5: ~halves B-panel traffic).
                let mut i = ib;
                while i + 1 < iend {
                    let a0 = &ad[(row0 + i) * k..(row0 + i + 1) * k];
                    let a1 = &ad[(row0 + i + 1) * k..(row0 + i + 2) * k];
                    let (c0, rest) = cd[i * n + jb..].split_at_mut(n);
                    let c0 = &mut c0[..width];
                    let c1 = &mut rest[..width];
                    for p in kb..kend {
                        let (v0, v1) = (a0[p], a1[p]);
                        if v0 == 0.0 && v1 == 0.0 {
                            continue;
                        }
                        let brow = &bd[p * n + jb..p * n + jend];
                        for ((x0, x1), bv) in c0.iter_mut().zip(c1.iter_mut()).zip(brow.iter()) {
                            *x0 += v0 * bv;
                            *x1 += v1 * bv;
                        }
                    }
                    i += 2;
                }
                if i < iend {
                    let arow = &ad[(row0 + i) * k..(row0 + i + 1) * k];
                    let crow = &mut cd[i * n + jb..i * n + jend];
                    for p in kb..kend {
                        let aval = arow[p];
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &bd[p * n + jb..p * n + jend];
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aval * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = Aᵀ @ B` without materializing the transpose.
///
/// Used for Kronecker-factor statistics `U = Xᵀ X / m` where `X` is a
/// `(batch, d)` activation matrix.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: row mismatch");
    let (m, ka) = (a.rows(), a.cols());
    let n = b.cols();
    let mut c = Mat::zeros(ka, n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    // c[i][j] = sum_p a[p][i] * b[p][j]; iterate p outer for contiguity.
    for p in 0..m {
        let arow = &ad[p * ka..(p + 1) * ka];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..ka {
            let aval = arow[i];
            if aval == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..i * n + n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aval * bv;
            }
        }
    }
    c
}

/// `C = A @ Bᵀ` without materializing the transpose.
///
/// Row-dot formulation with 4 independent accumulators per dot product so
/// the FP adds pipeline (§Perf iteration 3), sharded across threads by rows
/// of `A` when large.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: col mismatch");
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    let mut c = Mat::zeros(m, n);
    let ad = a.data();
    let bd = b.data();
    let nt = num_threads();
    let flops = 2 * m * k * n;
    if nt <= 1 || flops < PAR_FLOPS || m < 2 {
        a_bt_rows(ad, bd, c.data_mut(), 0, m, k, n);
        return c;
    }
    let nt = nt.min(m);
    let rows_per = m.div_ceil(nt);
    let chunks: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|scope| {
        for (ci, chunk) in chunks.into_iter().enumerate() {
            let row0 = ci * rows_per;
            let rows = chunk.len() / n;
            scope.spawn(move || {
                a_bt_rows(ad, bd, chunk, row0, rows, k, n);
            });
        }
    });
    c
}

fn a_bt_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for i in 0..rows {
        let arow = &ad[(row0 + i) * k..(row0 + i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            cd[i * n + j] = dot4(arow, brow);
        }
    }
}

/// Dot product with 4 independent accumulator lanes.
#[inline]
fn dot4(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = 4 * c;
        a0 += x[i] * y[i];
        a1 += x[i + 1] * y[i + 1];
        a2 += x[i + 2] * y[i + 2];
        a3 += x[i + 3] * y[i + 3];
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for i in 4 * chunks..n {
        acc += x[i] * y[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Pcg;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::ones(2, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut rng = Pcg::new(7);
        for _ in 0..10 {
            let m = 1 + (rng.next_u32() % 70) as usize;
            let k = 1 + (rng.next_u32() % 70) as usize;
            let n = 1 + (rng.next_u32() % 70) as usize;
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_blocked_crosses_tile_boundaries() {
        let mut rng = Pcg::new(3);
        let a = Mat::from_fn(MC + 3, KC + 5, |_, _| rng.normal());
        let b = Mat::from_fn(KC + 5, NC + 2, |_, _| rng.normal());
        assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg::new(11);
        let a = Mat::from_fn(17, 9, |_, _| rng.normal());
        let b = Mat::from_fn(17, 13, |_, _| rng.normal());
        assert_close(&matmul_at_b(&a, &b), &matmul(&a.transpose(), &b), 1e-5);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg::new(13);
        let a = Mat::from_fn(8, 21, |_, _| rng.normal());
        let b = Mat::from_fn(5, 21, |_, _| rng.normal());
        assert_close(&matmul_a_bt(&a, &b), &matmul(&a, &b.transpose()), 1e-5);
    }

    #[test]
    fn matmul_into_accumulates() {
        let a = Mat::eye(3);
        let b = Mat::ones(3, 3);
        let mut c = Mat::ones(3, 3);
        matmul_into(&a, &b, &mut c, true);
        assert_eq!(c.at(0, 0), 2.0);
        matmul_into(&a, &b, &mut c, false);
        assert_eq!(c.at(0, 0), 1.0);
    }
}
