//! Persistent worker pool for the compute hot path.
//!
//! Every parallel kernel in the crate (dense matmuls, structured factor
//! ops, per-layer optimizer steps) runs on ONE lazily-initialized pool of
//! channel-fed worker threads instead of spawning OS threads per call —
//! thread spawn/join costs tens of microseconds, which used to dominate
//! mid-sized products and made per-layer parallelism a net loss.
//!
//! # Lifecycle
//!
//! The pool is created on the first parallel submission
//! ([`run_jobs`] / [`parallel_for_rows`] / [`parallel_chunks_mut`]) and
//! lives for the rest of the process: workers block on a condvar-guarded
//! queue when idle and are never joined (they are detached daemons; the
//! queue and latches are the only synchronization). Worker count is fixed
//! at creation time by [`num_threads`].
//!
//! # The `SINGD_THREADS` contract
//!
//! `SINGD_THREADS=<n>` caps the pool size and the default sharding factor;
//! it is read ONCE, at first use, and cached. `SINGD_THREADS=1` disables
//! parallelism entirely (no pool is ever created; all helpers run inline
//! on the caller). Tests and embedders that need to vary parallelism at
//! runtime use [`with_threads`], a thread-local override of the *sharding*
//! factor — the pool itself keeps its size, idle workers just stay idle.
//!
//! # Scoped borrows & safety
//!
//! Jobs may borrow stack data (`&`/`&mut` slices of matrices). This is
//! sound because [`run_jobs`] blocks on a completion latch until every
//! submitted job has finished, so no borrow outlives the call — the same
//! argument `std::thread::scope` makes, minus the per-call spawns. A panic
//! inside a job is caught on the worker (keeping the pool alive), recorded
//! on the latch, and re-raised as a panic in the submitting thread once
//! the batch has drained.
//!
//! # Nesting & determinism
//!
//! A job that itself calls into the pool (e.g. a per-layer optimizer job
//! whose matmuls are large enough to shard) runs the nested batch INLINE
//! on its worker: this bounds worker usage, cannot deadlock, and keeps
//! results identical — every kernel in `tensor::matmul` is written so that
//! row-sharded and serial execution produce bitwise-identical output (the
//! per-element floating-point accumulation order never depends on the
//! partition; see the determinism tests in `rust/tests/parallel.rs`).

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A unit of work queued on the pool (lifetime-erased; see [`run_jobs`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    available: Condvar,
}

/// Batch-completion latch: (jobs remaining, any job panicked).
struct Latch {
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

static POOL: OnceLock<Arc<Queue>> = OnceLock::new();

thread_local! {
    /// True on pool worker threads — nested submissions run inline.
    static IS_WORKER: Cell<bool> = const { Cell::new(false) };
    /// Per-thread sharding override set by [`with_threads`] (0 = none).
    static THREAD_OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

/// Worker count for parallel kernels (respects `SINGD_THREADS`; read once).
pub fn num_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("SINGD_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// True when the calling thread is a pool worker. Long-blocking work
/// (e.g. the rank bodies of [`crate::dist::run_ranks`], which wait on each
/// other at collective rendezvous points) must NOT be enqueued from — or
/// sized beyond — the pool in ways that could leave a queued job behind a
/// blocked worker; callers use this to fall back to dedicated threads.
pub fn is_worker_thread() -> bool {
    IS_WORKER.with(|c| c.get())
}

/// Effective sharding factor for the current thread: the [`with_threads`]
/// override when one is active, [`num_threads`] otherwise.
pub fn current_threads() -> usize {
    let o = THREAD_OVERRIDE.with(|c| c.get());
    if o > 0 {
        o
    } else {
        num_threads()
    }
}

/// Run `f` with the sharding factor forced to `n` on this thread
/// (`n = 1` forces fully serial execution). Restores the previous value on
/// exit, including on panic. Used by the determinism tests to compare
/// serial and pooled trajectories inside one process.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = THREAD_OVERRIDE.with(|c| {
        let p = c.get();
        c.set(n.max(1));
        p
    });
    let _restore = Restore(prev);
    f()
}

fn queue() -> &'static Arc<Queue> {
    POOL.get_or_init(|| {
        crate::obs_gauge!("pool.threads", num_threads() as f64);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        });
        for i in 0..num_threads() {
            let q = Arc::clone(&queue);
            std::thread::Builder::new()
                .name(format!("singd-pool-{i}"))
                .spawn(move || worker_loop(q))
                .expect("spawn singd pool worker");
        }
        queue
    })
}

fn worker_loop(q: Arc<Queue>) {
    IS_WORKER.with(|c| c.set(true));
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(j) = jobs.pop_front() {
                    break j;
                }
                jobs = q.available.wait(jobs).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Jobs are wrapped with catch_unwind in run_jobs; this call does
        // not unwind, so the worker survives any job.
        job();
    }
}

/// Execute a batch of jobs on the pool and block until all complete.
///
/// Jobs may borrow the caller's stack (the `'scope` lifetime): the call
/// does not return until every job has run, which is what makes the
/// lifetime erasure below sound. Runs inline (in submission order) when
/// the batch is trivial, the effective thread count is 1, or the caller is
/// itself a pool worker (nesting). Panics if any job panicked.
pub fn run_jobs<'scope>(jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    if jobs.len() <= 1 || current_threads() <= 1 || IS_WORKER.with(|c| c.get()) {
        crate::obs_count!("pool.jobs_inline", jobs.len() as u64);
        for job in jobs {
            job();
        }
        return;
    }
    // Occupancy accounting: always-on relaxed counters (the traffic.rs
    // discipline) plus one span per *batch* — never per job — when a
    // trace session is armed; disabled-tracing cost is one relaxed load.
    crate::obs_count!("pool.batches", 1);
    crate::obs_count!("pool.jobs", jobs.len() as u64);
    let mut sp = crate::obs::trace::span("pool_batch", "pool");
    if sp.is_recording() {
        sp.arg("jobs", crate::obs::trace::ArgVal::U(jobs.len() as u64));
    }
    let q = queue();
    let latch = Arc::new(Latch {
        state: Mutex::new((jobs.len(), false)),
        done: Condvar::new(),
    });
    {
        let mut pending = q.jobs.lock().unwrap_or_else(|e| e.into_inner());
        for job in jobs {
            // SAFETY: this function blocks on `latch` until every job in
            // the batch has finished executing, so all borrows captured by
            // `job` strictly outlive its execution on the worker thread.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
            };
            let l = Arc::clone(&latch);
            pending.push_back(Box::new(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                let mut st = l.state.lock().unwrap_or_else(|e| e.into_inner());
                st.0 -= 1;
                if result.is_err() {
                    st.1 = true;
                }
                if st.0 == 0 {
                    l.done.notify_all();
                }
            }));
        }
        q.available.notify_all();
    }
    let mut st = latch.state.lock().unwrap_or_else(|e| e.into_inner());
    while st.0 > 0 {
        st = latch.done.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    if st.1 {
        panic!("singd pool: a parallel job panicked");
    }
}

/// Spawn a dedicated named thread for long-**blocking** work (collective
/// progress engines, transport listeners) and return its join handle.
///
/// Such work must NOT ride the pool queue: the rank bodies of
/// [`crate::dist::run_ranks`] may occupy every worker, and a blocking
/// progress job queued behind a blocked worker would deadlock the world —
/// the same hazard [`is_worker_thread`] exists to sidestep. A dedicated
/// thread costs one spawn (~tens of µs) and is immune to pool pressure;
/// the pool stays reserved for short compute-bound jobs.
pub fn spawn_blocking<F>(name: &str, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .expect("pool: spawn blocking thread")
}

/// Shard the half-open row range `0..rows` across the pool, calling
/// `f(start, end)` once per shard. Shards have at least `min_rows` rows
/// (the whole range runs inline when it is that small, the effective
/// thread count is 1, or the caller is a pool worker). `f` only gets
/// shared access — use [`parallel_chunks_mut`] when each shard owns a
/// disjoint `&mut` slice of the output.
pub fn parallel_for_rows<F>(rows: usize, min_rows: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if rows == 0 {
        return;
    }
    let nt = current_threads();
    if nt <= 1 || rows <= min_rows.max(1) || IS_WORKER.with(|c| c.get()) {
        f(0, rows);
        return;
    }
    let per = rows.div_ceil(nt).max(min_rows.max(1));
    let fr = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..rows.div_ceil(per))
        .map(|t| {
            let start = t * per;
            let end = (start + per).min(rows);
            Box::new(move || fr(start, end)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_jobs(jobs);
}

/// Shard a row-major buffer of `row_width`-wide rows into contiguous
/// row-block chunks of at least `min_rows` rows and call
/// `f(first_row, chunk)` per shard, each owning its disjoint `&mut` slice.
/// The workhorse for "each worker owns a row-block of the output matrix"
/// kernels (dense matmuls, structured right/left multiplies).
pub fn parallel_chunks_mut<F>(data: &mut [f32], row_width: usize, min_rows: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_width > 0, "parallel_chunks_mut: zero row width");
    let rows = data.len() / row_width;
    let nt = current_threads();
    if nt <= 1 || rows <= min_rows.max(1) || IS_WORKER.with(|c| c.get()) {
        f(0, data);
        return;
    }
    let chunk_rows = rows.div_ceil(nt).max(min_rows.max(1));
    let fr = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
        .chunks_mut(chunk_rows * row_width)
        .enumerate()
        .map(|(ci, chunk)| {
            Box::new(move || fr(ci * chunk_rows, chunk)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_jobs(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_jobs_executes_every_job() {
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..17)
            .map(|i| {
                let c = &counter;
                Box::new(move || {
                    c.fetch_add(i, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_jobs(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), (0..17).sum::<usize>());
    }

    #[test]
    fn parallel_for_rows_covers_range_exactly_once() {
        for rows in [0usize, 1, 2, 7, 64, 1001] {
            let hits: Vec<AtomicUsize> = (0..rows).map(|_| AtomicUsize::new(0)).collect();
            parallel_for_rows(rows, 1, |s, e| {
                for h in &hits[s..e] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "rows={rows}");
        }
    }

    #[test]
    fn parallel_chunks_mut_partitions_disjointly() {
        let width = 3;
        let rows = 101;
        let mut data = vec![0.0f32; rows * width];
        parallel_chunks_mut(&mut data, width, 2, |row0, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += (row0 * width + i) as f32;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as f32, "row-major offset {i}");
        }
    }

    #[test]
    fn with_threads_overrides_and_restores() {
        let outer = current_threads();
        with_threads(3, || {
            assert_eq!(current_threads(), 3);
            with_threads(1, || assert_eq!(current_threads(), 1));
            assert_eq!(current_threads(), 3);
        });
        assert_eq!(current_threads(), outer);
    }

    #[test]
    fn nested_submission_runs_inline_without_deadlock() {
        let total = AtomicUsize::new(0);
        with_threads(4, || {
            let t = &total;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(move || {
                        // Nested batch from (potentially) a worker thread.
                        parallel_for_rows(32, 1, |s, e| {
                            t.fetch_add(e - s, Ordering::Relaxed);
                        });
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_jobs(jobs);
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 32);
    }

    #[test]
    fn job_panic_propagates_to_submitter() {
        let result = std::panic::catch_unwind(|| {
            with_threads(4, || {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                    .map(|i| {
                        Box::new(move || {
                            if i == 2 {
                                panic!("boom");
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                run_jobs(jobs);
            });
        });
        assert!(result.is_err(), "panic must propagate");
        // The pool must remain usable afterwards.
        let counter = AtomicUsize::new(0);
        with_threads(4, || {
            parallel_for_rows(16, 1, |s, e| {
                counter.fetch_add(e - s, Ordering::Relaxed);
            });
        });
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }
}
