//! KFAC (Martens & Grosse, 2015) — the paper's Fig. 3 (left).
//!
//! Maintains EMA Kronecker factors `S_K` (input side) and `S_C` (output
//! side) per layer, and preconditions the gradient with
//! `(S_C + λI)⁻¹ ∇W (S_K + λI)⁻¹`.
//!
//! Faithful to real-world low-precision behaviour (paper §4): the factors
//! are *stored* in the policy's storage format (bf16 EMA accumulation),
//! the inversion is carried out in fp32 (as PyTorch must — there is no
//! bf16 inverse kernel), and the inverse is rounded back to the storage
//! format. The instability arises because the bf16-rounded EMA loses
//! positive-definiteness / dynamic range, so the fp32 inverse of the
//! rounded matrix is wrong or enormous. When Cholesky fails we fall back
//! to a general LU inverse (mirroring `torch.linalg.inv` not raising), and
//! training blows up — exactly the failure mode the paper reports.

use super::{Hyper, KronStats, Optimizer};
use crate::linalg::{lu_inverse, spd_inverse};
use crate::numerics::Policy;
use crate::tensor::Mat;

struct LayerState {
    s_k: Mat,
    s_c: Mat,
    s_k_inv: Mat,
    s_c_inv: Mat,
    m_mu: Mat,
}

pub struct Kfac {
    hp: Hyper,
    layers: Vec<LayerState>,
    diverged: bool,
    /// Count of preconditioner refreshes where Cholesky failed (stability
    /// telemetry for the Fig. 1 experiment).
    pub chol_failures: usize,
}

impl Kfac {
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper) -> Self {
        let layers = shapes
            .iter()
            .map(|&(o, i)| LayerState {
                s_k: Mat::eye(i),
                s_c: Mat::eye(o),
                s_k_inv: Mat::eye(i),
                s_c_inv: Mat::eye(o),
                m_mu: Mat::zeros(o, i),
            })
            .collect();
        Kfac { hp: hp.clone(), layers, diverged: false, chol_failures: 0 }
    }

    /// `(S + λI)⁻¹` with fp32 compute but storage-format rounding of the
    /// result — the paper's "transform into FP32, invert, transform back"
    /// recipe.
    fn damped_inverse(&mut self, s: &Mat, policy: &Policy) -> Mat {
        let mut damped = s.clone();
        damped.add_diag(self.hp.damping);
        let inv = match spd_inverse(&damped) {
            Some(inv) => inv,
            None => {
                self.chol_failures += 1;
                match lu_inverse(&damped) {
                    Some(inv) => inv,
                    None => {
                        // Exactly singular: real frameworks return inf/nan.
                        self.diverged = true;
                        Mat::from_fn(damped.rows(), damped.cols(), |_, _| f32::NAN)
                    }
                }
            }
        };
        let mut inv = inv;
        policy.quantize_mat(&mut inv);
        inv
    }
}

impl Optimizer for Kfac {
    fn name(&self) -> String {
        "kfac".into()
    }

    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], stats: &[KronStats]) {
        let policy = self.hp.policy;
        let b1 = self.hp.precond_lr;
        if t % self.hp.t_update == 0 {
            for l in 0..params.len() {
                // EMA of the Kronecker factors, accumulated in the storage
                // format (this is where bf16 hurts).
                let u = stats[l].u_dense();
                let g = stats[l].g_dense();
                let (s_k, s_c) = {
                    let st = &mut self.layers[l];
                    st.s_k.ema(1.0 - b1, b1, &u);
                    st.s_c.ema(1.0 - b1, b1, &g);
                    policy.quantize_mat(&mut st.s_k);
                    policy.quantize_mat(&mut st.s_c);
                    (st.s_k.clone(), st.s_c.clone())
                };
                let k_inv = self.damped_inverse(&s_k, &policy);
                let c_inv = self.damped_inverse(&s_c, &policy);
                let st = &mut self.layers[l];
                st.s_k_inv = k_inv;
                st.s_c_inv = c_inv;
            }
        }
        for l in 0..params.len() {
            let st = &mut self.layers[l];
            // m_μ ← α₂ m_μ + S_C⁻¹ ∇W S_K⁻¹ + γ W
            let precond = crate::tensor::matmul(&st.s_c_inv, &crate::tensor::matmul(&grads[l], &st.s_k_inv));
            st.m_mu.ema(self.hp.momentum, 1.0, &precond);
            st.m_mu.axpy(self.hp.weight_decay, &params[l]);
            policy.quantize_mat(&mut st.m_mu);
            // KL-style RMS trust region on the preconditioned update.
            let f = super::update_clip_factor(self.hp.lr, &st.m_mu, self.hp.update_clip);
            params[l].axpy(-self.hp.lr * f, &st.m_mu);
            policy.quantize_mat(&mut params[l]);
            self.diverged |= params[l].has_nonfinite() || st.m_mu.has_nonfinite();
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn state_bytes(&self) -> usize {
        // S_K, S_C, their inverses, and the momentum buffer.
        self.layers
            .iter()
            .map(|st| {
                self.hp.policy.stored_bytes(st.s_k.rows(), st.s_k.cols()) * 2
                    + self.hp.policy.stored_bytes(st.s_c.rows(), st.s_c.cols()) * 2
                    + self.hp.policy.stored_bytes(st.m_mu.rows(), st.m_mu.cols())
            })
            .sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn telemetry(&self) -> String {
        if self.chol_failures > 0 {
            format!("chol_failures={}", self.chol_failures)
        } else {
            String::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{testutil, Method};
    use crate::proptest::Pcg;

    #[test]
    fn kfac_converges_fast_on_ill_conditioned_quadratic() {
        // Second-order advantage: on a cond≈8² quadratic KFAC should beat
        // SGD at the same modest step budget.
        let hp = Hyper {
            lr: 0.1,
            momentum: 0.0,
            t_update: 1,
            precond_lr: 0.9,
            damping: 1e-2,
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let (l0, ln) = testutil::run_quadratic(&Method::Kfac, &hp, 100, 13);
        assert!(ln < 1e-2 * l0, "kfac {l0} -> {ln}");
    }

    #[test]
    fn preconditioner_is_exact_newton_on_static_factors() {
        // One layer, t_update=1, β₁=1: after one refresh S_K = U, S_C = G;
        // the preconditioned gradient must equal (G+λ)⁻¹ ∇W (U+λ)⁻¹.
        let mut rng = Pcg::new(5);
        let hp = Hyper {
            lr: 1.0,
            momentum: 0.0,
            weight_decay: 0.0,
            damping: 1e-3,
            precond_lr: 1.0,
            t_update: 1,
            update_clip: 0.0, // exact direction check — no trust region
            ..Hyper::default()
        };
        let (d_i, d_o, m) = (6, 4, 32);
        let a = rng.normal_mat(m, d_i, 1.0);
        let gm = rng.normal_mat(m, d_o, 1.0);
        let stats = KronStats { a: a.clone(), g: gm.clone() };
        let grad = rng.normal_mat(d_o, d_i, 1.0);
        let w0 = Mat::zeros(d_o, d_i);
        let mut params = [w0.clone()];
        let mut opt = Kfac::new(&[(d_o, d_i)], &hp);
        opt.step(0, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
        let mut u = stats.u_dense();
        u.add_diag(hp.damping);
        let mut g = stats.g_dense();
        g.add_diag(hp.damping);
        let want_dir = crate::tensor::matmul(
            &crate::linalg::spd_inverse(&g).unwrap(),
            &crate::tensor::matmul(&grad, &crate::linalg::spd_inverse(&u).unwrap()),
        );
        let got_dir = w0.sub(&params[0]); // lr = 1
        crate::proptest::assert_mat_close(&got_dir, &want_dir, 1e-3, "kfac direction");
    }

    #[test]
    fn kfac_bf16_accumulates_cholesky_failures_on_correlated_stats() {
        // Strongly *correlated* activations (the realistic NN case) make
        // the correlation part of U ill-conditioned; entrywise bf16
        // rounding of the EMA then destroys positive-definiteness, so the
        // fp32 Cholesky of the bf16-stored factor fails — while the fp32
        // run stays clean. This is the paper's KFAC-in-BFP16 instability.
        let mut rng = Pcg::new(17);
        let (d_i, d_o, m) = (24, 8, 64);
        let run = |policy: Policy, rng: &mut Pcg| -> usize {
            let hp =
                Hyper { t_update: 1, precond_lr: 0.5, damping: 1e-5, policy, ..Hyper::default() };
            let mut opt = Kfac::new(&[(d_o, d_i)], &hp);
            let mut params = [rng.normal_mat(d_o, d_i, 0.1)];
            for t in 0..25 {
                // a_ic = shared signal + 2% independent noise → correlation
                // matrix ≈ ones + 4e-4·I: min eig far below bf16's 2⁻⁸.
                let mut a = Mat::zeros(m, d_i);
                for r in 0..m {
                    let s = rng.normal() * 2.0;
                    for c in 0..d_i {
                        *a.at_mut(r, c) = s + 0.02 * rng.normal();
                    }
                }
                let gm = rng.normal_mat(m, d_o, 1.0);
                let grad = rng.normal_mat(d_o, d_i, 0.01);
                let stats = KronStats { a, g: gm };
                opt.step(t, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
            }
            opt.chol_failures
        };
        let fails_fp32 = run(Policy::fp32(), &mut rng);
        let fails_bf16 = run(Policy::bf16_mixed(), &mut rng);
        assert_eq!(fails_fp32, 0, "fp32 KFAC must not fail Cholesky");
        assert!(fails_bf16 > 0, "bf16 KFAC expected to hit Cholesky failures");
    }
}
