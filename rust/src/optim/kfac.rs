//! KFAC (Martens & Grosse, 2015) — the paper's Fig. 3 (left).
//!
//! Maintains EMA Kronecker factors `S_K` (input side) and `S_C` (output
//! side) per layer, and preconditions the gradient with
//! `(S_C + λI)⁻¹ ∇W (S_K + λI)⁻¹`.
//!
//! Faithful to real-world low-precision behaviour (paper §4): the factors
//! are *stored* in the policy's storage format (bf16 EMA accumulation),
//! the inversion is carried out in fp32 (as PyTorch must — there is no
//! bf16 inverse kernel), and the inverse is rounded back to the storage
//! format. The instability arises because the bf16-rounded EMA loses
//! positive-definiteness / dynamic range, so the fp32 inverse of the
//! rounded matrix is wrong or enormous. When Cholesky fails we fall back
//! to a general LU inverse (mirroring `torch.linalg.inv` not raising), and
//! training blows up — exactly the failure mode the paper reports.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use super::{Hyper, KronStats, Optimizer};
use crate::dist::DistCtx;
use crate::linalg::{lu_inverse, spd_inverse};
use crate::numerics::{Policy, QMat};
use crate::tensor::{pool, Mat};

/// Per-layer factor state, physically stored in the policy's storage
/// dtype via [`QMat`] (2 bytes/element under bf16/fp16, plain f32 under
/// the reference policy). Working copies are widened — exactly — for the
/// f32 EMA/inversion arithmetic, and the preconditioning matmuls widen at
/// pack time so the 4-byte image is never materialized.
struct LayerState {
    s_k: QMat,
    s_c: QMat,
    s_k_inv: QMat,
    s_c_inv: QMat,
    m_mu: QMat,
}

/// `(S + λI)⁻¹` with fp32 compute but storage-format rounding of the
/// result — the paper's "transform into FP32, invert, transform back"
/// recipe. A free function (with atomic failure telemetry) so per-layer
/// refreshes can run concurrently on the worker pool. Shared with
/// [`super::RkFac`], whose k×k Woodbury core is the same damped inverse.
pub(super) fn damped_inverse(
    s: &Mat,
    damping: f32,
    policy: &Policy,
    chol_failures: &AtomicUsize,
    diverged: &AtomicBool,
) -> Mat {
    let mut damped = s.clone();
    damped.add_diag(damping);
    let mut inv = match spd_inverse(&damped) {
        Some(inv) => inv,
        None => {
            chol_failures.fetch_add(1, Ordering::Relaxed);
            match lu_inverse(&damped) {
                Some(inv) => inv,
                None => {
                    // Exactly singular: real frameworks return inf/nan.
                    diverged.store(true, Ordering::Relaxed);
                    Mat::from_fn(damped.rows(), damped.cols(), |_, _| f32::NAN)
                }
            }
        }
    };
    policy.quantize_mat(&mut inv);
    inv
}

pub struct Kfac {
    hp: Hyper,
    /// Per-layer factor state; `None` for layers this rank does not own
    /// under [`DistCtx`] (factor-sharded).
    layers: Vec<Option<LayerState>>,
    /// Per-layer refresh periods ([`Optimizer::set_precond_schedule`]);
    /// empty → uniform [`Hyper::t_update`]. Indexed by *global* layer id.
    schedule: Vec<usize>,
    dist: DistCtx,
    diverged: bool,
    /// Count of preconditioner refreshes where Cholesky failed (stability
    /// telemetry for the Fig. 1 experiment).
    pub chol_failures: usize,
}

impl Kfac {
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper) -> Self {
        Self::with_dist(shapes, hp, DistCtx::single())
    }

    /// One rank of a distributed topology: under the factor-sharded
    /// strategy only owned layers allocate `S_K`/`S_C`/inverses.
    pub fn with_dist(shapes: &[(usize, usize)], hp: &Hyper, dist: DistCtx) -> Self {
        let store = hp.policy.store;
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(l, &(o, i))| {
                dist.owns_layer(l).then(|| LayerState {
                    s_k: QMat::eye(store, i),
                    s_c: QMat::eye(store, o),
                    s_k_inv: QMat::eye(store, i),
                    s_c_inv: QMat::eye(store, o),
                    m_mu: QMat::zeros(store, o, i),
                })
            })
            .collect();
        Kfac { hp: hp.clone(), layers, schedule: Vec::new(), dist, diverged: false, chol_failures: 0 }
    }
}

impl Optimizer for Kfac {
    fn name(&self) -> String {
        "kfac".into()
    }

    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], stats: &[KronStats]) {
        assert_eq!(params.len(), self.layers.len(), "kfac: params/layers mismatch");
        assert_eq!(grads.len(), params.len(), "kfac: grads/params mismatch");
        assert_eq!(stats.len(), params.len(), "kfac: stats/params mismatch");
        let policy = self.hp.policy;
        let b1 = self.hp.precond_lr;
        let hp = &self.hp;
        {
            // Per-layer refresh — the `u_dense`/`g_dense` statistics
            // products plus two inversions — fans out across the pool; the
            // failure counters are the only shared state. Each layer is
            // due on its own cadence (the paper's `T`, layer-wise; uniform
            // `t_update` unless a schedule overrides it), so with the
            // default schedule this block refreshes all owned layers when
            // `t % t_update == 0` and none otherwise — bitwise identical
            // to the former whole-step gate.
            let chol_failures = AtomicUsize::new(0);
            let diverged = AtomicBool::new(false);
            let schedule = &self.schedule;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                .layers
                .iter_mut()
                .zip(stats.iter())
                .enumerate()
                .filter(|(l, _)| t % schedule.get(*l).copied().unwrap_or(hp.t_update).max(1) == 0)
                .filter_map(|(_, (st, stat))| st.as_mut().map(|st| (st, stat)))
                .map(|(st, stat)| {
                    let cf = &chol_failures;
                    let dv = &diverged;
                    Box::new(move || {
                        // EMA of the Kronecker factors, accumulated in the
                        // storage format (this is where bf16 hurts). The
                        // stored u16 factors widen exactly into the f32
                        // working copies; re-storing after quantization is
                        // a lossless narrowing.
                        let u = stat.u_dense();
                        let g = stat.g_dense();
                        let mut s_k = st.s_k.widen();
                        let mut s_c = st.s_c.widen();
                        s_k.ema(1.0 - b1, b1, &u);
                        s_c.ema(1.0 - b1, b1, &g);
                        policy.quantize_mat(&mut s_k);
                        policy.quantize_mat(&mut s_c);
                        st.s_k_inv = QMat::from_quantized(
                            policy.store,
                            damped_inverse(&s_k, hp.damping, &policy, cf, dv),
                        );
                        st.s_c_inv = QMat::from_quantized(
                            policy.store,
                            damped_inverse(&s_c, hp.damping, &policy, cf, dv),
                        );
                        st.s_k = QMat::from_quantized(policy.store, s_k);
                        st.s_c = QMat::from_quantized(policy.store, s_c);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            if !jobs.is_empty() {
                pool::run_jobs(jobs);
            }
            self.chol_failures += chol_failures.load(Ordering::Relaxed);
            self.diverged |= diverged.load(Ordering::Relaxed);
        }
        let diverged = AtomicBool::new(false);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
            .layers
            .iter_mut()
            .zip(params.iter_mut().zip(grads.iter()))
            .filter_map(|(st, (p, g))| st.as_mut().map(|st| (st, p, g)))
            .map(|(st, p, g)| {
                let dv = &diverged;
                Box::new(move || {
                    // m_μ ← α₂ m_μ + S_C⁻¹ ∇W S_K⁻¹ + γ W. The inverse
                    // factors stay in u16 storage; the two matmuls widen
                    // them at pack time.
                    let precond = st.s_c_inv.matmul_qa(&st.s_k_inv.matmul_qb(g));
                    let mut m_mu = st.m_mu.widen();
                    m_mu.ema(hp.momentum, 1.0, &precond);
                    m_mu.axpy(hp.weight_decay, p);
                    policy.quantize_mat(&mut m_mu);
                    // KL-style RMS trust region on the preconditioned update.
                    let f = super::update_clip_factor(hp.lr, &m_mu, hp.update_clip);
                    p.axpy(-hp.lr * f, &m_mu);
                    policy.quantize_mat(p);
                    if p.has_nonfinite() || m_mu.has_nonfinite() {
                        dv.store(true, Ordering::Relaxed);
                    }
                    st.m_mu = QMat::from_quantized(policy.store, m_mu);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_jobs(jobs);
        self.diverged |= diverged.load(Ordering::Relaxed);
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn set_precond_schedule(&mut self, periods: Vec<usize>) {
        self.schedule = periods;
    }

    fn state_bytes(&self) -> usize {
        // S_K, S_C, their inverses, and the momentum buffer — owned
        // layers only (per-rank bytes under factor sharding). These are
        // the *physical* payload sizes of the QMat allocations, which by
        // construction equal `policy.stored_bytes` for each shape.
        self.layers
            .iter()
            .flatten()
            .map(|st| {
                st.s_k.bytes()
                    + st.s_c.bytes()
                    + st.s_k_inv.bytes()
                    + st.s_c_inv.bytes()
                    + st.m_mu.bytes()
            })
            .sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn telemetry(&self) -> String {
        if self.chol_failures > 0 {
            format!("chol_failures={}", self.chol_failures)
        } else {
            String::new()
        }
    }

    fn owned_layers(&self) -> Option<Vec<usize>> {
        self.dist.owned_layers(self.layers.len())
    }

    fn state_blobs_per_layer(&self) -> usize {
        5
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        // Five blobs per owned layer: S_K, S_C, S_K⁻¹, S_C⁻¹, m_μ — as
        // the exact f32 images of the stored values (widening is exact, so
        // the checkpoint round-trip stays bitwise).
        let mut out = Vec::new();
        for st in self.layers.iter().flatten() {
            out.push(st.s_k.widen().data().to_vec());
            out.push(st.s_c.widen().data().to_vec());
            out.push(st.s_k_inv.widen().data().to_vec());
            out.push(st.s_c_inv.widen().data().to_vec());
            out.push(st.m_mu.widen().data().to_vec());
        }
        out
    }

    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        let want: Vec<usize> = self
            .layers
            .iter()
            .flatten()
            .flat_map(|st| {
                [st.s_k.len(), st.s_c.len(), st.s_k_inv.len(), st.s_c_inv.len(), st.m_mu.len()]
            })
            .collect();
        super::check_blob_lens("kfac", blobs, &want)?;
        let store = self.hp.policy.store;
        let mut it = blobs.iter();
        for st in self.layers.iter_mut().flatten() {
            // Checkpointed values were widened from this dtype, so the
            // narrowing below is lossless.
            let mut load = |rows: usize, cols: usize| {
                QMat::from_quantized(store, Mat::from_vec(rows, cols, it.next().unwrap().clone()))
            };
            st.s_k = load(st.s_k.rows(), st.s_k.cols());
            st.s_c = load(st.s_c.rows(), st.s_c.cols());
            st.s_k_inv = load(st.s_k_inv.rows(), st.s_k_inv.cols());
            st.s_c_inv = load(st.s_c_inv.rows(), st.s_c_inv.cols());
            st.m_mu = load(st.m_mu.rows(), st.m_mu.cols());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{testutil, Method};
    use crate::proptest::Pcg;

    #[test]
    fn kfac_converges_fast_on_ill_conditioned_quadratic() {
        // Second-order advantage: on a cond≈8² quadratic KFAC should beat
        // SGD at the same modest step budget.
        let hp = Hyper {
            lr: 0.1,
            momentum: 0.0,
            t_update: 1,
            precond_lr: 0.9,
            damping: 1e-2,
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let (l0, ln) = testutil::run_quadratic(&Method::Kfac, &hp, 100, 13);
        assert!(ln < 1e-2 * l0, "kfac {l0} -> {ln}");
    }

    #[test]
    fn preconditioner_is_exact_newton_on_static_factors() {
        // One layer, t_update=1, β₁=1: after one refresh S_K = U, S_C = G;
        // the preconditioned gradient must equal (G+λ)⁻¹ ∇W (U+λ)⁻¹.
        let mut rng = Pcg::new(5);
        let hp = Hyper {
            lr: 1.0,
            momentum: 0.0,
            weight_decay: 0.0,
            damping: 1e-3,
            precond_lr: 1.0,
            t_update: 1,
            update_clip: 0.0, // exact direction check — no trust region
            ..Hyper::default()
        };
        let (d_i, d_o, m) = (6, 4, 32);
        let a = rng.normal_mat(m, d_i, 1.0);
        let gm = rng.normal_mat(m, d_o, 1.0);
        let stats = KronStats { a: a.clone(), g: gm.clone() };
        let grad = rng.normal_mat(d_o, d_i, 1.0);
        let w0 = Mat::zeros(d_o, d_i);
        let mut params = [w0.clone()];
        let mut opt = Kfac::new(&[(d_o, d_i)], &hp);
        opt.step(0, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
        let mut u = stats.u_dense();
        u.add_diag(hp.damping);
        let mut g = stats.g_dense();
        g.add_diag(hp.damping);
        let want_dir = crate::tensor::matmul(
            &crate::linalg::spd_inverse(&g).unwrap(),
            &crate::tensor::matmul(&grad, &crate::linalg::spd_inverse(&u).unwrap()),
        );
        let got_dir = w0.sub(&params[0]); // lr = 1
        crate::proptest::assert_mat_close(&got_dir, &want_dir, 1e-3, "kfac direction");
    }

    #[test]
    fn kfac_state_vectors_roundtrip_bitwise() {
        let mut rng = Pcg::new(61);
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = Kfac::new(&shapes, &hp);
        let mut params = vec![rng.normal_mat(5, 4, 0.2), rng.normal_mat(3, 5, 0.2)];
        for t in 0..2 {
            let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(12, 4, 1.0), g: rng.normal_mat(12, 5, 1.0) },
                KronStats { a: rng.normal_mat(12, 5, 1.0), g: rng.normal_mat(12, 3, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
        }
        let snap = opt.state_vectors();
        assert_eq!(snap.len(), 2 * 5);
        let mut fresh = Kfac::new(&shapes, &hp);
        fresh.load_state_vectors(&snap).unwrap();
        assert_eq!(fresh.state_vectors(), snap);
        assert!(fresh.load_state_vectors(&snap[..4]).is_err());
    }

    /// Per-layer refresh cadence: an explicit uniform schedule is bitwise
    /// the default gate, and staggered periods freeze the off-cadence
    /// layer's factors between refreshes.
    #[test]
    fn kfac_per_layer_precond_schedule() {
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 2, ..Hyper::default() };
        let run = |schedule: Option<Vec<usize>>| -> Vec<Vec<Vec<f32>>> {
            let mut rng = Pcg::new(64);
            let mut opt = Kfac::new(&shapes, &hp);
            if let Some(s) = schedule {
                opt.set_precond_schedule(s);
            }
            let mut params = vec![Mat::zeros(5, 4), Mat::zeros(3, 5)];
            let mut snaps = Vec::new();
            for t in 0..6 {
                let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
                let stats = vec![
                    KronStats { a: rng.normal_mat(12, 4, 1.0), g: rng.normal_mat(12, 5, 1.0) },
                    KronStats { a: rng.normal_mat(12, 5, 1.0), g: rng.normal_mat(12, 3, 1.0) },
                ];
                opt.step(t, &mut params, &grads, &stats);
                snaps.push(opt.state_vectors());
            }
            snaps
        };
        assert_eq!(run(None), run(Some(vec![2, 2])), "uniform schedule must be a no-op");
        // Blob layout: 5 per layer, S_K first → layer 1's S_K is blob 5.
        let staggered = run(Some(vec![1, 3]));
        for t in 1..6 {
            assert_ne!(staggered[t][0], staggered[t - 1][0], "t={t}: layer 0 refreshes each step");
            if t % 3 == 0 {
                assert_ne!(staggered[t][5], staggered[t - 1][5], "t={t}: layer 1 must refresh");
            } else {
                assert_eq!(staggered[t][5], staggered[t - 1][5], "t={t}: layer 1 stays frozen");
            }
        }
    }

    #[test]
    fn half_precision_factor_state_is_physically_half_sized() {
        // QMat stores u16 words under a half policy: the real allocation
        // is half the fp32 footprint, matching the stored_bytes formula.
        let shapes = [(8usize, 6usize), (4, 8)];
        let bytes = |policy: Policy| {
            Kfac::new(&shapes, &Hyper { policy, ..Hyper::default() }).state_bytes()
        };
        assert_eq!(bytes(Policy::bf16_mixed()) * 2, bytes(Policy::fp32()));
        assert_eq!(bytes(Policy::fp16_mixed()), bytes(Policy::bf16_mixed()));
    }

    #[test]
    fn kfac_bf16_accumulates_cholesky_failures_on_correlated_stats() {
        // Strongly *correlated* activations (the realistic NN case) make
        // the correlation part of U ill-conditioned; entrywise bf16
        // rounding of the EMA then destroys positive-definiteness, so the
        // fp32 Cholesky of the bf16-stored factor fails — while the fp32
        // run stays clean. This is the paper's KFAC-in-BFP16 instability.
        let mut rng = Pcg::new(17);
        let (d_i, d_o, m) = (24, 8, 64);
        let run = |policy: Policy, rng: &mut Pcg| -> usize {
            let hp =
                Hyper { t_update: 1, precond_lr: 0.5, damping: 1e-5, policy, ..Hyper::default() };
            let mut opt = Kfac::new(&[(d_o, d_i)], &hp);
            let mut params = [rng.normal_mat(d_o, d_i, 0.1)];
            for t in 0..25 {
                // a_ic = shared signal + 2% independent noise → correlation
                // matrix ≈ ones + 4e-4·I: min eig far below bf16's 2⁻⁸.
                let mut a = Mat::zeros(m, d_i);
                for r in 0..m {
                    let s = rng.normal() * 2.0;
                    for c in 0..d_i {
                        *a.at_mut(r, c) = s + 0.02 * rng.normal();
                    }
                }
                let gm = rng.normal_mat(m, d_o, 1.0);
                let grad = rng.normal_mat(d_o, d_i, 0.01);
                let stats = KronStats { a, g: gm };
                opt.step(t, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
            }
            opt.chol_failures
        };
        let fails_fp32 = run(Policy::fp32(), &mut rng);
        let fails_bf16 = run(Policy::bf16_mixed(), &mut rng);
        assert_eq!(fails_fp32, 0, "fp32 KFAC must not fail Cholesky");
        assert!(fails_bf16 > 0, "bf16 KFAC expected to hit Cholesky failures");
    }
}
