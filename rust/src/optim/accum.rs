//! Gradient accumulation (`--accum-steps`): fold `k` micro-batches into
//! the full-batch backward result, bitwise.
//!
//! The [`crate::model::Model`] backward makes this exact rather than
//! approximate. Per-row Kronecker statistics are scale-free — a layer's
//! `G` rows are `dy · m` with the mean-loss `1/m` undone, so a micro-batch
//! of any height produces the *same* stat rows the full batch would — and
//! the canonical contiguous split rule
//! ([`crate::dist::shard::row_shard_range`]) makes micro-batch stats the
//! exact row-slices of the full-batch stats. Accumulation is therefore
//! concatenation (no floating-point reduction at all), the gradient is
//! rebuilt from the concatenated stats with the distributed driver's own
//! reconstruction formula `∇W = Gᵀ A / m`, and the f64 loss partials
//! combine through the same fixed halving tree
//! ([`crate::dist::collectives::tree_sum_f64`]) the serial loss uses.
//!
//! Bitwise caveat (the same carve-out the distributed driver documents):
//! the per-micro `1/m` softmax scale is an exact exponent shift only when
//! every micro-batch height is a power of two, so `k` micro-batches of
//! `B/k` reproduce one batch of `B` bit-for-bit exactly when the
//! power-of-two heights align (e.g. `B = 32`, `k ∈ {1, 2, 4, 8}`). A
//! non-dividing `B % k ≠ 0` split stays fully deterministic — the
//! `row_shard_range` rule fixes every micro height — but forfeits bitwise
//! equality with the unsplit batch, exactly like a world size that does
//! not divide the batch.

use crate::dist::shard::row_shard_range;
use crate::model::{BackwardResult, Batch, Model};
use crate::optim::KronStats;
use crate::tensor::{matmul_at_b, Mat};

/// Split a batch into `k` contiguous micro-batches by the canonical
/// row-shard rule ([`row_shard_range`] — the same split the distributed
/// driver deals ranks). Empty micro-batches (`rows < k`) are dropped.
pub fn split_batch(batch: &Batch, k: usize) -> Vec<Batch> {
    let k = k.max(1);
    let rows = batch.x.rows();
    (0..k)
        .filter_map(|i| {
            let rg = row_shard_range(rows, k, i);
            if rg.is_empty() {
                return None;
            }
            let x = Mat::from_fn(rg.len(), batch.x.cols(), |r, c| batch.x.at(rg.start + r, c));
            Some(Batch { x, y: batch.y[rg].to_vec() })
        })
        .collect()
}

/// One layer's accumulated stat rows (flat row-major buffers, appended
/// micro-batch by micro-batch — pure concatenation, no arithmetic).
struct LayerBuf {
    a: Vec<f32>,
    a_cols: usize,
    g: Vec<f32>,
    g_cols: usize,
    rows: usize,
}

/// Folds the backward results of `k` contiguous micro-batches into the
/// full-batch equivalent (see the module docs for the bitwise contract).
///
/// Streaming-friendly: [`BatchAccumulator::push_stats`] accepts one
/// layer at a time, and [`BatchAccumulator::layer_concat`] can splice a
/// final micro-batch's just-computed layer stats onto the buffered rows
/// without mutating — which is what lets the distributed driver issue a
/// layer's gather from inside the *last* micro-batch's backward hook,
/// while that micro-batch's earlier layers are still being
/// differentiated.
pub struct BatchAccumulator {
    layers: Vec<LayerBuf>,
    loss_parts: Vec<f64>,
    loss_rows: usize,
    correct: usize,
}

impl BatchAccumulator {
    /// An empty accumulator for a model with `n_layers` trainable layers.
    pub fn new(n_layers: usize) -> Self {
        BatchAccumulator {
            layers: (0..n_layers)
                .map(|_| LayerBuf { a: Vec::new(), a_cols: 0, g: Vec::new(), g_cols: 0, rows: 0 })
                .collect(),
            loss_parts: Vec::new(),
            loss_rows: 0,
            correct: 0,
        }
    }

    /// Number of micro-batches folded so far.
    pub fn micros(&self) -> usize {
        self.loss_parts.len()
    }

    /// Total stat rows accumulated for layer `l`.
    pub fn layer_rows(&self, l: usize) -> usize {
        self.layers[l].rows
    }

    /// Append one layer's micro-batch stats (row concatenation).
    pub fn push_stats(&mut self, l: usize, st: &KronStats) {
        let buf = &mut self.layers[l];
        if buf.rows == 0 {
            buf.a_cols = st.a.cols();
            buf.g_cols = st.g.cols();
        }
        assert_eq!(buf.a_cols, st.a.cols(), "layer {l}: A col mismatch across micro-batches");
        assert_eq!(buf.g_cols, st.g.cols(), "layer {l}: G col mismatch across micro-batches");
        assert_eq!(st.a.rows(), st.g.rows(), "layer {l}: A/G row mismatch");
        buf.a.extend_from_slice(st.a.data());
        buf.g.extend_from_slice(st.g.data());
        buf.rows += st.a.rows();
    }

    /// Fold one micro-batch's loss bookkeeping (f64 partial, row count,
    /// correct count) without touching the per-layer stats.
    pub fn push_loss(&mut self, res: &BackwardResult) {
        self.loss_parts.push(res.loss_sum);
        self.loss_rows += res.loss_rows;
        self.correct += res.correct;
    }

    /// Fold one micro-batch's full backward result (all layers + loss).
    pub fn push_result(&mut self, res: &BackwardResult) {
        for (l, st) in res.stats.iter().enumerate() {
            self.push_stats(l, st);
        }
        self.push_loss(res);
    }

    /// Layer `l`'s accumulated stats with `tail`'s rows spliced on the
    /// end, as owned matrices — the buffered micro-batches stay untouched.
    pub fn layer_concat(&self, l: usize, tail: Option<&KronStats>) -> KronStats {
        let buf = &self.layers[l];
        let (tail_a, tail_g, tail_rows, a_cols, g_cols) = match tail {
            Some(st) => (st.a.data(), st.g.data(), st.a.rows(), st.a.cols(), st.g.cols()),
            None => (&[][..], &[][..], 0, buf.a_cols, buf.g_cols),
        };
        if buf.rows > 0 {
            assert_eq!(buf.a_cols, a_cols, "layer {l}: A col mismatch at concat");
            assert_eq!(buf.g_cols, g_cols, "layer {l}: G col mismatch at concat");
        }
        let rows = buf.rows + tail_rows;
        let mut a = Vec::with_capacity(rows * a_cols);
        a.extend_from_slice(&buf.a);
        a.extend_from_slice(tail_a);
        let mut g = Vec::with_capacity(rows * g_cols);
        g.extend_from_slice(&buf.g);
        g.extend_from_slice(tail_g);
        KronStats { a: Mat::from_vec(rows, a_cols, a), g: Mat::from_vec(rows, g_cols, g) }
    }

    /// The accumulated f64 loss partials combined through the fixed
    /// halving tree, plus the total loss rows and correct count.
    pub fn loss(&self) -> (f64, usize, usize) {
        (crate::dist::collectives::tree_sum_f64(&self.loss_parts), self.loss_rows, self.correct)
    }

    /// The full-batch-equivalent [`BackwardResult`]: concatenated stats,
    /// gradients rebuilt as `∇W = Gᵀ A / m` (the distributed driver's
    /// reconstruction formula), tree-combined loss.
    pub fn finalize(&self) -> BackwardResult {
        self.finalize_impl(true)
    }

    /// [`BatchAccumulator::finalize`] without the gradient matmuls
    /// (`grads` is left empty) — for the distributed driver, which
    /// rebuilds gradients from the *gathered* statistics anyway.
    pub fn finalize_stats(&self) -> BackwardResult {
        self.finalize_impl(false)
    }

    fn finalize_impl(&self, with_grads: bool) -> BackwardResult {
        let stats: Vec<KronStats> =
            (0..self.layers.len()).map(|l| self.layer_concat(l, None)).collect();
        let grads: Vec<Mat> = if with_grads {
            stats
                .iter()
                .map(|st| {
                    let m = st.a.rows().max(1) as f32;
                    matmul_at_b(&st.g, &st.a).scale(1.0 / m)
                })
                .collect()
        } else {
            Vec::new()
        };
        let (loss_sum, loss_rows, correct) = self.loss();
        BackwardResult {
            loss: (loss_sum / loss_rows.max(1) as f64) as f32,
            correct,
            grads,
            stats,
            loss_sum,
            loss_rows,
        }
    }
}

/// Run `batch` as `k` contiguous micro-batches through the model's
/// backward and fold them into the full-batch-equivalent result. `k <= 1`
/// delegates to the plain single-pass backward.
pub fn forward_backward_accum<M: Model + ?Sized>(
    model: &M,
    batch: &Batch,
    k: usize,
) -> BackwardResult {
    if k <= 1 {
        return model.forward_backward(batch);
    }
    let mut acc = BatchAccumulator::new(model.shapes().len());
    for micro in split_batch(batch, k) {
        acc.push_result(&model.forward_backward(&micro));
    }
    acc.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Mlp;
    use crate::proptest::{assert_mat_close, Pcg};

    fn toy_batch(rng: &mut Pcg, m: usize, d: usize, c: usize) -> Batch {
        Batch { x: rng.normal_mat(m, d, 1.0), y: (0..m).map(|i| i % c).collect() }
    }

    #[test]
    fn split_batch_covers_rows_in_order() {
        let mut rng = Pcg::new(71);
        let b = toy_batch(&mut rng, 10, 3, 4);
        for k in [1usize, 2, 3, 4, 7, 10, 16] {
            let micros = split_batch(&b, k);
            let total: usize = micros.iter().map(|m| m.x.rows()).sum();
            assert_eq!(total, 10, "k={k}: row coverage");
            let mut r = 0usize;
            for m in &micros {
                assert!(!m.y.is_empty(), "k={k}: empty micro-batches must be dropped");
                for rr in 0..m.x.rows() {
                    assert_eq!(m.x.row(rr), b.x.row(r), "k={k}: row {r} order");
                    assert_eq!(m.y[rr], b.y[r]);
                    r += 1;
                }
            }
        }
    }

    /// The headline property: power-of-two micro-batches of a power-of-
    /// two batch reproduce the unsplit backward bitwise — stats, grads
    /// and loss — across randomized shapes and micro counts.
    #[test]
    fn pow2_micro_batches_match_full_batch_bitwise() {
        let mut rng = Pcg::new(72);
        for trial in 0..6 {
            let dims = vec![
                2 + rng.below(6),
                3 + rng.below(8),
                2 + rng.below(5),
                2 + rng.below(4),
            ];
            let m = [8usize, 16, 32][rng.below(3)];
            let mlp = Mlp::new(&mut rng, &dims);
            let batch = toy_batch(&mut rng, m, dims[0], *dims.last().unwrap());
            let full = mlp.forward_backward(&batch);
            for k in [1usize, 2, 4, 8] {
                let acc = forward_backward_accum(&mlp, &batch, k);
                assert_eq!(
                    acc.loss_sum.to_bits(),
                    full.loss_sum.to_bits(),
                    "trial {trial} k={k}: loss_sum"
                );
                assert_eq!(acc.loss_rows, full.loss_rows);
                assert_eq!(acc.correct, full.correct);
                for l in 0..full.grads.len() {
                    assert_eq!(
                        acc.stats[l].a.data(),
                        full.stats[l].a.data(),
                        "trial {trial} k={k} layer {l}: A"
                    );
                    assert_eq!(
                        acc.stats[l].g.data(),
                        full.stats[l].g.data(),
                        "trial {trial} k={k} layer {l}: G"
                    );
                    // Grads go through the reconstruction formula; for
                    // power-of-two heights the 1/m shifts commute exactly.
                    assert_eq!(
                        acc.grads[l].data(),
                        full.grads[l].data(),
                        "trial {trial} k={k} layer {l}: grads"
                    );
                }
            }
        }
    }

    /// The non-dividing edge (`B % k != 0`): deterministic (two runs are
    /// bitwise identical) and numerically equivalent to the unsplit
    /// batch, but not bit-equal — the documented carve-out.
    #[test]
    fn non_dividing_split_is_deterministic_and_close() {
        let mut rng = Pcg::new(73);
        let dims = [5usize, 7, 4];
        let mlp = Mlp::new(&mut rng, &dims);
        let batch = toy_batch(&mut rng, 10, 5, 4);
        let full = mlp.forward_backward(&batch);
        for k in [3usize, 4, 7] {
            let a = forward_backward_accum(&mlp, &batch, k);
            let b = forward_backward_accum(&mlp, &batch, k);
            assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits(), "k={k}: deterministic loss");
            for l in 0..full.grads.len() {
                assert_eq!(a.grads[l].data(), b.grads[l].data(), "k={k} layer {l}: deterministic");
                assert_mat_close(&a.grads[l], &full.grads[l], 1e-4, &format!("k={k} layer {l}"));
                // Stat rows are scale-free, so even the non-dividing split
                // keeps A exactly (pure row slices of the same inputs).
                assert_eq!(a.stats[l].a.data(), full.stats[l].a.data(), "k={k} layer {l}: A rows");
            }
            assert!((a.loss - full.loss).abs() <= 1e-5 * (1.0 + full.loss.abs()), "k={k}: loss");
        }
    }

    /// More micro-batches than rows: the empty shards drop out and the
    /// result still matches the full batch (each micro is a single row).
    #[test]
    fn more_micros_than_rows_degenerates_to_per_row() {
        let mut rng = Pcg::new(74);
        let dims = [4usize, 6, 3];
        let mlp = Mlp::new(&mut rng, &dims);
        let batch = toy_batch(&mut rng, 4, 4, 3);
        let full = mlp.forward_backward(&batch);
        let acc = forward_backward_accum(&mlp, &batch, 4);
        for l in 0..full.grads.len() {
            // 4 rows / 4 micros: every micro height is 1 = 2^0, aligned
            // power-of-two blocks — bitwise holds.
            assert_eq!(acc.stats[l].g.data(), full.stats[l].g.data(), "layer {l}: G");
            assert_eq!(acc.grads[l].data(), full.grads[l].data(), "layer {l}: grads");
        }
        let over = forward_backward_accum(&mlp, &batch, 9);
        assert_eq!(over.loss_rows, 4);
        assert_eq!(over.stats[0].a.rows(), 4);
    }

    /// Streaming splice: `layer_concat` with the last micro's stats as
    /// `tail` must equal folding that micro in and concatenating.
    #[test]
    fn layer_concat_tail_matches_push_then_concat() {
        let mut rng = Pcg::new(75);
        let dims = [4usize, 5, 3];
        let mlp = Mlp::new(&mut rng, &dims);
        let batch = toy_batch(&mut rng, 8, 4, 3);
        let micros = split_batch(&batch, 4);
        let mut acc = BatchAccumulator::new(2);
        for m in &micros[..3] {
            acc.push_result(&mlp.forward_backward(m));
        }
        let last = mlp.forward_backward(&micros[3]);
        for l in 0..2 {
            let spliced = acc.layer_concat(l, Some(&last.stats[l]));
            let mut folded = BatchAccumulator::new(2);
            for m in &micros {
                folded.push_result(&mlp.forward_backward(m));
            }
            let full = folded.layer_concat(l, None);
            assert_eq!(spliced.a.data(), full.a.data(), "layer {l}: A splice");
            assert_eq!(spliced.g.data(), full.g.data(), "layer {l}: G splice");
            assert_eq!(spliced.a.rows(), 8);
        }
    }
}
