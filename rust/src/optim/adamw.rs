//! AdamW in the paper's notation (Fig. 9, right): EMA second moment with
//! bias correction, momentum on the raw gradient, decoupled weight decay.

use super::{Hyper, KronStats, Optimizer};
use crate::tensor::Mat;

pub struct AdamW {
    hp: Hyper,
    /// Second-moment EMA `m_s` (Fig. 9).
    second: Vec<Mat>,
    /// First-moment momentum buffer `m_μ`.
    first: Vec<Mat>,
    diverged: bool,
}

impl AdamW {
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper) -> Self {
        AdamW {
            hp: hp.clone(),
            second: shapes.iter().map(|&(o, i)| Mat::zeros(o, i)).collect(),
            first: shapes.iter().map(|&(o, i)| Mat::zeros(o, i)).collect(),
            diverged: false,
        }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        "adamw".into()
    }

    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], _stats: &[KronStats]) {
        let p = self.hp.policy;
        // Fig. 9 uses β₁ for the second-moment EMA and α₂ for momentum.
        let b1 = self.hp.precond_lr.clamp(1e-4, 0.5); // 1−β₂ᴬᵈᵃᵐ, e.g. 0.01
        let a2 = self.hp.momentum;
        let t1 = (t + 1) as i32;
        for l in 0..params.len() {
            let g = &grads[l];
            // m_s ← (1−b1) m_s + b1 g²
            let g2 = g.hadamard(g);
            self.second[l].ema(1.0 - b1, b1, &g2);
            p.quantize_mat(&mut self.second[l]);
            // m_μ ← a2 m_μ + (1−a2) g
            self.first[l].ema(a2, 1.0 - a2, g);
            p.quantize_mat(&mut self.first[l]);
            // Bias corrections.
            let bc2 = 1.0 - (1.0 - b1).powi(t1);
            let bc1 = 1.0 - a2.powi(t1);
            let damping = self.hp.eps.max(1e-12);
            // w ← w − β₂ ( m̂ / (√v̂ + λ) + γ w )
            let wmat = &mut params[l];
            for i in 0..wmat.len() {
                let v = (self.second[l].data()[i] / bc2).max(0.0);
                let mhat = self.first[l].data()[i] / bc1;
                let upd = mhat / (v.sqrt() + damping) + self.hp.weight_decay * wmat.data()[i];
                wmat.data_mut()[i] -= self.hp.lr * upd;
            }
            p.quantize_mat(wmat);
            self.diverged |= wmat.has_nonfinite();
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn state_bytes(&self) -> usize {
        self.second
            .iter()
            .chain(self.first.iter())
            .map(|m| self.hp.policy.stored_bytes(m.rows(), m.cols()))
            .sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn state_blobs_per_layer(&self) -> usize {
        2
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        // Two blobs per layer: second moment, then first moment.
        self.second
            .iter()
            .zip(&self.first)
            .flat_map(|(s, f)| [s.data().to_vec(), f.data().to_vec()])
            .collect()
    }

    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        let want: Vec<usize> =
            self.second.iter().zip(&self.first).flat_map(|(s, f)| [s.len(), f.len()]).collect();
        super::check_blob_lens("adamw", blobs, &want)?;
        let mut it = blobs.iter();
        for (s, f) in self.second.iter_mut().zip(self.first.iter_mut()) {
            s.data_mut().copy_from_slice(it.next().unwrap());
            f.data_mut().copy_from_slice(it.next().unwrap());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{testutil, Method};

    #[test]
    fn adamw_converges_on_quadratic() {
        let hp = Hyper { lr: 0.05, precond_lr: 0.05, weight_decay: 0.0, ..Hyper::default() };
        let (l0, ln) = testutil::run_quadratic(&Method::AdamW, &hp, 150, 11);
        assert!(ln < 0.1 * l0, "{l0} -> {ln}");
    }

    #[test]
    fn adamw_step_size_is_lr_bounded_early() {
        // With bias correction, the very first step is ≈ lr·sign(g).
        let hp = Hyper { lr: 0.1, momentum: 0.9, weight_decay: 0.0, eps: 1e-8, ..Hyper::default() };
        let mut opt = AdamW::new(&[(1, 1)], &hp);
        let mut params = [Mat::zeros(1, 1)];
        let grads = [Mat::from_vec(1, 1, vec![3.0])];
        let stats = [KronStats { a: Mat::zeros(1, 1), g: Mat::zeros(1, 1) }];
        opt.step(0, &mut params, &grads, &stats);
        assert!((params[0].at(0, 0) + 0.1).abs() < 1e-3, "{}", params[0].at(0, 0));
    }

    #[test]
    fn state_is_two_buffers() {
        let hp = Hyper::default();
        let opt = AdamW::new(&[(8, 4)], &hp);
        assert_eq!(opt.state_bytes(), 2 * 8 * 4 * 4);
    }
}
