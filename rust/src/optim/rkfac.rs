//! RK-FAC — randomized (sketched) KFAC, after "Randomized K-FACs"
//! (arXiv 2206.15397): the Kronecker factors `U = AᵀA/m` and
//! `G = GmᵀGm/m` are never formed. Each refresh draws a deterministic
//! rank-`k` Rademacher sketch `S ∈ {±1}^{k×m}` and keeps only
//! `Y = S·X/√(km)` (so `E[YᵀY] = XᵀX/m`), plus the k×k Woodbury core
//! `C = (λI_k + Y Yᵀ)⁻¹`. The damped inverse applies by the Woodbury
//! identity without ever materializing a d×d matrix:
//!
//! ```text
//! (λI_d + YᵀY)⁻¹ = (I_d − Yᵀ C Y) / λ,     C = (λI_k + Y Yᵀ)⁻¹,
//! ```
//!
//! so per-layer state is `O(k·d)` per side plus the `d_o×d_i` momentum
//! buffer — between MAC's `O(d)` and dense KFAC's `O(d²)` (the
//! `state_bytes_ordering_matches_table3` pin in `optim::tests`).
//!
//! Determinism contract (rust/tests/dist.rs digest grid): the sketch
//! bits are a pure function of `(layer, t)` through a *local* SplitMix64
//! stream — no shared RNG, no call-order dependence — so every rank and
//! every pool size derives bitwise-identical sketches from the gathered
//! batch (`sketch_bits_are_thread_and_shard_invariant` below is the test
//! a shared-RNG-call-order bug must fail before the digest grid does).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use super::{Hyper, KronStats, Optimizer};
use crate::dist::DistCtx;
use crate::numerics::QMat;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, pool, Mat};

/// Default sketch rank for `Method::RkFac` (`"rkfac"` without a suffix).
pub const DEFAULT_SKETCH_RANK: usize = 4;

/// Domain-separation constant for the sketch stream (an arbitrary odd
/// 64-bit constant, distinct from the transport and numerics streams).
const SKETCH_STREAM: u64 = 0x5ee7_c4fa_c0de_2397;

/// One SplitMix64 output; advances `state`. Local to this module on
/// purpose: the sketch must not share a stream (or call order) with any
/// other consumer.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Seed of the sketch stream for `(layer, refresh step)` — the only
/// inputs, so identical on every rank/thread for the same global step.
pub fn sketch_seed(layer: usize, t: usize) -> u64 {
    let mut s = SKETCH_STREAM
        ^ (layer as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ (t as u64).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    splitmix64(&mut s)
}

/// The Rademacher sign pattern `S ∈ {±1}^{k×m}` as raw bits, row-major
/// (one bit per entry, drawn in fixed `(row, col)` order). Exposed for
/// the determinism test; [`sketch`] consumes the same stream.
pub fn sketch_signs(seed: u64, k: usize, m: usize) -> Vec<bool> {
    let mut state = seed;
    (0..k * m).map(|_| splitmix64(&mut state) & 1 == 1).collect()
}

/// `Y = S·X/√(km)` for the deterministic sign pattern of `seed`.
/// Accumulation is scalar, row `i` ascending — independent of pool size.
pub fn sketch(seed: u64, k: usize, x: &Mat) -> Mat {
    let m = x.rows();
    let d = x.cols();
    let mut y = Mat::zeros(k, d);
    let scale = 1.0 / ((k.max(1) * m.max(1)) as f32).sqrt();
    let mut state = seed;
    for r in 0..k {
        let yr = &mut y.data_mut()[r * d..(r + 1) * d];
        for i in 0..m {
            let s = if splitmix64(&mut state) & 1 == 1 { -scale } else { scale };
            let xr = x.row(i);
            for (yv, &xv) in yr.iter_mut().zip(xr.iter()) {
                *yv += s * xv;
            }
        }
    }
    y
}

/// Per-layer sketched factor state (storage dtype via [`QMat`], exactly
/// like KFAC): `y_k`/`c_k` input side (`k×d_i`, `k×k`), `y_c`/`c_c`
/// output side, and the momentum buffer.
struct LayerState {
    y_k: QMat,
    c_k: QMat,
    y_c: QMat,
    c_c: QMat,
    m_mu: QMat,
}

pub struct RkFac {
    hp: Hyper,
    k: usize,
    /// Per-layer state; `None` for layers this rank does not own under
    /// [`DistCtx`] (factor-sharded).
    layers: Vec<Option<LayerState>>,
    /// Per-layer refresh periods; empty → uniform [`Hyper::t_update`].
    schedule: Vec<usize>,
    dist: DistCtx,
    diverged: bool,
    /// Cholesky failures of the k×k Woodbury core (stability telemetry).
    pub chol_failures: usize,
}

impl RkFac {
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper, k: usize) -> Self {
        Self::with_dist(shapes, hp, k, DistCtx::single())
    }

    pub fn with_dist(shapes: &[(usize, usize)], hp: &Hyper, k: usize, dist: DistCtx) -> Self {
        let store = hp.policy.store;
        let k = k.max(1);
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(l, &(o, i))| {
                dist.owns_layer(l).then(|| LayerState {
                    y_k: QMat::zeros(store, k, i),
                    c_k: QMat::zeros(store, k, k),
                    y_c: QMat::zeros(store, k, o),
                    c_c: QMat::zeros(store, k, k),
                    m_mu: QMat::zeros(store, o, i),
                })
            })
            .collect();
        RkFac {
            hp: hp.clone(),
            k,
            layers,
            schedule: Vec::new(),
            dist,
            diverged: false,
            chol_failures: 0,
        }
    }

    /// Woodbury application of the damped input-factor inverse on the
    /// right of a `d_o × d_i` gradient ([`Self::woodbury_left`] is the
    /// output-factor mirror).
    fn woodbury_right(g: &Mat, y: &Mat, c: &Mat, damping: f32) -> Mat {
        // G (λI + YᵀY)⁻¹ = (G − (G Yᵀ) C Y) / λ
        let gy = matmul_a_bt(g, y); // d_o × k
        let corr = matmul(&matmul(&gy, c), y); // d_o × d_i
        g.sub(&corr).scale(1.0 / damping)
    }

    fn woodbury_left(v: &Mat, y: &Mat, c: &Mat, damping: f32) -> Mat {
        // (λI + YᵀY)⁻¹ V = (V − Yᵀ C (Y V)) / λ
        let yv = matmul(y, v); // k × d_i
        let corr = matmul_at_b(y, &matmul(c, &yv)); // d_o × d_i
        v.sub(&corr).scale(1.0 / damping)
    }
}

impl Optimizer for RkFac {
    fn name(&self) -> String {
        if self.k == DEFAULT_SKETCH_RANK {
            "rkfac".into()
        } else {
            format!("rkfac:{}", self.k)
        }
    }

    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], stats: &[KronStats]) {
        assert_eq!(params.len(), self.layers.len(), "rkfac: params/layers mismatch");
        assert_eq!(grads.len(), params.len(), "rkfac: grads/params mismatch");
        assert_eq!(stats.len(), params.len(), "rkfac: stats/params mismatch");
        let policy = self.hp.policy;
        let hp = &self.hp;
        let k = self.k;
        {
            // Sketch refresh fans out per owned layer; each job derives
            // its own SplitMix64 stream from (layer, t), so nothing here
            // depends on job execution order or pool size.
            let chol_failures = AtomicUsize::new(0);
            let diverged = AtomicBool::new(false);
            let schedule = &self.schedule;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                .layers
                .iter_mut()
                .zip(stats.iter())
                .enumerate()
                .filter(|(l, _)| t % schedule.get(*l).copied().unwrap_or(hp.t_update).max(1) == 0)
                .filter_map(|(l, (st, stat))| st.as_mut().map(|st| (l, st, stat)))
                .map(|(l, st, stat)| {
                    let cf = &chol_failures;
                    let dv = &diverged;
                    Box::new(move || {
                        let seed = sketch_seed(l, t);
                        // Two sides share one stream: input signs first,
                        // output signs continue where the input left off
                        // (both sketches still pure functions of (l, t)).
                        let m = stat.a.rows();
                        let mut y_k = sketch(seed, k, &stat.a);
                        let mut state = seed;
                        for _ in 0..k * m {
                            splitmix64(&mut state);
                        }
                        let mut y_c = sketch(state, k, &stat.g);
                        policy.quantize_mat(&mut y_k);
                        policy.quantize_mat(&mut y_c);
                        // k×k Woodbury cores C = (λI + Y Yᵀ)⁻¹, fp32
                        // compute with storage rounding (same recipe and
                        // failure telemetry as KFAC's damped inverse).
                        let c_k = super::kfac::damped_inverse(
                            &matmul_a_bt(&y_k, &y_k),
                            hp.damping,
                            &policy,
                            cf,
                            dv,
                        );
                        let c_c = super::kfac::damped_inverse(
                            &matmul_a_bt(&y_c, &y_c),
                            hp.damping,
                            &policy,
                            cf,
                            dv,
                        );
                        st.y_k = QMat::from_quantized(policy.store, y_k);
                        st.y_c = QMat::from_quantized(policy.store, y_c);
                        st.c_k = QMat::from_quantized(policy.store, c_k);
                        st.c_c = QMat::from_quantized(policy.store, c_c);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            if !jobs.is_empty() {
                pool::run_jobs(jobs);
            }
            self.chol_failures += chol_failures.load(Ordering::Relaxed);
            self.diverged |= diverged.load(Ordering::Relaxed);
        }
        let diverged = AtomicBool::new(false);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
            .layers
            .iter_mut()
            .zip(params.iter_mut().zip(grads.iter()))
            .filter_map(|(st, (p, g))| st.as_mut().map(|st| (st, p, g)))
            .map(|(st, p, g)| {
                let dv = &diverged;
                Box::new(move || {
                    // m_μ ← α₂ m_μ + Ĝ⁻¹ ∇W Û⁻¹ + γ W with both damped
                    // inverses applied through the Woodbury identity.
                    let y_k = st.y_k.widen();
                    let c_k = st.c_k.widen();
                    let y_c = st.y_c.widen();
                    let c_c = st.c_c.widen();
                    let right = Self::woodbury_right(g, &y_k, &c_k, hp.damping);
                    let precond = Self::woodbury_left(&right, &y_c, &c_c, hp.damping);
                    let mut m_mu = st.m_mu.widen();
                    m_mu.ema(hp.momentum, 1.0, &precond);
                    m_mu.axpy(hp.weight_decay, p);
                    policy.quantize_mat(&mut m_mu);
                    let f = super::update_clip_factor(hp.lr, &m_mu, hp.update_clip);
                    p.axpy(-hp.lr * f, &m_mu);
                    policy.quantize_mat(p);
                    if p.has_nonfinite() || m_mu.has_nonfinite() {
                        dv.store(true, Ordering::Relaxed);
                    }
                    st.m_mu = QMat::from_quantized(policy.store, m_mu);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_jobs(jobs);
        self.diverged |= diverged.load(Ordering::Relaxed);
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn set_precond_schedule(&mut self, periods: Vec<usize>) {
        self.schedule = periods;
    }

    fn state_bytes(&self) -> usize {
        self.layers
            .iter()
            .flatten()
            .map(|st| {
                st.y_k.bytes() + st.c_k.bytes() + st.y_c.bytes() + st.c_c.bytes() + st.m_mu.bytes()
            })
            .sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn telemetry(&self) -> String {
        if self.chol_failures > 0 {
            format!("chol_failures={}", self.chol_failures)
        } else {
            String::new()
        }
    }

    fn owned_layers(&self) -> Option<Vec<usize>> {
        self.dist.owned_layers(self.layers.len())
    }

    fn state_blobs_per_layer(&self) -> usize {
        5
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        // Five blobs per owned layer: Y_K, C_K, Y_C, C_C, m_μ (exact f32
        // images of the stored values — the round-trip stays bitwise).
        let mut out = Vec::new();
        for st in self.layers.iter().flatten() {
            out.push(st.y_k.widen().data().to_vec());
            out.push(st.c_k.widen().data().to_vec());
            out.push(st.y_c.widen().data().to_vec());
            out.push(st.c_c.widen().data().to_vec());
            out.push(st.m_mu.widen().data().to_vec());
        }
        out
    }

    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        let want: Vec<usize> = self
            .layers
            .iter()
            .flatten()
            .flat_map(|st| [st.y_k.len(), st.c_k.len(), st.y_c.len(), st.c_c.len(), st.m_mu.len()])
            .collect();
        super::check_blob_lens("rkfac", blobs, &want)?;
        let store = self.hp.policy.store;
        let mut it = blobs.iter();
        for st in self.layers.iter_mut().flatten() {
            let mut load = |rows: usize, cols: usize| {
                QMat::from_quantized(store, Mat::from_vec(rows, cols, it.next().unwrap().clone()))
            };
            st.y_k = load(st.y_k.rows(), st.y_k.cols());
            st.c_k = load(st.c_k.rows(), st.c_k.cols());
            st.y_c = load(st.y_c.rows(), st.y_c.cols());
            st.c_c = load(st.c_c.rows(), st.c_c.cols());
            st.m_mu = load(st.m_mu.rows(), st.m_mu.cols());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{DistCtx, DistStrategy};
    use crate::optim::{testutil, Method};
    use crate::proptest::Pcg;

    #[test]
    fn rkfac_converges_on_ill_conditioned_quadratic() {
        let hp = Hyper {
            lr: 0.05,
            momentum: 0.3,
            damping: 0.1,
            precond_lr: 1.0,
            weight_decay: 0.0,
            t_update: 1,
            ..Hyper::default()
        };
        let (l0, ln) =
            testutil::run_quadratic(&Method::RkFac { k: DEFAULT_SKETCH_RANK }, &hp, 100, 23);
        assert!(ln < 0.1 * l0, "rkfac {l0} -> {ln}");
    }

    /// The ISSUE-10 deterministic-sketch contract: the sketch bits for a
    /// given (layer, refresh step) are identical across pool sizes and
    /// across rank decompositions. A shared-RNG-call-order bug (e.g.
    /// seeding from a global stream that other layers also advance) must
    /// fail here, before the dist.rs digest grid ever runs.
    #[test]
    fn sketch_bits_are_thread_and_shard_invariant() {
        let mut rng = Pcg::new(91);
        let x = rng.normal_mat(24, 10, 1.0);
        let baseline: Vec<(Vec<bool>, Mat)> = (0..4)
            .map(|l| (sketch_signs(sketch_seed(l, 6), 3, 24), sketch(sketch_seed(l, 6), 3, &x)))
            .collect();
        for threads in [1usize, 4] {
            pool::with_threads(threads, || {
                // Sketch every layer in reverse order too: a call-order
                // dependence would shift the stream; a pure per-(l, t)
                // stream cannot notice.
                for &l in &[3usize, 1, 0, 2] {
                    let signs = sketch_signs(sketch_seed(l, 6), 3, 24);
                    assert_eq!(signs, baseline[l].0, "threads={threads} layer={l}");
                    let y = sketch(sketch_seed(l, 6), 3, &x);
                    assert_eq!(y.data(), baseline[l].1.data(), "threads={threads} layer={l}");
                }
            });
        }
        // Rank decompositions {1, 4}: every rank that owns layer l under
        // factor sharding derives the identical sketch for (l, t).
        for world in [1usize, 4] {
            for rank in 0..world {
                let ctx = DistCtx { rank, world, strategy: DistStrategy::FactorSharded };
                for l in 0..4 {
                    if ctx.owns_layer(l) {
                        assert_eq!(
                            sketch_signs(sketch_seed(l, 6), 3, 24),
                            baseline[l].0,
                            "world={world} rank={rank} layer={l}"
                        );
                    }
                }
            }
        }
        // Distinct (layer, t) keys give distinct sign patterns.
        assert_ne!(sketch_signs(sketch_seed(0, 6), 3, 24), sketch_signs(sketch_seed(1, 6), 3, 24));
        assert_ne!(sketch_signs(sketch_seed(0, 6), 3, 24), sketch_signs(sketch_seed(0, 7), 3, 24));
    }

    #[test]
    fn sketch_gram_approximates_factor_in_expectation() {
        // Average YᵀY over many refresh keys ≈ XᵀX/m (the sketch is an
        // unbiased estimator; 256 draws shrink the variance enough for a
        // loose tolerance).
        let mut rng = Pcg::new(92);
        let x = rng.normal_mat(32, 6, 1.0);
        let want = crate::tensor::matmul_at_b(&x, &x).scale(1.0 / 32.0);
        let mut acc = Mat::zeros(6, 6);
        let draws = 256;
        for t in 0..draws {
            let y = sketch(sketch_seed(0, t), 4, &x);
            acc.axpy(1.0 / draws as f32, &crate::tensor::matmul_at_b(&y, &y));
        }
        crate::proptest::assert_mat_close(&acc, &want, 0.35, "sketch mean");
    }

    #[test]
    fn woodbury_matches_dense_damped_inverse() {
        // (λI + YᵀY)⁻¹ applied via the k×k core must agree with the
        // dense d×d inverse on both sides of the gradient.
        let mut rng = Pcg::new(93);
        let (k, d_i, d_o) = (3usize, 7usize, 5usize);
        let damping = 0.05f32;
        let y_k = rng.normal_mat(k, d_i, 1.0);
        let y_c = rng.normal_mat(k, d_o, 1.0);
        let g = rng.normal_mat(d_o, d_i, 1.0);
        let cores = |y: &Mat| {
            let mut s = matmul_a_bt(y, y);
            s.add_diag(damping);
            crate::linalg::spd_inverse(&s).unwrap()
        };
        let right = RkFac::woodbury_right(&g, &y_k, &cores(&y_k), damping);
        let left = RkFac::woodbury_left(&right, &y_c, &cores(&y_c), damping);
        let dense_inv = |y: &Mat, d: usize| {
            let mut s = matmul_at_b(y, y);
            s.add_diag(damping);
            assert_eq!(s.rows(), d);
            crate::linalg::spd_inverse(&s).unwrap()
        };
        let want = matmul(&dense_inv(&y_c, d_o), &matmul(&g, &dense_inv(&y_k, d_i)));
        crate::proptest::assert_mat_close(&left, &want, 1e-3, "woodbury");
    }

    #[test]
    fn rkfac_state_vectors_roundtrip_bitwise() {
        let mut rng = Pcg::new(94);
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = RkFac::new(&shapes, &hp, 2);
        let mut params = vec![rng.normal_mat(5, 4, 0.2), rng.normal_mat(3, 5, 0.2)];
        for t in 0..2 {
            let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(12, 4, 1.0), g: rng.normal_mat(12, 5, 1.0) },
                KronStats { a: rng.normal_mat(12, 5, 1.0), g: rng.normal_mat(12, 3, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
        }
        let snap = opt.state_vectors();
        assert_eq!(snap.len(), 2 * 5);
        let mut fresh = RkFac::new(&shapes, &hp, 2);
        fresh.load_state_vectors(&snap).unwrap();
        assert_eq!(fresh.state_vectors(), snap);
        assert!(fresh.load_state_vectors(&snap[..4]).is_err());
    }

    #[test]
    fn rkfac_per_layer_precond_schedule() {
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 2, damping: 0.1, ..Hyper::default() };
        let run = |schedule: Option<Vec<usize>>| -> Vec<Vec<Vec<f32>>> {
            let mut rng = Pcg::new(95);
            let mut opt = RkFac::new(&shapes, &hp, 2);
            if let Some(s) = schedule {
                opt.set_precond_schedule(s);
            }
            let mut params = vec![Mat::zeros(5, 4), Mat::zeros(3, 5)];
            let mut snaps = Vec::new();
            for t in 0..6 {
                let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
                let stats = vec![
                    KronStats { a: rng.normal_mat(12, 4, 1.0), g: rng.normal_mat(12, 5, 1.0) },
                    KronStats { a: rng.normal_mat(12, 5, 1.0), g: rng.normal_mat(12, 3, 1.0) },
                ];
                opt.step(t, &mut params, &grads, &stats);
                snaps.push(opt.state_vectors());
            }
            snaps
        };
        assert_eq!(run(None), run(Some(vec![2, 2])), "uniform schedule must be a no-op");
        // Blob layout: 5 per layer, Y_K first → layer 1's Y_K is blob 5.
        let staggered = run(Some(vec![1, 3]));
        for t in 1..6 {
            assert_ne!(staggered[t][0], staggered[t - 1][0], "t={t}: layer 0 refreshes each step");
            if t % 3 == 0 {
                assert_ne!(staggered[t][5], staggered[t - 1][5], "t={t}: layer 1 must refresh");
            } else {
                assert_eq!(staggered[t][5], staggered[t - 1][5], "t={t}: layer 1 stays frozen");
            }
        }
    }

    #[test]
    fn factor_sharded_ranks_only_hold_owned_state() {
        let shapes = [(5usize, 4usize), (3, 5), (4, 3), (6, 4)];
        let hp = Hyper::default();
        let full = RkFac::new(&shapes, &hp, 2).state_bytes();
        let mut sharded = 0usize;
        for rank in 0..4 {
            let ctx = DistCtx { rank, world: 4, strategy: DistStrategy::FactorSharded };
            let opt = RkFac::with_dist(&shapes, &hp, 2, ctx);
            assert_eq!(opt.owned_layers(), Some(vec![rank]));
            sharded += opt.state_bytes();
        }
        assert_eq!(sharded, full, "per-rank shards partition the full state");
    }
}
