//! Optimizers: SGD, AdamW (Fig. 9), KFAC (Fig. 3 left), IKFAC (Fig. 3
//! right), INGD and SINGD (Fig. 4).
//!
//! All optimizers speak the same per-layer interface. Every layer `l` is a
//! (generalized) linear layer with weight matrix `W_l ∈ R^{d_o × d_i}`
//! (bias folded in homogeneous coordinates by the models). The backward
//! pass supplies, per layer:
//!
//! - the gradient `∇W_l ∈ R^{d_o × d_i}`, and
//! - [`KronStats`]: the layer *input* activations `A ∈ R^{m × d_i}` and the
//!   loss gradient w.r.t. the layer *output* `Gm ∈ R^{m × d_o}` — from
//!   which the Kronecker curvature factors are `U = AᵀA/m` (input side,
//!   `S_K`/`K`) and `G = GmᵀGm/m` (output side, `S_C`/`C`).
//!
//! Second-order optimizers refresh their preconditioner every
//! [`Hyper::t_update`] steps (the `T` of Figs. 3/4) and precondition the
//! gradient every step. All state mutations are routed through a
//! [`Policy`] so the whole optimizer runs in emulated bf16/fp16 when
//! configured — reproducing the paper's mixed-precision results.
//!
//! Layers are independent, so the second-order methods (KFAC and the
//! SINGD family) fan their per-layer refresh + update work out across the
//! persistent worker pool in [`crate::tensor::pool`]; pooled and serial
//! stepping produce identical trajectories (`rust/tests/parallel.rs`).

pub mod accum;
mod adamw;
mod kfac;
mod mac;
mod rkfac;
mod sgd;
mod singd;

pub use accum::BatchAccumulator;
pub use adamw::AdamW;
pub use kfac::Kfac;
pub use mac::Mac;
pub use rkfac::{RkFac, DEFAULT_SKETCH_RANK};
pub use sgd::Sgd;
pub use singd::Singd;

use crate::numerics::Policy;
use crate::structured::Structure;
use crate::tensor::Mat;

/// Per-layer Kronecker statistics from the backward pass.
#[derive(Clone, Debug)]
pub struct KronStats {
    /// Layer inputs, `m × d_i` (bias column included when the layer has one).
    pub a: Mat,
    /// Loss gradient w.r.t. layer outputs, `m × d_o`.
    pub g: Mat,
}

impl KronStats {
    /// Dense input factor `U = AᵀA / m`.
    pub fn u_dense(&self) -> Mat {
        crate::tensor::matmul_at_b(&self.a, &self.a).scale(1.0 / self.a.rows() as f32)
    }

    /// Dense output factor `G = GmᵀGm / m`.
    pub fn g_dense(&self) -> Mat {
        crate::tensor::matmul_at_b(&self.g, &self.g).scale(1.0 / self.g.rows() as f32)
    }
}

/// Hyper-parameters shared across methods (paper Table 4 notation).
#[derive(Clone, Debug)]
pub struct Hyper {
    /// `β₂` — parameter learning rate.
    pub lr: f32,
    /// `α₂` — momentum on the update direction.
    pub momentum: f32,
    /// `γ` — decoupled (L2) weight decay.
    pub weight_decay: f32,
    /// `λ` — damping.
    pub damping: f32,
    /// `β₁` — preconditioner learning rate / EMA weight.
    pub precond_lr: f32,
    /// `α₁` — Riemannian momentum (INGD/SINGD only).
    pub riem_momentum: f32,
    /// `T` — preconditioner update interval.
    pub t_update: usize,
    /// Numeric precision policy for optimizer state and updates.
    pub policy: Policy,
    /// AdamW `ε`-like floor (also used as AdamW damping λ in Fig. 9).
    pub eps: f32,
    /// Trust region on the log-space preconditioner step of IKFAC/SINGD:
    /// the multiplicative update uses `Expm(−β₁ m) ≈ I − β₁ m`, which is
    /// only valid for `‖β₁ m‖ ≲ 1`; when the curvature spikes (early
    /// training, large losses) the raw step can flip K's spectrum and
    /// blow up (paper footnote 1 notes K may go singular under first-order
    /// truncation). We rescale the step so `β₁·‖m‖ ≤ precond_clip`,
    /// preserving the direction — exact Expm would need no clip.
    pub precond_clip: f32,
    /// RMS trust region on the per-layer parameter update `β₂·m_μ` of the
    /// second-order methods (KFAC and SINGD family): when damping is small
    /// and the curvature has near-vanished directions, `(S+λI)⁻¹` amplifies
    /// the gradient by up to `1/λ`; every production KFAC applies a KL/norm
    /// clip here. `0` disables.
    pub update_clip: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            damping: 1e-3,
            precond_lr: 0.05,
            riem_momentum: 0.9,
            t_update: 5,
            policy: Policy::fp32(),
            eps: 1e-8,
            precond_clip: 1.0,
            update_clip: 0.1,
        }
    }
}

/// Validate that checkpoint blobs match the expected lengths exactly
/// (count and per-blob size) before any state is overwritten, so a
/// failed [`Optimizer::load_state_vectors`] never leaves partial state.
pub(crate) fn check_blob_lens(name: &str, blobs: &[Vec<f32>], want: &[usize]) -> Result<(), String> {
    if blobs.len() != want.len() {
        return Err(format!("{name}: {} state blobs, expected {}", blobs.len(), want.len()));
    }
    for (i, (b, &w)) in blobs.iter().zip(want).enumerate() {
        if b.len() != w {
            return Err(format!("{name}: blob {i} has {} floats, expected {w}", b.len()));
        }
    }
    Ok(())
}

/// Per-layer update trust region: scale factor keeping the RMS of
/// `lr · update` at or below `clip` (1.0 when `clip == 0`).
pub(crate) fn update_clip_factor(lr: f32, update: &Mat, clip: f32) -> f32 {
    if clip <= 0.0 {
        return 1.0;
    }
    let rms = lr.abs() * update.fro_norm() / (update.len() as f32).sqrt();
    if rms > clip && rms.is_finite() {
        clip / rms
    } else {
        1.0
    }
}

/// Common optimizer interface.
///
/// `Send` so per-rank optimizer replicas can live behind the distributed
/// training driver's rank threads ([`crate::train::train_dist`]).
pub trait Optimizer: Send {
    /// Human-readable method name (used in logs / CSV headers).
    fn name(&self) -> String;

    /// Apply one optimization step at iteration `t` (0-based).
    ///
    /// `params[l]` is updated in place from `grads[l]` and `stats[l]`.
    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], stats: &[KronStats]);

    /// Bytes of optimizer state under its precision policy (Table 3).
    fn state_bytes(&self) -> usize;

    /// Update the parameter learning rate `β₂` (LR schedules).
    fn set_lr(&mut self, lr: f32);

    /// Give each layer its own preconditioner refresh period (the paper's
    /// `T`, per layer): layer `l` refreshes its factor pair at steps where
    /// `t % periods[l] == 0`. The second-order methods (KFAC and the
    /// SINGD family) honour this; first-order baselines have no
    /// preconditioner and ignore it. An empty vector — and the default
    /// for layers beyond `periods.len()` — means "use [`Hyper::t_update`]
    /// uniformly", which is bitwise identical to never calling this.
    /// Periods are clamped to ≥ 1.
    fn set_precond_schedule(&mut self, periods: Vec<usize>) {
        let _ = periods;
    }

    /// True once any state became NaN/Inf (divergence detection for the
    /// stability experiments).
    fn diverged(&self) -> bool {
        false
    }

    /// Free-form stability telemetry (e.g. KFAC's Cholesky-failure count).
    fn telemetry(&self) -> String {
        String::new()
    }

    /// Layers whose state this instance owns under its
    /// [`crate::dist::DistStrategy`]; `None` means "all layers"
    /// (replicated / non-distributed). The distributed driver uses this
    /// to decide whether a post-step parameter exchange is needed.
    fn owned_layers(&self) -> Option<Vec<usize>> {
        None
    }

    /// Flat snapshot of the optimizer state (momenta, Kronecker/
    /// structured factors) for checkpoint v2. The blob order is an
    /// implementation contract of each optimizer; `state_vectors` and
    /// [`Optimizer::load_state_vectors`] must round-trip bitwise.
    fn state_vectors(&self) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Number of [`Optimizer::state_vectors`] blobs each layer
    /// contributes (a per-method constant). The elastic resharding path
    /// uses it to re-deal a canonical (serial-layout) state snapshot to
    /// a different world size: layer `l`'s blobs are the consecutive
    /// `l·n .. (l+1)·n` slots of the canonical snapshot. `0` means the
    /// optimizer carries no checkpointable state.
    fn state_blobs_per_layer(&self) -> usize {
        0
    }

    /// Restore state captured by [`Optimizer::state_vectors`] from an
    /// identically-configured optimizer. Errors on any count/length
    /// mismatch without modifying state.
    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        if blobs.is_empty() {
            Ok(())
        } else {
            Err(format!("{}: optimizer has no loadable state", self.name()))
        }
    }
}

/// Method selector used by configs, sweeps and benches.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    Sgd,
    AdamW,
    Kfac,
    /// IKFAC — inverse-free KFAC (non-adaptive, no Riemannian momentum).
    Ikfac { structure: Structure },
    /// INGD ≡ SINGD-Dense; SINGD with any structure.
    Singd { structure: Structure },
    /// RK-FAC — KFAC with rank-`k` sketched Kronecker factors
    /// (arXiv 2206.15397), applied through the Woodbury identity.
    RkFac { k: usize },
    /// MAC — mean-activation approximated curvature (arXiv 2506.08464):
    /// a rank-1 input-side preconditioner with `O(d)` state.
    Mac,
}

impl Method {
    /// Parse `"sgd" | "adamw" | "kfac" | "ikfac" | "ingd" |
    /// "singd:<structure>" | "rkfac[:<k>]" | "mac"`.
    pub fn parse(s: &str) -> Option<Method> {
        let low = s.to_ascii_lowercase();
        match low.as_str() {
            "sgd" => Some(Method::Sgd),
            "adamw" | "adam" => Some(Method::AdamW),
            "kfac" => Some(Method::Kfac),
            "ikfac" => Some(Method::Ikfac { structure: Structure::Dense }),
            "ingd" => Some(Method::Singd { structure: Structure::Dense }),
            "rkfac" => Some(Method::RkFac { k: DEFAULT_SKETCH_RANK }),
            "mac" => Some(Method::Mac),
            _ => {
                if let Some(rest) = low.strip_prefix("singd:") {
                    Structure::parse(rest).map(|st| Method::Singd { structure: st })
                } else if let Some(rest) = low.strip_prefix("ikfac:") {
                    Structure::parse(rest).map(|st| Method::Ikfac { structure: st })
                } else if let Some(rest) = low.strip_prefix("rkfac:") {
                    rest.parse::<usize>().ok().filter(|&k| k >= 1).map(|k| Method::RkFac { k })
                } else {
                    None
                }
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            Method::Sgd => "sgd".into(),
            Method::AdamW => "adamw".into(),
            Method::Kfac => "kfac".into(),
            Method::Ikfac { structure } => {
                if *structure == Structure::Dense {
                    "ikfac".into()
                } else {
                    format!("ikfac:{}", structure.name())
                }
            }
            Method::Singd { structure } => {
                if *structure == Structure::Dense {
                    "ingd".into()
                } else {
                    format!("singd:{}", structure.name())
                }
            }
            Method::RkFac { k } => {
                if *k == DEFAULT_SKETCH_RANK {
                    "rkfac".into()
                } else {
                    format!("rkfac:{k}")
                }
            }
            Method::Mac => "mac".into(),
        }
    }

    /// Instantiate for a set of layer shapes `(d_out, d_in)`.
    pub fn build(&self, shapes: &[(usize, usize)], hp: &Hyper) -> Box<dyn Optimizer> {
        self.build_dist(shapes, hp, crate::dist::DistCtx::single())
    }

    /// Instantiate one rank's optimizer under a distributed topology.
    /// The second-order methods (KFAC and the SINGD family) honour
    /// [`crate::dist::DistStrategy::FactorSharded`] by allocating only
    /// their owned layers' factor state; the first-order baselines have
    /// no factors to shard and always run replicated.
    pub fn build_dist(
        &self,
        shapes: &[(usize, usize)],
        hp: &Hyper,
        dist: crate::dist::DistCtx,
    ) -> Box<dyn Optimizer> {
        match self {
            Method::Sgd => Box::new(Sgd::new(shapes, hp)),
            Method::AdamW => Box::new(AdamW::new(shapes, hp)),
            Method::Kfac => Box::new(Kfac::with_dist(shapes, hp, dist)),
            Method::Ikfac { structure } => Box::new(Singd::ikfac_dist(shapes, hp, *structure, dist)),
            Method::Singd { structure } => Box::new(Singd::with_dist(shapes, hp, *structure, dist)),
            Method::RkFac { k } => Box::new(RkFac::with_dist(shapes, hp, *k, dist)),
            Method::Mac => Box::new(Mac::with_dist(shapes, hp, dist)),
        }
    }
}

/// Shared test/bench workload: a controllable synthetic quadratic.
pub mod testutil {
    use super::*;
    use crate::proptest::Pcg;

    /// A tiny synthetic quadratic problem: minimize
    /// `0.5‖W X − Y‖²/m` for one linear layer. Any sane optimizer must
    /// reduce the loss; second-order methods must do so faster per step on
    /// ill-conditioned inputs.
    pub struct Quadratic {
        pub x: Mat, // m × d_i
        pub y: Mat, // m × d_o
    }

    impl Quadratic {
        pub fn new(rng: &mut Pcg, m: usize, d_i: usize, d_o: usize, cond: f32) -> Self {
            // Inputs with geometric per-feature scaling → controllable
            // curvature condition number.
            let mut x = rng.normal_mat(m, d_i, 1.0);
            for c in 0..d_i {
                let s = cond.powf(c as f32 / (d_i.max(2) - 1) as f32);
                for r in 0..m {
                    *x.at_mut(r, c) *= s;
                }
            }
            // Modest target scale keeps initial residuals O(1) so the
            // empirical-Fisher C-side curvature is well-scaled (as it is in
            // normalized training losses).
            let w_true = rng.normal_mat(d_o, d_i, 0.2);
            let y = crate::tensor::matmul_a_bt(&x, &w_true);
            Quadratic { x, y }
        }

        pub fn loss(&self, w: &Mat) -> f32 {
            let pred = crate::tensor::matmul_a_bt(&self.x, w);
            let diff = pred.sub(&self.y);
            0.5 * diff.fro_norm().powi(2) / self.x.rows() as f32
        }

        /// Returns (grad, stats) at `w`.
        pub fn grad(&self, w: &Mat) -> (Mat, KronStats) {
            let m = self.x.rows() as f32;
            let pred = crate::tensor::matmul_a_bt(&self.x, w);
            let gm = pred.sub(&self.y); // ∂L/∂pred, m × d_o
            let grad = crate::tensor::matmul_at_b(&gm, &self.x).scale(1.0 / m); // d_o × d_i
            (grad, KronStats { a: self.x.clone(), g: gm })
        }
    }

    /// Run `steps` optimizer steps on the quadratic; return (loss0, lossN).
    pub fn run_quadratic(
        method: &Method,
        hp: &Hyper,
        steps: usize,
        seed: u64,
    ) -> (f32, f32) {
        let mut rng = Pcg::new(seed);
        let (m, d_i, d_o) = (32, 12, 6);
        let q = Quadratic::new(&mut rng, m, d_i, d_o, 4.0);
        let mut w = rng.normal_mat(d_o, d_i, 0.2);
        let mut opt = method.build(&[(d_o, d_i)], hp);
        let loss0 = q.loss(&w);
        for t in 0..steps {
            let (g, st) = q.grad(&w);
            let mut params = [w];
            opt.step(t, &mut params, &[g], std::slice::from_ref(&st));
            [w] = params;
        }
        (loss0, q.loss(&w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for name in [
            "sgd", "adamw", "kfac", "ikfac", "ingd", "singd:diag", "singd:block:8",
            "singd:hier:16", "singd:toeplitz", "singd:rankk:2", "singd:tril",
            "rkfac", "rkfac:2", "mac",
        ] {
            let m = Method::parse(name).unwrap_or_else(|| panic!("parse {name}"));
            assert_eq!(Method::parse(&m.name()).unwrap(), m, "{name}");
        }
        assert!(Method::parse("foo").is_none());
        assert!(Method::parse("rkfac:0").is_none());
        assert!(Method::parse("rkfac:x").is_none());
    }

    #[test]
    fn all_methods_reduce_quadratic_loss() {
        let hp = Hyper {
            lr: 0.05,
            momentum: 0.3,
            riem_momentum: 0.0,
            t_update: 1,
            ..Hyper::default()
        };
        for m in [
            Method::Sgd,
            Method::AdamW,
            Method::Kfac,
            Method::Ikfac { structure: Structure::Dense },
            Method::Singd { structure: Structure::Dense },
            Method::Singd { structure: Structure::Diagonal },
            Method::Singd { structure: Structure::BlockDiag { k: 4 } },
            Method::Singd { structure: Structure::Hierarchical { k1: 2, k2: 2 } },
            Method::Singd { structure: Structure::TriuToeplitz },
            Method::Singd { structure: Structure::RankKTril { k: 2 } },
            Method::Singd { structure: Structure::Tril },
        ] {
            let (l0, ln) = testutil::run_quadratic(&m, &hp, 60, 99);
            assert!(
                ln < 0.5 * l0,
                "{} failed to optimize: {l0} -> {ln}",
                m.name()
            );
        }
        // The sketched/rank-1 methods amplify their curvature null space by
        // 1/λ, so they need the heavier second-order damping to be stable on
        // this quadratic (same value their own unit tests use).
        let hp2 = Hyper { damping: 0.1, ..hp };
        for m in [Method::RkFac { k: DEFAULT_SKETCH_RANK }, Method::Mac] {
            let (l0, ln) = testutil::run_quadratic(&m, &hp2, 60, 99);
            assert!(
                ln < 0.5 * l0,
                "{} failed to optimize: {l0} -> {ln}",
                m.name()
            );
        }
    }

    #[test]
    fn state_bytes_ordering_matches_table3() {
        // SINGD-Diag ≤ AdamW < SINGD-Dense(=INGD) for a square-ish layer.
        let hp = Hyper::default();
        let shapes = [(128usize, 128usize)];
        let adamw = Method::AdamW.build(&shapes, &hp).state_bytes();
        let dense = Method::Singd { structure: Structure::Dense }.build(&shapes, &hp).state_bytes();
        let diag =
            Method::Singd { structure: Structure::Diagonal }.build(&shapes, &hp).state_bytes();
        let kfac = Method::Kfac.build(&shapes, &hp).state_bytes();
        assert!(diag < adamw, "diag {diag} < adamw {adamw}");
        assert!(adamw < dense, "adamw {adamw} < dense {dense}");
        assert!(adamw < kfac, "adamw {adamw} < kfac {kfac}");
        // Optimizer-zoo memory ordering (acceptance criterion): the rank-1
        // MAC state is smaller than sketched RK-FAC, which is smaller than
        // dense KFAC factors.
        let mac = Method::Mac.build(&shapes, &hp).state_bytes();
        let rkfac =
            Method::RkFac { k: DEFAULT_SKETCH_RANK }.build(&shapes, &hp).state_bytes();
        assert!(mac < rkfac, "mac {mac} < rkfac {rkfac}");
        assert!(rkfac < kfac, "rkfac {rkfac} < kfac {kfac}");
    }
}
