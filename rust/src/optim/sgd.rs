//! SGD with (heavy-ball) momentum and decoupled weight decay.
//!
//! The paper's strong first-order baseline for CNNs (Sec. 4).

use super::{Hyper, KronStats, Optimizer};
use crate::tensor::Mat;

pub struct Sgd {
    hp: Hyper,
    momentum: Vec<Mat>,
    diverged: bool,
}

impl Sgd {
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper) -> Self {
        Sgd {
            hp: hp.clone(),
            momentum: shapes.iter().map(|&(o, i)| Mat::zeros(o, i)).collect(),
            diverged: false,
        }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn step(&mut self, _t: usize, params: &mut [Mat], grads: &[Mat], _stats: &[KronStats]) {
        let p = self.hp.policy;
        for l in 0..params.len() {
            let m = &mut self.momentum[l];
            // m ← α₂ m + g + γ w ; w ← w − β₂ m
            m.ema(self.hp.momentum, 1.0, &grads[l]);
            m.axpy(self.hp.weight_decay, &params[l]);
            p.quantize_mat(m);
            params[l].axpy(-self.hp.lr, m);
            p.quantize_mat(&mut params[l]);
            self.diverged |= m.has_nonfinite() || params[l].has_nonfinite();
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn state_bytes(&self) -> usize {
        self.momentum.iter().map(|m| self.hp.policy.stored_bytes(m.rows(), m.cols())).sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn state_blobs_per_layer(&self) -> usize {
        1
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        self.momentum.iter().map(|m| m.data().to_vec()).collect()
    }

    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        let want: Vec<usize> = self.momentum.iter().map(|m| m.len()).collect();
        super::check_blob_lens("sgd", blobs, &want)?;
        for (m, b) in self.momentum.iter_mut().zip(blobs) {
            m.data_mut().copy_from_slice(b);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{testutil, Method};

    #[test]
    fn sgd_converges_on_quadratic() {
        let hp = Hyper { lr: 0.02, momentum: 0.9, weight_decay: 0.0, ..Hyper::default() };
        let (l0, ln) = testutil::run_quadratic(&Method::Sgd, &hp, 100, 7);
        assert!(ln < 0.1 * l0, "{l0} -> {ln}");
    }

    #[test]
    fn weight_decay_shrinks_weights_at_zero_grad() {
        let hp = Hyper { lr: 0.1, momentum: 0.0, weight_decay: 0.1, ..Hyper::default() };
        let mut opt = Sgd::new(&[(2, 2)], &hp);
        let mut params = [Mat::ones(2, 2)];
        let grads = [Mat::zeros(2, 2)];
        let stats = [KronStats { a: Mat::zeros(1, 2), g: Mat::zeros(1, 2) }];
        opt.step(0, &mut params, &grads, &stats);
        // w ← w − lr·(0 + γ·w) = (1 − 0.01)·w
        assert!((params[0].at(0, 0) - 0.99).abs() < 1e-6);
    }

    #[test]
    fn bf16_policy_quantizes_state() {
        let hp = Hyper { policy: crate::numerics::Policy::bf16_mixed(), ..Hyper::default() };
        let mut opt = Sgd::new(&[(2, 2)], &hp);
        let mut params = [Mat::ones(2, 2)];
        let grads = [Mat::from_vec(2, 2, vec![1.0 + 2f32.powi(-12); 4])];
        let stats = [KronStats { a: Mat::zeros(1, 2), g: Mat::zeros(1, 2) }];
        opt.step(0, &mut params, &grads, &stats);
        for &v in opt.momentum[0].data() {
            assert_eq!(v, crate::numerics::Dtype::Bf16.round(v));
        }
    }
}
