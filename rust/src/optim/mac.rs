//! MAC — mean-activation approximated curvature (after arXiv 2506.08464):
//! the input-side Kronecker factor `U = AᵀA/m` is collapsed to the rank-1
//! outer product of the running mean activation `ā ∈ R^{d_i}`, giving a
//! nearly memory-free preconditioner — `O(d_i)` state per layer, the
//! smallest of the zoo (`state_bytes_ordering_matches_table3`).
//!
//! The damped rank-1 inverse has a closed Sherman–Morrison form; we apply
//! it scaled by `λ` so the step reduces to plain gradient descent in the
//! directions orthogonal to `ā` (scale-stable at any damping — a
//! rank-deficient curvature model must not amplify its own null space):
//!
//! ```text
//! ∇W ← ∇W (I − ā āᵀ / (λ + āᵀā))  =  λ · ∇W (λI + ā āᵀ)⁻¹.
//! ```
//!
//! `ā` refreshes on the [`Hyper::t_update`] cadence (per-layer via
//! [`Optimizer::set_precond_schedule`]) as an EMA of the gathered batch's
//! column means with weight `β₁ = precond_lr`. The gathered statistics
//! are identical on every rank and the column-mean loop accumulates rows
//! in ascending order, so MAC inherits every determinism contract (1–8)
//! with no per-method machinery.

use std::sync::atomic::{AtomicBool, Ordering};

use super::{Hyper, KronStats, Optimizer};
use crate::dist::DistCtx;
use crate::numerics::QMat;
use crate::tensor::{matmul, matmul_a_bt, pool, Mat};

/// Per-layer state: the running mean activation `ā` as a `1 × d_i` row
/// (stored in the policy's storage dtype, like every optimizer buffer).
struct LayerState {
    a_bar: QMat,
}

pub struct Mac {
    hp: Hyper,
    /// Per-layer state; `None` for layers this rank does not own under
    /// [`DistCtx`] (factor-sharded).
    layers: Vec<Option<LayerState>>,
    /// Per-layer refresh periods; empty → uniform [`Hyper::t_update`].
    schedule: Vec<usize>,
    dist: DistCtx,
    diverged: bool,
}

impl Mac {
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper) -> Self {
        Self::with_dist(shapes, hp, DistCtx::single())
    }

    pub fn with_dist(shapes: &[(usize, usize)], hp: &Hyper, dist: DistCtx) -> Self {
        let store = hp.policy.store;
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(l, &(_, i))| {
                dist.owns_layer(l).then(|| LayerState { a_bar: QMat::zeros(store, 1, i) })
            })
            .collect();
        Mac { hp: hp.clone(), layers, schedule: Vec::new(), dist, diverged: false }
    }

    /// Column means of the gathered activations, rows accumulated in
    /// ascending order (deterministic for any pool size / rank count).
    fn column_mean(a: &Mat) -> Mat {
        let (m, d) = (a.rows(), a.cols());
        let mut out = Mat::zeros(1, d);
        for r in 0..m {
            let row = a.row(r);
            for (o, &v) in out.data_mut().iter_mut().zip(row.iter()) {
                *o += v;
            }
        }
        let inv = 1.0 / m.max(1) as f32;
        for o in out.data_mut() {
            *o *= inv;
        }
        out
    }
}

impl Optimizer for Mac {
    fn name(&self) -> String {
        "mac".into()
    }

    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], stats: &[KronStats]) {
        assert_eq!(params.len(), self.layers.len(), "mac: params/layers mismatch");
        assert_eq!(grads.len(), params.len(), "mac: grads/params mismatch");
        assert_eq!(stats.len(), params.len(), "mac: stats/params mismatch");
        let policy = self.hp.policy;
        let hp = &self.hp;
        let b1 = hp.precond_lr;
        let schedule = &self.schedule;
        let diverged = AtomicBool::new(false);
        // One job per owned layer: refresh (when due) + preconditioned
        // update. Layers share no state, so pooled and serial stepping
        // are bitwise identical.
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
            .layers
            .iter_mut()
            .zip(params.iter_mut().zip(grads.iter().zip(stats.iter())))
            .enumerate()
            .filter_map(|(l, (st, rest))| st.as_mut().map(|st| (l, st, rest)))
            .map(|(l, st, (p, (g, stat)))| {
                let dv = &diverged;
                Box::new(move || {
                    if t % schedule.get(l).copied().unwrap_or(hp.t_update).max(1) == 0 {
                        // ā ← (1−β₁) ā + β₁ · colmean(A), EMA accumulated
                        // in the storage format like every factor EMA.
                        let mean = Self::column_mean(&stat.a);
                        let mut a_bar = st.a_bar.widen();
                        a_bar.ema(1.0 - b1, b1, &mean);
                        policy.quantize_mat(&mut a_bar);
                        st.a_bar = QMat::from_quantized(policy.store, a_bar);
                    }
                    // u = ∇W (I − ā āᵀ / (λ + āᵀā)) + γ W (Sherman–
                    // Morrison, λ-scaled so u → ∇W as ā → 0).
                    let a_bar = st.a_bar.widen();
                    let norm2: f32 = a_bar.data().iter().map(|&v| v * v).sum();
                    let ga = matmul_a_bt(g, &a_bar); // d_o × 1
                    let corr = matmul(&ga, &a_bar).scale(1.0 / (hp.damping + norm2));
                    let mut u = g.sub(&corr);
                    u.axpy(hp.weight_decay, p);
                    policy.quantize_mat(&mut u);
                    let f = super::update_clip_factor(hp.lr, &u, hp.update_clip);
                    p.axpy(-hp.lr * f, &u);
                    policy.quantize_mat(p);
                    if p.has_nonfinite() || u.has_nonfinite() {
                        dv.store(true, Ordering::Relaxed);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_jobs(jobs);
        self.diverged |= diverged.load(Ordering::Relaxed);
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn set_precond_schedule(&mut self, periods: Vec<usize>) {
        self.schedule = periods;
    }

    fn state_bytes(&self) -> usize {
        self.layers.iter().flatten().map(|st| st.a_bar.bytes()).sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn owned_layers(&self) -> Option<Vec<usize>> {
        self.dist.owned_layers(self.layers.len())
    }

    fn state_blobs_per_layer(&self) -> usize {
        1
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        // One blob per owned layer: ā (exact f32 image of the store).
        self.layers.iter().flatten().map(|st| st.a_bar.widen().data().to_vec()).collect()
    }

    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        let want: Vec<usize> = self.layers.iter().flatten().map(|st| st.a_bar.len()).collect();
        super::check_blob_lens("mac", blobs, &want)?;
        let store = self.hp.policy.store;
        let mut it = blobs.iter();
        for st in self.layers.iter_mut().flatten() {
            st.a_bar = QMat::from_quantized(
                store,
                Mat::from_vec(1, st.a_bar.cols(), it.next().unwrap().clone()),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{DistCtx, DistStrategy};
    use crate::optim::{testutil, Method};
    use crate::proptest::Pcg;

    #[test]
    fn mac_converges_on_quadratic() {
        let hp = Hyper {
            lr: 0.05,
            damping: 0.1,
            precond_lr: 0.1,
            weight_decay: 0.0,
            t_update: 1,
            ..Hyper::default()
        };
        let (l0, ln) = testutil::run_quadratic(&Method::Mac, &hp, 100, 31);
        assert!(ln < 0.1 * l0, "mac {l0} -> {ln}");
    }

    #[test]
    fn mac_suppresses_the_mean_activation_direction() {
        // With ā fully refreshed (β₁ = 1) and a gradient aligned to ā,
        // the preconditioned update shrinks by λ/(λ+‖ā‖²) relative to the
        // orthogonal direction.
        let hp = Hyper {
            lr: 1.0,
            weight_decay: 0.0,
            damping: 0.5,
            precond_lr: 1.0,
            t_update: 1,
            update_clip: 0.0,
            ..Hyper::default()
        };
        let d_i = 3;
        // Constant activations → ā = (2, 0, 0), ‖ā‖² = 4.
        let mut a = Mat::zeros(8, d_i);
        for r in 0..8 {
            *a.at_mut(r, 0) = 2.0;
        }
        let stats = KronStats { a, g: Mat::zeros(8, 1) };
        let grad = Mat::from_vec(1, d_i, vec![1.0, 1.0, 0.0]);
        let mut params = [Mat::zeros(1, d_i)];
        let mut opt = Mac::new(&[(1, d_i)], &hp);
        opt.step(0, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
        let step0 = -params[0].at(0, 0); // along ā
        let step1 = -params[0].at(0, 1); // orthogonal
        assert!((step1 - 1.0).abs() < 1e-5, "orthogonal direction is plain GD: {step1}");
        let want = 0.5 / (0.5 + 4.0);
        assert!((step0 - want).abs() < 1e-5, "ā direction damped to λ/(λ+‖ā‖²): {step0}");
    }

    #[test]
    fn mac_state_vectors_roundtrip_bitwise() {
        let mut rng = Pcg::new(37);
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = Mac::new(&shapes, &hp);
        let mut params = vec![rng.normal_mat(5, 4, 0.2), rng.normal_mat(3, 5, 0.2)];
        for t in 0..3 {
            let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(12, 4, 1.0), g: rng.normal_mat(12, 5, 1.0) },
                KronStats { a: rng.normal_mat(12, 5, 1.0), g: rng.normal_mat(12, 3, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
        }
        let snap = opt.state_vectors();
        assert_eq!(snap.len(), 2);
        assert!(snap.iter().any(|b| b.iter().any(|&v| v != 0.0)), "ā must be non-trivial");
        let mut fresh = Mac::new(&shapes, &hp);
        fresh.load_state_vectors(&snap).unwrap();
        assert_eq!(fresh.state_vectors(), snap);
        assert!(fresh.load_state_vectors(&snap[..1]).is_err());
    }

    #[test]
    fn mac_per_layer_precond_schedule() {
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 2, ..Hyper::default() };
        let run = |schedule: Option<Vec<usize>>| -> Vec<Vec<Vec<f32>>> {
            let mut rng = Pcg::new(38);
            let mut opt = Mac::new(&shapes, &hp);
            if let Some(s) = schedule {
                opt.set_precond_schedule(s);
            }
            let mut params = vec![Mat::zeros(5, 4), Mat::zeros(3, 5)];
            let mut snaps = Vec::new();
            for t in 0..6 {
                let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
                let stats = vec![
                    KronStats { a: rng.normal_mat(12, 4, 1.0), g: rng.normal_mat(12, 5, 1.0) },
                    KronStats { a: rng.normal_mat(12, 5, 1.0), g: rng.normal_mat(12, 3, 1.0) },
                ];
                opt.step(t, &mut params, &grads, &stats);
                snaps.push(opt.state_vectors());
            }
            snaps
        };
        assert_eq!(run(None), run(Some(vec![2, 2])), "uniform schedule must be a no-op");
        // Blob layout: 1 per layer → layer 1's ā is blob 1.
        let staggered = run(Some(vec![1, 3]));
        for t in 1..6 {
            assert_ne!(staggered[t][0], staggered[t - 1][0], "t={t}: layer 0 refreshes each step");
            if t % 3 == 0 {
                assert_ne!(staggered[t][1], staggered[t - 1][1], "t={t}: layer 1 must refresh");
            } else {
                assert_eq!(staggered[t][1], staggered[t - 1][1], "t={t}: layer 1 stays frozen");
            }
        }
    }

    #[test]
    fn factor_sharded_ranks_only_hold_owned_state() {
        let shapes = [(5usize, 4usize), (3, 5), (4, 3), (6, 4)];
        let hp = Hyper::default();
        let full = Mac::new(&shapes, &hp).state_bytes();
        let mut sharded = 0usize;
        for rank in 0..4 {
            let ctx = DistCtx { rank, world: 4, strategy: DistStrategy::FactorSharded };
            let opt = Mac::with_dist(&shapes, &hp, ctx);
            assert_eq!(opt.owned_layers(), Some(vec![rank]));
            sharded += opt.state_bytes();
        }
        assert_eq!(sharded, full, "per-rank shards partition the full state");
    }
}
