//! SINGD / INGD / IKFAC — the paper's contribution (Figs. 3-right & 4).
//!
//! One engine covers all three methods:
//!
//! - **SINGD** (Fig. 4, right): structured factors `K̂`, `Ĉ`, Riemannian
//!   momentum `α₁`, adaptive curvature (`Tr(H_C)`, `Tr(H_K)`) and adaptive
//!   damping (`c² = λ·Tr(CᵀC)`, `κ² = λ·Tr(KᵀK)`).
//! - **INGD** = SINGD with `Structure::Dense`.
//! - **IKFAC** (Fig. 3, right) = SINGD with `adaptive = false` and
//!   `α₁ = 0`: the trace factors collapse to `Tr(I) = d`, recovering the
//!   update `K ← K(I − β₁/2 (H_K + λKᵀK − I))` of Eq. (8), which tracks
//!   `(S_K + λI)⁻¹` to `O(β₁²)` (Theorem 1 — tested below).
//!
//! The update is *inverse-free*: only matrix multiplications and
//! subtractions, all performed in the structure class, all rounded through
//! the precision policy — hence stable in bf16 where KFAC breaks.
//!
//! Per-layer curvature enters via [`KronStats`] as the raw matrices
//! `A ∈ R^{m×d_i}`, `Gm ∈ R^{m×d_o}`. We never form dense `U`/`G`:
//! `H_K = Kᵀ U K = (A K)ᵀ(A K)/m` is consumed through the structure's
//! `gram_project`, and `Tr(H_K) = ‖A K‖²_F/m`.

use std::sync::atomic::{AtomicBool, Ordering};

use super::{Hyper, KronStats, Optimizer};
use crate::dist::DistCtx;
use crate::structured::{SMat, Structure};
use crate::tensor::{pool, Mat};

struct LayerState {
    k: SMat,
    c: SMat,
    m_k: SMat,
    m_c: SMat,
    m_mu: Mat,
}

pub struct Singd {
    hp: Hyper,
    #[allow(dead_code)]
    structure: Structure,
    /// INGD-style adaptive curvature/damping traces (false → IKFAC).
    adaptive: bool,
    /// Riemannian momentum α₁ (forced to 0 for IKFAC).
    alpha1: f32,
    /// Per-layer preconditioner state; `None` for layers this rank does
    /// not own under [`DistCtx`] (factor-sharded) — unowned layers cost
    /// no factor memory and are skipped by `step`.
    layers: Vec<Option<LayerState>>,
    /// Per-layer preconditioner refresh periods
    /// ([`Optimizer::set_precond_schedule`]); empty → uniform
    /// [`Hyper::t_update`]. Indexed by *global* layer id.
    schedule: Vec<usize>,
    dist: DistCtx,
    diverged: bool,
    label: String,
}

impl Singd {
    /// Full SINGD (INGD when `structure == Dense`).
    pub fn new(shapes: &[(usize, usize)], hp: &Hyper, structure: Structure) -> Self {
        Self::with_dist(shapes, hp, structure, DistCtx::single())
    }

    /// Full SINGD as one rank of a distributed topology.
    pub fn with_dist(
        shapes: &[(usize, usize)],
        hp: &Hyper,
        structure: Structure,
        dist: DistCtx,
    ) -> Self {
        Self::build(shapes, hp, structure, true, hp.riem_momentum, None, dist)
    }

    /// IKFAC: non-adaptive, zero Riemannian momentum (Fig. 3, right).
    /// A structured variant of IKFAC (SIKFAC) is obtained with a
    /// non-dense structure.
    pub fn ikfac(shapes: &[(usize, usize)], hp: &Hyper, structure: Structure) -> Self {
        Self::ikfac_dist(shapes, hp, structure, DistCtx::single())
    }

    /// IKFAC as one rank of a distributed topology.
    pub fn ikfac_dist(
        shapes: &[(usize, usize)],
        hp: &Hyper,
        structure: Structure,
        dist: DistCtx,
    ) -> Self {
        let label = if structure == Structure::Dense {
            "ikfac".to_string()
        } else {
            format!("ikfac:{}", structure.name())
        };
        Self::build(shapes, hp, structure, false, 0.0, Some(label), dist)
    }

    fn build(
        shapes: &[(usize, usize)],
        hp: &Hyper,
        structure: Structure,
        adaptive: bool,
        alpha1: f32,
        label: Option<String>,
        dist: DistCtx,
    ) -> Self {
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(l, &(o, i))| {
                dist.owns_layer(l).then(|| LayerState {
                    k: SMat::identity(structure, i),
                    c: SMat::identity(structure, o),
                    m_k: SMat::zeros(structure, i),
                    m_c: SMat::zeros(structure, o),
                    m_mu: Mat::zeros(o, i),
                })
            })
            .collect();
        let label = label.unwrap_or_else(|| {
            if structure == Structure::Dense {
                if adaptive {
                    "ingd".to_string()
                } else {
                    "ikfac".to_string()
                }
            } else {
                format!("singd:{}", structure.name())
            }
        });
        Singd {
            hp: hp.clone(),
            structure,
            adaptive,
            alpha1,
            layers,
            schedule: Vec::new(),
            dist,
            diverged: false,
            label,
        }
    }

    /// Access a layer's `K` factor (tests / telemetry). Panics for a
    /// layer this rank does not own.
    pub fn k_factor(&self, layer: usize) -> &SMat {
        &self.layers[layer].as_ref().expect("k_factor: layer not owned by this rank").k
    }

    pub fn c_factor(&self, layer: usize) -> &SMat {
        &self.layers[layer].as_ref().expect("c_factor: layer not owned by this rank").c
    }

    /// Refresh the preconditioner of one layer (Fig. 4 step 1).
    fn refresh_layer(st: &mut LayerState, stats: &KronStats, hp: &Hyper, adaptive: bool, alpha1: f32) {
        let policy = hp.policy;
        let lambda = hp.damping;
        let m = stats.a.rows().max(1) as f32;
        let d_i = st.k.dim() as f32;
        let d_o = st.c.dim() as f32;

        // B_K = A K ∈ R^{m×d_i};  B_C = Gm C ∈ R^{m×d_o}.
        let b_k = st.k.right_mul(&stats.a, false);
        let b_c = st.c.right_mul(&stats.g, false);

        // Tr(H_K) = ‖B_K‖²/m, Tr(H_C) = ‖B_C‖²/m.
        let tr_h_k = b_k.fro_norm().powi(2) / m;
        let tr_h_c = b_c.fro_norm().powi(2) / m;

        // Adaptive vs IKFAC coefficients:
        //   adaptive: Tr(H_C)·H_K + λ·Tr(CᵀC)·KᵀK − d_o·I   (scaled 1/(2d_o))
        //   ikfac:    d_o·H_K    + λ·d_o·KᵀK     − d_o·I    (scaled 1/(2d_o))
        let (w_h_k, w_damp_k) =
            if adaptive { (tr_h_c, lambda * st.c.fro_sq()) } else { (d_o, lambda * d_o) };
        let (w_h_c, w_damp_c) =
            if adaptive { (tr_h_k, lambda * st.k.fro_sq()) } else { (d_i, lambda * d_i) };

        // m_K ← α₁ m_K + 1/(2d_o) Π̂(w_h·H_K + w_damp·KᵀK − d_o·I)
        let mut upd_k = st.k.gram_project(&b_k, w_h_k / (m * 2.0 * d_o));
        upd_k.axpy(1.0, &st.k.self_gram_project(w_damp_k / (2.0 * d_o)));
        upd_k.axpy(-0.5, &SMat::identity(st.k.structure(), st.k.dim()));
        st.m_k.scale_inplace(alpha1);
        st.m_k.axpy(1.0, &upd_k);
        st.m_k.quantize(&policy);

        let mut upd_c = st.c.gram_project(&b_c, w_h_c / (m * 2.0 * d_i));
        upd_c.axpy(1.0, &st.c.self_gram_project(w_damp_c / (2.0 * d_i)));
        upd_c.axpy(-0.5, &SMat::identity(st.c.structure(), st.c.dim()));
        st.m_c.scale_inplace(alpha1);
        st.m_c.axpy(1.0, &upd_c);
        st.m_c.quantize(&policy);

        // K ← K (I − β₁ m_K)  (truncated matrix exponential, Eq. 8),
        // with a trust region keeping the truncation valid: rescale so
        // β₁·‖m_K‖∞ ≤ precond_clip (see `Hyper::precond_clip`).
        // Frobenius norm bounds the spectral norm for symmetric m; at the
        // orthonormalized fixed point m → 0, so the clip never binds once
        // the preconditioner has adapted.
        let clip = |m: &SMat| -> f32 {
            let norm = hp.precond_lr * m.fro_sq().sqrt();
            if norm > hp.precond_clip && norm.is_finite() {
                hp.precond_clip / norm
            } else {
                1.0
            }
        };
        let mut step_k = SMat::identity(st.k.structure(), st.k.dim());
        step_k.axpy(-hp.precond_lr * clip(&st.m_k), &st.m_k);
        st.k = st.k.matmul(&step_k);
        st.k.quantize(&policy);

        let mut step_c = SMat::identity(st.c.structure(), st.c.dim());
        step_c.axpy(-hp.precond_lr * clip(&st.m_c), &st.m_c);
        st.c = st.c.matmul(&step_c);
        st.c.quantize(&policy);
    }
}

impl Optimizer for Singd {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(&mut self, t: usize, params: &mut [Mat], grads: &[Mat], stats: &[KronStats]) {
        // Layers are independent, so the whole per-layer pipeline —
        // preconditioner refresh (Fig. 4 step 1) fused with the
        // preconditioned update (steps 2–3) — fans out across the worker
        // pool, one job per layer. Each job owns its layer's state and
        // parameter matrix; divergence is the only shared output.
        assert_eq!(params.len(), self.layers.len(), "singd: params/layers mismatch");
        assert_eq!(grads.len(), params.len(), "singd: grads/params mismatch");
        assert_eq!(stats.len(), params.len(), "singd: stats/params mismatch");
        let policy = self.hp.policy;
        let hp = &self.hp;
        let schedule = &self.schedule;
        let adaptive = self.adaptive;
        let alpha1 = self.alpha1;
        let diverged = AtomicBool::new(false);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
            .layers
            .iter_mut()
            .zip(params.iter_mut())
            .zip(grads.iter().zip(stats.iter()))
            .enumerate()
            .filter_map(|(l, ((st, p), (g, stat)))| st.as_mut().map(|st| (l, st, p, g, stat)))
            .map(|(l, st, p, g, stat)| {
                let dv = &diverged;
                // Per-layer refresh cadence (the paper's `T`, layer-wise):
                // default uniform `t_update` unless a schedule overrides it.
                let period = schedule.get(l).copied().unwrap_or(hp.t_update).max(1);
                let refresh = t % period == 0;
                Box::new(move || {
                    if refresh {
                        Self::refresh_layer(st, stat, hp, adaptive, alpha1);
                    }
                    // m_μ ← α₂ m_μ + C Cᵀ ∇W K Kᵀ + γ W   (Fig. 4, step 2)
                    let precond = st.c.kkt_left(&st.k.kkt_right(g));
                    st.m_mu.ema(hp.momentum, 1.0, &precond);
                    st.m_mu.axpy(hp.weight_decay, p);
                    policy.quantize_mat(&mut st.m_mu);
                    // μ ← μ − β₂ m_μ   (Fig. 4, step 3), with the KL-style
                    // RMS trust region every production KFAC applies.
                    let f = super::update_clip_factor(hp.lr, &st.m_mu, hp.update_clip);
                    p.axpy(-hp.lr * f, &st.m_mu);
                    policy.quantize_mat(p);
                    if p.has_nonfinite()
                        || st.m_mu.has_nonfinite()
                        || st.k.has_nonfinite()
                        || st.c.has_nonfinite()
                    {
                        dv.store(true, Ordering::Relaxed);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_jobs(jobs);
        self.diverged |= diverged.load(Ordering::Relaxed);
    }

    fn set_lr(&mut self, lr: f32) {
        self.hp.lr = lr;
    }

    fn set_precond_schedule(&mut self, periods: Vec<usize>) {
        self.schedule = periods;
    }

    fn state_bytes(&self) -> usize {
        // Per-rank bytes: only owned layers allocate state, so the
        // factor-sharded strategy reports ~1/world of the replicated
        // footprint (Table 3 × the dist_scaling bench).
        let p = &self.hp.policy;
        self.layers
            .iter()
            .flatten()
            .map(|st| {
                let mut b = st.k.bytes(p) + st.c.bytes(p) + p.stored_bytes(st.m_mu.rows(), st.m_mu.cols());
                // Riemannian momentum buffers only exist when α₁ ≠ 0
                // (IKFAC drops them — Fig. 1 right).
                if self.alpha1 != 0.0 {
                    b += st.m_k.bytes(p) + st.m_c.bytes(p);
                }
                b
            })
            .sum()
    }

    fn diverged(&self) -> bool {
        self.diverged
    }

    fn owned_layers(&self) -> Option<Vec<usize>> {
        self.dist.owned_layers(self.layers.len())
    }

    fn state_blobs_per_layer(&self) -> usize {
        5
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        // Five blobs per owned layer: K, C, m_K, m_C (structured
        // coefficient order), then m_μ (row-major).
        let mut out = Vec::new();
        for st in self.layers.iter().flatten() {
            out.push(st.k.coeffs());
            out.push(st.c.coeffs());
            out.push(st.m_k.coeffs());
            out.push(st.m_c.coeffs());
            out.push(st.m_mu.data().to_vec());
        }
        out
    }

    fn load_state_vectors(&mut self, blobs: &[Vec<f32>]) -> Result<(), String> {
        let want: Vec<usize> = self
            .layers
            .iter()
            .flatten()
            .flat_map(|st| {
                [st.k.nnz(), st.c.nnz(), st.m_k.nnz(), st.m_c.nnz(), st.m_mu.len()]
            })
            .collect();
        super::check_blob_lens(&self.label, blobs, &want)?;
        let mut it = blobs.iter();
        for st in self.layers.iter_mut().flatten() {
            st.k.set_coeffs(it.next().unwrap());
            st.c.set_coeffs(it.next().unwrap());
            st.m_k.set_coeffs(it.next().unwrap());
            st.m_c.set_coeffs(it.next().unwrap());
            st.m_mu.data_mut().copy_from_slice(it.next().unwrap());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Policy;
    use crate::optim::{testutil, Method};
    use crate::proptest::{assert_mat_close, Pcg};
    use crate::structured::Structure;

    #[test]
    fn ingd_converges_on_quadratic() {
        // α₁ = 0 for a clean convergence check: on square loss the
        // empirical Fisher vanishes at the optimum, so Riemannian momentum
        // produces a benign late-time oscillation that a pointwise loss
        // assertion would flag (classification losses — used in the paper's
        // experiments and the exp/ drivers — do not have this pathology).
        let hp = Hyper {
            lr: 0.5,
            momentum: 0.0,
            riem_momentum: 0.0,
            t_update: 1,
            damping: 1e-3,
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let (l0, ln) =
            testutil::run_quadratic(&Method::Singd { structure: Structure::Dense }, &hp, 120, 23);
        assert!(ln < 0.1 * l0, "ingd {l0} -> {ln}");
    }

    #[test]
    fn singd_all_structures_stable_in_pure_bf16() {
        // The headline stability claim: even in *pure* bf16 (every op
        // rounded) the inverse-free update keeps finite state.
        let hp = Hyper {
            lr: 0.05,
            momentum: 0.0,
            riem_momentum: 0.0,
            t_update: 1,
            damping: 1e-3,
            policy: Policy::bf16_pure(),
            ..Hyper::default()
        };
        for st in [
            Structure::Dense,
            Structure::Diagonal,
            Structure::BlockDiag { k: 4 },
            Structure::Hierarchical { k1: 2, k2: 2 },
            Structure::TriuToeplitz,
            Structure::RankKTril { k: 2 },
        ] {
            let (l0, ln) = testutil::run_quadratic(&Method::Singd { structure: st }, &hp, 60, 29);
            assert!(ln.is_finite(), "singd:{} diverged in pure bf16", st.name());
            assert!(ln < l0, "singd:{} did not improve: {l0} -> {ln}", st.name());
        }
    }

    /// Theorem 1: with the same curvature sequence, IKFAC's `K Kᵀ` tracks
    /// KFAC's `(S_K + λI)⁻¹` with error `O(β₁²)` — halving β₁ must shrink
    /// the deviation ≈4×.
    #[test]
    fn theorem1_ikfac_tracks_kfac_inverse_second_order() {
        let mut rng = Pcg::new(31);
        let d = 8;
        let lambda = 0.1f32;
        let steps = 20;
        // Shared curvature sequence U_t (well-conditioned SPD).
        let us: Vec<Mat> = (0..steps).map(|_| rng.spd_mat(d, 0.2)).collect();

        let error_for = |beta1: f32| -> f32 {
            // KFAC side: S̄ ← (1−β₁)S̄ + β₁(U + λI), S̄₀ = (1+λ)I (so K₀ = I
            // matches S̄₀ = K₀⁻ᵀK₀⁻¹ ... use S̄₀ = I and λ folded: the
            // theorem needs S̄₀ = K₀⁻ᵀK₀⁻¹; K₀ = I → S̄₀ = I.)
            let mut s_bar = Mat::eye(d);
            let mut k = Mat::eye(d);
            let mut err_max = 0.0f32;
            for u in &us {
                // KFAC update of the damped factor.
                let mut u_damped = u.clone();
                u_damped.add_diag(lambda);
                s_bar = s_bar.scale(1.0 - beta1);
                s_bar.axpy(beta1, &u_damped);
                // IKFAC update (Eq. 8).
                let ku = crate::tensor::matmul(&crate::tensor::matmul(&k.transpose(), u), &k);
                let ktk = crate::tensor::matmul_at_b(&k, &k);
                let mut m_k = ku;
                m_k.axpy(lambda, &ktk);
                m_k.add_diag(-1.0);
                let mut step = Mat::eye(d);
                step.axpy(-beta1 / 2.0, &m_k);
                k = crate::tensor::matmul(&k, &step);
                // Compare K Kᵀ with S̄⁻¹.
                let kkt = crate::tensor::matmul_a_bt(&k, &k);
                let inv = crate::linalg::spd_inverse(&s_bar).unwrap();
                let diff = kkt.sub(&inv).fro_norm() / inv.fro_norm();
                err_max = err_max.max(diff);
            }
            err_max
        };

        let e1 = error_for(0.2);
        let e2 = error_for(0.1);
        let e3 = error_for(0.05);
        // O(β²): each halving should reduce the error by ~4; allow slack.
        assert!(e2 < e1 / 2.5, "e(0.2)={e1}, e(0.1)={e2}");
        assert!(e3 < e2 / 2.5, "e(0.1)={e2}, e(0.05)={e3}");
    }

    /// Appendix F: INGD/SINGD are invariant to the Kronecker rescaling
    /// `U → αU, G → G/α`; IKFAC/KFAC are not.
    #[test]
    fn invariance_of_ingd_to_kronecker_rescaling() {
        let mut rng = Pcg::new(37);
        let (d_i, d_o, m) = (6, 5, 16);
        let a = rng.normal_mat(m, d_i, 1.0);
        let gm = rng.normal_mat(m, d_o, 1.0);
        let grad = rng.normal_mat(d_o, d_i, 1.0);
        let alpha = 3.0f32;

        let run = |adaptive: bool, scale_a: f32, scale_g: f32| -> Mat {
            let hp = Hyper { lr: 0.1, t_update: 1, momentum: 0.0, weight_decay: 0.0, ..Hyper::default() };
            let mut opt = if adaptive {
                Singd::new(&[(d_o, d_i)], &hp, Structure::Dense)
            } else {
                Singd::ikfac(&[(d_o, d_i)], &hp, Structure::Dense)
            };
            let mut params = [Mat::zeros(d_o, d_i)];
            // U = (scale_a A)ᵀ(scale_a A)/m = scale_a² U₀ → pick scale_a = √α.
            let stats = KronStats { a: a.scale(scale_a), g: gm.scale(scale_g) };
            for t in 0..5 {
                opt.step(t, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
            }
            params[0].clone()
        };

        let sqrt_a = alpha.sqrt();
        // INGD: rescaled run must match the unscaled one.
        let w_base = run(true, 1.0, 1.0);
        let w_scaled = run(true, sqrt_a, 1.0 / sqrt_a);
        assert_mat_close(&w_base, &w_scaled, 5e-3, "INGD invariance");

        // IKFAC: rescaling must change the trajectory.
        let w_base_ik = run(false, 1.0, 1.0);
        let w_scaled_ik = run(false, sqrt_a, 1.0 / sqrt_a);
        let diff = w_base_ik.sub(&w_scaled_ik).fro_norm() / (1e-9 + w_base_ik.fro_norm());
        assert!(diff > 1e-2, "IKFAC unexpectedly invariant (diff {diff})");
    }

    #[test]
    fn factor_sharded_rank_allocates_only_owned_layers() {
        use crate::dist::{DistCtx, DistStrategy};
        let hp = Hyper::default();
        let shapes: Vec<(usize, usize)> = vec![(32, 32); 8];
        let full = Singd::new(&shapes, &hp, Structure::Dense);
        let ctx = DistCtx::new(DistStrategy::FactorSharded, 0, 4);
        let rank0 = Singd::with_dist(&shapes, &hp, Structure::Dense, ctx);
        assert_eq!(rank0.owned_layers(), Some(vec![0, 4]));
        // 2 of 8 equal layers → exactly 1/4 of the replicated state.
        assert_eq!(rank0.state_bytes() * 4, full.state_bytes());
        assert_eq!(rank0.state_vectors().len(), 2 * 5);
    }

    #[test]
    fn state_vectors_roundtrip_bitwise() {
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut rng = Pcg::new(51);
        let shapes = [(6usize, 5usize), (4, 6)];
        let mut opt = Singd::new(&shapes, &hp, Structure::BlockDiag { k: 2 });
        let mut params = vec![rng.normal_mat(6, 5, 0.2), rng.normal_mat(4, 6, 0.2)];
        for t in 0..3 {
            let grads = vec![rng.normal_mat(6, 5, 0.1), rng.normal_mat(4, 6, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(16, 5, 1.0), g: rng.normal_mat(16, 6, 1.0) },
                KronStats { a: rng.normal_mat(16, 6, 1.0), g: rng.normal_mat(16, 4, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
        }
        let snap = opt.state_vectors();
        let mut fresh = Singd::new(&shapes, &hp, Structure::BlockDiag { k: 2 });
        fresh.load_state_vectors(&snap).unwrap();
        assert_eq!(fresh.state_vectors(), snap);
        // Mismatched blob lengths are rejected without touching state.
        let mut bad = snap.clone();
        bad[0].pop();
        assert!(fresh.load_state_vectors(&bad).is_err());
        assert!(fresh.load_state_vectors(&snap[1..]).is_err());
        assert_eq!(fresh.state_vectors(), snap);
    }

    /// An explicit uniform schedule must be bitwise identical to the
    /// default `t_update` gate (the "never called" baseline).
    #[test]
    fn uniform_precond_schedule_matches_default_bitwise() {
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 3, ..Hyper::default() };
        let run = |schedule: Option<Vec<usize>>| -> Vec<Vec<f32>> {
            let mut rng = Pcg::new(62);
            let mut opt = Singd::new(&shapes, &hp, Structure::Dense);
            if let Some(s) = schedule {
                opt.set_precond_schedule(s);
            }
            let mut params = vec![Mat::zeros(5, 4), Mat::zeros(3, 5)];
            for t in 0..7 {
                let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
                let stats = vec![
                    KronStats { a: rng.normal_mat(8, 4, 1.0), g: rng.normal_mat(8, 5, 1.0) },
                    KronStats { a: rng.normal_mat(8, 5, 1.0), g: rng.normal_mat(8, 3, 1.0) },
                ];
                opt.step(t, &mut params, &grads, &stats);
            }
            params.iter().map(|p| p.data().to_vec()).collect()
        };
        assert_eq!(run(None), run(Some(vec![3, 3])), "uniform schedule must be a no-op");
        // A short schedule falls back to t_update for the uncovered tail.
        assert_eq!(run(None), run(Some(vec![3])), "tail layers default to t_update");
        assert_ne!(run(None), run(Some(vec![1, 1])), "a different cadence must matter");
    }

    /// Staggered periods: each layer's factors refresh exactly on its own
    /// multiples and stay bit-frozen in between.
    #[test]
    fn staggered_precond_schedule_refreshes_per_layer() {
        let shapes = [(5usize, 4usize), (3, 5)];
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut rng = Pcg::new(63);
        let mut opt = Singd::new(&shapes, &hp, Structure::Dense);
        opt.set_precond_schedule(vec![1, 3]);
        let mut params = vec![Mat::zeros(5, 4), Mat::zeros(3, 5)];
        let mut prev_k0 = opt.k_factor(0).coeffs();
        let mut prev_k1 = opt.k_factor(1).coeffs();
        for t in 0..7 {
            let grads = vec![rng.normal_mat(5, 4, 0.1), rng.normal_mat(3, 5, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(8, 4, 1.0), g: rng.normal_mat(8, 5, 1.0) },
                KronStats { a: rng.normal_mat(8, 5, 1.0), g: rng.normal_mat(8, 3, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
            let k0 = opt.k_factor(0).coeffs();
            let k1 = opt.k_factor(1).coeffs();
            assert_ne!(k0, prev_k0, "t={t}: layer 0 (period 1) must refresh every step");
            if t % 3 == 0 {
                assert_ne!(k1, prev_k1, "t={t}: layer 1 (period 3) must refresh");
            } else {
                assert_eq!(k1, prev_k1, "t={t}: layer 1 (period 3) must stay bit-frozen");
            }
            prev_k0 = k0;
            prev_k1 = k1;
        }
    }

    #[test]
    fn ikfac_without_momentum_uses_less_state_than_ingd() {
        let hp = Hyper::default();
        let shapes = [(64usize, 64usize)];
        let ingd = Singd::new(&shapes, &hp, Structure::Dense).state_bytes();
        let ikfac = Singd::ikfac(&shapes, &hp, Structure::Dense).state_bytes();
        assert!(ikfac < ingd, "ikfac {ikfac} < ingd {ingd}");
    }

    #[test]
    fn structured_and_dense_agree_when_projection_is_lossless() {
        // If curvature is diagonal (uncorrelated features) and K starts at
        // I, SINGD-Diag and SINGD-Dense produce identical K diagonals.
        let mut rng = Pcg::new(41);
        let (d_i, d_o, m) = (6, 4, 512);
        // Diagonal-dominant statistics: independent features.
        let mut a = Mat::zeros(m, d_i);
        for r in 0..m {
            for c in 0..d_i {
                *a.at_mut(r, c) = if r % d_i == c { rng.normal() * (1.0 + c as f32) } else { 0.0 };
            }
        }
        let mut gm = Mat::zeros(m, d_o);
        for r in 0..m {
            for c in 0..d_o {
                *gm.at_mut(r, c) = if r % d_o == c { rng.normal() } else { 0.0 };
            }
        }
        let grad = rng.normal_mat(d_o, d_i, 1.0);
        let hp = Hyper { lr: 0.1, t_update: 1, momentum: 0.0, weight_decay: 0.0, ..Hyper::default() };
        let run = |structure: Structure| -> Mat {
            let mut opt = Singd::new(&[(d_o, d_i)], &hp, structure);
            let mut params = [Mat::zeros(d_o, d_i)];
            let stats = KronStats { a: a.clone(), g: gm.clone() };
            for t in 0..4 {
                opt.step(t, &mut params, std::slice::from_ref(&grad), std::slice::from_ref(&stats));
            }
            opt.k_factor(0).to_dense()
        };
        let k_dense = run(Structure::Dense);
        let k_diag = run(Structure::Diagonal);
        for i in 0..d_i {
            let (x, y) = (k_dense.at(i, i), k_diag.at(i, i));
            assert!((x - y).abs() < 5e-3 * (1.0 + x.abs()), "diag {i}: {x} vs {y}");
        }
    }
}
