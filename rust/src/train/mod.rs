//! Training-loop driver: LR schedules, metric logging, checkpoints,
//! divergence detection, optimizer-state memory accounting, and the
//! deterministic data-parallel driver ([`train_dist`]).

mod checkpoint;

pub use checkpoint::{
    load_checkpoint, load_checkpoint_auto, load_checkpoint_driver, load_checkpoint_full,
    load_checkpoint_meta, save_checkpoint, save_checkpoint_driver, save_checkpoint_full,
    save_checkpoint_meta, DriverState, OptMeta,
};

use crate::data::Dataset;
use crate::dist::{
    self, bucket, collectives, shard, transport, Algo, Communicator, DistCtx, DistStrategy,
    SocketComm, Transport,
};
use crate::model::{BackwardResult, Batch, Model};
use crate::numerics::{Dtype, GradScaler, Policy};
use crate::obs::metrics as obs_metrics;
use crate::obs::trace::{self, ArgVal};
use crate::optim::{Hyper, KronStats, Method, Optimizer};
use crate::proptest::Pcg;
use crate::tensor::Mat;
use std::io::Write;
use std::sync::{Arc, Mutex};

/// Learning-rate schedule (paper §4: cosine for transformers, step decay
/// for VGG/ConvMixer, constant for the GNN).
#[derive(Clone, Debug)]
pub enum Schedule {
    Constant,
    /// Cosine decay to zero over `total` steps.
    Cosine { total: usize },
    /// Multiply by `gamma` every `every` steps.
    Step { every: usize, gamma: f32 },
}

impl Schedule {
    pub fn factor(&self, t: usize) -> f32 {
        match self {
            Schedule::Constant => 1.0,
            Schedule::Cosine { total } => {
                let p = (t as f32 / (*total).max(1) as f32).min(1.0);
                0.5 * (1.0 + (std::f32::consts::PI * p).cos())
            }
            Schedule::Step { every, gamma } => {
                // Guard `every == 0` (a config typo) as "decay every step"
                // rather than dividing by zero.
                let every = (*every).max(1);
                gamma.powi((t / every) as i32)
            }
        }
    }

    pub fn parse(s: &str) -> Option<Schedule> {
        let low = s.to_ascii_lowercase();
        if low == "constant" {
            return Some(Schedule::Constant);
        }
        if let Some(rest) = low.strip_prefix("cosine:") {
            return rest.parse().ok().map(|total| Schedule::Cosine { total });
        }
        if let Some(rest) = low.strip_prefix("step:") {
            let (every, gamma) = rest.split_once(',')?;
            return Some(Schedule::Step { every: every.parse().ok()?, gamma: gamma.parse().ok()? });
        }
        None
    }
}

/// One row of the training log.
#[derive(Clone, Debug)]
pub struct LogRow {
    pub step: usize,
    pub epoch: usize,
    pub train_loss: f32,
    pub test_loss: f32,
    pub test_err: f32,
    pub lr: f32,
    pub diverged: bool,
}

/// Result of a full training run.
pub struct RunResult {
    pub rows: Vec<LogRow>,
    pub final_test_err: f32,
    pub best_test_err: f32,
    pub diverged: bool,
    pub optimizer_bytes: usize,
    pub wall_secs: f64,
    pub steps_run: usize,
    /// Optimizer stability telemetry (e.g. KFAC Cholesky-failure count).
    pub telemetry: String,
    /// FNV-1a digest over the run's loss-curve bits and final parameter
    /// bits ([`run_digest`]) — the cross-process handle the determinism
    /// suites compare, since formatted CSV output rounds away the bits.
    pub param_digest: u64,
}

/// FNV-1a 64 digest ([`checkpoint::checksum`], the checkpoint framing
/// hash) over each log row's loss bits and every parameter's f32 bits.
/// Two runs digest equal iff their curves and final parameters are
/// bitwise identical — the transport/rank-invariance contracts in
/// `rust/tests/dist_proc.rs` compare these across OS processes.
pub fn run_digest(rows: &[LogRow], params: &[Mat]) -> u64 {
    let bytes = 12 * rows.len() + params.iter().map(|p| 4 * p.len()).sum::<usize>();
    let mut body = Vec::with_capacity(bytes);
    for r in rows {
        for bits in [r.train_loss.to_bits(), r.test_loss.to_bits(), r.test_err.to_bits()] {
            body.extend_from_slice(&bits.to_le_bytes());
        }
    }
    for p in params {
        for &v in p.data() {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    checkpoint::checksum(&body)
}

impl RunResult {
    /// Serialize the loss/error curves as CSV.
    pub fn to_csv(&self, label: &str) -> String {
        let mut out = String::from("label,step,epoch,train_loss,test_loss,test_err,lr,diverged\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{label},{},{},{:.6},{:.6},{:.6},{:.6},{}\n",
                r.step, r.epoch, r.train_loss, r.test_loss, r.test_err, r.lr, r.diverged as u8
            ));
        }
        out
    }
}

/// Configuration of a single training run.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub method: Method,
    pub hyper: Hyper,
    pub schedule: Schedule,
    pub epochs: usize,
    pub batch_size: usize,
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` steps (0 = per epoch).
    pub eval_every: usize,
    /// Stop early when loss goes non-finite.
    pub stop_on_divergence: bool,
    /// Resume from this checkpoint (`[train] resume` / `--resume`): the
    /// run restores parameters, canonical optimizer state and the driver
    /// bookkeeping, then replays the skipped batches' RNG draws so the
    /// continued trajectory is bitwise identical to an uninterrupted run.
    pub resume: Option<std::path::PathBuf>,
    /// Write checkpoints to this path (`[train] ckpt` / `--ckpt`);
    /// atomic tmp+fsync+rename with a `.prev` last-good sibling.
    pub ckpt: Option<std::path::PathBuf>,
    /// Checkpoint cadence in optimizer steps (0 = never). Elastic runs
    /// require `>= 1`: the cadence bounds the work lost to a failure.
    pub ckpt_every: usize,
    /// Gradient accumulation (`[train] accum_steps` / `--accum-steps`):
    /// split every batch — each rank's shard, under the distributed
    /// driver — into this many contiguous micro-batches and fold them
    /// back into the full-batch backward result before the optimizer
    /// step ([`crate::optim::accum`]). `0`/`1` disable. Statistics fold
    /// by exact row concatenation and the f64 loss partials by the fixed
    /// halving tree, so with power-of-two micro heights `k` micro-batches
    /// of `B/k` reproduce one batch of `B` bitwise — gradients, stats,
    /// loss, and the [`GradScaler`] overflow verdict (skip lockstep).
    pub accum_steps: usize,
    /// Arm a trace session and export per-rank span artifacts
    /// (`r<N>.jsonl` + `r<N>.trace.json`) into this directory
    /// (`[obs] trace_dir` / `--trace-dir` / `SINGD_TRACE`). Tracing is
    /// observation-only: digests are bitwise identical with it on or
    /// off (the non-interference contract, ARCHITECTURE.md
    /// §Observability).
    pub trace_dir: Option<std::path::PathBuf>,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            method: Method::Sgd,
            hyper: Hyper::default(),
            schedule: Schedule::Constant,
            epochs: 5,
            batch_size: 32,
            seed: 0,
            eval_every: 0,
            stop_on_divergence: true,
            resume: None,
            ckpt: None,
            ckpt_every: 0,
            accum_steps: 1,
            trace_dir: None,
        }
    }
}

/// The epoch/eval/divergence bookkeeping shared by the serial and
/// distributed drivers: batch sampling, LR scheduling, loss accounting,
/// eval cadence, checkpoint cadence, resume replay, and the divergence
/// stop. `step_fn` performs one optimization step on a batch and returns
/// `(batch loss, diverged)`. Keeping this loop single-sourced is part of
/// the rank-invariance contract — both drivers see identical batches,
/// schedules and rows.
///
/// # Resume replay
///
/// With `resume = Some(d)` the caller has already restored parameters
/// and optimizer state as of step `d.step`; the loop re-draws the same
/// seeded batch stream but skips `step_fn` for steps `< d.step`, then
/// restores the partial-epoch f64 loss accumulators at the boundary.
/// Rows/best resume from `d`, so the continued run's log — including
/// the re-emitted row of a partially-complete epoch — is bitwise
/// identical to an uninterrupted run's (`rust/tests/dist.rs` asserts
/// the digests match).
///
/// # Checkpoint hook
///
/// When `cfg.ckpt_every > 0`, `ckpt_hook` fires after each
/// `ckpt_every`-th step (after that step's eval row, before any
/// epoch-end row) with the model and the [`DriverState`] a resumed run
/// needs to reproduce the remainder bit for bit.
fn train_loop<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
    resume: Option<DriverState>,
    mut ckpt_hook: Option<&mut dyn FnMut(&M, &DriverState)>,
    mut step_fn: impl FnMut(&mut M, &Batch, usize, f32) -> (f32, bool),
) -> (Vec<LogRow>, f32, usize, bool, f64) {
    let mut rng = Pcg::with_stream(cfg.seed, 0x7261696e);
    let base_lr = cfg.hyper.lr;
    let start = std::time::Instant::now();

    let resume_step = resume.as_ref().map(|d| d.step).unwrap_or(0);
    let (mut rows, mut best, resume_el, resume_nb) = match resume {
        Some(d) => (d.rows, d.best, d.epoch_loss, d.nb),
        None => (Vec::new(), f32::INFINITY, 0.0, 0),
    };
    let mut step = 0usize;
    let mut diverged = false;
    'outer: for epoch in 0..cfg.epochs {
        let batches = dataset.epoch_batches(&mut rng, cfg.batch_size);
        let mut epoch_loss = 0.0f64;
        let mut nb = 0usize;
        for b in &batches {
            if step < resume_step {
                // Replay-skip: consume the batch (the RNG stream already
                // advanced identically) without stepping; at the resume
                // boundary restore the checkpointed partial-epoch
                // accumulators so the interrupted epoch's row re-emits
                // from the exact f64 partials.
                step += 1;
                if step == resume_step {
                    epoch_loss = resume_el;
                    nb = resume_nb;
                }
                continue;
            }
            let lr = base_lr * cfg.schedule.factor(step);
            let mut sp = trace::span("step", "step");
            if sp.is_recording() {
                sp.arg("step", ArgVal::U(step as u64));
            }
            let (loss, div) = step_fn(model, b, step, lr);
            drop(sp);
            epoch_loss += loss as f64;
            nb += 1;
            step += 1;
            // Live telemetry for the STATUS endpoint: always-on relaxed
            // stores, read only by the control plane — never by math.
            obs_metrics::set_step(step as u64);
            obs_metrics::set_loss(loss as f64);
            diverged = diverged || !loss.is_finite() || div;
            if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
                let row = eval_row(model, dataset, step, epoch, (epoch_loss / nb as f64) as f32, base_lr * cfg.schedule.factor(step), diverged);
                best = best.min(row.test_err);
                rows.push(row);
            }
            if cfg.ckpt_every > 0 && step % cfg.ckpt_every == 0 {
                if let Some(hook) = ckpt_hook.as_mut() {
                    hook(
                        model,
                        // The scaler snapshot (if any) is filled in by the
                        // driver-owned hook — the loop doesn't know about
                        // loss scaling.
                        &DriverState { step, best, epoch_loss, nb, rows: rows.clone(), scaler: None },
                    );
                }
            }
            if diverged && cfg.stop_on_divergence {
                rows.push(LogRow {
                    step,
                    epoch,
                    train_loss: f32::NAN,
                    test_loss: f32::NAN,
                    test_err: 1.0,
                    lr: base_lr,
                    diverged: true,
                });
                break 'outer;
            }
        }
        if cfg.eval_every == 0 && step >= resume_step {
            let row = eval_row(model, dataset, step, epoch, (epoch_loss / nb.max(1) as f64) as f32, base_lr * cfg.schedule.factor(step), diverged);
            best = best.min(row.test_err);
            rows.push(row);
        }
    }
    (rows, best, step, diverged, start.elapsed().as_secs_f64())
}

/// Restore checkpointed parameters into `model`, erroring loudly on a
/// layer-count or shape mismatch (a resume against the wrong config).
fn restore_params<M: Model + ?Sized>(model: &mut M, params: Vec<Mat>) {
    let cur = model.params();
    assert_eq!(
        params.len(),
        cur.len(),
        "resume: checkpoint has {} layers but the model has {} — \
         the checkpoint was written by a different model config",
        params.len(),
        cur.len()
    );
    for (l, (p, c)) in params.iter().zip(cur.iter()).enumerate() {
        assert_eq!(
            (p.rows(), p.cols()),
            (c.rows(), c.cols()),
            "resume: layer {l} is {}x{} in the checkpoint but {}x{} in the model — \
             the checkpoint was written by a different model config",
            p.rows(),
            p.cols(),
            c.rows(),
            c.cols()
        );
    }
    *model.params_mut() = params;
}

/// Load `cfg.resume` (if set) into the model, apply the canonical
/// optimizer-state snapshot through `load_state`, and return the
/// [`DriverState`] for [`train_loop`]'s replay. `load_state` receives
/// the canonical (serial-layout) blobs and is responsible for any
/// world-specific dealing; it is not called when the checkpoint carries
/// no optimizer state (a fresh step-0 checkpoint).
fn apply_resume<M: Model + ?Sized>(
    model: &mut M,
    cfg: &TrainCfg,
    mut load_state: impl FnMut(&[Vec<f32>]),
) -> Option<DriverState> {
    let path = cfg.resume.as_ref()?;
    let (params, state, driver, meta) = checkpoint::load_checkpoint_auto(path)
        .unwrap_or_else(|e| panic!("resume: {e}"));
    check_resume_meta("resume", &cfg.method, &state, meta.as_ref());
    restore_params(model, params);
    if !state.is_empty() {
        load_state(&state);
    }
    Some(driver.unwrap_or_default())
}

/// Build the dynamic loss scaler for runs whose optimizer state is
/// stored in true half precision ([`Dtype::Fp16`], whose 5-bit exponent
/// under- and overflows on real gradients; bf16 shares f32's exponent
/// range and needs none). Restores a checkpointed schedule snapshot so a
/// resumed run continues the identical scale trajectory — the fp16
/// resume-determinism contract.
fn build_scaler(hp: &Hyper, resume: Option<&DriverState>) -> Option<Mutex<GradScaler>> {
    if hp.policy.store != Dtype::Fp16 {
        return None;
    }
    let mut s = GradScaler::default();
    if let Some((scale, clean, skipped)) = resume.and_then(|d| d.scaler) {
        s.restore(scale, clean, skipped);
    }
    Some(Mutex::new(s))
}

/// Snapshot the active scaler's schedule for a checkpoint (`None` when
/// the run trains without loss scaling).
fn scaler_snapshot(scaler: &Option<Mutex<GradScaler>>) -> Option<(f32, usize, usize)> {
    scaler.as_ref().map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).state())
}

/// Optimizer identity section for a v5 checkpoint: the configured
/// method name plus the per-layer blob stride of the live optimizer.
fn opt_meta(method: &Method, blobs_per_layer: usize) -> OptMeta {
    OptMeta { method: method.name(), blobs_per_layer }
}

/// Reject resuming optimizer state written by a different method: the
/// blob layout is method-specific, so a silent misparse would train on
/// garbage state. Pre-v5 checkpoints carry no metadata and skip the
/// check (their layout mismatches still fail in `load_state_vectors`).
fn check_resume_meta(who: &str, method: &Method, state: &[Vec<f32>], meta: Option<&OptMeta>) {
    let Some(m) = meta else { return };
    if !state.is_empty() && m.method != method.name() {
        panic!(
            "{who}: checkpoint optimizer state was written by method '{}' \
             ({} blobs/layer) but this run uses '{}'",
            m.method,
            m.blobs_per_layer,
            method.name()
        );
    }
}

/// Reassemble the canonical (serial-layout) optimizer-state snapshot on
/// every rank of a socket world: under factor sharding each rank
/// contributes its owned blobs as `1×len` matrices over the exchange and
/// the canonical deal is merged back; replicated state is already
/// canonical on every rank.
fn gather_canonical_state(
    comm: &dyn Communicator,
    opt: &Mutex<Box<dyn Optimizer>>,
    n_layers: usize,
) -> Vec<Vec<f32>> {
    let (mine, owned, bpl) = {
        let o = opt.lock().unwrap_or_else(|e| e.into_inner());
        (o.state_vectors(), o.owned_layers().is_some(), o.state_blobs_per_layer())
    };
    if !owned || bpl == 0 || comm.world_size() == 1 {
        return mine;
    }
    let mats: Vec<Mat> =
        mine.iter().map(|b| Mat::from_vec(1, b.len(), b.clone())).collect();
    let parts = comm.exchange_mats(mats);
    let per_rank: Vec<Vec<Vec<f32>>> = parts
        .iter()
        .map(|ms| ms.iter().map(|m| m.data().to_vec()).collect())
        .collect();
    shard::merge_state(&per_rank, bpl, n_layers)
}

/// Arm the process-wide trace session from `cfg.trace_dir`. Returns
/// whether this call owns the session and must call [`trace::finish`]
/// when the run completes — nested drivers (e.g. [`train_dist`]
/// delegating to [`train_image_model`] for a one-rank world) arm once
/// at the outermost layer and the inner call is a no-op. With
/// `trace_dir` unset this is a single branch and tracing stays
/// entirely off the hot path.
fn arm_trace(cfg: &TrainCfg, default_rank: usize) -> bool {
    match &cfg.trace_dir {
        Some(dir) => trace::begin(Some(dir), default_rank),
        None => false,
    }
}

/// Train `model` on `dataset`; returns loss/error curves + telemetry.
pub fn train_image_model<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
) -> RunResult {
    let owns_trace = arm_trace(cfg, 0);
    let opt: Mutex<Box<dyn Optimizer>> =
        Mutex::new(cfg.method.build(&model.shapes(), &cfg.hyper));
    let resume = apply_resume(model, cfg, |state| {
        opt.lock()
            .unwrap_or_else(|e| e.into_inner())
            .load_state_vectors(state)
            .unwrap_or_else(|e| panic!("resume: optimizer state mismatch: {e}"));
    });
    let scaler = build_scaler(&cfg.hyper, resume.as_ref());
    let mut hook_impl;
    let hook: Option<&mut dyn FnMut(&M, &DriverState)> = match &cfg.ckpt {
        Some(path) if cfg.ckpt_every > 0 => {
            let path = path.clone();
            let scaler_ref = &scaler;
            let opt_ref = &opt;
            let method = &cfg.method;
            hook_impl = move |m: &M, d: &DriverState| {
                let (state, bpl) = {
                    let o = opt_ref.lock().unwrap_or_else(|e| e.into_inner());
                    (o.state_vectors(), o.state_blobs_per_layer())
                };
                let d = DriverState { scaler: scaler_snapshot(scaler_ref), ..d.clone() };
                let meta = opt_meta(method, bpl);
                checkpoint::save_checkpoint_meta(&path, m.params(), &state, Some(&d), Some(&meta))
                    .unwrap_or_else(|e| panic!("checkpoint save {}: {e}", path.display()));
            };
            Some(&mut hook_impl)
        }
        _ => None,
    };
    let (rows, best, steps_run, diverged, wall_secs) =
        train_loop(model, dataset, cfg, resume, hook, |model, b, step, lr| {
            // `accum_steps <= 1` is a straight delegation to the plain
            // single-pass backward — zero accumulation overhead.
            let res = crate::optim::accum::forward_backward_accum(&*model, b, cfg.accum_steps);
            let mut opt = opt.lock().unwrap_or_else(|e| e.into_inner());
            opt.set_lr(lr);
            if let Some(sc) = &scaler {
                // Fp16 storage: scale the gradients, pass them through
                // the half-precision round they are stored at (tiny
                // entries survive, overflowed ones go infinite), then
                // unscale for the step — or skip it entirely at a
                // backed-off scale when any entry overflowed.
                let mut sc = sc.lock().unwrap_or_else(|e| e.into_inner());
                let mut grads: Vec<Mat> = res
                    .grads
                    .iter()
                    .map(|g| {
                        let mut sg = sc.scale_mat(g);
                        cfg.hyper.policy.quantize_mat(&mut sg);
                        sg
                    })
                    .collect();
                if !sc.unscale_and_update(&mut grads) {
                    return (res.loss, opt.diverged());
                }
                opt.step(step, model.params_mut(), &grads, &res.stats);
            } else {
                opt.step(step, model.params_mut(), &res.grads, &res.stats);
            }
            (res.loss, opt.diverged())
        });
    if owns_trace {
        let _ = trace::finish();
    }
    let final_err = rows.last().map(|r| r.test_err).unwrap_or(1.0);
    RunResult {
        final_test_err: final_err,
        best_test_err: best.min(final_err),
        diverged,
        optimizer_bytes: {
            let opt2 = cfg.method.build(&model.shapes(), &cfg.hyper);
            opt2.state_bytes()
        },
        wall_secs,
        steps_run,
        telemetry: opt.lock().unwrap_or_else(|e| e.into_inner()).telemetry(),
        param_digest: run_digest(&rows, model.params()),
        rows,
    }
}

/// Distributed topology of a training run (the `[dist]` config section /
/// `--ranks` + `--transport` + `--algo` + `--overlap` + `--stream` +
/// `--wire-dtype` CLI knobs / `SINGD_RANKS` + `SINGD_TRANSPORT` +
/// `SINGD_ALGO` + `SINGD_OVERLAP` + `SINGD_STREAM` + `SINGD_WIRE_DTYPE`
/// env defaults).
#[derive(Clone, Debug)]
pub struct DistCfg {
    /// World size; `1` falls back to the serial driver.
    pub ranks: usize,
    /// Optimizer state layout across ranks.
    pub strategy: DistStrategy,
    /// Communicator backend: in-process threads or multi-process sockets.
    pub transport: Transport,
    /// Collective algorithm: rank-0 fan-in star or bandwidth-optimal
    /// ring (the default; bitwise identical either way).
    pub algo: Algo,
    /// Comm/compute overlap: nonblocking stats gather + bucketed update
    /// all-reduce in `rank_step` and the chunk-pipelined ring (the
    /// default; bitwise identical either way — contract 4 of
    /// [`crate::dist`]).
    pub overlap: bool,
    /// Wire dtype for the heavy collectives (`[dist] wire_dtype` /
    /// `--wire-dtype` / `SINGD_WIRE_DTYPE`): statistics all-gathers and
    /// update all-reduces move 2-byte payloads when set to a half
    /// format. Runs stay bitwise deterministic across transport × algo ×
    /// overlap at any fixed wire dtype, but a half wire forfeits the
    /// serial-equality contract (see [`crate::dist`] §Wire dtype).
    pub wire_dtype: Dtype,
    /// Layer-streamed backward↔comm fusion (`[dist] stream` / `--stream`
    /// / `SINGD_STREAM`, default on): `rank_step` issues layer `l`'s
    /// statistics gather from *inside* the backward pass, the moment that
    /// layer's hook event fires — so the transfer overlaps the backward
    /// of layers `l−1…0` still computing, not just the reconstruction
    /// loop. Requires `overlap` (it rides the same FIFO engine) and is a
    /// no-op without it. The hook is a pure observation seam and the
    /// engine executes ops in the SPMD-consistent issue order, so runs
    /// are bitwise identical with streaming on or off (determinism
    /// contract 8, ARCHITECTURE.md; `stream_` cells in
    /// `rust/tests/dist.rs`). The knob is purely about wall-clock
    /// (`benches/dist_scaling.rs` measures the hidden-comm fraction).
    pub stream: bool,
    /// Elastic fault tolerance (`[dist] elastic` / `--elastic`): survive
    /// worker death and admit joiners by re-rendezvousing into a new
    /// membership generation and resharding optimizer state from the
    /// last checkpoint (socket transport only; requires `ckpt` +
    /// `ckpt_every >= 1`). See [`train_dist`] §Elastic fault tolerance.
    pub elastic: bool,
}

impl Default for DistCfg {
    fn default() -> Self {
        DistCfg {
            ranks: dist::default_ranks(),
            strategy: DistStrategy::Replicated,
            transport: dist::default_transport(),
            algo: dist::default_algo(),
            overlap: dist::default_overlap(),
            wire_dtype: dist::default_wire_dtype(),
            stream: dist::default_stream(),
            elastic: false,
        }
    }
}

impl DistCfg {
    /// An explicit in-process topology (the common test fixture); the
    /// collective algorithm, overlap mode and streaming mode follow the
    /// `SINGD_ALGO` / `SINGD_OVERLAP` / `SINGD_STREAM` env defaults so
    /// the ci.sh matrix drives the whole dist suite through both
    /// schedules, both overlap modes and both streaming modes.
    pub fn local(ranks: usize, strategy: DistStrategy) -> DistCfg {
        DistCfg {
            ranks,
            strategy,
            transport: Transport::Local,
            algo: dist::default_algo(),
            overlap: dist::default_overlap(),
            wire_dtype: dist::default_wire_dtype(),
            stream: dist::default_stream(),
            elastic: false,
        }
    }
}

/// Deterministic data-parallel training driver.
///
/// Each global batch is split into `ranks` contiguous row shards; every
/// rank runs forward/backward on its shard only, then the ranks exchange
/// *exact* data — per-row Kronecker statistics (all-gather by row
/// concatenation, no floating-point reduction) and f64 loss partials
/// (fixed halving tree) — so every rank reconstructs the identical
/// full-batch gradient `∇W = (Gᵀ A)/m` with the standard kernels. Under
/// [`DistStrategy::Replicated`] every rank then steps an identical
/// optimizer replica; under [`DistStrategy::FactorSharded`] each rank
/// steps only its owned layers (per-rank factor memory ≈ 1/ranks) and
/// the preconditioned parameter updates are completed with a zero-padded
/// bucketed all-reduce (exact: one nonzero contributor per element).
///
/// # Determinism contract
///
/// `ranks = 1` delegates to [`train_image_model`] and is bitwise
/// identical to it by construction. `ranks = R` is bitwise identical to
/// `ranks = 1` — same per-step losses, same final parameters — when:
///
/// - `R` is a power of two and divides the batch size (the per-shard
///   `1/m` loss scaling then differs from the full-batch one by an exact
///   exponent shift that commutes with the row-local backward pass), and
/// - every layer's per-batch statistics row count is a power of two
///   (gradient reconstruction commutes with the `1/m` scale), which
///   holds for power-of-two batch sizes and weight-sharing expansion
///   factors — all the shapes the experiment configs use.
///
/// The batch size must be at least `ranks` (asserted; the CLI rejects
/// worse combinations up front). Rank counts that do not divide the
/// batch shard it with the balanced padding rule of
/// [`shard::row_shard_range`]: such runs are still deterministic at a
/// fixed world size and track the serial trajectory to rounding, but
/// odd shard row counts make the per-shard `1/m` scaling inexact, so
/// they forfeit the bitwise guarantee. `rust/tests/dist.rs` asserts
/// the contract end to end.
///
/// # Transports
///
/// [`Transport::Local`] runs the ranks as threads of this process over
/// the shared-memory rendezvous. [`Transport::Socket`] runs them as
/// separate OS processes over [`SocketComm`]: if the
/// `SINGD_RANK`/`SINGD_WORLD`/`SINGD_RENDEZVOUS` env contract is set,
/// this process joins the world as that rank; otherwise it re-execs
/// itself as ranks `1..R` ([`transport::launch_workers`]) and becomes
/// rank 0. The collectives route over either transport unchanged and
/// exchange byte-exact payloads, so `--transport socket` is bitwise
/// identical to `--transport local` and to serial `ranks = 1`
/// (`rust/tests/dist_proc.rs` asserts this across real processes).
///
/// # Collective algorithm
///
/// [`DistCfg::algo`] picks where the bytes flow: [`Algo::Ring`] (the
/// default) runs the statistics gather and update all-reduce as
/// bandwidth-balanced ring schedules over the point-to-point seam
/// (`~2·(R−1)/R·N` bytes per rank); [`Algo::Star`] funnels them through
/// the rank-0 exchange. The ring reduces every chunk with the same
/// halving tree the star uses, so `--algo ring` and `--algo star` are
/// bitwise identical — the knob is purely about bandwidth
/// (`benches/dist_scaling.rs` measures both).
///
/// # Comm/compute overlap
///
/// [`DistCfg::overlap`] (default on; `SINGD_OVERLAP` / `[dist] overlap`
/// / `--overlap`) hides collective latency behind compute: `rank_step`
/// issues the loss exchange and every layer's statistics gather as
/// nonblocking ops ([`Communicator::istart_all_gather`]) and waits each
/// one only at its true data dependency (layer `l`'s gradient
/// reconstruction overlaps layer `l+1`'s transfer), the factor-sharded
/// update exchange issues every bucket before draining
/// ([`crate::dist::bucket::all_reduce_sum_bucketed`]), and ring
/// all-reduces run chunk-pipelined
/// ([`crate::dist::collectives::all_reduce_sum_pipelined`]). By the
/// overlap-invariance contract (contract 4 of [`crate::dist`]) the run
/// is bitwise identical with the knob on or off — `rust/tests/dist.rs`
/// and `rust/tests/dist_proc.rs` compare the digests across
/// `SINGD_OVERLAP ∈ {0,1}` × transport × algo; the knob is purely about
/// wall-clock (`benches/dist_scaling.rs` measures the difference).
///
/// # Elastic fault tolerance
///
/// [`DistCfg::elastic`] (socket transport + Unix-domain rendezvous only;
/// requires [`TrainCfg::ckpt`] and `ckpt_every >= 1`) makes the world
/// survive worker death and admit late joiners: rank 0 runs the control
/// plane of PROTOCOL.md §Elastic rendezvous v2, a failure poisons the
/// collectives on every survivor (the panic-on-EOF contract), survivors
/// re-rendezvous into generation `g+1` with contiguous re-assigned
/// ranks, reload the last checkpoint, re-deal the canonical optimizer
/// state to the new world size, and resume via [`train_loop`]'s replay.
/// Because any fixed world size is deterministic, the continued run is
/// bitwise identical to an uninterrupted run of the *new* world size
/// resumed from the same checkpoint — `rust/tests/dist_proc.rs` kills a
/// real worker mid-step and asserts the digest equality.
pub fn train_dist<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
    dcfg: &DistCfg,
) -> RunResult {
    if dcfg.ranks <= 1 {
        return train_image_model(model, dataset, cfg);
    }
    let world = dcfg.ranks;
    assert!(
        cfg.batch_size >= world,
        "train_dist: batch_size {} must be >= ranks {world}",
        cfg.batch_size
    );
    // Arm the per-process trace session at the outermost driver layer.
    // Under the socket transport each OS process hosts one rank, so a
    // worker's session defaults to its own rank; the launcher (and the
    // whole local-transport world) defaults to 0 and per-thread
    // [`trace::rank_scope`] guards in `rank_step` attribute the rest.
    let default_rank = transport::worker_env().map(|we| we.rank).unwrap_or(0);
    let owns_trace = arm_trace(cfg, default_rank);
    let out = match dcfg.transport {
        Transport::Local => {
            assert!(
                !dcfg.elastic,
                "train_dist: elastic mode requires the socket transport \
                 (--transport socket); the in-process local transport has \
                 no processes to lose"
            );
            train_dist_local(model, dataset, cfg, dcfg)
        }
        Transport::Socket => {
            if dcfg.elastic {
                train_dist_elastic(model, dataset, cfg, dcfg)
            } else {
                train_dist_socket(model, dataset, cfg, dcfg)
            }
        }
    };
    if owns_trace {
        let _ = trace::finish();
    }
    out
}

/// In-process data-parallel driver: SPMD rank closures over the
/// shared-memory rendezvous of [`dist::run_ranks`].
fn train_dist_local<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
    dcfg: &DistCfg,
) -> RunResult {
    let world = dcfg.ranks;
    let shapes = model.shapes();
    // One optimizer replica per rank, alive across the whole run.
    let opts: Vec<Mutex<Box<dyn Optimizer>>> = (0..world)
        .map(|r| {
            let ctx = DistCtx::new(dcfg.strategy, r, world);
            Mutex::new(cfg.method.build_dist(&shapes, &cfg.hyper, ctx))
        })
        .collect();
    let n_layers = shapes.len();
    let resume = apply_resume(model, cfg, |state| {
        // Each in-process rank restores its slice of the canonical
        // snapshot: factor-sharded optimizers get their owned layers'
        // blobs re-dealt for this world size, replicated ones (and
        // optimizers without layer ownership) load the full canonical.
        for (r, o) in opts.iter().enumerate() {
            let mut o = o.lock().unwrap_or_else(|e| e.into_inner());
            let bpl = o.state_blobs_per_layer();
            let dealt;
            let blobs: &[Vec<f32>] = if o.owned_layers().is_some() && bpl > 0 {
                dealt = shard::deal_state(state, bpl, world, r);
                &dealt
            } else {
                state
            };
            o.load_state_vectors(blobs)
                .unwrap_or_else(|e| panic!("resume: rank {r} optimizer state mismatch: {e}"));
        }
    });
    let scaler = build_scaler(&cfg.hyper, resume.as_ref());
    let mut hook_impl;
    let hook: Option<&mut dyn FnMut(&M, &DriverState)> = match &cfg.ckpt {
        Some(path) if cfg.ckpt_every > 0 => {
            let path = path.clone();
            let opts_ref = &opts;
            let scaler_ref = &scaler;
            hook_impl = move |m: &M, d: &DriverState| {
                // Merge the per-rank shards back into the canonical
                // serial layout so the checkpoint is world-size-free.
                let (owned, bpl) = {
                    let o = opts_ref[0].lock().unwrap_or_else(|e| e.into_inner());
                    (o.owned_layers().is_some(), o.state_blobs_per_layer())
                };
                let canonical = if owned && bpl > 0 {
                    let per_rank: Vec<Vec<Vec<f32>>> = opts_ref
                        .iter()
                        .map(|o| o.lock().unwrap_or_else(|e| e.into_inner()).state_vectors())
                        .collect();
                    shard::merge_state(&per_rank, bpl, n_layers)
                } else {
                    opts_ref[0].lock().unwrap_or_else(|e| e.into_inner()).state_vectors()
                };
                let d = DriverState { scaler: scaler_snapshot(scaler_ref), ..d.clone() };
                let meta = opt_meta(&cfg.method, bpl);
                checkpoint::save_checkpoint_meta(
                    &path,
                    m.params(),
                    &canonical,
                    Some(&d),
                    Some(&meta),
                )
                .unwrap_or_else(|e| panic!("checkpoint save {}: {e}", path.display()));
            };
            Some(&mut hook_impl)
        }
        _ => None,
    };
    // One persistent world for the whole run: the communicators (p2p
    // sequence counters, lazily spawned progress engines) live across
    // steps, exactly like a SocketComm world — with overlap on, the
    // per-rank engine thread is spawned once per run, not once per step.
    let local_world = dist::LocalWorld::new_wire(world, dcfg.algo, dcfg.overlap, dcfg.wire_dtype);
    let (rows, best, steps_run, diverged, wall_secs) =
        train_loop(model, dataset, cfg, resume, hook, |model, b, step, lr| {
            let model_ref = &*model;
            // One driver-owned scaler: every rank steps at the same
            // scale, and the schedule advances once per step from the
            // OR-reduced overflow flag.
            let amp = scaler.as_ref().map(|s| {
                (s.lock().unwrap_or_else(|e| e.into_inner()).scale(), cfg.hyper.policy)
            });
            let outs = local_world.run(|comm| {
                rank_step(
                    comm,
                    model_ref,
                    b,
                    &opts[comm.rank()],
                    step,
                    lr,
                    amp,
                    dcfg.stream,
                    cfg.accum_steps,
                )
            });
            let first = outs.into_iter().next().unwrap();
            if let Some(s) = &scaler {
                s.lock().unwrap_or_else(|e| e.into_inner()).update(first.overflow);
            }
            // All ranks hold bitwise-identical post-step parameters
            // (redundantly for replicated, via the exact zero-padded
            // all-reduce for factor-sharded); rank 0's become canonical.
            // The diverged flag is already OR-reduced across ranks
            // inside rank_step, so every rank agrees on it.
            *model.params_mut() = first.params;
            (first.loss, first.diverged)
        });
    let final_err = rows.last().map(|r| r.test_err).unwrap_or(1.0);
    // Telemetry lives on whichever rank owns the layer that produced it,
    // so aggregate across ranks: identical reports (replicated) collapse
    // to one, distinct reports (factor-sharded) are labelled per rank.
    let telemetry = {
        let per_rank: Vec<String> = opts
            .iter()
            .map(|o| o.lock().unwrap_or_else(|e| e.into_inner()).telemetry())
            .collect();
        let nonempty: Vec<(usize, String)> =
            per_rank.into_iter().enumerate().filter(|(_, t)| !t.is_empty()).collect();
        if nonempty.windows(2).all(|w| w[0].1 == w[1].1) {
            nonempty.first().map(|(_, t)| t.clone()).unwrap_or_default()
        } else {
            let parts: Vec<String> =
                nonempty.iter().map(|(r, t)| format!("rank{r}:{t}")).collect();
            parts.join(" ")
        }
    };
    RunResult {
        final_test_err: final_err,
        best_test_err: best.min(final_err),
        diverged,
        // Per-rank state bytes (rank 0): under factor sharding this is
        // the ~1/ranks footprint the dist_scaling bench reports.
        optimizer_bytes: {
            let ctx = DistCtx::new(dcfg.strategy, 0, world);
            cfg.method.build_dist(&shapes, &cfg.hyper, ctx).state_bytes()
        },
        wall_secs,
        steps_run,
        telemetry,
        param_digest: run_digest(&rows, model.params()),
        rows,
    }
}

/// Multi-process data-parallel driver: this process is exactly one rank
/// of a [`SocketComm`] world (see [`train_dist`] §Transports). Every
/// rank runs the same `train_loop` on the same seeded dataset/model and
/// converges on identical parameters; rank 0 (the launcher) additionally
/// reaps its workers and owns the returned [`RunResult`].
fn train_dist_socket<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
    dcfg: &DistCfg,
) -> RunResult {
    let world = dcfg.ranks;
    let (rank, rendezvous, run_id, mut workers) = match transport::worker_env() {
        Some(we) => {
            assert_eq!(
                we.world, world,
                "train_dist[socket]: SINGD_WORLD {} != configured ranks {world}",
                we.world
            );
            (we.rank, we.rendezvous, we.run_id, Vec::new())
        }
        None => {
            let rendezvous = transport::fresh_rendezvous();
            let run_id = transport::fresh_run_id();
            let workers = transport::launch_workers(
                world,
                &rendezvous,
                run_id,
                dcfg.algo,
                dcfg.overlap,
                dcfg.stream,
                dcfg.wire_dtype,
            )
            .unwrap_or_else(|e| panic!("train_dist[socket]: launching workers: {e}"));
            (0, rendezvous, run_id, workers)
        }
    };
    let comm = SocketComm::connect_opts_wire(
        rank, world, &rendezvous, run_id, dcfg.algo, dcfg.overlap, dcfg.wire_dtype,
    )
    .unwrap_or_else(|e| panic!("train_dist[socket]: rank {rank} rendezvous: {e}"));
    let shapes = model.shapes();
    let ctx = DistCtx::new(dcfg.strategy, rank, world);
    let opt: Mutex<Box<dyn Optimizer>> =
        Mutex::new(cfg.method.build_dist(&shapes, &cfg.hyper, ctx));
    // Every rank reads the checkpoint itself (shared filesystem) and
    // restores its own slice of the canonical optimizer state.
    let resume = apply_resume(model, cfg, |state| {
        let mut o = opt.lock().unwrap_or_else(|e| e.into_inner());
        let bpl = o.state_blobs_per_layer();
        let dealt;
        let blobs: &[Vec<f32>] = if o.owned_layers().is_some() && bpl > 0 {
            dealt = shard::deal_state(state, bpl, world, rank);
            &dealt
        } else {
            state
        };
        o.load_state_vectors(blobs)
            .unwrap_or_else(|e| panic!("resume: rank {rank} optimizer state mismatch: {e}"));
    });
    // Every process holds a scaler replica; the OR-reduced overflow flag
    // drives all of them through the identical schedule, so rank 0's
    // checkpointed snapshot speaks for the world.
    let scaler = build_scaler(&cfg.hyper, resume.as_ref());
    let n_layers = shapes.len();
    let mut hook_impl;
    let hook: Option<&mut dyn FnMut(&M, &DriverState)> = match &cfg.ckpt {
        Some(path) if cfg.ckpt_every > 0 => {
            let path = path.clone();
            let comm_ref = &comm;
            let opt_ref = &opt;
            let scaler_ref = &scaler;
            hook_impl = move |m: &M, d: &DriverState| {
                // SPMD: every rank joins the state gather (the exchange
                // is a collective), but only rank 0 touches the disk.
                let canonical = gather_canonical_state(comm_ref, opt_ref, n_layers);
                if comm_ref.rank() == 0 {
                    let d = DriverState { scaler: scaler_snapshot(scaler_ref), ..d.clone() };
                    let bpl = opt_ref
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .state_blobs_per_layer();
                    let meta = opt_meta(&cfg.method, bpl);
                    checkpoint::save_checkpoint_meta(
                        &path,
                        m.params(),
                        &canonical,
                        Some(&d),
                        Some(&meta),
                    )
                    .unwrap_or_else(|e| panic!("checkpoint save {}: {e}", path.display()));
                }
            };
            Some(&mut hook_impl)
        }
        _ => None,
    };
    let (rows, best, steps_run, diverged, wall_secs) =
        train_loop(model, dataset, cfg, resume, hook, |model, b, step, lr| {
            let amp = scaler.as_ref().map(|s| {
                (s.lock().unwrap_or_else(|e| e.into_inner()).scale(), cfg.hyper.policy)
            });
            let out =
                rank_step(&comm, &*model, b, &opt, step, lr, amp, dcfg.stream, cfg.accum_steps);
            if let Some(s) = &scaler {
                s.lock().unwrap_or_else(|e| e.into_inner()).update(out.overflow);
            }
            *model.params_mut() = out.params;
            (out.loss, out.diverged)
        });
    // Clean shutdown (goodbye frames) before reaping the workers.
    drop(comm);
    if let Err(e) = transport::wait_workers(&mut workers) {
        panic!("train_dist[socket]: {e}");
    }
    let final_err = rows.last().map(|r| r.test_err).unwrap_or(1.0);
    RunResult {
        final_test_err: final_err,
        best_test_err: best.min(final_err),
        diverged,
        optimizer_bytes: {
            let ctx0 = DistCtx::new(dcfg.strategy, 0, world);
            cfg.method.build_dist(&shapes, &cfg.hyper, ctx0).state_bytes()
        },
        wall_secs,
        steps_run,
        // This rank's telemetry only; under factor sharding each process
        // sees just its owned layers (workers report via their exit
        // status, not strings).
        telemetry: opt.lock().unwrap_or_else(|e| e.into_inner()).telemetry(),
        param_digest: run_digest(&rows, model.params()),
        rows,
    }
}

/// Elastic multi-process driver (see [`train_dist`] §Elastic fault
/// tolerance): each membership generation runs the normal SPMD step
/// loop under `catch_unwind`; a poisoned collective (peer death) or a
/// coordinator join request unwinds every survivor into the recovery
/// path — sever the links, re-rendezvous into generation `g+1`, reload
/// the last checkpoint, re-deal the canonical optimizer state to the
/// new world size, and resume from the checkpointed step.
fn train_dist_elastic<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
    dcfg: &DistCfg,
) -> RunResult {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let init_world = dcfg.ranks;
    let ckpt_path = cfg.ckpt.clone().unwrap_or_else(|| {
        panic!(
            "train_dist[elastic]: elastic mode requires a checkpoint path \
             ([train] ckpt / --ckpt): recovery reloads the last checkpoint"
        )
    });
    assert!(
        cfg.ckpt_every >= 1,
        "train_dist[elastic]: elastic mode requires ckpt_every >= 1 \
         (the checkpoint cadence bounds the work lost to a failure)"
    );
    let (orig_rank, rendezvous, run_id, mut workers) = match transport::worker_env() {
        Some(we) => {
            assert_eq!(
                we.world, init_world,
                "train_dist[elastic]: SINGD_WORLD {} != configured ranks {init_world}",
                we.world
            );
            (we.rank, we.rendezvous, we.run_id, Vec::new())
        }
        None => {
            let rendezvous = transport::fresh_rendezvous();
            let run_id = transport::fresh_run_id();
            let workers = transport::launch_workers(
                init_world,
                &rendezvous,
                run_id,
                dcfg.algo,
                dcfg.overlap,
                dcfg.stream,
                dcfg.wire_dtype,
            )
            .unwrap_or_else(|e| panic!("train_dist[elastic]: launching workers: {e}"));
            (0, rendezvous, run_id, workers)
        }
    };
    // Fault-injection knob for the chaos suite: SINGD_CHAOS_ABORT =
    // "<rank>:<step>" hard-aborts this process (no goodbye, no unwind —
    // a simulated crash) just before the 1-based step <step> of
    // generation 0 on original rank <rank>.
    let chaos: Option<(usize, usize)> = std::env::var("SINGD_CHAOS_ABORT")
        .ok()
        .filter(|v| !v.is_empty())
        .map(|v| {
            let parsed = v.split_once(':').and_then(|(r, s)| {
                Some((r.trim().parse().ok()?, s.trim().parse().ok()?))
            });
            parsed.unwrap_or_else(|| {
                panic!(
                    "train_dist[elastic]: SINGD_CHAOS_ABORT={v:?} is malformed \
                     (expected \"<rank>:<step>\", e.g. \"2:3\")"
                )
            })
        });
    let coord = if orig_rank == 0 {
        Some(
            transport::Coordinator::new(&rendezvous, run_id, init_world)
                .unwrap_or_else(|e| panic!("train_dist[elastic]: coordinator: {e}")),
        )
    } else {
        None
    };
    let shapes = model.shapes();
    let n_layers = shapes.len();

    // Establish the recovery point: an explicit resume checkpoint, or a
    // fresh step-0 checkpoint rank 0 writes up front so even a failure
    // before the first cadence point has something to reload. An empty
    // state section means "fresh optimizer" (nothing to re-deal).
    let mut canonical_state: Vec<Vec<f32>> = Vec::new();
    let mut resume: DriverState = match &cfg.resume {
        Some(path) => {
            let (params, state, driver, meta) = checkpoint::load_checkpoint_auto(path)
                .unwrap_or_else(|e| panic!("train_dist[elastic]: resume: {e}"));
            check_resume_meta("train_dist[elastic]: resume", &cfg.method, &state, meta.as_ref());
            restore_params(model, params);
            canonical_state = state;
            driver.unwrap_or_default()
        }
        None => {
            if orig_rank == 0 {
                // Fresh step-0 checkpoint: no optimizer state yet, so
                // the meta stride is 0 but the method name already
                // guards later resumes against a method switch.
                checkpoint::save_checkpoint_meta(
                    &ckpt_path,
                    model.params(),
                    &[],
                    Some(&DriverState::default()),
                    Some(&opt_meta(&cfg.method, 0)),
                )
                .unwrap_or_else(|e| panic!("train_dist[elastic]: initial checkpoint: {e}"));
            }
            DriverState::default()
        }
    };

    let mut rank = orig_rank;
    let mut world = init_world;
    let mut gen: u64 = 0;
    let mut gens_used = 1usize;
    loop {
        // Live telemetry: the STATUS endpoint reports the membership
        // generation this process is currently training in.
        obs_metrics::set_gen(gen);
        // The communicator lives OUTSIDE catch_unwind so the recovery
        // path below can sever and drop it after a caught panic.
        let comm = SocketComm::connect_elastic(
            rank, world, &rendezvous, run_id, gen, dcfg.algo, dcfg.overlap, dcfg.wire_dtype,
        )
        .unwrap_or_else(|e| {
            panic!("train_dist[elastic]: rank {rank} gen {gen} rendezvous: {e}")
        });
        let ctx = DistCtx::new(dcfg.strategy, rank, world);
        let opt: Mutex<Box<dyn Optimizer>> =
            Mutex::new(cfg.method.build_dist(&shapes, &cfg.hyper, ctx));
        // The scaler restarts each generation from the checkpointed
        // schedule (`resume.scaler`), exactly like optimizer state —
        // recovery rewinds both to the same step.
        let scaler = build_scaler(&cfg.hyper, Some(&resume));
        if !canonical_state.is_empty() {
            let mut o = opt.lock().unwrap_or_else(|e| e.into_inner());
            let bpl = o.state_blobs_per_layer();
            let dealt;
            let blobs: &[Vec<f32>] = if o.owned_layers().is_some() && bpl > 0 {
                dealt = shard::deal_state(&canonical_state, bpl, world, rank);
                &dealt
            } else {
                &canonical_state
            };
            o.load_state_vectors(blobs).unwrap_or_else(|e| {
                panic!("train_dist[elastic]: rank {rank} optimizer state mismatch: {e}")
            });
        }
        let gen_resume = resume.clone();
        let out = catch_unwind(AssertUnwindSafe(|| {
            let mut hook_impl = |m: &M, d: &DriverState| {
                let canonical = gather_canonical_state(&comm, &opt, n_layers);
                if comm.rank() == 0 {
                    let d = DriverState { scaler: scaler_snapshot(&scaler), ..d.clone() };
                    let bpl =
                        opt.lock().unwrap_or_else(|e| e.into_inner()).state_blobs_per_layer();
                    let meta = opt_meta(&cfg.method, bpl);
                    checkpoint::save_checkpoint_meta(
                        &ckpt_path,
                        m.params(),
                        &canonical,
                        Some(&d),
                        Some(&meta),
                    )
                    .unwrap_or_else(|e| {
                        panic!("train_dist[elastic]: checkpoint save {}: {e}", ckpt_path.display())
                    });
                }
            };
            train_loop(
                model,
                dataset,
                cfg,
                Some(gen_resume),
                Some(&mut hook_impl),
                |model, b, step, lr| {
                    // Fold the coordinator's join-pending flag into a
                    // per-step scalar exchange so every rank routes
                    // through the same recovery path a failure takes.
                    // The exchanged flags never touch the training math,
                    // so the digest is unaffected.
                    let jp = if coord.as_ref().is_some_and(|c| c.join_pending()) { 1.0 } else { 0.0 };
                    let flags = comm.exchange_f64(vec![jp]);
                    if flags.iter().any(|p| p[0] != 0.0) {
                        panic!("train_dist[elastic]: regroup requested (worker joining)");
                    }
                    if gen == 0 {
                        if let Some((cr, cs)) = chaos {
                            if cr == rank && step + 1 == cs {
                                // Simulated crash: peers see a raw EOF.
                                std::process::abort();
                            }
                        }
                    }
                    let amp = scaler.as_ref().map(|s| {
                        (s.lock().unwrap_or_else(|e| e.into_inner()).scale(), cfg.hyper.policy)
                    });
                    let out = rank_step(
                        &comm,
                        &*model,
                        b,
                        &opt,
                        step,
                        lr,
                        amp,
                        dcfg.stream,
                        cfg.accum_steps,
                    );
                    if let Some(s) = &scaler {
                        s.lock().unwrap_or_else(|e| e.into_inner()).update(out.overflow);
                    }
                    *model.params_mut() = out.params;
                    (out.loss, out.diverged)
                },
            )
        }));
        match out {
            Ok((rows, best, steps_run, diverged, wall_secs)) => {
                if let Some(c) = &coord {
                    c.finish();
                }
                // Clean shutdown (goodbye frames) before reaping.
                drop(comm);
                for f in transport::wait_workers_lenient(&mut workers) {
                    // Chaos-killed workers exit nonzero by design; the
                    // run completed, so report and move on.
                    crate::obs_warn!("train_dist[elastic]: note: {f}");
                }
                // Close the final generation's traffic epoch so its
                // per-rank byte totals survive in the metrics registry
                // (`traffic.gen<G>.r<N>`).
                let _ = crate::dist::traffic::epoch(&format!("gen{gen}"));
                let final_err = rows.last().map(|r| r.test_err).unwrap_or(1.0);
                let telemetry = {
                    let t = opt.lock().unwrap_or_else(|e| e.into_inner()).telemetry();
                    let tag = format!("elastic:gens={gens_used} world={world}");
                    if t.is_empty() { tag } else { format!("{t} {tag}") }
                };
                return RunResult {
                    final_test_err: final_err,
                    best_test_err: best.min(final_err),
                    diverged,
                    optimizer_bytes: {
                        let ctx0 = DistCtx::new(dcfg.strategy, 0, world);
                        cfg.method.build_dist(&shapes, &cfg.hyper, ctx0).state_bytes()
                    },
                    wall_secs,
                    steps_run,
                    telemetry,
                    param_digest: run_digest(&rows, model.params()),
                    rows,
                };
            }
            Err(_) => {
                // A peer died (poisoned collective) or a regroup was
                // requested: finish propagating the failure, then
                // negotiate the next membership generation.
                comm.sever();
                drop(comm);
                // The failed generation is over and nothing is in
                // flight: close its traffic epoch so per-generation
                // byte totals stay separated in the metrics registry.
                let _ = crate::dist::traffic::epoch(&format!("gen{gen}"));
                gen += 1;
                gens_used += 1;
                let m = if let Some(c) = &coord {
                    c.regroup(gen).unwrap_or_else(|e| {
                        panic!("train_dist[elastic]: regroup gen {gen}: {e}")
                    })
                } else {
                    transport::rejoin(&rendezvous, run_id, rank, gen).unwrap_or_else(|e| {
                        panic!("train_dist[elastic]: rank {rank} rejoin gen {gen}: {e}")
                    })
                };
                rank = m.rank;
                world = m.world;
                assert!(
                    cfg.batch_size >= world,
                    "train_dist[elastic]: batch_size {} must be >= regrouped world {world}",
                    cfg.batch_size
                );
                if rank == 0 {
                    // Preserve the recovery point for the determinism
                    // audit: an uninterrupted world-R' run resumed from
                    // this exact file must reproduce our digest. Copy
                    // before any gen-g checkpoint overwrites it.
                    let tag = format!("{}.resharded-g{gen}", ckpt_path.display());
                    std::fs::copy(&ckpt_path, &tag).unwrap_or_else(|e| {
                        panic!("train_dist[elastic]: snapshot {tag}: {e}")
                    });
                }
                let (params, state, driver, _meta) = checkpoint::load_checkpoint_auto(&ckpt_path)
                    .unwrap_or_else(|e| {
                        panic!("train_dist[elastic]: reload after regroup: {e}")
                    });
                restore_params(model, params);
                canonical_state = state;
                resume = driver.unwrap_or_default();
            }
        }
    }
}

/// One rank's work for one global batch: shard forward/backward, exact
/// gather, full-batch gradient reconstruction, optimizer step, and (for
/// factor sharding) the parameter-update exchange.
struct RankStepOut {
    params: Vec<Mat>,
    loss: f32,
    diverged: bool,
    /// Any rank saw a non-finite scaled gradient this step (OR-reduced;
    /// always `false` without loss scaling). The step was skipped on
    /// every rank; the driver feeds this to [`GradScaler::update`] so
    /// the replicated schedule advances identically everywhere.
    overflow: bool,
}

/// One rank's optimization step. `amp` carries the fp16 loss-scaling
/// context when active: `(current scale, storage policy)`. The scaled
/// gradients pass through the policy's half-precision round, the
/// overflow verdict is OR-reduced across ranks *before* any optimizer
/// state moves, and an overflowed step leaves parameters and state
/// untouched on every rank — the distributed split of
/// [`GradScaler::unscale_and_update`].
///
/// `stream` ([`DistCfg::stream`]) moves the per-layer statistics gather
/// *into* the backward pass: the model's layer hook
/// ([`Model::forward_backward_hooked`]) issues layer `l`'s gather as a
/// pending op under a `layer_gather_issue` span the moment that layer's
/// backward completes, so the transfer overlaps the remaining layers'
/// differentiation. Effective only with `overlap` (it rides the same
/// FIFO engine); the payload bytes and the SPMD-consistent issue order
/// are exactly the batched path's, so the step is bitwise identical
/// with streaming on or off. `accum` ([`TrainCfg::accum_steps`]) runs
/// this rank's shard as contiguous micro-batches folded through
/// [`crate::optim::accum`]; when both are active the first `k−1`
/// micro-batches accumulate locally and the *last* micro-batch streams,
/// each hook splicing its layer's fresh rows onto the buffered ones so
/// the gathers still launch from inside the backward.
fn rank_step<M: Model + ?Sized>(
    comm: &dyn Communicator,
    model: &M,
    batch: &Batch,
    opt: &Mutex<Box<dyn Optimizer>>,
    step: usize,
    lr: f32,
    amp: Option<(f32, Policy)>,
    stream: bool,
    accum: usize,
) -> RankStepOut {
    let world = comm.world_size();
    let rank = comm.rank();
    // Attribute every span/instant this thread records (and any log
    // line it emits) to this rank — under the local transport all ranks
    // share one process, so the session default rank is not enough.
    let _rank_scope = trace::rank_scope(rank);
    let overlap = comm.overlap() && world > 1;
    let m_total = batch.y.len();
    // Contiguous balanced shard (the padding rule for non-dividing
    // world sizes; equal blocks whenever world | rows).
    let block = shard::row_shard_range(m_total, world, rank);
    let shard = Batch {
        x: Mat::from_fn(block.len(), batch.x.cols(), |r, c| batch.x.at(block.start + r, c)),
        y: batch.y[block.clone()].to_vec(),
    };
    let streaming = stream && overlap;
    let k = accum.max(1);
    let n = model.shapes().len();
    let owned_mask: Option<Vec<bool>> =
        opt.lock().unwrap_or_else(|e| e.into_inner()).owned_layers().map(|owned| {
            let mut mask = vec![false; n];
            for l in owned {
                mask[l] = true;
            }
            mask
        });

    // The statistics gather arrives in one of three SPMD-equivalent
    // forms: one batched all-gather of every layer's `(A, G)` rows
    // (blocking path), one pending per-layer gather issued after the
    // backward (overlap path), or one pending per-layer gather issued
    // from *inside* the backward by the layer hook (streaming path) —
    // the same bytes in the same SPMD-consistent queue discipline every
    // way, so reconstruction below is identical bit for bit.
    #[allow(clippy::type_complexity)]
    enum Gathered {
        /// `parts[r]` holds `[a_0, g_0, a_1, g_1, …]` of rank `r`.
        Batched(Vec<Arc<Vec<Mat>>>),
        /// One pending `[a_l, g_l]` gather per layer, waited in order.
        PerLayer(Vec<Option<dist::PendingOp<Vec<Arc<Vec<Mat>>>>>>),
    }

    // Global loss: tree-combine the shard f64 partials. Contiguous equal
    // shards are complete subtrees of the full-batch halving tree, so
    // this reproduces the serial loss bit for bit.
    let (loss, mut gathered) = if streaming {
        // Streaming: each layer's gather launches from inside the
        // backward, the moment its hook event fires — reverse layer
        // order, identically on every rank — so the engine moves layer
        // l's rows while layers l−1…0 are still differentiating. The
        // loss exchange rides the same FIFO queue once the backward
        // returns. No blocking collective may run while these are in
        // flight (engine exclusivity), so the loss goes pending too.
        let fb_span = trace::span("forward_backward", "compute");
        let mut gather_ops: Vec<Option<dist::PendingOp<Vec<Arc<Vec<Mat>>>>>> =
            (0..n).map(|_| None).collect();
        let issue = |ops: &mut Vec<Option<dist::PendingOp<Vec<Arc<Vec<Mat>>>>>>,
                     layer: usize,
                     a: Mat,
                     g: Mat| {
            let mut sp = trace::span("layer_gather_issue", "comm");
            if sp.is_recording() {
                sp.arg("layer", ArgVal::U(layer as u64));
            }
            ops[layer] = Some(comm.istart_all_gather(vec![a, g]));
            drop(sp);
        };
        let (loss_sum, loss_rows) = if k > 1 {
            // Accumulating: fold the first k−1 micro-batches locally,
            // then stream the last one — each hook splices its layer's
            // fresh rows onto the buffered micro-batches, so the gather
            // payload is the full accumulated shard.
            let micros = crate::optim::accum::split_batch(&shard, k);
            let mut acc = crate::optim::BatchAccumulator::new(n);
            let (last, head) = micros.split_last().expect("shard has at least one micro-batch");
            for mb in head {
                acc.push_result(&model.forward_backward(mb));
            }
            let last_res = {
                let acc_ref = &acc;
                let ops_ref = &mut gather_ops;
                model.forward_backward_hooked(last, &mut |ev| {
                    let full = acc_ref.layer_concat(ev.layer_id, Some(ev.kron_stats));
                    issue(ops_ref, ev.layer_id, full.a, full.g);
                })
            };
            acc.push_loss(&last_res);
            let (loss_sum, loss_rows, _) = acc.loss();
            (loss_sum, loss_rows)
        } else {
            let ops_ref = &mut gather_ops;
            let res = model.forward_backward_hooked(&shard, &mut |ev| {
                issue(ops_ref, ev.layer_id, ev.kron_stats.a.clone(), ev.kron_stats.g.clone());
            });
            (res.loss_sum, res.loss_rows)
        };
        drop(fb_span);
        let loss_op = comm.istart_exchange_f64(vec![loss_sum, loss_rows as f64]);
        let scal = loss_op.wait();
        let sums: Vec<f64> = scal.iter().map(|v| v[0]).collect();
        let total_rows: f64 = scal.iter().map(|v| v[1]).sum();
        let loss = (collectives::tree_sum_f64(&sums) / total_rows.max(1.0)) as f32;
        (loss, Gathered::PerLayer(gather_ops))
    } else {
        let fb_span = trace::span("forward_backward", "compute");
        let res: BackwardResult = if k > 1 {
            // Fold the shard's micro-batches; gradients are rebuilt from
            // the *gathered* statistics below, so skip their local
            // reconstruction.
            let mut acc = crate::optim::BatchAccumulator::new(n);
            for mb in crate::optim::accum::split_batch(&shard, k) {
                acc.push_result(&model.forward_backward(&mb));
            }
            acc.finalize_stats()
        } else {
            model.forward_backward(&shard)
        };
        drop(fb_span);
        if overlap {
            // Issue the loss exchange and every layer's statistics gather
            // as pending ops up front; the engine moves layer l+1's rows
            // while this thread reconstructs layer l's gradient below —
            // waiting only at each layer's true data dependency.
            let loss_op = comm.istart_exchange_f64(vec![res.loss_sum, res.loss_rows as f64]);
            let gather_ops: Vec<_> = res
                .stats
                .iter()
                .map(|st| Some(comm.istart_all_gather(vec![st.a.clone(), st.g.clone()])))
                .collect();
            let scal = loss_op.wait();
            let sums: Vec<f64> = scal.iter().map(|v| v[0]).collect();
            let total_rows: f64 = scal.iter().map(|v| v[1]).sum();
            let loss = (collectives::tree_sum_f64(&sums) / total_rows.max(1.0)) as f32;
            (loss, Gathered::PerLayer(gather_ops))
        } else {
            let loss_span = trace::span("loss_exchange", "comm");
            let scal = comm.exchange_f64(vec![res.loss_sum, res.loss_rows as f64]);
            drop(loss_span);
            let sums: Vec<f64> = scal.iter().map(|v| v[0]).collect();
            let total_rows: f64 = scal.iter().map(|v| v[1]).sum();
            let loss = (collectives::tree_sum_f64(&sums) / total_rows.max(1.0)) as f32;
            let mut payload = Vec::with_capacity(2 * n);
            for st in &res.stats {
                payload.push(st.a.clone());
                payload.push(st.g.clone());
            }
            // Route the gather through the algo-dispatched collective:
            // under the ring it circulates over neighbor links instead of
            // fanning in at rank 0 — this is the heaviest exchange of the
            // step. Pure data movement either way, so the reconstruction
            // below is exact.
            let gather_span = trace::span("stats_gather", "comm");
            let parts = collectives::all_gather(comm, payload);
            drop(gather_span);
            (loss, Gathered::Batched(parts))
        }
    };

    // Gather full-batch statistics rows (exact concatenation in rank
    // order; `g = dy·m` is scale-free across shard sizes) and recompute
    // each layer's gradient from them with the standard kernel. Every
    // rank must *contribute* all layers' shard rows (their owners need
    // them), but only reconstructs the layers its own optimizer will
    // actually step — under factor sharding that skips (R−1)/R of the
    // gradient contractions, the heaviest op in the step.
    let mut grads = Vec::with_capacity(n);
    let mut stats = Vec::with_capacity(n);
    for l in 0..n {
        if let Some(mask) = &owned_mask {
            if !mask[l] {
                // Unowned layer: the optimizer skips it and its update
                // arrives via the exchange below — placeholders only.
                // The pending gather is still drained (this rank's rows
                // were contributed above; waiting keeps any transfer
                // failure surfacing here rather than via engine poison).
                if let Gathered::PerLayer(ops) = &mut gathered {
                    let _ = ops[l].take().expect("stats gather issued").wait();
                }
                grads.push(Mat::zeros(0, 0));
                stats.push(KronStats { a: Mat::zeros(0, 0), g: Mat::zeros(0, 0) });
                continue;
            }
        }
        let (a, g) = match &mut gathered {
            Gathered::Batched(parts) => {
                (collectives::concat_rows(parts, 2 * l), collectives::concat_rows(parts, 2 * l + 1))
            }
            Gathered::PerLayer(ops) => {
                let parts = ops[l].take().expect("stats gather issued").wait();
                (collectives::concat_rows(&parts, 0), collectives::concat_rows(&parts, 1))
            }
        };
        let mut sp = trace::span("grad_reconstruct", "compute");
        if sp.is_recording() {
            sp.arg("layer", ArgVal::U(l as u64));
        }
        let m_l = a.rows().max(1) as f32;
        grads.push(crate::tensor::matmul_at_b(&g, &a).scale(1.0 / m_l));
        stats.push(KronStats { a, g });
        drop(sp);
    }

    // Fp16 loss scaling: scale each reconstructed gradient, pass it
    // through the half-precision storage round, and OR-reduce the
    // overflow verdict BEFORE the optimizer step — every rank then
    // agrees to skip (or keep) the step, so replicated optimizer state
    // never forks. Reconstruction is bitwise identical on every rank,
    // so under replication the flags already agree; the exchange is for
    // factor sharding, where only a layer's owner reconstructs it.
    if let Some((scale, policy)) = amp {
        let mut local_overflow = false;
        for g in grads.iter_mut() {
            let mut sg = g.scale(scale);
            policy.quantize_mat(&mut sg);
            local_overflow |= sg.has_nonfinite();
            *g = sg;
        }
        let flags = comm.exchange_f64(vec![if local_overflow { 1.0 } else { 0.0 }]);
        if flags.iter().any(|p| p[0] != 0.0) {
            // Skipped step: unchanged parameters on every rank, no
            // optimizer state touched, no divergence verdict to reduce.
            return RankStepOut {
                params: model.params().clone(),
                loss,
                diverged: false,
                overflow: true,
            };
        }
        let inv = 1.0 / scale;
        for g in grads.iter_mut() {
            g.map_inplace(|x| x * inv);
        }
    }

    // Step this rank's optimizer replica on a scratch parameter copy.
    let mut params: Vec<Mat> = model.params().clone();
    let opt_span = trace::span("precond_update", "compute");
    let diverged = {
        let mut opt = opt.lock().unwrap_or_else(|e| e.into_inner());
        opt.set_lr(lr);
        opt.step(step, &mut params, &grads, &stats);
        opt.diverged()
    };
    drop(opt_span);
    if let Some(mask) = &owned_mask {
        // Factor-sharded: this rank only updated its owned layers. Zero
        // the rest and all-reduce — every element has exactly one
        // nonzero contributor (its owner), so the tree-ordered sum is
        // exact and all ranks converge on identical parameters.
        for (p, &own) in params.iter_mut().zip(mask) {
            if !own {
                p.map_inplace(|_| 0.0);
            }
        }
        let ps_span = trace::span("param_step", "comm");
        bucket::all_reduce_sum_bucketed(comm, &mut params, bucket::DEFAULT_BUCKET_ELEMS);
        drop(ps_span);
    }
    // OR-reduce the divergence flag so every rank stops at the same step
    // — under factor sharding only the owner of a sick layer sees it,
    // and a one-sided early stop would desynchronize the SPMD loop
    // (fatal for the socket transport, wasteful for the local one).
    let flags = comm.exchange_f64(vec![if diverged { 1.0 } else { 0.0 }]);
    let any_diverged = flags.iter().any(|p| p[0] != 0.0);
    RankStepOut { params, loss, diverged: any_diverged, overflow: false }
}

fn eval_row<M: Model + ?Sized>(
    model: &M,
    dataset: &Dataset,
    step: usize,
    epoch: usize,
    train_loss: f32,
    lr: f32,
    diverged: bool,
) -> LogRow {
    let tb = dataset.test_batch();
    let (test_loss, correct) = model.evaluate(&tb);
    LogRow {
        step,
        epoch,
        train_loss,
        test_loss,
        test_err: 1.0 - correct as f32 / tb.y.len() as f32,
        lr,
        diverged,
    }
}

/// Write a CSV string into `results/` (created on demand).
pub fn write_csv(name: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(contents.as_bytes())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Mlp;

    #[test]
    fn schedule_shapes() {
        let c = Schedule::Cosine { total: 100 };
        assert!((c.factor(0) - 1.0).abs() < 1e-6);
        assert!(c.factor(50) < 0.51 && c.factor(50) > 0.49);
        assert!(c.factor(100) < 1e-6);
        let s = Schedule::Step { every: 10, gamma: 0.5 };
        assert_eq!(s.factor(9), 1.0);
        assert_eq!(s.factor(10), 0.5);
        assert_eq!(s.factor(25), 0.25);
    }

    #[test]
    fn constant_schedule_is_flat() {
        for t in [0usize, 1, 10, 1_000_000] {
            assert_eq!(Schedule::Constant.factor(t), 1.0);
        }
    }

    #[test]
    fn cosine_schedule_clamps_past_total_and_guards_zero() {
        let c = Schedule::Cosine { total: 10 };
        assert!(c.factor(10_000) < 1e-6, "past-total must stay at the floor");
        // total = 0 must not divide by zero; t ≥ total ⇒ factor 0.
        let z = Schedule::Cosine { total: 0 };
        assert!(z.factor(5).is_finite());
        assert!(z.factor(5) < 1e-6);
    }

    #[test]
    fn step_schedule_boundaries_and_zero_every_guard() {
        let s = Schedule::Step { every: 10, gamma: 0.5 };
        assert_eq!(s.factor(0), 1.0);
        assert_eq!(s.factor(19), 0.5);
        assert_eq!(s.factor(20), 0.25);
        // every = 0 is guarded as "decay every step", never a panic.
        let z = Schedule::Step { every: 0, gamma: 0.5 };
        assert_eq!(z.factor(0), 1.0);
        assert_eq!(z.factor(1), 0.5);
        assert_eq!(z.factor(3), 0.125);
        assert!(z.factor(100).is_finite());
    }

    #[test]
    fn schedule_parse() {
        assert!(matches!(Schedule::parse("constant"), Some(Schedule::Constant)));
        assert!(matches!(Schedule::parse("cosine:500"), Some(Schedule::Cosine { total: 500 })));
        assert!(matches!(Schedule::parse("step:40,0.1"), Some(Schedule::Step { .. })));
        assert!(Schedule::parse("bogus").is_none());
    }

    #[test]
    fn schedule_parse_all_three_with_values() {
        assert!(matches!(Schedule::parse("CONSTANT"), Some(Schedule::Constant)));
        let Some(Schedule::Cosine { total }) = Schedule::parse("cosine:123") else {
            panic!("cosine parse")
        };
        assert_eq!(total, 123);
        let Some(Schedule::Step { every, gamma }) = Schedule::parse("step:7,0.25") else {
            panic!("step parse")
        };
        assert_eq!(every, 7);
        assert_eq!(gamma, 0.25);
        // A parsed every = 0 is accepted and guarded at use.
        let Some(z) = Schedule::parse("step:0,0.5") else { panic!("step:0 parse") };
        assert_eq!(z.factor(2), 0.25);
        // Malformed inputs.
        assert!(Schedule::parse("cosine:").is_none());
        assert!(Schedule::parse("step:10").is_none());
        assert!(Schedule::parse("step:x,0.5").is_none());
    }

    #[test]
    fn trainer_reduces_error_on_easy_data() {
        let mut rng = Pcg::new(71);
        let ds = crate::data::prototype_images(
            &mut rng,
            crate::model::cnn::ImgShape { c: 1, h: 8, w: 8 },
            4,
            120,
            40,
            2.0,
        );
        let mut mlp = Mlp::new(&mut rng, &[64, 32, 4]);
        let cfg = TrainCfg {
            method: Method::Sgd,
            hyper: Hyper { lr: 0.1, momentum: 0.9, ..Default::default() },
            epochs: 6,
            batch_size: 30,
            ..Default::default()
        };
        let res = train_image_model(&mut mlp, &ds, &cfg);
        assert!(!res.diverged);
        assert!(res.rows.len() == 6);
        let first = res.rows.first().unwrap().test_err;
        let last = res.final_test_err;
        assert!(last < first, "err {first} -> {last}");
        assert!(last < 0.4, "final err {last}");
    }

    #[test]
    fn csv_roundtrip_format() {
        let rr = RunResult {
            rows: vec![LogRow {
                step: 1,
                epoch: 0,
                train_loss: 0.5,
                test_loss: 0.6,
                test_err: 0.25,
                lr: 0.1,
                diverged: false,
            }],
            final_test_err: 0.25,
            best_test_err: 0.25,
            diverged: false,
            optimizer_bytes: 1024,
            wall_secs: 0.1,
            steps_run: 1,
            telemetry: String::new(),
            param_digest: 0,
        };
        let csv = rr.to_csv("sgd");
        assert!(csv.starts_with("label,step"));
        assert!(csv.contains("sgd,1,0,0.5"));
    }
}
