//! Training-loop driver: LR schedules, metric logging, checkpoints,
//! divergence detection, and optimizer-state memory accounting.

mod checkpoint;

pub use checkpoint::{load_checkpoint, save_checkpoint};

use crate::data::Dataset;
use crate::model::Model;
use crate::optim::{Hyper, Method};
use crate::proptest::Pcg;
use std::io::Write;

/// Learning-rate schedule (paper §4: cosine for transformers, step decay
/// for VGG/ConvMixer, constant for the GNN).
#[derive(Clone, Debug)]
pub enum Schedule {
    Constant,
    /// Cosine decay to zero over `total` steps.
    Cosine { total: usize },
    /// Multiply by `gamma` every `every` steps.
    Step { every: usize, gamma: f32 },
}

impl Schedule {
    pub fn factor(&self, t: usize) -> f32 {
        match self {
            Schedule::Constant => 1.0,
            Schedule::Cosine { total } => {
                let p = (t as f32 / (*total).max(1) as f32).min(1.0);
                0.5 * (1.0 + (std::f32::consts::PI * p).cos())
            }
            Schedule::Step { every, gamma } => gamma.powi((t / every.max(&1).clone()) as i32),
        }
    }

    pub fn parse(s: &str) -> Option<Schedule> {
        let low = s.to_ascii_lowercase();
        if low == "constant" {
            return Some(Schedule::Constant);
        }
        if let Some(rest) = low.strip_prefix("cosine:") {
            return rest.parse().ok().map(|total| Schedule::Cosine { total });
        }
        if let Some(rest) = low.strip_prefix("step:") {
            let (every, gamma) = rest.split_once(',')?;
            return Some(Schedule::Step { every: every.parse().ok()?, gamma: gamma.parse().ok()? });
        }
        None
    }
}

/// One row of the training log.
#[derive(Clone, Debug)]
pub struct LogRow {
    pub step: usize,
    pub epoch: usize,
    pub train_loss: f32,
    pub test_loss: f32,
    pub test_err: f32,
    pub lr: f32,
    pub diverged: bool,
}

/// Result of a full training run.
pub struct RunResult {
    pub rows: Vec<LogRow>,
    pub final_test_err: f32,
    pub best_test_err: f32,
    pub diverged: bool,
    pub optimizer_bytes: usize,
    pub wall_secs: f64,
    pub steps_run: usize,
    /// Optimizer stability telemetry (e.g. KFAC Cholesky-failure count).
    pub telemetry: String,
}

impl RunResult {
    /// Serialize the loss/error curves as CSV.
    pub fn to_csv(&self, label: &str) -> String {
        let mut out = String::from("label,step,epoch,train_loss,test_loss,test_err,lr,diverged\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{label},{},{},{:.6},{:.6},{:.6},{:.6},{}\n",
                r.step, r.epoch, r.train_loss, r.test_loss, r.test_err, r.lr, r.diverged as u8
            ));
        }
        out
    }
}

/// Configuration of a single training run.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub method: Method,
    pub hyper: Hyper,
    pub schedule: Schedule,
    pub epochs: usize,
    pub batch_size: usize,
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` steps (0 = per epoch).
    pub eval_every: usize,
    /// Stop early when loss goes non-finite.
    pub stop_on_divergence: bool,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            method: Method::Sgd,
            hyper: Hyper::default(),
            schedule: Schedule::Constant,
            epochs: 5,
            batch_size: 32,
            seed: 0,
            eval_every: 0,
            stop_on_divergence: true,
        }
    }
}

/// Train `model` on `dataset`; returns loss/error curves + telemetry.
pub fn train_image_model<M: Model + ?Sized>(
    model: &mut M,
    dataset: &Dataset,
    cfg: &TrainCfg,
) -> RunResult {
    let mut rng = Pcg::with_stream(cfg.seed, 0x7261696e);
    let mut opt = cfg.method.build(&model.shapes(), &cfg.hyper);
    let base_lr = cfg.hyper.lr;
    let start = std::time::Instant::now();

    let mut rows = Vec::new();
    let mut best = f32::INFINITY;
    let mut step = 0usize;
    let mut diverged = false;
    'outer: for epoch in 0..cfg.epochs {
        let batches = dataset.epoch_batches(&mut rng, cfg.batch_size);
        let mut epoch_loss = 0.0f64;
        let mut nb = 0usize;
        for b in &batches {
            let res = model.forward_backward(b);
            epoch_loss += res.loss as f64;
            nb += 1;
            opt.set_lr(base_lr * cfg.schedule.factor(step));
            opt.step(step, model.params_mut(), &res.grads, &res.stats);
            step += 1;
            diverged = diverged || !res.loss.is_finite() || opt.diverged();
            if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
                let row = eval_row(model, dataset, step, epoch, (epoch_loss / nb as f64) as f32, base_lr * cfg.schedule.factor(step), diverged);
                best = best.min(row.test_err);
                rows.push(row);
            }
            if diverged && cfg.stop_on_divergence {
                rows.push(LogRow {
                    step,
                    epoch,
                    train_loss: f32::NAN,
                    test_loss: f32::NAN,
                    test_err: 1.0,
                    lr: base_lr,
                    diverged: true,
                });
                break 'outer;
            }
        }
        if cfg.eval_every == 0 {
            let row = eval_row(model, dataset, step, epoch, (epoch_loss / nb.max(1) as f64) as f32, base_lr * cfg.schedule.factor(step), diverged);
            best = best.min(row.test_err);
            rows.push(row);
        }
    }
    let final_err = rows.last().map(|r| r.test_err).unwrap_or(1.0);
    let telemetry = opt.telemetry();
    RunResult {
        final_test_err: final_err,
        best_test_err: best.min(final_err),
        diverged,
        optimizer_bytes: {
            let opt2 = cfg.method.build(&model.shapes(), &cfg.hyper);
            opt2.state_bytes()
        },
        wall_secs: start.elapsed().as_secs_f64(),
        steps_run: step,
        telemetry,
        rows,
    }
}

fn eval_row<M: Model + ?Sized>(
    model: &M,
    dataset: &Dataset,
    step: usize,
    epoch: usize,
    train_loss: f32,
    lr: f32,
    diverged: bool,
) -> LogRow {
    let tb = dataset.test_batch();
    let (test_loss, correct) = model.evaluate(&tb);
    LogRow {
        step,
        epoch,
        train_loss,
        test_loss,
        test_err: 1.0 - correct as f32 / tb.y.len() as f32,
        lr,
        diverged,
    }
}

/// Write a CSV string into `results/` (created on demand).
pub fn write_csv(name: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(contents.as_bytes())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Mlp;

    #[test]
    fn schedule_shapes() {
        let c = Schedule::Cosine { total: 100 };
        assert!((c.factor(0) - 1.0).abs() < 1e-6);
        assert!(c.factor(50) < 0.51 && c.factor(50) > 0.49);
        assert!(c.factor(100) < 1e-6);
        let s = Schedule::Step { every: 10, gamma: 0.5 };
        assert_eq!(s.factor(9), 1.0);
        assert_eq!(s.factor(10), 0.5);
        assert_eq!(s.factor(25), 0.25);
    }

    #[test]
    fn schedule_parse() {
        assert!(matches!(Schedule::parse("constant"), Some(Schedule::Constant)));
        assert!(matches!(Schedule::parse("cosine:500"), Some(Schedule::Cosine { total: 500 })));
        assert!(matches!(Schedule::parse("step:40,0.1"), Some(Schedule::Step { .. })));
        assert!(Schedule::parse("bogus").is_none());
    }

    #[test]
    fn trainer_reduces_error_on_easy_data() {
        let mut rng = Pcg::new(71);
        let ds = crate::data::prototype_images(
            &mut rng,
            crate::model::cnn::ImgShape { c: 1, h: 8, w: 8 },
            4,
            120,
            40,
            2.0,
        );
        let mut mlp = Mlp::new(&mut rng, &[64, 32, 4]);
        let cfg = TrainCfg {
            method: Method::Sgd,
            hyper: Hyper { lr: 0.1, momentum: 0.9, ..Default::default() },
            epochs: 6,
            batch_size: 30,
            ..Default::default()
        };
        let res = train_image_model(&mut mlp, &ds, &cfg);
        assert!(!res.diverged);
        assert!(res.rows.len() == 6);
        let first = res.rows.first().unwrap().test_err;
        let last = res.final_test_err;
        assert!(last < first, "err {first} -> {last}");
        assert!(last < 0.4, "final err {last}");
    }

    #[test]
    fn csv_roundtrip_format() {
        let rr = RunResult {
            rows: vec![LogRow {
                step: 1,
                epoch: 0,
                train_loss: 0.5,
                test_loss: 0.6,
                test_err: 0.25,
                lr: 0.1,
                diverged: false,
            }],
            final_test_err: 0.25,
            best_test_err: 0.25,
            diverged: false,
            optimizer_bytes: 1024,
            wall_secs: 0.1,
            steps_run: 1,
            telemetry: String::new(),
        };
        let csv = rr.to_csv("sgd");
        assert!(csv.starts_with("label,step"));
        assert!(csv.contains("sgd,1,0,0.5"));
    }
}
