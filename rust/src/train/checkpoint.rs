//! Minimal binary checkpoint format for model parameters.
//!
//! Layout (little-endian):
//! `magic "SNGD" | u32 version | u32 n_layers | per layer: u32 rows, u32
//! cols, rows·cols f32 | u64 fletcher-style checksum`.

use crate::tensor::Mat;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SNGD";
const VERSION: u32 = 1;

fn checksum(data: &[u8]) -> u64 {
    // FNV-1a 64.
    let mut h = 0xcbf29ce484222325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save parameter matrices to `path`.
pub fn save_checkpoint(path: &Path, params: &[Mat]) -> std::io::Result<()> {
    let mut body = Vec::new();
    body.extend_from_slice(MAGIC);
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for p in params {
        body.extend_from_slice(&(p.rows() as u32).to_le_bytes());
        body.extend_from_slice(&(p.cols() as u32).to_le_bytes());
        for &v in p.data() {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = checksum(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::File::create(path)?.write_all(&body)
}

/// Load parameter matrices from `path` (validates magic + checksum).
pub fn load_checkpoint(path: &Path) -> std::io::Result<Vec<Mat>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    if buf.len() < 20 {
        return Err(err("truncated checkpoint"));
    }
    let (body, sum_bytes) = buf.split_at(buf.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    if checksum(body) != stored {
        return Err(err("checksum mismatch"));
    }
    if &body[0..4] != MAGIC {
        return Err(err("bad magic"));
    }
    let ver = u32::from_le_bytes(body[4..8].try_into().unwrap());
    if ver != VERSION {
        return Err(err("unsupported version"));
    }
    let n = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
    let mut off = 12usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if off + 8 > body.len() {
            return Err(err("truncated layer header"));
        }
        let rows = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
        let cols = u32::from_le_bytes(body[off + 4..off + 8].try_into().unwrap()) as usize;
        off += 8;
        let need = rows * cols * 4;
        if off + need > body.len() {
            return Err(err("truncated layer data"));
        }
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows * cols {
            data.push(f32::from_le_bytes(body[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
        }
        off += need;
        out.push(Mat::from_vec(rows, cols, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Pcg;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg::new(81);
        let params = vec![rng.normal_mat(3, 5, 1.0), rng.normal_mat(7, 2, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt.bin");
        save_checkpoint(&path, &params).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Pcg::new(82);
        let params = vec![rng.normal_mat(4, 4, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_bad.bin");
        save_checkpoint(&path, &params).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
