//! Minimal binary checkpoint format for model parameters, optimizer
//! state and (v3) training-driver state.
//!
//! Layout (little-endian):
//!
//! - v1: `magic "SNGD" | u32 version=1 | u32 n_layers | per layer: u32
//!   rows, u32 cols, rows·cols f32 | u64 FNV-1a checksum`.
//! - v2: the v1 parameter section, followed by `u32 n_blobs |
//!   per blob: u32 len, len f32` — the optimizer's
//!   [`crate::optim::Optimizer::state_vectors`] snapshot (momenta,
//!   Kronecker/structured factors in coefficient order) — before the
//!   checksum. `n_blobs = 0` is a pure-parameter checkpoint.
//! - v3: the v2 sections, followed by `u8 flag`; when the flag
//!   is 1, a [`DriverState`] section: `u64 step | f32 best | f64
//!   epoch_loss | u64 nb | u32 n_rows | per row: u64 step, u64 epoch,
//!   f32 train_loss, f32 test_loss, f32 test_err, f32 lr, u8 diverged`.
//!   The driver section lets a resumed run replay its pre-checkpoint log
//!   rows bitwise (the [`super::run_digest`] hashes every row), carry
//!   the best-so-far error, and restore the partial-epoch f64 loss
//!   accumulators so an epoch interrupted mid-way re-emits the identical
//!   epoch-average row.
//! - v4: the v3 driver section additionally ends with `u8 has_scaler`;
//!   when 1, a [`crate::numerics::GradScaler`] schedule snapshot
//!   follows: `f32 scale | u64 clean_steps | u64 skipped`. Without it a
//!   resumed fp16 run would restart the loss scale at its default and
//!   break bitwise resume determinism.
//! - v5 (current): the v4 sections, followed by `u8 has_meta`; when 1,
//!   an [`OptMeta`] section: `u32 name_len | name_len utf-8 bytes |
//!   u32 blobs_per_layer` — the optimizer method name and its
//!   [`crate::optim::Optimizer::state_blobs_per_layer`] stride. The
//!   optimizer-zoo resume path uses it to reject resuming a checkpoint
//!   into a different method (whose blobs would silently misparse)
//!   before any blob is interpreted.
//!
//! Readers accept all five versions (v1 loads with empty optimizer
//! state; v1/v2 load with no driver state; v1-v3 load with no scaler
//! state; v1-v4 load with no optimizer metadata); the writer always
//! emits v5. The checksum covers everything before it, so truncation
//! and bit corruption are both rejected.
//!
//! Writes are atomic and keep one generation of history: the body is
//! written to `<path>.tmp` and fsynced, any existing `<path>` is renamed
//! to `<path>.prev` (the last-good copy), and the tmp file is renamed
//! over `<path>`. A crash mid-write can therefore corrupt at most the
//! tmp file; [`load_checkpoint_auto`] falls back to `<path>.prev` when
//! the primary fails validation.

use super::LogRow;
use crate::tensor::Mat;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"SNGD";
const VERSION: u32 = 5;

/// FNV-1a 64 over a byte image — shared by the checkpoint framing and
/// the run digest of [`super::run_digest`].
pub(super) fn checksum(data: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Training-driver progress stored alongside parameters and optimizer
/// state (checkpoint v3): everything [`super::train_loop`] needs to
/// resume mid-run and reproduce the uninterrupted run's digest bitwise.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DriverState {
    /// Global step count at checkpoint time (batches consumed).
    pub step: usize,
    /// Best test error seen so far.
    pub best: f32,
    /// Partial-epoch f64 training-loss accumulator.
    pub epoch_loss: f64,
    /// Batches accumulated into `epoch_loss` this epoch.
    pub nb: usize,
    /// Every log row emitted before the checkpoint (replayed on resume
    /// so [`super::run_digest`] matches the uninterrupted run).
    pub rows: Vec<LogRow>,
    /// Loss-scale schedule snapshot of the active
    /// [`crate::numerics::GradScaler`] (v4): `(scale, clean_steps,
    /// skipped)`. `None` for runs without fp16 storage (and for any
    /// pre-v4 checkpoint).
    pub scaler: Option<(f32, usize, usize)>,
}

/// Optimizer identity stored in the checkpoint (v5): which method wrote
/// the state blobs and at what per-layer stride. Lets the resume path
/// fail loudly on a method mismatch instead of misparsing blobs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OptMeta {
    /// [`crate::optim::Method::name`] of the optimizer that produced
    /// the state blobs (e.g. `"rkfac:4"`, `"mac"`, `"singd:diag"`).
    pub method: String,
    /// [`crate::optim::Optimizer::state_blobs_per_layer`] of that
    /// optimizer; 0 for stateless methods.
    pub blobs_per_layer: usize,
}

/// `<path>.suffix` as a sibling file (`ckpt.bin` → `ckpt.bin.tmp`).
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(suffix);
    PathBuf::from(name)
}

/// Save parameter matrices to `path` (no optimizer state).
pub fn save_checkpoint(path: &Path, params: &[Mat]) -> std::io::Result<()> {
    save_checkpoint_full(path, params, &[])
}

/// Save parameters plus an optimizer-state snapshot
/// ([`crate::optim::Optimizer::state_vectors`]) to `path`.
pub fn save_checkpoint_full(
    path: &Path,
    params: &[Mat],
    state: &[Vec<f32>],
) -> std::io::Result<()> {
    save_checkpoint_driver(path, params, state, None)
}

/// Save parameters, optimizer state, and optional [`DriverState`]
/// (checkpoint v3) atomically: body → `<path>.tmp` (fsynced), existing
/// `<path>` → `<path>.prev`, tmp renamed over `<path>`.
pub fn save_checkpoint_driver(
    path: &Path,
    params: &[Mat],
    state: &[Vec<f32>],
    driver: Option<&DriverState>,
) -> std::io::Result<()> {
    save_checkpoint_meta(path, params, state, driver, None)
}

/// Save parameters, optimizer state, optional [`DriverState`] and
/// optional [`OptMeta`] (checkpoint v5) atomically.
pub fn save_checkpoint_meta(
    path: &Path,
    params: &[Mat],
    state: &[Vec<f32>],
    driver: Option<&DriverState>,
    meta: Option<&OptMeta>,
) -> std::io::Result<()> {
    let mut body = Vec::new();
    body.extend_from_slice(MAGIC);
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for p in params {
        body.extend_from_slice(&(p.rows() as u32).to_le_bytes());
        body.extend_from_slice(&(p.cols() as u32).to_le_bytes());
        for &v in p.data() {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    body.extend_from_slice(&(state.len() as u32).to_le_bytes());
    for blob in state {
        body.extend_from_slice(&(blob.len() as u32).to_le_bytes());
        for &v in blob {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    match driver {
        None => body.push(0u8),
        Some(d) => {
            body.push(1u8);
            body.extend_from_slice(&(d.step as u64).to_le_bytes());
            body.extend_from_slice(&d.best.to_le_bytes());
            body.extend_from_slice(&d.epoch_loss.to_le_bytes());
            body.extend_from_slice(&(d.nb as u64).to_le_bytes());
            body.extend_from_slice(&(d.rows.len() as u32).to_le_bytes());
            for r in &d.rows {
                body.extend_from_slice(&(r.step as u64).to_le_bytes());
                body.extend_from_slice(&(r.epoch as u64).to_le_bytes());
                body.extend_from_slice(&r.train_loss.to_le_bytes());
                body.extend_from_slice(&r.test_loss.to_le_bytes());
                body.extend_from_slice(&r.test_err.to_le_bytes());
                body.extend_from_slice(&r.lr.to_le_bytes());
                body.push(u8::from(r.diverged));
            }
            match d.scaler {
                None => body.push(0u8),
                Some((scale, clean, skipped)) => {
                    body.push(1u8);
                    body.extend_from_slice(&scale.to_le_bytes());
                    body.extend_from_slice(&(clean as u64).to_le_bytes());
                    body.extend_from_slice(&(skipped as u64).to_le_bytes());
                }
            }
        }
    }
    // v5 optimizer-metadata section (top-level: present even for
    // driver-less parameter checkpoints).
    match meta {
        None => body.push(0u8),
        Some(m) => {
            body.push(1u8);
            body.extend_from_slice(&(m.method.len() as u32).to_le_bytes());
            body.extend_from_slice(m.method.as_bytes());
            body.extend_from_slice(&(m.blobs_per_layer as u32).to_le_bytes());
        }
    }
    let sum = checksum(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // Atomic publish: a crash can corrupt only the tmp file, never the
    // checkpoint readers see; the previous good file survives as .prev.
    let tmp = sibling(path, ".tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&body)?;
        f.sync_all()?;
    }
    if path.exists() {
        std::fs::rename(path, sibling(path, ".prev"))?;
    }
    std::fs::rename(&tmp, path)
}

/// Load parameter matrices from `path` (any version; optimizer and
/// driver state are validated but dropped).
pub fn load_checkpoint(path: &Path) -> std::io::Result<Vec<Mat>> {
    load_checkpoint_full(path).map(|(params, _)| params)
}

/// Load parameters and optimizer-state blobs from `path` (validates
/// magic, version and checksum; v1 files yield empty state; any v3
/// driver state is validated but dropped).
pub fn load_checkpoint_full(path: &Path) -> std::io::Result<(Vec<Mat>, Vec<Vec<f32>>)> {
    load_checkpoint_driver(path).map(|(params, state, _)| (params, state))
}

/// Load parameters, optimizer state and (v3+) [`DriverState`] from
/// `path`. v1/v2 files yield `None` driver state; v3 files yield driver
/// state with no scaler snapshot. Any v5 [`OptMeta`] is validated but
/// dropped; use [`load_checkpoint_meta`] to keep it.
pub fn load_checkpoint_driver(
    path: &Path,
) -> std::io::Result<(Vec<Mat>, Vec<Vec<f32>>, Option<DriverState>)> {
    load_checkpoint_meta(path).map(|(params, state, driver, _)| (params, state, driver))
}

/// Load parameters, optimizer state, (v3+) [`DriverState`] and (v5+)
/// [`OptMeta`] from `path`. Pre-v5 files yield `None` metadata.
pub fn load_checkpoint_meta(
    path: &Path,
) -> std::io::Result<(Vec<Mat>, Vec<Vec<f32>>, Option<DriverState>, Option<OptMeta>)> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    if buf.len() < 20 {
        return Err(err("truncated checkpoint"));
    }
    let (body, sum_bytes) = buf.split_at(buf.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    if checksum(body) != stored {
        return Err(err("checksum mismatch"));
    }
    if &body[0..4] != MAGIC {
        return Err(err("bad magic"));
    }
    let ver = u32::from_le_bytes(body[4..8].try_into().unwrap());
    if ver == 0 || ver > VERSION {
        return Err(err("unsupported version"));
    }
    let n = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
    let mut off = 12usize;
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        if off + 8 > body.len() {
            return Err(err("truncated layer header"));
        }
        let rows = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
        let cols = u32::from_le_bytes(body[off + 4..off + 8].try_into().unwrap()) as usize;
        off += 8;
        let need = rows * cols * 4;
        if off + need > body.len() {
            return Err(err("truncated layer data"));
        }
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows * cols {
            data.push(f32::from_le_bytes(body[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
        }
        off += need;
        params.push(Mat::from_vec(rows, cols, data));
    }
    let mut state = Vec::new();
    if ver >= 2 {
        if off + 4 > body.len() {
            return Err(err("truncated state header"));
        }
        let n_blobs = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        for _ in 0..n_blobs {
            if off + 4 > body.len() {
                return Err(err("truncated blob header"));
            }
            let len = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            let need = len * 4;
            if off + need > body.len() {
                return Err(err("truncated blob data"));
            }
            let mut blob = Vec::with_capacity(len);
            for i in 0..len {
                blob.push(f32::from_le_bytes(
                    body[off + 4 * i..off + 4 * i + 4].try_into().unwrap(),
                ));
            }
            off += need;
            state.push(blob);
        }
    }
    let mut driver = None;
    if ver >= 3 {
        if off + 1 > body.len() {
            return Err(err("truncated driver flag"));
        }
        let flag = body[off];
        off += 1;
        if flag > 1 {
            return Err(err("bad driver flag"));
        }
        if flag == 1 {
            if off + 8 + 4 + 8 + 8 + 4 > body.len() {
                return Err(err("truncated driver header"));
            }
            let step = u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) as usize;
            let best = f32::from_le_bytes(body[off + 8..off + 12].try_into().unwrap());
            let epoch_loss = f64::from_le_bytes(body[off + 12..off + 20].try_into().unwrap());
            let nb = u64::from_le_bytes(body[off + 20..off + 28].try_into().unwrap()) as usize;
            let n_rows = u32::from_le_bytes(body[off + 28..off + 32].try_into().unwrap()) as usize;
            off += 32;
            const ROW_BYTES: usize = 8 + 8 + 4 * 4 + 1;
            let mut rows = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                if off + ROW_BYTES > body.len() {
                    return Err(err("truncated driver row"));
                }
                rows.push(LogRow {
                    step: u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) as usize,
                    epoch: u64::from_le_bytes(body[off + 8..off + 16].try_into().unwrap())
                        as usize,
                    train_loss: f32::from_le_bytes(body[off + 16..off + 20].try_into().unwrap()),
                    test_loss: f32::from_le_bytes(body[off + 20..off + 24].try_into().unwrap()),
                    test_err: f32::from_le_bytes(body[off + 24..off + 28].try_into().unwrap()),
                    lr: f32::from_le_bytes(body[off + 28..off + 32].try_into().unwrap()),
                    diverged: body[off + 32] != 0,
                });
                off += ROW_BYTES;
            }
            let mut scaler = None;
            if ver >= 4 {
                if off + 1 > body.len() {
                    return Err(err("truncated scaler flag"));
                }
                let sflag = body[off];
                off += 1;
                if sflag > 1 {
                    return Err(err("bad scaler flag"));
                }
                if sflag == 1 {
                    if off + 4 + 8 + 8 > body.len() {
                        return Err(err("truncated scaler state"));
                    }
                    let scale = f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
                    let clean =
                        u64::from_le_bytes(body[off + 4..off + 12].try_into().unwrap()) as usize;
                    let skipped =
                        u64::from_le_bytes(body[off + 12..off + 20].try_into().unwrap()) as usize;
                    off += 20;
                    scaler = Some((scale, clean, skipped));
                }
            }
            driver = Some(DriverState { step, best, epoch_loss, nb, rows, scaler });
        }
    }
    let mut meta = None;
    if ver >= 5 {
        if off + 1 > body.len() {
            return Err(err("truncated meta flag"));
        }
        let mflag = body[off];
        off += 1;
        if mflag > 1 {
            return Err(err("bad meta flag"));
        }
        if mflag == 1 {
            if off + 4 > body.len() {
                return Err(err("truncated meta header"));
            }
            let name_len = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            if off + name_len + 4 > body.len() {
                return Err(err("truncated meta payload"));
            }
            let method = std::str::from_utf8(&body[off..off + name_len])
                .map_err(|_| err("non-utf8 method name in meta"))?
                .to_string();
            off += name_len;
            let blobs_per_layer =
                u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            meta = Some(OptMeta { method, blobs_per_layer });
        }
    }
    if off != body.len() {
        return Err(err("trailing bytes after checkpoint payload"));
    }
    Ok((params, state, driver, meta))
}

/// [`load_checkpoint_driver`] with automatic fallback to the
/// `<path>.prev` last-good copy when the primary file fails validation
/// (e.g. a crash corrupted it mid-write before the atomic rename
/// landed, or the disk ate it). A fallback is reported on stderr so the
/// data loss is visible; when both fail the primary's error is
/// returned, annotated with the fallback failure.
pub fn load_checkpoint_auto(
    path: &Path,
) -> std::io::Result<(Vec<Mat>, Vec<Vec<f32>>, Option<DriverState>, Option<OptMeta>)> {
    match load_checkpoint_meta(path) {
        Ok(ok) => Ok(ok),
        Err(primary) => {
            let prev = sibling(path, ".prev");
            match load_checkpoint_meta(&prev) {
                Ok(ok) => {
                    crate::obs_warn!(
                        "warning: checkpoint {}: {primary}; resumed from last-good {}",
                        path.display(),
                        prev.display()
                    );
                    Ok(ok)
                }
                Err(fallback) => Err(std::io::Error::new(
                    primary.kind(),
                    format!(
                        "checkpoint {}: {primary} (fallback {}: {fallback})",
                        path.display(),
                        prev.display()
                    ),
                )),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Hyper, KronStats, Method, Optimizer};
    use crate::proptest::Pcg;
    use crate::structured::Structure;

    /// Write a v1-format file (no state section) for back-compat tests.
    fn write_v1(path: &Path, params: &[Mat]) {
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(params.len() as u32).to_le_bytes());
        for p in params {
            body.extend_from_slice(&(p.rows() as u32).to_le_bytes());
            body.extend_from_slice(&(p.cols() as u32).to_le_bytes());
            for &v in p.data() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = checksum(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(path, &body).unwrap();
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg::new(81);
        let params = vec![rng.normal_mat(3, 5, 1.0), rng.normal_mat(7, 2, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt.bin");
        save_checkpoint(&path, &params).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
    }

    #[test]
    fn v2_roundtrips_optimizer_state_bitwise() {
        // Train a SINGD optimizer a few steps so momenta and structured
        // factors are all non-trivial, then save → load → bitwise-equal.
        let mut rng = Pcg::new(83);
        let shapes = [(6usize, 5usize), (4, 6)];
        let method = Method::Singd { structure: Structure::BlockDiag { k: 2 } };
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = method.build(&shapes, &hp);
        let mut params = vec![rng.normal_mat(6, 5, 0.2), rng.normal_mat(4, 6, 0.2)];
        for t in 0..3 {
            let grads = vec![rng.normal_mat(6, 5, 0.1), rng.normal_mat(4, 6, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(16, 5, 1.0), g: rng.normal_mat(16, 6, 1.0) },
                KronStats { a: rng.normal_mat(16, 6, 1.0), g: rng.normal_mat(16, 4, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
        }
        let state = opt.state_vectors();
        assert!(!state.is_empty());
        let path = std::env::temp_dir().join("singd_test_ckpt_v2.bin");
        save_checkpoint_full(&path, &params, &state).unwrap();
        let (lp, ls) = load_checkpoint_full(&path).unwrap();
        assert_eq!(lp, params);
        assert_eq!(ls, state, "state blobs must round-trip bitwise");
        // Restoring into a freshly-built optimizer reproduces the state.
        let mut fresh = method.build(&shapes, &hp);
        fresh.load_state_vectors(&ls).unwrap();
        assert_eq!(fresh.state_vectors(), state);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
    }

    #[test]
    fn optimizer_zoo_blobs_roundtrip_bitwise_with_meta() {
        // The RK-FAC and MAC state blobs (sketches, Woodbury cores, mean
        // activations) must survive a v5 save→load bitwise, and the meta
        // section must identify the writing method.
        for method in [Method::RkFac { k: 2 }, Method::Mac] {
            let mut rng = Pcg::new(91);
            let shapes = [(6usize, 5usize), (4, 6)];
            let hp = Hyper { t_update: 1, damping: 0.1, ..Hyper::default() };
            let mut opt = method.build(&shapes, &hp);
            let mut params = vec![rng.normal_mat(6, 5, 0.2), rng.normal_mat(4, 6, 0.2)];
            for t in 0..3 {
                let grads = vec![rng.normal_mat(6, 5, 0.1), rng.normal_mat(4, 6, 0.1)];
                let stats = vec![
                    KronStats { a: rng.normal_mat(16, 5, 1.0), g: rng.normal_mat(16, 6, 1.0) },
                    KronStats { a: rng.normal_mat(16, 6, 1.0), g: rng.normal_mat(16, 4, 1.0) },
                ];
                opt.step(t, &mut params, &grads, &stats);
            }
            let state = opt.state_vectors();
            assert!(!state.is_empty(), "{} must carry state", method.name());
            let meta =
                OptMeta { method: method.name(), blobs_per_layer: opt.state_blobs_per_layer() };
            let path = std::env::temp_dir()
                .join(format!("singd_test_ckpt_zoo_{}.bin", method.name().replace(':', "_")));
            save_checkpoint_meta(&path, &params, &state, None, Some(&meta)).unwrap();
            let (lp, ls, _, lm) = load_checkpoint_meta(&path).unwrap();
            assert_eq!(lp, params);
            assert_eq!(ls, state, "{} blobs must round-trip bitwise", method.name());
            assert_eq!(lm, Some(meta));
            let mut fresh = method.build(&shapes, &hp);
            fresh.load_state_vectors(&ls).unwrap();
            assert_eq!(fresh.state_vectors(), state);
            std::fs::remove_file(&path).ok();
            std::fs::remove_file(sibling(&path, ".prev")).ok();
        }
    }

    #[test]
    fn v3_driver_state_roundtrips_bitwise() {
        let mut rng = Pcg::new(87);
        let params = vec![rng.normal_mat(3, 4, 1.0)];
        let driver = DriverState {
            step: 12,
            best: 0.251f32,
            epoch_loss: 3.0625f64 + 1e-12,
            nb: 4,
            rows: vec![
                LogRow {
                    step: 4,
                    epoch: 0,
                    train_loss: 1.5,
                    test_loss: 1.25,
                    test_err: 0.5,
                    lr: 0.05,
                    diverged: false,
                },
                LogRow {
                    step: 8,
                    epoch: 1,
                    train_loss: 1.25,
                    test_loss: 1.0,
                    test_err: 0.251,
                    lr: 0.025,
                    diverged: true,
                },
            ],
            scaler: None,
        };
        let path = std::env::temp_dir().join("singd_test_ckpt_v3.bin");
        save_checkpoint_driver(&path, &params, &[vec![1.0, 2.0]], Some(&driver)).unwrap();
        let (lp, ls, ld) = load_checkpoint_driver(&path).unwrap();
        assert_eq!(lp, params);
        assert_eq!(ls, vec![vec![1.0, 2.0]]);
        assert_eq!(ld, Some(driver));
        // A driver-less v3 file loads with None.
        save_checkpoint_full(&path, &params, &[]).unwrap();
        let (_, _, ld) = load_checkpoint_driver(&path).unwrap();
        assert_eq!(ld, None);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        std::fs::remove_file(sibling(&path, ".tmp")).ok();
    }

    #[test]
    fn v4_scaler_state_roundtrips_bitwise() {
        let mut rng = Pcg::new(89);
        let params = vec![rng.normal_mat(2, 3, 1.0)];
        let driver = DriverState {
            step: 7,
            best: 0.5,
            epoch_loss: 1.75,
            nb: 3,
            rows: Vec::new(),
            scaler: Some((32768.0, 41, 2)),
        };
        let path = std::env::temp_dir().join("singd_test_ckpt_v4.bin");
        save_checkpoint_driver(&path, &params, &[], Some(&driver)).unwrap();
        let (_, _, ld) = load_checkpoint_driver(&path).unwrap();
        assert_eq!(ld, Some(driver), "scaler schedule must round-trip bitwise");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        std::fs::remove_file(sibling(&path, ".tmp")).ok();
    }

    #[test]
    fn v5_opt_meta_roundtrips() {
        let mut rng = Pcg::new(90);
        let params = vec![rng.normal_mat(2, 2, 1.0)];
        let meta = OptMeta { method: "rkfac:4".into(), blobs_per_layer: 5 };
        let path = std::env::temp_dir().join("singd_test_ckpt_v5.bin");
        save_checkpoint_meta(&path, &params, &[vec![1.0, 2.0]], None, Some(&meta)).unwrap();
        let (lp, ls, ld, lm) = load_checkpoint_meta(&path).unwrap();
        assert_eq!(lp, params);
        assert_eq!(ls, vec![vec![1.0, 2.0]]);
        assert_eq!(ld, None);
        assert_eq!(lm, Some(meta), "opt meta must round-trip exactly");
        // A meta-less v5 file (the delegating legacy writers) loads with
        // None metadata.
        save_checkpoint_full(&path, &params, &[]).unwrap();
        let (_, _, _, lm) = load_checkpoint_meta(&path).unwrap();
        assert_eq!(lm, None);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        std::fs::remove_file(sibling(&path, ".tmp")).ok();
    }

    #[test]
    fn v5_meta_section_corruption_rejected() {
        // Hand-craft v5 bodies with a hostile meta section; each must be
        // rejected with a real error, never a silent misparse. The
        // checksum is recomputed so the framing check alone cannot save
        // us — the section parser has to do the work.
        let write = |mutate: &dyn Fn(&mut Vec<u8>)| {
            let mut body = Vec::new();
            body.extend_from_slice(MAGIC);
            body.extend_from_slice(&5u32.to_le_bytes());
            body.extend_from_slice(&0u32.to_le_bytes()); // n_layers
            body.extend_from_slice(&0u32.to_le_bytes()); // n_blobs
            body.push(0u8); // driver flag
            mutate(&mut body);
            let sum = checksum(&body);
            body.extend_from_slice(&sum.to_le_bytes());
            let path = std::env::temp_dir().join("singd_test_ckpt_v5_bad.bin");
            std::fs::write(&path, &body).unwrap();
            let out = load_checkpoint_meta(&path);
            std::fs::remove_file(&path).ok();
            out
        };
        // Meta flag byte missing entirely.
        assert!(write(&|_| {}).is_err(), "missing meta flag must be rejected");
        // Flag value outside {0, 1}.
        assert!(write(&|b| b.push(7u8)).is_err(), "bad meta flag must be rejected");
        // Flag=1 but the name length points past the end of the body.
        assert!(
            write(&|b| {
                b.push(1u8);
                b.extend_from_slice(&1000u32.to_le_bytes());
            })
            .is_err(),
            "oversized meta name must be rejected"
        );
        // Flag=1 with a non-utf8 method name.
        assert!(
            write(&|b| {
                b.push(1u8);
                b.extend_from_slice(&2u32.to_le_bytes());
                b.extend_from_slice(&[0xff, 0xfe]);
                b.extend_from_slice(&1u32.to_le_bytes());
            })
            .is_err(),
            "non-utf8 meta name must be rejected"
        );
        // Trailing garbage after a valid meta section.
        assert!(
            write(&|b| {
                b.push(0u8);
                b.push(0xabu8);
            })
            .is_err(),
            "trailing bytes must be rejected"
        );
        // Control: the minimal well-formed body loads.
        let ok = write(&|b| b.push(0u8)).unwrap();
        assert_eq!(ok.3, None);
    }

    #[test]
    fn v4_files_load_with_no_opt_meta() {
        // Hand-write a v4 file (scaler flag present, no meta section):
        // readers must accept it and yield `meta: None`.
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&4u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes()); // n_layers
        body.extend_from_slice(&1u32.to_le_bytes()); // rows
        body.extend_from_slice(&1u32.to_le_bytes()); // cols
        body.extend_from_slice(&1.5f32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes()); // n_blobs
        body.extend_from_slice(&1u32.to_le_bytes()); // blob len
        body.extend_from_slice(&3.5f32.to_le_bytes());
        body.push(1u8); // driver flag
        body.extend_from_slice(&6u64.to_le_bytes()); // step
        body.extend_from_slice(&0.25f32.to_le_bytes()); // best
        body.extend_from_slice(&1.0f64.to_le_bytes()); // epoch_loss
        body.extend_from_slice(&2u64.to_le_bytes()); // nb
        body.extend_from_slice(&0u32.to_le_bytes()); // n_rows
        body.push(1u8); // scaler flag
        body.extend_from_slice(&1024.0f32.to_le_bytes());
        body.extend_from_slice(&3u64.to_le_bytes());
        body.extend_from_slice(&1u64.to_le_bytes());
        let sum = checksum(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        let path = std::env::temp_dir().join("singd_test_ckpt_v4_compat.bin");
        std::fs::write(&path, &body).unwrap();
        let (lp, ls, ld, lm) = load_checkpoint_meta(&path).unwrap();
        assert_eq!(lp[0].at(0, 0), 1.5);
        assert_eq!(ls, vec![vec![3.5]]);
        let d = ld.unwrap();
        assert_eq!(d.scaler, Some((1024.0, 3, 1)));
        assert_eq!(lm, None, "v4 files carry no optimizer metadata");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_files_load_with_no_scaler_state() {
        // Hand-write a v3 file (driver section without the scaler flag):
        // readers must accept it and yield `scaler: None`.
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&3u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes()); // n_layers
        body.extend_from_slice(&1u32.to_le_bytes()); // rows
        body.extend_from_slice(&1u32.to_le_bytes()); // cols
        body.extend_from_slice(&2.5f32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes()); // n_blobs
        body.push(1u8); // driver flag
        body.extend_from_slice(&9u64.to_le_bytes()); // step
        body.extend_from_slice(&0.125f32.to_le_bytes()); // best
        body.extend_from_slice(&2.0f64.to_le_bytes()); // epoch_loss
        body.extend_from_slice(&1u64.to_le_bytes()); // nb
        body.extend_from_slice(&0u32.to_le_bytes()); // n_rows
        let sum = checksum(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        let path = std::env::temp_dir().join("singd_test_ckpt_v3_compat.bin");
        std::fs::write(&path, &body).unwrap();
        let (lp, _, ld) = load_checkpoint_driver(&path).unwrap();
        assert_eq!(lp[0].at(0, 0), 2.5);
        let d = ld.unwrap();
        assert_eq!((d.step, d.best, d.nb), (9, 0.125, 1));
        assert_eq!(d.scaler, None, "v3 driver state carries no scaler snapshot");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_files_still_load_with_empty_state() {
        let mut rng = Pcg::new(84);
        let params = vec![rng.normal_mat(4, 3, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_v1.bin");
        write_v1(&path, &params);
        let (lp, ls) = load_checkpoint_full(&path).unwrap();
        assert_eq!(lp, params);
        assert!(ls.is_empty());
        assert_eq!(load_checkpoint(&path).unwrap(), params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Pcg::new(82);
        let params = vec![rng.normal_mat(4, 4, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_bad.bin");
        save_checkpoint_full(&path, &params, &[vec![1.0, 2.0]]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
    }

    #[test]
    fn truncated_checkpoint_rejected() {
        let mut rng = Pcg::new(85);
        let params = vec![rng.normal_mat(4, 4, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_trunc.bin");
        save_checkpoint_full(&path, &params, &[vec![1.0; 8]]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Chop mid-file: the checksum (over a shorter body) cannot match.
        std::fs::write(&path, &bytes[..bytes.len() - 13]).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        // Shorter than any valid header.
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
    }

    #[test]
    fn future_version_rejected() {
        let path = std::env::temp_dir().join("singd_test_ckpt_future.bin");
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&99u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        let sum = checksum(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &body).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn kill_mid_write_leaves_last_good_recoverable() {
        // Simulate a crash mid-write: the new body reaches only the tmp
        // file (truncated), while the previous save's rename already
        // published a good primary. The auto loader must (a) prefer the
        // intact primary, and (b) when the primary itself is later
        // corrupted, fall back to `<path>.prev`.
        let mut rng = Pcg::new(88);
        let gen1 = vec![rng.normal_mat(3, 3, 1.0)];
        let gen2 = vec![rng.normal_mat(3, 3, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_crash.bin");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        save_checkpoint(&path, &gen1).unwrap();
        save_checkpoint(&path, &gen2).unwrap();
        // gen1 survived as .prev, gen2 is the primary.
        assert_eq!(load_checkpoint(&sibling(&path, ".prev")).unwrap(), gen1);
        // "Crash" while writing gen3: a truncated tmp file exists.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(sibling(&path, ".tmp"), &bytes[..bytes.len() / 2]).unwrap();
        let (p, _, _, _) = load_checkpoint_auto(&path).unwrap();
        assert_eq!(p, gen2, "intact primary must win despite a stale tmp file");
        // Corrupt the primary: auto falls back to the last-good .prev.
        let mut bad = bytes.clone();
        bad[16] ^= 0x55;
        std::fs::write(&path, &bad).unwrap();
        let (p, _, _, _) = load_checkpoint_auto(&path).unwrap();
        assert_eq!(p, gen1, "corrupted primary must fall back to .prev");
        // Both corrupted: a real error naming both files.
        std::fs::write(sibling(&path, ".prev"), b"junk").unwrap();
        let e = load_checkpoint_auto(&path).unwrap_err().to_string();
        assert!(e.contains(".prev"), "error must name the fallback: {e}");
        // A leftover tmp file never breaks the next save.
        save_checkpoint(&path, &gen1).unwrap();
        assert_eq!(load_checkpoint(&path).unwrap(), gen1);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        std::fs::remove_file(sibling(&path, ".tmp")).ok();
    }
}
