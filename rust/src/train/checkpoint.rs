//! Minimal binary checkpoint format for model parameters and optimizer
//! state.
//!
//! Layout (little-endian):
//!
//! - v1: `magic "SNGD" | u32 version=1 | u32 n_layers | per layer: u32
//!   rows, u32 cols, rows·cols f32 | u64 FNV-1a checksum`.
//! - v2 (current): the v1 parameter section, followed by `u32 n_blobs |
//!   per blob: u32 len, len f32` — the optimizer's
//!   [`crate::optim::Optimizer::state_vectors`] snapshot (momenta,
//!   Kronecker/structured factors in coefficient order) — before the
//!   checksum. `n_blobs = 0` is a pure-parameter checkpoint.
//!
//! Readers accept both versions (v1 loads with empty optimizer state);
//! the writer always emits v2. The checksum covers everything before it,
//! so truncation and bit corruption are both rejected.

use crate::tensor::Mat;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SNGD";
const VERSION: u32 = 2;

/// FNV-1a 64 over a byte image — shared by the checkpoint framing and
/// the run digest of [`super::run_digest`].
pub(super) fn checksum(data: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save parameter matrices to `path` (no optimizer state).
pub fn save_checkpoint(path: &Path, params: &[Mat]) -> std::io::Result<()> {
    save_checkpoint_full(path, params, &[])
}

/// Save parameters plus an optimizer-state snapshot
/// ([`crate::optim::Optimizer::state_vectors`]) to `path`.
pub fn save_checkpoint_full(
    path: &Path,
    params: &[Mat],
    state: &[Vec<f32>],
) -> std::io::Result<()> {
    let mut body = Vec::new();
    body.extend_from_slice(MAGIC);
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for p in params {
        body.extend_from_slice(&(p.rows() as u32).to_le_bytes());
        body.extend_from_slice(&(p.cols() as u32).to_le_bytes());
        for &v in p.data() {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    body.extend_from_slice(&(state.len() as u32).to_le_bytes());
    for blob in state {
        body.extend_from_slice(&(blob.len() as u32).to_le_bytes());
        for &v in blob {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = checksum(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::File::create(path)?.write_all(&body)
}

/// Load parameter matrices from `path` (v1 or v2; any optimizer state is
/// validated but dropped).
pub fn load_checkpoint(path: &Path) -> std::io::Result<Vec<Mat>> {
    load_checkpoint_full(path).map(|(params, _)| params)
}

/// Load parameters and optimizer-state blobs from `path` (validates
/// magic, version and checksum; v1 files yield empty state).
pub fn load_checkpoint_full(path: &Path) -> std::io::Result<(Vec<Mat>, Vec<Vec<f32>>)> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    if buf.len() < 20 {
        return Err(err("truncated checkpoint"));
    }
    let (body, sum_bytes) = buf.split_at(buf.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    if checksum(body) != stored {
        return Err(err("checksum mismatch"));
    }
    if &body[0..4] != MAGIC {
        return Err(err("bad magic"));
    }
    let ver = u32::from_le_bytes(body[4..8].try_into().unwrap());
    if ver == 0 || ver > VERSION {
        return Err(err("unsupported version"));
    }
    let n = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
    let mut off = 12usize;
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        if off + 8 > body.len() {
            return Err(err("truncated layer header"));
        }
        let rows = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
        let cols = u32::from_le_bytes(body[off + 4..off + 8].try_into().unwrap()) as usize;
        off += 8;
        let need = rows * cols * 4;
        if off + need > body.len() {
            return Err(err("truncated layer data"));
        }
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows * cols {
            data.push(f32::from_le_bytes(body[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
        }
        off += need;
        params.push(Mat::from_vec(rows, cols, data));
    }
    let mut state = Vec::new();
    if ver >= 2 {
        if off + 4 > body.len() {
            return Err(err("truncated state header"));
        }
        let n_blobs = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        for _ in 0..n_blobs {
            if off + 4 > body.len() {
                return Err(err("truncated blob header"));
            }
            let len = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            let need = len * 4;
            if off + need > body.len() {
                return Err(err("truncated blob data"));
            }
            let mut blob = Vec::with_capacity(len);
            for i in 0..len {
                blob.push(f32::from_le_bytes(
                    body[off + 4 * i..off + 4 * i + 4].try_into().unwrap(),
                ));
            }
            off += need;
            state.push(blob);
        }
    }
    if off != body.len() {
        return Err(err("trailing bytes after checkpoint payload"));
    }
    Ok((params, state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Hyper, KronStats, Method, Optimizer};
    use crate::proptest::Pcg;
    use crate::structured::Structure;

    /// Write a v1-format file (no state section) for back-compat tests.
    fn write_v1(path: &Path, params: &[Mat]) {
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(params.len() as u32).to_le_bytes());
        for p in params {
            body.extend_from_slice(&(p.rows() as u32).to_le_bytes());
            body.extend_from_slice(&(p.cols() as u32).to_le_bytes());
            for &v in p.data() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = checksum(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(path, &body).unwrap();
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg::new(81);
        let params = vec![rng.normal_mat(3, 5, 1.0), rng.normal_mat(7, 2, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt.bin");
        save_checkpoint(&path, &params).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_roundtrips_optimizer_state_bitwise() {
        // Train a SINGD optimizer a few steps so momenta and structured
        // factors are all non-trivial, then save → load → bitwise-equal.
        let mut rng = Pcg::new(83);
        let shapes = [(6usize, 5usize), (4, 6)];
        let method = Method::Singd { structure: Structure::BlockDiag { k: 2 } };
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = method.build(&shapes, &hp);
        let mut params = vec![rng.normal_mat(6, 5, 0.2), rng.normal_mat(4, 6, 0.2)];
        for t in 0..3 {
            let grads = vec![rng.normal_mat(6, 5, 0.1), rng.normal_mat(4, 6, 0.1)];
            let stats = vec![
                KronStats { a: rng.normal_mat(16, 5, 1.0), g: rng.normal_mat(16, 6, 1.0) },
                KronStats { a: rng.normal_mat(16, 6, 1.0), g: rng.normal_mat(16, 4, 1.0) },
            ];
            opt.step(t, &mut params, &grads, &stats);
        }
        let state = opt.state_vectors();
        assert!(!state.is_empty());
        let path = std::env::temp_dir().join("singd_test_ckpt_v2.bin");
        save_checkpoint_full(&path, &params, &state).unwrap();
        let (lp, ls) = load_checkpoint_full(&path).unwrap();
        assert_eq!(lp, params);
        assert_eq!(ls, state, "state blobs must round-trip bitwise");
        // Restoring into a freshly-built optimizer reproduces the state.
        let mut fresh = method.build(&shapes, &hp);
        fresh.load_state_vectors(&ls).unwrap();
        assert_eq!(fresh.state_vectors(), state);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_files_still_load_with_empty_state() {
        let mut rng = Pcg::new(84);
        let params = vec![rng.normal_mat(4, 3, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_v1.bin");
        write_v1(&path, &params);
        let (lp, ls) = load_checkpoint_full(&path).unwrap();
        assert_eq!(lp, params);
        assert!(ls.is_empty());
        assert_eq!(load_checkpoint(&path).unwrap(), params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Pcg::new(82);
        let params = vec![rng.normal_mat(4, 4, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_bad.bin");
        save_checkpoint_full(&path, &params, &[vec![1.0, 2.0]]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_checkpoint_rejected() {
        let mut rng = Pcg::new(85);
        let params = vec![rng.normal_mat(4, 4, 1.0)];
        let path = std::env::temp_dir().join("singd_test_ckpt_trunc.bin");
        save_checkpoint_full(&path, &params, &[vec![1.0; 8]]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Chop mid-file: the checksum (over a shorter body) cannot match.
        std::fs::write(&path, &bytes[..bytes.len() - 13]).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        // Shorter than any valid header.
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn future_version_rejected() {
        let path = std::env::temp_dir().join("singd_test_ckpt_future.bin");
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&99u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        let sum = checksum(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &body).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
