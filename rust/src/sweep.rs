//! Random hyper-parameter search (paper Table 4 / Appendix C).
//!
//! Samples log-uniform / categorical values over the same search space the
//! paper lists and runs each trial through the experiment driver, keeping
//! the best configuration by final test error.

use crate::config::JobConfig;
use crate::exp::run_job;
use crate::optim::{Hyper, Method};
use crate::proptest::Pcg;

/// Search-space specification for one hyper-parameter (log-uniform range).
#[derive(Clone, Debug)]
pub struct LogRange {
    pub lo: f32,
    pub hi: f32,
}

impl LogRange {
    pub fn sample(&self, rng: &mut Pcg) -> f32 {
        (self.lo.ln() + (self.hi.ln() - self.lo.ln()) * rng.uniform()).exp()
    }
}

/// The Table-4 search space: `β₂` (lr), `γ` (weight decay), `λ` (damping),
/// `β₁` (preconditioner lr), `α₁` (Riemannian momentum, SINGD only);
/// `α₂` fixed at 0.9 as in the paper.
#[derive(Clone, Debug)]
pub struct Space {
    pub lr: LogRange,
    pub weight_decay: LogRange,
    pub damping: LogRange,
    pub precond_lr: LogRange,
    /// Candidate α₁ values (categorical, SINGD only).
    pub riem_momentum: Vec<f32>,
}

impl Default for Space {
    fn default() -> Self {
        Space {
            lr: LogRange { lo: 1e-4, hi: 0.3 },
            weight_decay: LogRange { lo: 1e-6, hi: 1e-2 },
            damping: LogRange { lo: 1e-5, hi: 1e-1 },
            precond_lr: LogRange { lo: 1e-3, hi: 0.2 },
            riem_momentum: vec![0.0, 0.3, 0.6, 0.9],
        }
    }
}

impl Space {
    /// Draw a full hyper-parameter set for `method`.
    pub fn sample(&self, method: &Method, base: &Hyper, rng: &mut Pcg) -> Hyper {
        let mut hp = base.clone();
        hp.lr = self.lr.sample(rng);
        hp.weight_decay = self.weight_decay.sample(rng);
        hp.damping = self.damping.sample(rng);
        hp.precond_lr = self.precond_lr.sample(rng);
        hp.momentum = 0.9; // fixed, as in the paper
        hp.riem_momentum = match method {
            Method::Singd { .. } => self.riem_momentum[rng.below(self.riem_momentum.len())],
            _ => 0.0,
        };
        hp
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct Trial {
    pub hyper: Hyper,
    pub final_err: f32,
    pub diverged: bool,
}

/// Run `n_trials` random-search trials of `base` (model/data/schedule kept
/// fixed, optimizer hyper-parameters resampled). Returns all trials sorted
/// best-first.
pub fn random_search(base: &JobConfig, space: &Space, n_trials: usize, seed: u64) -> Vec<Trial> {
    let mut rng = Pcg::with_stream(seed, 0x5eed);
    let mut trials = Vec::with_capacity(n_trials);
    for i in 0..n_trials {
        let hyper = space.sample(&base.method, &base.hyper, &mut rng);
        let mut cfg = base.clone();
        cfg.hyper = hyper.clone();
        cfg.seed = seed ^ (i as u64).wrapping_mul(0x9e37);
        let res = run_job(&cfg);
        crate::obs_info!(
            "trial {i:>3}: lr={:.2e} wd={:.2e} λ={:.2e} β₁={:.2e} α₁={:.1} → err {:.3}{}",
            hyper.lr,
            hyper.weight_decay,
            hyper.damping,
            hyper.precond_lr,
            hyper.riem_momentum,
            res.final_test_err,
            if res.diverged { " (diverged)" } else { "" },
        );
        trials.push(Trial { hyper, final_err: res.final_test_err, diverged: res.diverged });
    }
    trials.sort_by(|a, b| a.final_err.partial_cmp(&b.final_err).unwrap_or(std::cmp::Ordering::Equal));
    trials
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Arch;
    use crate::structured::Structure;
    use crate::train::Schedule;

    #[test]
    fn log_range_within_bounds() {
        let mut rng = Pcg::new(91);
        let r = LogRange { lo: 1e-4, hi: 1e-1 };
        for _ in 0..200 {
            let v = r.sample(&mut rng);
            assert!(v >= 1e-4 && v <= 1e-1);
        }
    }

    #[test]
    fn sample_respects_method_specific_fields() {
        let mut rng = Pcg::new(92);
        let space = Space::default();
        let base = Hyper::default();
        let sgd = space.sample(&Method::Sgd, &base, &mut rng);
        assert_eq!(sgd.riem_momentum, 0.0);
        let singd =
            space.sample(&Method::Singd { structure: Structure::Diagonal }, &base, &mut rng);
        assert!(space.riem_momentum.contains(&singd.riem_momentum));
        assert_eq!(singd.momentum, 0.9);
    }

    #[test]
    fn random_search_ranks_trials() {
        let base = JobConfig {
            arch: Arch::Mlp { hidden: vec![16] },
            dataset: "cifar100".into(),
            classes: 3,
            n_train: 90,
            n_test: 30,
            method: Method::Sgd,
            hyper: Hyper::default(),
            schedule: Schedule::Constant,
            epochs: 2,
            batch_size: 30,
            seed: 1,
            label: "sweep-test".into(),
            ranks: 1,
            dist_strategy: crate::dist::DistStrategy::Replicated,
            transport: crate::dist::Transport::Local,
            algo: crate::dist::default_algo(),
            overlap: crate::dist::default_overlap(),
            wire_dtype: crate::dist::default_wire_dtype(),
            resume: None,
            ckpt: None,
            ckpt_every: 0,
            elastic: false,
            trace_dir: None,
            log: None,
        };
        let trials = random_search(&base, &Space::default(), 3, 42);
        assert_eq!(trials.len(), 3);
        assert!(trials[0].final_err <= trials[2].final_err);
    }
}
