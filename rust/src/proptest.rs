//! Seeded randomized property-testing helpers.
//!
//! `proptest`/`quickcheck` are unavailable in this offline environment, so
//! this module provides the small subset the test-suite needs: a fast,
//! reproducible PCG-XSH-RR generator plus `forall`-style drivers that run a
//! property over many random cases and report the failing seed.

use crate::tensor::Mat;

/// PCG-XSH-RR 64/32 pseudo-random generator (O'Neill 2014).
///
/// Deterministic, seedable, and good enough statistical quality for
/// synthetic data generation and property tests. Also used by
/// [`crate::data`] so whole training runs are reproducible from a config
/// seed.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    const MULT: u64 = 6364136223846793005;

    /// Seeded generator (stream id fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seeded generator with an explicit stream id.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut pcg = Pcg { state: 0, inc: (stream << 1) | 1 };
        pcg.state = pcg.state.wrapping_mul(Self::MULT).wrapping_add(pcg.inc);
        pcg.state = pcg.state.wrapping_add(seed);
        pcg.state = pcg.state.wrapping_mul(Self::MULT).wrapping_add(pcg.inc);
        pcg
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Random matrix with iid `N(0, scale²)` entries.
    pub fn normal_mat(&mut self, rows: usize, cols: usize, scale: f32) -> Mat {
        Mat::from_fn(rows, cols, |_, _| self.normal() * scale)
    }

    /// Random symmetric positive-definite matrix `c·(GᵀG/n) + jitter·I`.
    pub fn spd_mat(&mut self, n: usize, jitter: f32) -> Mat {
        let g = self.normal_mat(n.max(2), n, 1.0);
        let mut s = crate::tensor::matmul_at_b(&g, &g).scale(1.0 / n as f32);
        s.add_diag(jitter);
        s.symmetrize()
    }

    /// Random orthonormal matrix via Gram–Schmidt on a Gaussian matrix.
    pub fn orthonormal_mat(&mut self, n: usize) -> Mat {
        let mut q = self.normal_mat(n, n, 1.0);
        for i in 0..n {
            // Orthogonalize row i against previous rows (twice for stability).
            for _ in 0..2 {
                for j in 0..i {
                    let dot: f32 = (0..n).map(|c| q.at(i, c) * q.at(j, c)).sum();
                    for c in 0..n {
                        *q.at_mut(i, c) -= dot * q.at(j, c);
                    }
                }
            }
            let norm: f32 = (0..n).map(|c| q.at(i, c).powi(2)).sum::<f32>().sqrt().max(1e-12);
            for c in 0..n {
                *q.at_mut(i, c) /= norm;
            }
        }
        q
    }

    /// Random SPD matrix with a prescribed eigenvalue range:
    /// `S = Q diag(d) Qᵀ` with `d` log-uniform in `[lo, hi]`, `Q` orthonormal.
    pub fn spd_with_spectrum(&mut self, n: usize, lo: f32, hi: f32) -> Mat {
        let q = self.orthonormal_mat(n);
        let d: Vec<f32> = (0..n)
            .map(|i| {
                if i == 0 {
                    lo
                } else if i == n - 1 {
                    hi
                } else {
                    lo * (hi / lo).powf(self.uniform())
                }
            })
            .collect();
        let qd = Mat::from_fn(n, n, |r, c| q.at(c, r) * d[c]); // Qᵀ scaled → columns
        crate::tensor::matmul(&qd, &q).symmetrize()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Run `prop` on `cases` random instances; panic with the failing case index
/// and seed so the case can be replayed.
pub fn forall(seed: u64, cases: usize, mut prop: impl FnMut(&mut Pcg, usize)) {
    for case in 0..cases {
        let mut rng = Pcg::with_stream(seed, case as u64 + 1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            panic!("property failed at case {case} (seed {seed}): {:?}", e.downcast_ref::<String>());
        }
    }
}

/// Assert two matrices are elementwise close with mixed abs/rel tolerance.
pub fn assert_mat_close(a: &Mat, b: &Mat, tol: f32, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape mismatch");
    for i in 0..a.len() {
        let (x, y) = (a.data()[i], b.data()[i]);
        let denom = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * denom,
            "{ctx}: element {i} differs: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg::with_stream(1, 1);
        let mut b = Pcg::with_stream(1, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg::new(3);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut rng = Pcg::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn spd_mat_is_spd() {
        let mut rng = Pcg::new(9);
        let s = rng.spd_mat(8, 0.1);
        // symmetric
        for i in 0..8 {
            for j in 0..8 {
                assert!((s.at(i, j) - s.at(j, i)).abs() < 1e-6);
            }
        }
        // positive definite: Cholesky succeeds
        assert!(crate::linalg::cholesky(&s).is_some());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
