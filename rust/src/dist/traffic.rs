//! Per-rank bytes-on-wire accounting for the collective layer.
//!
//! Every collective payload frame a rank sends is recorded here —
//! actual frame bytes on the socket transport, the identical modeled
//! frame bytes on the local transport (which moves pointers, not bytes,
//! but would put exactly these frames on a wire). The counters are what
//! `benches/dist_scaling.rs` reads to compare the star exchange's rank-0
//! fan-in (`~(R−1)·R·N` sent by rank 0 per all-reduce) against the ring
//! schedule's balanced `~2·(R−1)/R·N` per rank.
//!
//! Counters are process-wide atomics: under the local transport all
//! ranks live in one process and each increments its own slot; under the
//! socket transport each OS process tracks the one rank it hosts.
//! Handshake and goodbye frames are *not* counted — only collective
//! payload traffic, so the numbers are a pure function of the algorithm
//! and payload sizes.
//!
//! # Per-op attribution under concurrent in-flight ops
//!
//! Nonblocking collectives ([`crate::dist::pending`]) execute on a
//! communicator's progress engine while the issuing thread computes, so
//! a global-counter snapshot taken mid-flight could otherwise observe a
//! half-accounted collective. Bytes sent while an engine op executes
//! therefore accumulate on that op's own counter
//! ([`crate::dist::pending::PendingOp::bytes_sent`]) and are **merged
//! into the global per-rank slots only when the op completes** — global
//! totals move in whole-collective increments, and per-op byte counts
//! are exact regardless of what else is in flight (the property the
//! ring-bandwidth pinning test in `rust/tests/dist.rs` relies on).
//!
//! # Lifecycle: epochs and the metrics registry
//!
//! The slots are process-global, so consecutive runs in one process
//! (tests, benches, elastic generations) would otherwise accumulate
//! into each other's totals. The seam is **explicit and
//! caller-driven** — nothing in the train drivers auto-resets, because
//! concurrently running tests share the slots and an implicit reset
//! would race their deltas. [`reset`] zeroes the slots (bench
//! hygiene); [`epoch`] additionally preserves the closing totals as
//! per-rank counters in the [`crate::obs::metrics`] registry
//! (`traffic.<label>.r<N>`), which is how the elastic driver keeps
//! per-generation byte totals. Independently of epochs, every byte
//! that lands in a slot also lands on the process-lifetime
//! `traffic.bytes_sent` registry counter, which no reset touches.

use super::pending::OpBytes;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Number of per-rank counter slots; ranks at or above this fold into
/// the last slot (worlds that large are far beyond the tracked range).
pub const MAX_TRACKED_RANKS: usize = 64;

fn slots() -> &'static [AtomicU64] {
    static SLOTS: OnceLock<Vec<AtomicU64>> = OnceLock::new();
    SLOTS.get_or_init(|| (0..MAX_TRACKED_RANKS).map(|_| AtomicU64::new(0)).collect())
}

/// The process-lifetime registry twin of the slots: monotone across
/// [`reset`] / [`epoch`] calls (the registry lookup is cached here so
/// the hot path pays one extra relaxed add, nothing more).
fn lifetime_counter() -> &'static crate::obs::metrics::Counter {
    static C: OnceLock<&'static crate::obs::metrics::Counter> = OnceLock::new();
    C.get_or_init(|| crate::obs::metrics::counter("traffic.bytes_sent"))
}

/// The engine-thread op context: bytes recorded while set go to the op's
/// counter and are merged into `rank`'s global slot at [`op_end`].
struct OpCtx {
    rank: usize,
    op: Arc<dyn OpBytes>,
    total: u64,
}

thread_local! {
    static OP_CTX: RefCell<Option<OpCtx>> = const { RefCell::new(None) };
}

/// Enter per-op accounting on this (engine) thread: subsequent
/// [`record_sent`] calls accumulate on `op` until [`op_end`].
pub(crate) fn op_begin(rank: usize, op: Arc<dyn OpBytes>) {
    OP_CTX.with(|c| {
        let prev = c.borrow_mut().replace(OpCtx { rank, op, total: 0 });
        debug_assert!(prev.is_none(), "traffic: nested op contexts");
    });
}

/// Leave per-op accounting and merge the op's bytes into its rank's
/// global slot (one atomic increment per completed op).
pub(crate) fn op_end() {
    OP_CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().take() {
            if ctx.total > 0 {
                slots()[ctx.rank.min(MAX_TRACKED_RANKS - 1)]
                    .fetch_add(ctx.total, Ordering::Relaxed);
                lifetime_counter().add(ctx.total);
            }
        }
    });
}

/// Record `bytes` of collective payload frames sent by `rank`: onto the
/// current op's counter inside an engine op, directly onto the global
/// slot otherwise (blocking inline collectives).
pub(crate) fn record_sent(rank: usize, bytes: u64) {
    let deferred = OP_CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            debug_assert_eq!(ctx.rank, rank, "traffic: op recorded a foreign rank");
            ctx.op.add(bytes);
            ctx.total += bytes;
            true
        } else {
            false
        }
    });
    if !deferred {
        slots()[rank.min(MAX_TRACKED_RANKS - 1)].fetch_add(bytes, Ordering::Relaxed);
        lifetime_counter().add(bytes);
    }
}

/// Zero every per-rank counter (bench hygiene between measured runs).
pub fn reset() {
    for s in slots() {
        s.store(0, Ordering::Relaxed);
    }
}

/// Bytes sent per rank, for ranks `0..world` (clamped to the tracked
/// range). Relaxed snapshots that move in whole-op increments: call when
/// no collective is in flight for exact totals.
pub fn sent_by_rank(world: usize) -> Vec<u64> {
    (0..world.min(MAX_TRACKED_RANKS)).map(|r| slots()[r].load(Ordering::Relaxed)).collect()
}

/// Total bytes sent across all ranks since the last [`reset`] or
/// [`epoch`].
pub fn total_sent() -> u64 {
    slots().iter().map(|s| s.load(Ordering::Relaxed)).sum()
}

/// Close the current traffic epoch: atomically drain every per-rank
/// slot, preserve each nonzero closing total as a
/// `traffic.<label>.r<N>` counter in the [`crate::obs::metrics`]
/// registry, and return the drained grand total. The elastic driver
/// calls this at every generation boundary (`label = "genG"`), which
/// both exposes per-generation byte totals through the registry and
/// keeps generation totals from accumulating into each other. Call
/// only when no collective is in flight (in-flight op bytes merge at
/// op completion and land in the *next* epoch).
pub fn epoch(label: &str) -> u64 {
    let mut total = 0u64;
    for (r, slot) in slots().iter().enumerate() {
        let v = slot.swap(0, Ordering::Relaxed);
        if v > 0 {
            crate::obs::metrics::counter(&format!("traffic.{label}.r{r}")).add(v);
            total += v;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard};

    // The epoch test drains the process-global slots, which would race
    // the delta asserts of its sibling tests; everything in this module
    // serializes here. (Concurrent tests in *other* modules only ever
    // add, which the `>=` deltas tolerate.)
    fn slots_lock() -> MutexGuard<'static, ()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn record_accumulates_and_folds_out_of_range_ranks() {
        let _g = slots_lock();
        // Other dist tests may record concurrently, so assert deltas on
        // our own contributions only (the counters are monotone between
        // resets).
        let before = sent_by_rank(MAX_TRACKED_RANKS);
        record_sent(1, 100);
        record_sent(1, 50);
        record_sent(MAX_TRACKED_RANKS + 7, 8); // folds into the last slot
        let after = sent_by_rank(MAX_TRACKED_RANKS);
        assert!(after[1] - before[1] >= 150);
        assert!(after[MAX_TRACKED_RANKS - 1] - before[MAX_TRACKED_RANKS - 1] >= 8);
        assert!(total_sent() >= after.iter().sum::<u64>() - before.iter().sum::<u64>());
    }

    #[test]
    fn op_context_defers_bytes_until_op_end() {
        struct Probe(AtomicU64);
        impl OpBytes for Probe {
            fn add(&self, b: u64) -> u64 {
                self.0.fetch_add(b, Ordering::Relaxed) + b
            }
        }
        let _g = slots_lock();
        let probe = Arc::new(Probe(AtomicU64::new(0)));
        let before = sent_by_rank(4);
        op_begin(3, Arc::clone(&probe) as Arc<dyn OpBytes>);
        record_sent(3, 500);
        record_sent(3, 11);
        // Mid-op: the op counter sees the bytes, the global slot does not
        // (concurrent tests only ever *add*, and nothing else records for
        // an op context on this thread).
        assert_eq!(probe.0.load(Ordering::Relaxed), 511);
        op_end();
        let after = sent_by_rank(4);
        assert!(after[3] - before[3] >= 511, "merge at op_end must land on rank 3");
    }

    #[test]
    fn epoch_drains_slots_into_labeled_registry_counters() {
        let _g = slots_lock();
        let life_before = crate::obs::metrics::counter("traffic.bytes_sent").get();
        record_sent(0, 40);
        record_sent(2, 60);
        let drained = epoch("test_epoch");
        assert!(drained >= 100, "epoch must return at least our contribution");
        // Slots start the next epoch from zero (nothing else records
        // while we hold the lock... other *modules* may, so only check
        // the slots we own stayed drained or small).
        let c0 = crate::obs::metrics::counter("traffic.test_epoch.r0").get();
        let c2 = crate::obs::metrics::counter("traffic.test_epoch.r2").get();
        assert!(c0 >= 40, "per-rank closing total must reach the registry (r0: {c0})");
        assert!(c2 >= 60, "per-rank closing total must reach the registry (r2: {c2})");
        // The lifetime counter is reset-proof: it kept the bytes too.
        let life_after = crate::obs::metrics::counter("traffic.bytes_sent").get();
        assert!(life_after - life_before >= 100);
    }
}
