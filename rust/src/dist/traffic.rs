//! Per-rank bytes-on-wire accounting for the collective layer.
//!
//! Every collective payload frame a rank sends is recorded here —
//! actual frame bytes on the socket transport, the identical modeled
//! frame bytes on the local transport (which moves pointers, not bytes,
//! but would put exactly these frames on a wire). The counters are what
//! `benches/dist_scaling.rs` reads to compare the star exchange's rank-0
//! fan-in (`~(R−1)·R·N` sent by rank 0 per all-reduce) against the ring
//! schedule's balanced `~2·(R−1)/R·N` per rank.
//!
//! Counters are process-wide atomics: under the local transport all
//! ranks live in one process and each increments its own slot; under the
//! socket transport each OS process tracks the one rank it hosts.
//! Handshake and goodbye frames are *not* counted — only collective
//! payload traffic, so the numbers are a pure function of the algorithm
//! and payload sizes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Number of per-rank counter slots; ranks at or above this fold into
/// the last slot (worlds that large are far beyond the tracked range).
pub const MAX_TRACKED_RANKS: usize = 64;

fn slots() -> &'static [AtomicU64] {
    static SLOTS: OnceLock<Vec<AtomicU64>> = OnceLock::new();
    SLOTS.get_or_init(|| (0..MAX_TRACKED_RANKS).map(|_| AtomicU64::new(0)).collect())
}

/// Record `bytes` of collective payload frames sent by `rank`.
pub(crate) fn record_sent(rank: usize, bytes: u64) {
    slots()[rank.min(MAX_TRACKED_RANKS - 1)].fetch_add(bytes, Ordering::Relaxed);
}

/// Zero every per-rank counter (bench hygiene between measured runs).
pub fn reset() {
    for s in slots() {
        s.store(0, Ordering::Relaxed);
    }
}

/// Bytes sent per rank, for ranks `0..world` (clamped to the tracked
/// range). Relaxed snapshots: call when no collective is in flight.
pub fn sent_by_rank(world: usize) -> Vec<u64> {
    (0..world.min(MAX_TRACKED_RANKS)).map(|r| slots()[r].load(Ordering::Relaxed)).collect()
}

/// Total bytes sent across all ranks since the last [`reset`].
pub fn total_sent() -> u64 {
    slots().iter().map(|s| s.load(Ordering::Relaxed)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_and_folds_out_of_range_ranks() {
        // Other dist tests may record concurrently, so assert deltas on
        // our own contributions only (the counters are monotone between
        // resets).
        let before = sent_by_rank(MAX_TRACKED_RANKS);
        record_sent(1, 100);
        record_sent(1, 50);
        record_sent(MAX_TRACKED_RANKS + 7, 8); // folds into the last slot
        let after = sent_by_rank(MAX_TRACKED_RANKS);
        assert!(after[1] - before[1] >= 150);
        assert!(after[MAX_TRACKED_RANKS - 1] - before[MAX_TRACKED_RANKS - 1] >= 8);
        assert!(total_sent() >= after.iter().sum::<u64>() - before.iter().sum::<u64>());
    }
}
