//! ZeRO-style layer sharding: which rank owns which layer's Kronecker
//! factors.
//!
//! Layer-wise decomposition is the natural parallel axis for
//! Kronecker-factored methods: each layer's `(K, C)` pair (or `(S_K,
//! S_C)` for KFAC) is refreshed and applied independently, so ownership
//! can be distributed with no cross-layer communication. Under
//! [`crate::dist::DistStrategy::FactorSharded`], rank `r` allocates and
//! updates only its owned layers' factors and momenta — per-rank factor
//! memory drops by roughly the world size — and only the preconditioned
//! *updates* are exchanged (zero-padded bucketed all-reduce, exact by
//! construction).
//!
//! Two deterministic assignments are provided: the round-robin map used
//! by the optimizers (a pure function of `(layer, world)`, so driver and
//! optimizer never disagree), and a cost-balanced plan for telemetry and
//! future schedulers.

/// The canonical ownership map shared by optimizers and the training
/// driver: layer `l` belongs to rank `l mod world`.
pub fn round_robin_owner(layer: usize, world: usize) -> usize {
    layer % world.max(1)
}

/// The canonical contiguous row-shard plan, shared by the training
/// driver's batch split, [`crate::dist::collectives::reduce_scatter_rows`],
/// and the ring collectives' chunk schedule (chunk `c` of a ring
/// all-reduce is `row_shard_range(len, world, c)` of the flattened
/// payload, so the schedule is a pure function of `(len, world)`).
///
/// This is the *padding rule* for world sizes that do not divide the row
/// count: the first `rows mod world` ranks take `⌈rows/world⌉` rows, the
/// rest `⌊rows/world⌋` — equivalently, pad the trailing shards up to the
/// ceiling block and drop the padding, so shard heights differ by at
/// most one and concatenated ranges cover `0..rows` exactly. When
/// `world` divides `rows` every shard is `rows/world`, which is the
/// alignment the bitwise rank-invariance contract builds on; a shard is
/// empty only when `rows < world`.
///
/// The zero-row edge (`rows < world`, which every ring collective now
/// exercises per chunk): `q = 0`, `rem = rows`, so rank `r` gets
/// `min(r, rows)..min(r, rows) + (r < rows)` — the first `rows` ranks
/// take one row each, the rest take the empty range starting exactly at
/// `rows`. Coverage and balance hold with no off-by-one; the
/// `tiny_row_counts_*` regression tests below pin this for world ∈
/// {3, 5, 7}.
pub fn row_shard_range(rows: usize, world: usize, rank: usize) -> std::ops::Range<usize> {
    let world = world.max(1);
    assert!(rank < world, "row_shard_range: rank {rank} out of range for world {world}");
    let q = rows / world;
    let rem = rows % world;
    let start = rank * q + rank.min(rem);
    let end = start + q + usize::from(rank < rem);
    start..end
}

/// A materialized layer→rank assignment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    owner: Vec<usize>,
    world: usize,
}

impl ShardPlan {
    /// The round-robin plan ([`round_robin_owner`]).
    pub fn round_robin(n_layers: usize, world: usize) -> ShardPlan {
        let world = world.max(1);
        ShardPlan { owner: (0..n_layers).map(|l| round_robin_owner(l, world)).collect(), world }
    }

    /// Greedy longest-processing-time balancing: layers are assigned in
    /// decreasing cost order to the least-loaded rank (ties broken by
    /// rank index, then by layer index — fully deterministic).
    pub fn balanced(costs: &[usize], world: usize) -> ShardPlan {
        let world = world.max(1);
        let mut order: Vec<usize> = (0..costs.len()).collect();
        order.sort_by_key(|&l| (std::cmp::Reverse(costs[l]), l));
        let mut load = vec![0usize; world];
        let mut owner = vec![0usize; costs.len()];
        for l in order {
            let r = (0..world).min_by_key(|&r| (load[r], r)).unwrap();
            owner[l] = r;
            load[r] += costs[l];
        }
        ShardPlan { owner, world }
    }

    /// World size this plan was built for.
    pub fn world(&self) -> usize {
        self.world
    }

    /// Number of layers covered by the plan.
    pub fn n_layers(&self) -> usize {
        self.owner.len()
    }

    /// The rank that owns `layer`.
    pub fn owner(&self, layer: usize) -> usize {
        self.owner[layer]
    }

    /// Whether `rank` owns `layer`.
    pub fn owns(&self, rank: usize, layer: usize) -> bool {
        self.owner[layer] == rank
    }

    /// Layers owned by `rank`, ascending.
    pub fn owned(&self, rank: usize) -> Vec<usize> {
        (0..self.owner.len()).filter(|&l| self.owner[l] == rank).collect()
    }

    /// Total cost assigned to `rank`.
    pub fn load(&self, costs: &[usize], rank: usize) -> usize {
        (0..self.owner.len()).filter(|&l| self.owner[l] == rank).map(|l| costs[l]).sum()
    }
}

/// Deal a *canonical* optimizer-state snapshot (serial layout: layers
/// ascending, `blobs_per_layer` consecutive blobs each) to one rank of a
/// `world`-sized factor-sharded topology: the returned blobs are exactly
/// what `rank`'s optimizer ([`round_robin_owner`]-owned layers
/// ascending) expects from
/// [`crate::optim::Optimizer::load_state_vectors`]. This is the
/// resharding primitive of the elastic driver — a checkpoint written at
/// world R re-deals losslessly to any R′ because the canonical layout is
/// world-independent.
pub fn deal_state(
    canonical: &[Vec<f32>],
    blobs_per_layer: usize,
    world: usize,
    rank: usize,
) -> Vec<Vec<f32>> {
    if blobs_per_layer == 0 {
        return Vec::new();
    }
    assert_eq!(
        canonical.len() % blobs_per_layer,
        0,
        "deal_state: {} blobs not divisible by {blobs_per_layer} per layer",
        canonical.len()
    );
    let n_layers = canonical.len() / blobs_per_layer;
    (0..n_layers)
        .filter(|&l| round_robin_owner(l, world) == rank)
        .flat_map(|l| {
            canonical[l * blobs_per_layer..(l + 1) * blobs_per_layer].iter().cloned()
        })
        .collect()
}

/// Inverse of [`deal_state`]: merge every rank's owned-layer blobs
/// (`per_rank[r]` = rank `r`'s [`crate::optim::Optimizer::state_vectors`]
/// snapshot under the factor-sharded strategy) back into the canonical
/// serial layout. The gather side of a world-R checkpoint save.
pub fn merge_state(
    per_rank: &[Vec<Vec<f32>>],
    blobs_per_layer: usize,
    n_layers: usize,
) -> Vec<Vec<f32>> {
    let world = per_rank.len().max(1);
    if blobs_per_layer == 0 {
        return Vec::new();
    }
    let mut cursor = vec![0usize; world];
    let mut out = Vec::with_capacity(n_layers * blobs_per_layer);
    for l in 0..n_layers {
        let r = round_robin_owner(l, world);
        let at = cursor[r];
        assert!(
            at + blobs_per_layer <= per_rank[r].len(),
            "merge_state: rank {r} ran out of blobs at layer {l}"
        );
        out.extend(per_rank[r][at..at + blobs_per_layer].iter().cloned());
        cursor[r] = at + blobs_per_layer;
    }
    for (r, &c) in cursor.iter().enumerate() {
        assert_eq!(
            c,
            per_rank[r].len(),
            "merge_state: rank {r} had {} unconsumed blobs",
            per_rank[r].len() - c
        );
    }
    out
}

/// Per-layer dense Kronecker-factor element count `d_i² + d_o²` for
/// layer shapes `(d_o, d_i)` — the cost model for balanced sharding and
/// the per-rank memory telemetry of `benches/dist_scaling.rs`.
pub fn factor_cost(shapes: &[(usize, usize)]) -> Vec<usize> {
    shapes.iter().map(|&(o, i)| i * i + o * o).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_covers_all_ranks_evenly() {
        let p = ShardPlan::round_robin(8, 4);
        for r in 0..4 {
            assert_eq!(p.owned(r), vec![r, r + 4]);
        }
        assert!(p.owns(1, 5));
        assert!(!p.owns(1, 4));
    }

    #[test]
    fn round_robin_world1_owns_everything() {
        let p = ShardPlan::round_robin(5, 1);
        assert_eq!(p.owned(0), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn balanced_beats_round_robin_on_skewed_costs() {
        // One huge layer plus many small ones: round-robin piles the big
        // layer onto rank 0 together with others; LPT isolates it.
        let costs = [1000usize, 10, 10, 10, 10, 10, 10, 10];
        let rr = ShardPlan::round_robin(costs.len(), 4);
        let bal = ShardPlan::balanced(&costs, 4);
        let max_rr = (0..4).map(|r| rr.load(&costs, r)).max().unwrap();
        let max_bal = (0..4).map(|r| bal.load(&costs, r)).max().unwrap();
        assert!(max_bal <= max_rr);
        assert_eq!(max_bal, 1000, "LPT must isolate the dominant layer");
        // Deterministic.
        assert_eq!(bal, ShardPlan::balanced(&costs, 4));
    }

    #[test]
    fn deal_then_merge_is_identity_for_every_world() {
        // Canonical snapshot for 7 layers × 3 blobs each, values tagged
        // (layer, blob) so any mis-deal is visible.
        let bpl = 3usize;
        let n_layers = 7usize;
        let canonical: Vec<Vec<f32>> = (0..n_layers)
            .flat_map(|l| (0..bpl).map(move |b| vec![l as f32, b as f32, (l * bpl + b) as f32]))
            .collect();
        for world in 1..=5usize {
            let per_rank: Vec<Vec<Vec<f32>>> =
                (0..world).map(|r| deal_state(&canonical, bpl, world, r)).collect();
            // Each rank got exactly its owned layers' blobs, ascending.
            for (r, blobs) in per_rank.iter().enumerate() {
                let owned: Vec<usize> =
                    (0..n_layers).filter(|&l| round_robin_owner(l, world) == r).collect();
                assert_eq!(blobs.len(), owned.len() * bpl, "world {world} rank {r}");
                for (i, &l) in owned.iter().enumerate() {
                    assert_eq!(blobs[i * bpl][0], l as f32, "world {world} rank {r}");
                }
            }
            assert_eq!(
                merge_state(&per_rank, bpl, n_layers),
                canonical,
                "world {world}: deal∘merge must be identity"
            );
        }
        // Zero blobs per layer (stateless optimizer) is a no-op.
        assert!(deal_state(&canonical, 0, 4, 0).is_empty());
        assert!(merge_state(&[Vec::new(), Vec::new()], 0, n_layers).is_empty());
    }

    #[test]
    fn reshard_across_worlds_preserves_canonical_layout() {
        // The elastic R → R′ path: merge at world 4, re-deal at world 3,
        // merge again — canonical snapshot unchanged.
        let bpl = 5usize;
        let n_layers = 4usize;
        let canonical: Vec<Vec<f32>> =
            (0..n_layers * bpl).map(|i| vec![i as f32; 2 + i % 3]).collect();
        let at4: Vec<Vec<Vec<f32>>> =
            (0..4).map(|r| deal_state(&canonical, bpl, 4, r)).collect();
        let merged = merge_state(&at4, bpl, n_layers);
        let at3: Vec<Vec<Vec<f32>>> = (0..3).map(|r| deal_state(&merged, bpl, 3, r)).collect();
        assert_eq!(merge_state(&at3, bpl, n_layers), canonical);
    }

    #[test]
    fn factor_cost_is_quadratic_in_dims() {
        assert_eq!(factor_cost(&[(4, 8), (2, 2)]), vec![8 * 8 + 4 * 4, 2 * 2 + 2 * 2]);
    }

    #[test]
    fn row_shard_ranges_cover_and_balance() {
        for (rows, world) in [(32usize, 4usize), (33, 4), (7, 4), (8, 3), (1, 4), (0, 3), (5, 1)] {
            let mut next = 0usize;
            let mut sizes = Vec::new();
            for r in 0..world {
                let rg = row_shard_range(rows, world, r);
                assert_eq!(rg.start, next, "rows {rows} world {world} rank {r}");
                assert!(rg.end >= rg.start);
                sizes.push(rg.len());
                next = rg.end;
            }
            assert_eq!(next, rows, "rows {rows} world {world}: coverage");
            let (lo, hi) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "rows {rows} world {world}: balance {sizes:?}");
        }
    }

    #[test]
    fn row_shard_divisible_case_is_equal_blocks() {
        for r in 0..4 {
            assert_eq!(row_shard_range(32, 4, r), r * 8..(r + 1) * 8);
        }
        // Non-divisible: first `rem` ranks absorb the remainder.
        assert_eq!(row_shard_range(10, 4, 0), 0..3);
        assert_eq!(row_shard_range(10, 4, 1), 3..6);
        assert_eq!(row_shard_range(10, 4, 2), 6..8);
        assert_eq!(row_shard_range(10, 4, 3), 8..10);
        // Fewer rows than ranks: trailing shards are empty.
        assert_eq!(row_shard_range(1, 4, 0), 0..1);
        assert!(row_shard_range(1, 4, 3).is_empty());
    }

    #[test]
    fn tiny_row_counts_cover_exactly_for_odd_worlds() {
        // The zero-row-rank edge the ring collectives exercise per
        // chunk: every (rows < world) combination must cover 0..rows
        // contiguously, hand one row each to the first `rows` ranks, and
        // start every empty trailing shard exactly at `rows`.
        for world in [3usize, 5, 7] {
            for rows in 0..world {
                let mut next = 0usize;
                for r in 0..world {
                    let rg = row_shard_range(rows, world, r);
                    assert_eq!(rg.start, next, "rows {rows} world {world} rank {r}: start");
                    assert_eq!(
                        rg.len(),
                        usize::from(r < rows),
                        "rows {rows} world {world} rank {r}: len"
                    );
                    if rg.is_empty() {
                        assert_eq!(rg.start, rows, "empty shard must start at rows");
                    }
                    next = rg.end;
                }
                assert_eq!(next, rows, "rows {rows} world {world}: coverage");
            }
        }
    }

    #[test]
    fn tiny_row_counts_just_above_world_stay_balanced() {
        // rows slightly above world (world + 1 .. world + 2): heights
        // differ by at most one and the remainder lands on the leading
        // ranks.
        for world in [3usize, 5, 7] {
            for extra in 1..=2usize {
                let rows = world + extra;
                let mut next = 0usize;
                for r in 0..world {
                    let rg = row_shard_range(rows, world, r);
                    assert_eq!(rg.start, next, "rows {rows} world {world} rank {r}");
                    let want = 1 + usize::from(r < extra);
                    assert_eq!(rg.len(), want, "rows {rows} world {world} rank {r}: len");
                    next = rg.end;
                }
                assert_eq!(next, rows);
            }
        }
    }
}
