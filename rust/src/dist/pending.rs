//! Nonblocking collective handles: [`PendingOp`] results serviced by a
//! per-communicator FIFO progress engine.
//!
//! # The model
//!
//! Every `istart_*` method on [`crate::dist::Communicator`] captures its
//! inputs, enqueues the operation on the communicator's progress engine,
//! and returns a [`PendingOp`] immediately; the caller overlaps its own
//! compute with the transfer and blocks only at [`PendingOp::wait`] — the
//! true data dependency. The engine is one dedicated thread per
//! communicator (spawned lazily on the first `istart`, via
//! [`crate::tensor::pool::spawn_blocking`]; the shared worker pool is
//! unsuitable because collective progress blocks on peers, and a blocked
//! progress job queued behind a busy worker would deadlock the world).
//!
//! # Why overlap cannot change results
//!
//! The engine executes operations **in issue order**, one at a time. The
//! issue sequence is part of the SPMD program, so it is identical on
//! every rank; therefore the per-link wire order under overlap is exactly
//! the wire order of the blocking schedule, and the destination reduction
//! trees are untouched. Overlap reorders *time*, never *reduction order*
//! — the fourth determinism contract (`ARCHITECTURE.md §Contract 4`),
//! enforced by the `SINGD_OVERLAP ∈ {0,1}` digest suites in
//! `rust/tests/dist.rs` and `rust/tests/dist_proc.rs`. For the same
//! reason, once a communicator's engine is active its *blocking*
//! collectives are reimplemented as `istart + wait` (routed through the
//! same queue): a blocking call issued between two pending ops must take
//! its place in the issue order, not race the engine for the transport.
//!
//! # Failure semantics
//!
//! A panic inside an operation (peer death, severed socket, poisoned
//! rendezvous, SPMD violation) is caught on the engine thread, recorded,
//! and re-raised from [`PendingOp::wait`] on the issuing thread; the
//! engine is then poisoned, so later `istart`s fail fast instead of
//! queueing doomed work. Dropping a [`PendingOp`] without waiting
//! *detaches* it: the operation still executes (its peers depend on it —
//! skipping it would be an SPMD call-order violation), its result is
//! discarded, and a failure surfaces through the engine poison instead of
//! a panic. Dropping the communicator drains every queued operation
//! before the transport shuts down.
//!
//! # Traffic attribution
//!
//! Bytes sent while an operation executes accumulate on a per-op counter
//! ([`PendingOp::bytes_sent`]) and are merged into the global per-rank
//! counters of [`crate::dist::traffic`] when the operation completes, so
//! concurrently in-flight ops attribute bytes-on-wire atomically — a
//! snapshot never observes a half-accounted collective.
//!
//! # Observability
//!
//! When a trace session is armed ([`crate::obs::trace`]), each op's
//! lifecycle is journaled: an `op_issue` instant on the issuing thread,
//! an `op_exec` span (category `comm`, with final byte count) on the
//! engine thread, and an `op_wait` span (category `wait`) around
//! [`PendingOp::wait`], all correlated by a per-process op id. Disabled,
//! each hook is one relaxed atomic load; the id is only ever assigned
//! under an armed session, so the hot path is untouched — and nothing
//! here feeds back into execution (non-interference).

use crate::dist::traffic;
use crate::obs::trace;
use crate::tensor::pool;
use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// A queued unit of engine work (type-erased; the closure owns its
/// inputs and its result slot).
///
/// Re-entrancy is avoided *structurally*, not by thread checks: engine
/// jobs run collectives over a communicator's inline core (whose
/// `istart_*` methods execute immediately and return
/// [`PendingOp::ready`]), never over the engine-backed wrapper — so a
/// job can never enqueue on the engine that is executing it.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Completion slot of one pending operation.
enum Slot<T> {
    /// Still queued or executing.
    Pending,
    /// Finished; result ready for [`PendingOp::wait`].
    Done(T),
    /// The operation panicked; the payload re-raises at `wait`.
    Panicked(Box<dyn Any + Send>),
    /// Result already consumed by `wait`.
    Taken,
}

struct Shared<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
    /// Payload-frame bytes this op put on the wire (final once complete).
    bytes: AtomicU64,
    /// Trace correlation id (0 = untraced; assigned at submit only when
    /// a trace session is armed).
    op_id: AtomicU64,
}

/// Handle to a nonblocking collective in flight: poll with
/// [`PendingOp::poll`], block with [`PendingOp::wait`] (which re-raises
/// any failure of the operation), or drop to detach (the operation still
/// executes — see the module docs for the exact semantics).
pub struct PendingOp<T> {
    shared: Arc<Shared<T>>,
}

impl<T> PendingOp<T> {
    /// An already-completed handle. Used for world-size-1 short circuits
    /// and by inline transports whose `istart_*` has nothing to defer;
    /// also the constructor an external [`crate::dist::Communicator`]
    /// backend without a progress engine would use.
    pub fn ready(value: T) -> PendingOp<T> {
        PendingOp {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot::Done(value)),
                cv: Condvar::new(),
                bytes: AtomicU64::new(0),
                op_id: AtomicU64::new(0),
            }),
        }
    }

    fn fresh() -> (PendingOp<T>, Arc<Shared<T>>) {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot::Pending),
            cv: Condvar::new(),
            bytes: AtomicU64::new(0),
            op_id: AtomicU64::new(0),
        });
        (PendingOp { shared: Arc::clone(&shared) }, shared)
    }

    /// Whether the operation has completed (successfully or not).
    /// Nonblocking; `wait` will not block once this returns true.
    pub fn poll(&self) -> bool {
        !matches!(
            *self.shared.slot.lock().unwrap_or_else(|e| e.into_inner()),
            Slot::Pending
        )
    }

    /// Block until the operation completes, without consuming the handle
    /// or re-raising failures (those surface at [`PendingOp::wait`]).
    /// After `join`, [`PendingOp::bytes_sent`] is final.
    pub fn join(&self) {
        let mut slot = self.shared.slot.lock().unwrap_or_else(|e| e.into_inner());
        while matches!(*slot, Slot::Pending) {
            slot = self.shared.cv.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Payload-frame bytes this operation has sent so far (final once the
    /// op completes — the per-op counter the traffic accounting merges
    /// into the global per-rank totals at completion).
    pub fn bytes_sent(&self) -> u64 {
        self.shared.bytes.load(Ordering::Relaxed)
    }

    /// Block until the operation completes and return its result. If the
    /// operation panicked (peer death, severed link, SPMD violation), the
    /// panic is re-raised here — on the issuing thread — so failures of
    /// in-flight ops propagate exactly like failures of blocking
    /// collectives.
    pub fn wait(self) -> T {
        let mut sp = trace::span("op_wait", "wait");
        if sp.is_recording() {
            sp.arg("op", trace::ArgVal::U(self.shared.op_id.load(Ordering::Relaxed)));
        }
        let mut slot = self.shared.slot.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Pending => {
                    *slot = Slot::Pending;
                    slot = self.shared.cv.wait(slot).unwrap_or_else(|e| e.into_inner());
                }
                Slot::Done(v) => return v,
                Slot::Panicked(p) => {
                    drop(slot);
                    resume_unwind(p);
                }
                Slot::Taken => unreachable!("PendingOp::wait consumed twice"),
            }
        }
    }
}

/// A communicator's progress engine: one dedicated thread draining a
/// FIFO of operation closures. Created lazily on the first `istart`;
/// dropping it closes the queue, drains every remaining operation, and
/// joins the thread — so a communicator never shuts its transport down
/// under an op still in flight.
pub(crate) struct Engine {
    tx: Option<Sender<Job>>,
    join: Option<std::thread::JoinHandle<()>>,
    poisoned: Arc<AtomicBool>,
}

impl Engine {
    /// Spawn the progress thread (named for debuggability).
    pub(crate) fn new(name: &str) -> Engine {
        let (tx, rx) = channel::<Job>();
        let join = pool::spawn_blocking(name, move || {
            // Jobs wrap their body in catch_unwind, so this loop never
            // unwinds; it ends when the sender side is dropped.
            while let Ok(job) = rx.recv() {
                job();
            }
        });
        Engine { tx: Some(tx), join: Some(join), poisoned: Arc::new(AtomicBool::new(false)) }
    }

    /// Enqueue `f` as the next operation in issue order; returns its
    /// handle. `rank` attributes the op's wire bytes. Panics if an
    /// earlier operation on this engine failed (the world is poisoned —
    /// queueing more work could only deadlock or mislead).
    pub(crate) fn submit<T, F>(&self, rank: usize, f: F) -> PendingOp<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        assert!(
            !self.poisoned.load(Ordering::SeqCst),
            "dist: an earlier nonblocking collective on this communicator failed"
        );
        let (op, shared) = PendingOp::fresh();
        if trace::active() {
            static NEXT_OP_ID: AtomicU64 = AtomicU64::new(1);
            let id = NEXT_OP_ID.fetch_add(1, Ordering::Relaxed);
            shared.op_id.store(id, Ordering::Relaxed);
            trace::instant_rank("op_issue", "comm", rank, vec![("op", trace::ArgVal::U(id))]);
        }
        let poisoned = Arc::clone(&self.poisoned);
        let job: Job = Box::new(move || {
            traffic::op_begin(rank, Arc::clone(&shared));
            let mut sp = trace::span_rank("op_exec", "comm", rank);
            let out = catch_unwind(AssertUnwindSafe(f));
            traffic::op_end();
            if sp.is_recording() {
                sp.arg("op", trace::ArgVal::U(shared.op_id.load(Ordering::Relaxed)));
                sp.arg("bytes", trace::ArgVal::U(shared.bytes.load(Ordering::Relaxed)));
            }
            drop(sp);
            let mut slot = shared.slot.lock().unwrap_or_else(|e| e.into_inner());
            *slot = match out {
                Ok(v) => Slot::Done(v),
                Err(p) => {
                    poisoned.store(true, Ordering::SeqCst);
                    Slot::Panicked(p)
                }
            };
            shared.cv.notify_all();
        });
        self.tx
            .as_ref()
            .expect("engine queue closed")
            .send(job)
            .expect("dist: progress engine thread died");
        op
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Close the queue; the thread drains every already-issued op
        // (peers depend on them) and exits. Join so the transport the
        // ops borrow provably outlives them.
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The per-op byte-counter hook [`crate::dist::traffic`] uses without
/// knowing `T`: just the atomic the engine job registered.
pub(crate) trait OpBytes: Send + Sync {
    /// Add `bytes` to the op's counter; returns the new total.
    fn add(&self, bytes: u64) -> u64;
}

impl<T: Send> OpBytes for Shared<T> {
    fn add(&self, bytes: u64) -> u64 {
        self.bytes.fetch_add(bytes, Ordering::Relaxed) + bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_op_polls_complete_and_waits_immediately() {
        let op = PendingOp::ready(42usize);
        assert!(op.poll());
        op.join();
        assert_eq!(op.bytes_sent(), 0);
        assert_eq!(op.wait(), 42);
    }

    #[test]
    fn engine_runs_ops_in_issue_order() {
        let engine = Engine::new("pending-test-fifo");
        let log = Arc::new(Mutex::new(Vec::new()));
        let ops: Vec<PendingOp<usize>> = (0..8)
            .map(|i| {
                let log = Arc::clone(&log);
                engine.submit(0, move || {
                    log.lock().unwrap().push(i);
                    i
                })
            })
            .collect();
        for (i, op) in ops.into_iter().enumerate() {
            assert_eq!(op.wait(), i);
        }
        assert_eq!(*log.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panicked_op_reraises_at_wait_and_poisons_engine() {
        let engine = Engine::new("pending-test-panic");
        let bad: PendingOp<()> = engine.submit(0, || panic!("injected op failure"));
        let err = catch_unwind(AssertUnwindSafe(|| bad.wait()));
        assert!(err.is_err(), "op panic must re-raise at wait()");
        let refused = catch_unwind(AssertUnwindSafe(|| {
            let _ = engine.submit(0, || ());
        }));
        assert!(refused.is_err(), "poisoned engine must refuse new ops");
    }

    #[test]
    fn dropped_op_still_executes_before_engine_shutdown() {
        let ran = Arc::new(AtomicBool::new(false));
        {
            let engine = Engine::new("pending-test-drop");
            let flag = Arc::clone(&ran);
            let op = engine.submit(0, move || flag.store(true, Ordering::SeqCst));
            drop(op); // detach: the op must still run
        } // engine drop drains the queue
        assert!(ran.load(Ordering::SeqCst), "detached op must execute");
    }
}
