//! Multi-process socket transport behind [`Communicator`].
//!
//! [`SocketComm`] runs the same SPMD exchange primitive as [`LocalComm`]
//! over Unix-domain sockets (TCP fallback), so every collective in
//! [`crate::dist::collectives`] — and therefore the whole
//! [`crate::train::train_dist`] driver — routes over it unchanged. The
//! transport moves *bytes*, never floats: payloads are bit-exact f32/f64
//! little-endian images of the matrices each rank deposits, so the
//! determinism contract of [`crate::dist`] (tree-ordered reductions over
//! rank-indexed payloads) is transport-invariant by construction. The
//! cross-transport conformance suite in `rust/tests/dist.rs` asserts
//! bitwise equality against [`LocalComm`] for every collective.
//!
//! # Topology and wire format
//!
//! The full byte-level specification (every frame layout, handshake
//! field, and failure rule) lives in `PROTOCOL.md` at the repository
//! root; this is the summary.
//!
//! Rank 0 is the rendezvous server: it binds the rendezvous endpoint,
//! accepts `world − 1` connections, and validates a fixed-size hello
//! (magic, protocol version, run id, world size, rank) from each peer —
//! stale peers from a dead run (wrong run id), mis-sized worlds and
//! duplicate ranks are rejected at handshake time. After the star is up,
//! the world assembles a **full peer mesh** for point-to-point traffic:
//! every rank binds a mesh listener (a per-rank socket derived from the
//! rendezvous endpoint), the listener addresses are exchanged over the
//! star, and each rank dials every lower-ranked peer (a 20-byte mesh
//! hello carrying magic/run-id/rank identifies the dialer). The star
//! carries barrier exchanges; the mesh carries the ring collectives'
//! [`Communicator::send_recv_bytes`] steps. All frames share one layout:
//!
//! ```text
//! frame   := kind:u8 | seq:u64 | len:u64 | payload[len]      (LE)
//! mats    := count:u32 | (rows:u32 | cols:u32 | f32[rows*cols])*
//! wmats   := count:u32 | (rows:u32 | cols:u32 | u16[rows*cols])*  (half wire dtype)
//! f64s    := count:u32 | f64[count]
//! gathered:= count:u32 | (len:u64 | payload[len])*           (rank order)
//! chunk   := f32[len/4] | bf16[len/2] | fp16[len/2]          (ring chunks, wire dtype)
//! ```
//!
//! `wmats` frames (`KIND_MATS_WIRE`) carry the compressed-collective
//! payloads of [`Communicator::exchange_mats_wire`]: element images at
//! the run's wire dtype ([`Communicator::wire_dtype`], pinned via
//! `SINGD_WIRE_DTYPE`), which the dispatchers pre-snap so the narrowing
//! encode is lossless. On the `f32` wire (the default) the exact `mats`
//! frames are used and nothing changes. Ring `chunk` payloads carry the
//! same wire-dtype element images; both sides derive the element width
//! from the run-level wire dtype, never from the frame.
//!
//! `seq` is the per-communicator exchange counter on star frames and the
//! per-direction link counter on mesh frames; together with `kind` it is
//! checked on every frame, so an SPMD call-order violation fails loudly
//! instead of decoding garbage.
//!
//! # Failure semantics
//!
//! The socket transport maps peer failure onto the same panic-poisoning
//! contract as [`LocalComm`]'s rendezvous: a rank that panics drops its
//! `SocketComm`, which closes its sockets; every peer blocked in a
//! collective then observes EOF (or a goodbye frame where a contribution
//! was due) and panics in turn, so failures propagate instead of
//! deadlocking the world. Clean shutdown sends a goodbye frame first,
//! letting peers distinguish "finished early (SPMD violation)" from
//! "died". `SINGD_SOCK_TIMEOUT_SECS` bounds rendezvous (and, when set,
//! per-read) waits.
//!
//! # Elastic rendezvous v2
//!
//! The panic-poisoning above is also the *detection* mechanism for the
//! elastic layer (PROTOCOL.md §Elastic rendezvous v2): an elastic driver
//! catches the poison panic, severs its own links so the failure
//! propagates, and re-rendezvouses into a new **generation** — a fresh
//! world at a generation-derived sibling endpoint with a
//! generation-mixed run id. Rank 0 owns membership as the
//! [`Coordinator`]: it answers [`status`] queries on a `<path>.ctrl`
//! control endpoint, parks [`join`] requests from new workers, and on
//! regroup collects survivor [`rejoin`] hellos at a `<path>.r<gen>`
//! membership endpoint, assigning the new world's ranks (coordinator
//! first, survivors by old rank, joiners last). Hellos are
//! generation-stamped, so a straggler from generation `g` can never slip
//! into generation `g+1`. Coordinator death remains fatal to the world.
//!
//! # The `SINGD_RANK` / `SINGD_WORLD` / `SINGD_RENDEZVOUS` contract
//!
//! A multi-process world is assembled torchrun-style by re-exec'ing the
//! current binary: [`launch_workers`] spawns ranks `1..world` with the
//! same argv plus `SINGD_RANK=<r>`, `SINGD_WORLD=<w>`,
//! `SINGD_RENDEZVOUS=<endpoint>` and `SINGD_RUN_ID=<id>` in the
//! environment, while the launching process itself becomes rank 0. A
//! worker detects its role with [`worker_env`] and joins the rendezvous
//! instead of spawning further workers.

use super::pending::Engine;
use super::{collectives, traffic, Algo, Communicator, PendingOp};
use crate::numerics::{Bf16, Dtype, Fp16};
use crate::tensor::Mat;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Which transport backs the [`Communicator`] of a distributed run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// In-process shared-memory rendezvous ([`LocalComm`]): ranks are
    /// threads of one process.
    Local,
    /// Multi-process socket transport ([`SocketComm`]): ranks are
    /// separate OS processes joined over a rendezvous endpoint.
    Socket,
}

impl Transport {
    /// Parse `"local"` / `"socket"` (aliases: `"inproc"`, `"uds"`).
    pub fn parse(s: &str) -> Option<Transport> {
        match s.to_ascii_lowercase().as_str() {
            "local" | "inproc" | "shm" => Some(Transport::Local),
            "socket" | "uds" | "sock" => Some(Transport::Socket),
            _ => None,
        }
    }

    /// Canonical name (the string [`Transport::parse`] round-trips).
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Local => "local",
            Transport::Socket => "socket",
        }
    }
}

/// Environment key: this process's rank in a multi-process world.
pub const ENV_RANK: &str = "SINGD_RANK";
/// Environment key: the multi-process world size.
pub const ENV_WORLD: &str = "SINGD_WORLD";
/// Environment key: the rendezvous endpoint (`unix:<path>` or
/// `tcp:<host>:<port>`; a bare string is a Unix path).
pub const ENV_RENDEZVOUS: &str = "SINGD_RENDEZVOUS";
/// Environment key: the run id tag peers must echo at handshake.
pub const ENV_RUN_ID: &str = "SINGD_RUN_ID";
/// Environment key: rendezvous deadline and (when set) per-read timeout
/// in seconds. Default: 30 s rendezvous deadline, no read timeout.
pub const ENV_TIMEOUT: &str = "SINGD_SOCK_TIMEOUT_SECS";

const MAGIC: u64 = 0x5349_4e47_4456_0001; // "SINGDV" tag + wire rev
/// Wire revision 2: the hello grew from 28 to 40 bytes (generation +
/// intent fields — PROTOCOL.md §Elastic rendezvous v2). A v1 peer's
/// short hello fails the 40-byte read or the version check and is
/// dropped at handshake, never mid-collective.
const PROTO_VERSION: u32 = 2;
/// Sanity bound on a single frame (guards a garbled length prefix from
/// triggering an absurd allocation).
const MAX_FRAME: u64 = 1 << 36;
/// Frame header size: `kind:u8 | seq:u64 | len:u64` (PROTOCOL.md §Framing).
/// Shared with the local transport's wire-byte model in
/// [`crate::dist::traffic`].
pub(crate) const FRAME_HEADER_BYTES: usize = 17;

const KIND_MATS: u8 = 1;
const KIND_F64: u8 = 2;
const KIND_GATHERED_MATS: u8 = 3;
const KIND_GATHERED_F64: u8 = 4;
const KIND_GOODBYE: u8 = 5;
/// Point-to-point mesh frame (ring chunks); `seq` is the per-direction
/// link counter.
const KIND_P2P: u8 = 6;
/// Mesh-listener address advertisement (rendezvous-time star exchange).
const KIND_MESH: u8 = 7;
const KIND_GATHERED_MESH: u8 = 8;
/// Wire-dtype matrix-list frame (`wmats` payload — PROTOCOL.md §Wire
/// dtype): element images at the run's half wire dtype. Gathered replies
/// reuse `KIND_GATHERED_MATS` (the blob entries are opaque bytes).
const KIND_MATS_WIRE: u8 = 9;

// Handshake status codes in the welcome reply.
const ST_OK: u32 = 0;
const ST_BAD_RUN_ID: u32 = 2;
const ST_BAD_WORLD: u32 = 3;
const ST_BAD_RANK: u32 = 4;
const ST_DUP_RANK: u32 = 5;
/// Generation mismatch: a straggler from a previous membership epoch
/// dialled a newer world (elastic rendezvous v2).
const ST_BAD_GEN: u32 = 6;

// Hello intents (elastic rendezvous v2). Data-plane rendezvous uses
// WORKER; the control endpoint serves STATUS and JOIN; the per-regroup
// membership endpoint serves REJOIN.
const INTENT_WORKER: u32 = 0;
const INTENT_STATUS: u32 = 1;
const INTENT_JOIN: u32 = 2;
const INTENT_REJOIN: u32 = 3;

/// Rank sentinel in a REJOIN hello: "new joiner, no previous rank".
const RANK_NONE: u32 = u32::MAX;
/// Generation sentinel in a control grant: "world finished, go away".
const GEN_DONE: u64 = u64::MAX;

fn status_msg(st: u32) -> &'static str {
    match st {
        ST_BAD_RUN_ID => "stale peer: run id does not match this world",
        ST_BAD_WORLD => "world size mismatch",
        ST_BAD_RANK => "rank out of range",
        ST_DUP_RANK => "duplicate rank",
        ST_BAD_GEN => "stale generation: membership epoch has moved on",
        _ => "unknown handshake failure",
    }
}

/// Rendezvous endpoint: `unix:<path>`, `tcp:<host>:<port>`, or a bare
/// Unix socket path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// A Unix-domain socket path.
    Unix(String),
    /// A TCP `host:port` address.
    Tcp(String),
}

impl Endpoint {
    /// Parse an endpoint string (a bare string is a Unix path).
    pub fn parse(s: &str) -> Endpoint {
        if let Some(rest) = s.strip_prefix("unix:") {
            Endpoint::Unix(rest.to_string())
        } else if let Some(rest) = s.strip_prefix("tcp:") {
            Endpoint::Tcp(rest.to_string())
        } else {
            Endpoint::Unix(s.to_string())
        }
    }
}

/// A connected stream of either family.
enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(d),
            Stream::Tcp(s) => s.set_read_timeout(d),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(nb),
            Stream::Tcp(s) => s.set_nonblocking(nb),
        }
    }

    fn shutdown(&self) {
        let _ = match self {
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }

    /// This is a frame-per-round-trip protocol (a ring step cannot
    /// proceed until its frame lands), so Nagle + delayed ACK would
    /// stall every step on TCP links; no-op for Unix sockets.
    fn set_nodelay(&self) {
        if let Stream::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(nb),
            Listener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }
}

/// Parse a `SINGD_SOCK_TIMEOUT_SECS` value: a positive whole second
/// count. Pure so it is unit-testable without mutating the process
/// environment (tests run concurrently).
pub(crate) fn parse_timeout_secs(raw: &str) -> Result<u64, String> {
    match raw.trim().parse::<u64>() {
        Ok(0) => Err(format!("malformed value '{raw}': must be >= 1 second")),
        Ok(v) => Ok(v),
        Err(_) => Err(format!("malformed value '{raw}' (expected whole seconds, e.g. '30')")),
    }
}

/// Parse a `SINGD_RANK`/`SINGD_WORLD`/`SINGD_RUN_ID`-style unsigned env
/// value. Pure for the same concurrent-test reason as
/// [`parse_timeout_secs`].
pub(crate) fn parse_env_u64(key: &str, raw: &str) -> Result<u64, String> {
    raw.trim()
        .parse::<u64>()
        .map_err(|_| format!("{key}: malformed value '{raw}' (expected a non-negative integer)"))
}

fn timeout_secs() -> Option<u64> {
    let raw = std::env::var(ENV_TIMEOUT).ok()?;
    match parse_timeout_secs(&raw) {
        Ok(v) => Some(v),
        // A malformed timeout silently falling back to "no timeout"
        // turns a typo into an unbounded hang; fail loudly instead.
        Err(e) => panic!("dist[socket]: {ENV_TIMEOUT}: {e}"),
    }
}

/// Deadline for assembling the world (accept/connect retries).
fn rendezvous_timeout() -> Duration {
    Duration::from_secs(timeout_secs().unwrap_or(30).max(1))
}

/// Per-read timeout on established links; `None` (the default) blocks
/// indefinitely — peer death is detected by EOF, hangs by the CI-level
/// test timeout.
fn read_timeout() -> Option<Duration> {
    timeout_secs().map(|s| Duration::from_secs(s.max(1)))
}

/// Attach context to an I/O error (which endpoint, which phase) so a
/// failed dial or bind names its cause instead of a bare `ECONNREFUSED`.
fn io_ctx(e: io::Error, what: &str) -> io::Error {
    io::Error::new(e.kind(), format!("{what}: {e}"))
}

/// SplitMix64: the jitter hash behind [`Backoff`]. Deterministic — no
/// wall-clock entropy anywhere in the transport (the cross-transport
/// conformance suite replays runs bit-exactly).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Bounded exponential backoff with deterministic per-rank jitter, used
/// by every dial loop (rendezvous, mesh, rejoin). The delay for attempt
/// `n` is drawn from `[cap/2, cap]` of `base << n` (clamped to
/// `cap_ms`), with the draw keyed on `salt ^ n` — so a thundering herd
/// of ranks re-dialling a reborn coordinator decorrelates without any
/// wall-clock randomness.
pub(crate) struct Backoff {
    attempt: u32,
    base_ms: u64,
    cap_ms: u64,
    salt: u64,
}

impl Backoff {
    /// A dial backoff starting at `base_ms` and capped at `cap_ms`,
    /// jitter-keyed on `salt` (callers pass their rank).
    pub(crate) fn new(base_ms: u64, cap_ms: u64, salt: u64) -> Backoff {
        Backoff { attempt: 0, base_ms: base_ms.max(1), cap_ms: cap_ms.max(1), salt }
    }

    /// Delay before the next dial attempt; each call advances the
    /// schedule. Deterministic for a fixed `(base, cap, salt)`.
    pub(crate) fn next_delay(&mut self) -> Duration {
        let shift = self.attempt.min(16);
        self.attempt = self.attempt.saturating_add(1);
        let exp = self.base_ms.saturating_mul(1u64 << shift).min(self.cap_ms);
        // Jitter in [exp/2, exp]: full decorrelation while keeping the
        // exponential envelope (delay never exceeds `exp`).
        let half = (exp / 2).max(1);
        let jit = splitmix64(self.salt ^ self.attempt as u64) % (exp - half + 1).max(1);
        Duration::from_millis(half + jit)
    }
}

// ---------------------------------------------------------------------
// Payload encoding (pure byte images; no floating-point work).

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len()).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, "payload truncated")
        })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> io::Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(io::Error::new(io::ErrorKind::InvalidData, "trailing bytes in payload"))
        }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Encoded byte length of a matrix-list payload (the star/ring wire
/// image) without materializing it — the local transport's traffic model.
pub(crate) fn encoded_len_mats(mats: &[Mat]) -> usize {
    4 + mats.iter().map(|m| 8 + 4 * m.len()).sum::<usize>()
}

/// Encoded byte length of an `n`-scalar f64 payload.
pub(crate) fn encoded_len_f64s(n: usize) -> usize {
    4 + 8 * n
}

/// Encoded byte length of a gathered blob over per-rank payload lengths
/// — the single formula shared by `encode_gathered` (checked there) and
/// the local transport's wire-byte model, so the two cannot drift.
pub(crate) fn encoded_len_gathered(lens: &[usize]) -> usize {
    4 + lens.iter().map(|l| 8 + l).sum::<usize>()
}

pub(crate) fn encode_mats(mats: &[Mat]) -> Vec<u8> {
    let total: usize = encoded_len_mats(mats);
    let mut buf = Vec::with_capacity(total);
    buf.extend_from_slice(&(mats.len() as u32).to_le_bytes());
    for m in mats {
        buf.extend_from_slice(&(m.rows() as u32).to_le_bytes());
        buf.extend_from_slice(&(m.cols() as u32).to_le_bytes());
        for &v in m.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    buf
}

pub(crate) fn decode_mats(buf: &[u8]) -> io::Result<Vec<Mat>> {
    let mut cur = Cur::new(buf);
    let n = cur.u32()? as usize;
    // Clamp the pre-allocation: every entry needs an 8-byte shape header,
    // so a garbled count fails at the truncation check instead of
    // attempting an absurd up-front allocation.
    let mut out = Vec::with_capacity(n.min(cur.remaining() / 8));
    for _ in 0..n {
        let rows = cur.u32()? as usize;
        let cols = cur.u32()? as usize;
        let nbytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(4))
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "matrix shape overflow"))?;
        let bytes = cur.take(nbytes)?;
        let data: Vec<f32> =
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        out.push(Mat::from_vec(rows, cols, data));
    }
    cur.done()?;
    Ok(out)
}

/// Encoded byte length of a matrix-list payload at a wire dtype (the
/// `wmats` image: shape headers as in `mats`, elements at dtype width).
/// Equals [`encoded_len_mats`] on the `f32` wire — the one formula the
/// local transport's wire-byte model and the socket encoder share.
pub(crate) fn encoded_len_mats_wire(mats: &[Mat], wire: Dtype) -> usize {
    4 + mats.iter().map(|m| 8 + wire.bytes() * m.len()).sum::<usize>()
}

/// Encode a matrix list at the wire dtype (`wmats` payload). Callers
/// snap elements to the wire-representable set first, so the narrowing
/// `from_f32` here is bit-exact; on the `f32` wire this *is*
/// [`encode_mats`].
pub(crate) fn encode_mats_wire(mats: &[Mat], wire: Dtype) -> Vec<u8> {
    if wire == Dtype::F32 {
        return encode_mats(mats);
    }
    let mut buf = Vec::with_capacity(encoded_len_mats_wire(mats, wire));
    buf.extend_from_slice(&(mats.len() as u32).to_le_bytes());
    for m in mats {
        buf.extend_from_slice(&(m.rows() as u32).to_le_bytes());
        buf.extend_from_slice(&(m.cols() as u32).to_le_bytes());
        match wire {
            Dtype::F32 => unreachable!(),
            Dtype::Bf16 => {
                for &v in m.data() {
                    buf.extend_from_slice(&Bf16::from_f32(v).bits().to_le_bytes());
                }
            }
            Dtype::Fp16 => {
                for &v in m.data() {
                    buf.extend_from_slice(&Fp16::from_f32(v).bits().to_le_bytes());
                }
            }
        }
    }
    buf
}

/// Decode a `wmats` payload, widening each element exactly. The wire
/// dtype is a run-level constant known to both sides — never read from
/// the frame.
pub(crate) fn decode_mats_wire(buf: &[u8], wire: Dtype) -> io::Result<Vec<Mat>> {
    if wire == Dtype::F32 {
        return decode_mats(buf);
    }
    let mut cur = Cur::new(buf);
    let n = cur.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(cur.remaining() / 8));
    for _ in 0..n {
        let rows = cur.u32()? as usize;
        let cols = cur.u32()? as usize;
        let nbytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(wire.bytes()))
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "matrix shape overflow"))?;
        let bytes = cur.take(nbytes)?;
        let widen = |c: &[u8]| {
            let bits = u16::from_le_bytes(c.try_into().unwrap());
            match wire {
                Dtype::F32 => unreachable!(),
                Dtype::Bf16 => Bf16::from_bits(bits).to_f32(),
                Dtype::Fp16 => Fp16::from_bits(bits).to_f32(),
            }
        };
        let data: Vec<f32> = bytes.chunks_exact(2).map(widen).collect();
        out.push(Mat::from_vec(rows, cols, data));
    }
    cur.done()?;
    Ok(out)
}

fn encode_f64s(vals: &[f64]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(encoded_len_f64s(vals.len()));
    buf.extend_from_slice(&(vals.len() as u32).to_le_bytes());
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

fn decode_f64s(buf: &[u8]) -> io::Result<Vec<f64>> {
    let mut cur = Cur::new(buf);
    let n = cur.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(cur.remaining() / 8));
    for _ in 0..n {
        out.push(f64::from_le_bytes(cur.take(8)?.try_into().unwrap()));
    }
    cur.done()?;
    Ok(out)
}

fn encode_gathered(parts: &[Vec<u8>]) -> Vec<u8> {
    let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
    let total = encoded_len_gathered(&lens);
    let mut buf = Vec::with_capacity(total);
    buf.extend_from_slice(&(parts.len() as u32).to_le_bytes());
    for p in parts {
        buf.extend_from_slice(&(p.len() as u64).to_le_bytes());
        buf.extend_from_slice(p);
    }
    debug_assert_eq!(buf.len(), total, "encoded_len_gathered drifted from encode_gathered");
    buf
}

fn decode_gathered(buf: &[u8]) -> io::Result<Vec<Vec<u8>>> {
    let mut cur = Cur::new(buf);
    let n = cur.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(cur.remaining() / 8));
    for _ in 0..n {
        let len = cur.u64()? as usize;
        out.push(cur.take(len)?.to_vec());
    }
    cur.done()?;
    Ok(out)
}

// ---------------------------------------------------------------------
// Framing.

fn frame_header(kind: u8, seq: u64, len: usize) -> [u8; FRAME_HEADER_BYTES] {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    hdr[0] = kind;
    hdr[1..9].copy_from_slice(&seq.to_le_bytes());
    hdr[9..17].copy_from_slice(&(len as u64).to_le_bytes());
    hdr
}

fn write_frame(s: &mut Stream, kind: u8, seq: u64, payload: &[u8]) -> io::Result<()> {
    s.write_all(&frame_header(kind, seq, payload.len()))?;
    s.write_all(payload)?;
    s.flush()
}

fn read_frame(s: &mut Stream) -> io::Result<(u8, u64, Vec<u8>)> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    s.read_exact(&mut hdr)?;
    let kind = hdr[0];
    let seq = u64::from_le_bytes(hdr[1..9].try_into().unwrap());
    let len = u64::from_le_bytes(hdr[9..17].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized frame"));
    }
    let mut payload = vec![0u8; len as usize];
    s.read_exact(&mut payload)?;
    Ok((kind, seq, payload))
}

// ---------------------------------------------------------------------
// Handshake.

/// Decoded 40-byte v2 hello (PROTOCOL.md §Elastic rendezvous v2):
/// `magic u64 | version u32 | run_id u64 | world u32 | rank u32 |
/// gen u64 | intent u32`, all little-endian.
struct Hello {
    run_id: u64,
    world: u32,
    rank: u32,
    gen: u64,
    intent: u32,
}

fn write_hello(
    s: &mut Stream,
    run_id: u64,
    world: usize,
    rank: u32,
    gen: u64,
    intent: u32,
) -> io::Result<()> {
    let mut hello = [0u8; 40];
    hello[0..8].copy_from_slice(&MAGIC.to_le_bytes());
    hello[8..12].copy_from_slice(&PROTO_VERSION.to_le_bytes());
    hello[12..20].copy_from_slice(&run_id.to_le_bytes());
    hello[20..24].copy_from_slice(&(world as u32).to_le_bytes());
    hello[24..28].copy_from_slice(&rank.to_le_bytes());
    hello[28..36].copy_from_slice(&gen.to_le_bytes());
    hello[36..40].copy_from_slice(&intent.to_le_bytes());
    s.write_all(&hello)?;
    s.flush()
}

/// Read + validate the fixed fields of a v2 hello (magic, version).
/// A v1 peer's 28-byte hello either stalls the 40-byte read (bounded by
/// the caller's read timeout) or fails the version check — it is never
/// half-interpreted.
fn read_hello(s: &mut Stream) -> io::Result<Hello> {
    let mut hello = [0u8; 40];
    s.read_exact(&mut hello)?;
    let magic = u64::from_le_bytes(hello[0..8].try_into().unwrap());
    let version = u32::from_le_bytes(hello[8..12].try_into().unwrap());
    if magic != MAGIC || version != PROTO_VERSION {
        // Not even speaking our protocol: drop without a reply.
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic/version"));
    }
    Ok(Hello {
        run_id: u64::from_le_bytes(hello[12..20].try_into().unwrap()),
        world: u32::from_le_bytes(hello[20..24].try_into().unwrap()),
        rank: u32::from_le_bytes(hello[24..28].try_into().unwrap()),
        gen: u64::from_le_bytes(hello[28..36].try_into().unwrap()),
        intent: u32::from_le_bytes(hello[36..40].try_into().unwrap()),
    })
}

fn write_welcome(s: &mut Stream, status: u32) -> io::Result<()> {
    let mut w = [0u8; 12];
    w[0..8].copy_from_slice(&MAGIC.to_le_bytes());
    w[8..12].copy_from_slice(&status.to_le_bytes());
    s.write_all(&w)?;
    s.flush()
}

/// Write the unified 28-byte control/grant reply frame:
/// `magic u64 | status u32 | world u32 | gen u64 | extra u32` —
/// `extra` is the run state in a STATUS reply, the assigned rank in a
/// membership grant, and `u32::MAX` in a regroup announcement.
fn write_reply28(s: &mut Stream, status: u32, world: u32, gen: u64, extra: u32) -> io::Result<()> {
    let mut w = [0u8; 28];
    w[0..8].copy_from_slice(&MAGIC.to_le_bytes());
    w[8..12].copy_from_slice(&status.to_le_bytes());
    w[12..16].copy_from_slice(&world.to_le_bytes());
    w[16..24].copy_from_slice(&gen.to_le_bytes());
    w[24..28].copy_from_slice(&extra.to_le_bytes());
    s.write_all(&w)?;
    s.flush()
}

/// Write the 40-byte STATUS metrics block that follows a successful
/// STATUS reply: `step u64 | loss_bits u64 | bytes u64 | scale_bits
/// u64 | gen u64`, little-endian (PROTOCOL.md §control frames). Other
/// reply kinds stay 28 bytes — the block is appended only where the
/// client knows to read it.
fn write_status_metrics(
    s: &mut Stream,
    m: &crate::obs::metrics::StatusMetrics,
) -> io::Result<()> {
    let mut w = [0u8; 40];
    w[0..8].copy_from_slice(&m.step.to_le_bytes());
    w[8..16].copy_from_slice(&m.loss_bits.to_le_bytes());
    w[16..24].copy_from_slice(&m.bytes.to_le_bytes());
    w[24..32].copy_from_slice(&m.scale_bits.to_le_bytes());
    w[32..40].copy_from_slice(&m.gen.to_le_bytes());
    s.write_all(&w)?;
    s.flush()
}

/// Read the 40-byte STATUS metrics block (see [`write_status_metrics`]).
fn read_status_metrics(s: &mut Stream) -> io::Result<crate::obs::metrics::StatusMetrics> {
    let mut w = [0u8; 40];
    s.read_exact(&mut w)?;
    Ok(crate::obs::metrics::StatusMetrics {
        step: u64::from_le_bytes(w[0..8].try_into().unwrap()),
        loss_bits: u64::from_le_bytes(w[8..16].try_into().unwrap()),
        bytes: u64::from_le_bytes(w[16..24].try_into().unwrap()),
        scale_bits: u64::from_le_bytes(w[24..32].try_into().unwrap()),
        gen: u64::from_le_bytes(w[32..40].try_into().unwrap()),
    })
}

/// Read a 28-byte control/grant reply; returns `(status, world, gen,
/// extra)` after validating the magic.
fn read_reply28(s: &mut Stream) -> io::Result<(u32, u32, u64, u32)> {
    let mut w = [0u8; 28];
    s.read_exact(&mut w)?;
    let magic = u64::from_le_bytes(w[0..8].try_into().unwrap());
    if magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad control reply"));
    }
    Ok((
        u32::from_le_bytes(w[8..12].try_into().unwrap()),
        u32::from_le_bytes(w[12..16].try_into().unwrap()),
        u64::from_le_bytes(w[16..24].try_into().unwrap()),
        u32::from_le_bytes(w[24..28].try_into().unwrap()),
    ))
}

/// Server side: read and validate one peer's data-plane hello; reply
/// with a status. Returns the peer's rank on success.
fn handshake_server(
    s: &mut Stream,
    world: usize,
    run_id: u64,
    gen: u64,
    taken: &[bool],
) -> io::Result<usize> {
    let h = read_hello(s)?;
    let peer_rank = h.rank as usize;
    let status = if h.run_id != run_id {
        ST_BAD_RUN_ID
    } else if h.gen != gen || h.intent != INTENT_WORKER {
        // A straggler from another membership epoch, or a control-plane
        // intent aimed at the data endpoint.
        ST_BAD_GEN
    } else if h.world as usize != world {
        ST_BAD_WORLD
    } else if peer_rank == 0 || peer_rank >= world {
        ST_BAD_RANK
    } else if taken[peer_rank] {
        ST_DUP_RANK
    } else {
        ST_OK
    };
    write_welcome(s, status)?;
    if status == ST_OK {
        Ok(peer_rank)
    } else {
        Err(io::Error::new(io::ErrorKind::InvalidData, status_msg(status)))
    }
}

/// Rank 0: bind the endpoint and accept + validate `world − 1` peers.
/// Returns streams indexed by `peer rank − 1`.
fn accept_peers(ep: &Endpoint, world: usize, run_id: u64, gen: u64) -> io::Result<Vec<Stream>> {
    let listener = match ep {
        Endpoint::Unix(path) => {
            // A stale socket file from a dead run blocks bind; remove it.
            let _ = std::fs::remove_file(path);
            Listener::Unix(
                UnixListener::bind(path)
                    .map_err(|e| io_ctx(e, &format!("bind rendezvous unix:{path}")))?,
            )
        }
        Endpoint::Tcp(addr) => Listener::Tcp(
            TcpListener::bind(addr)
                .map_err(|e| io_ctx(e, &format!("bind rendezvous tcp:{addr}")))?,
        ),
    };
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + rendezvous_timeout();
    let mut slots: Vec<Option<Stream>> = (1..world).map(|_| None).collect();
    let mut taken = vec![false; world];
    let mut pending = world - 1;
    while pending > 0 {
        // Enforce the deadline on every iteration — including after a
        // rejected handshake — so junk connections cannot extend it.
        let now = Instant::now();
        if now >= deadline {
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                format!("rendezvous timed out with {pending} peer(s) missing"),
            ));
        }
        let budget = deadline.saturating_duration_since(now).max(Duration::from_millis(1));
        match listener.accept() {
            Ok(mut s) => {
                s.set_nonblocking(false)?;
                s.set_nodelay();
                // Bound the handshake read by the *remaining* rendezvous
                // budget so a connected-but-silent peer cannot stall past
                // the deadline.
                s.set_read_timeout(Some(budget))?;
                match handshake_server(&mut s, world, run_id, gen, &taken) {
                    Ok(r) => {
                        taken[r] = true;
                        slots[r - 1] = Some(s);
                        pending -= 1;
                    }
                    Err(_) => {
                        // Rejected (stale run id, bad world, dup rank) or
                        // garbled: drop the connection, keep listening.
                        s.shutdown();
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    if let Endpoint::Unix(path) = ep {
        // World assembled: the socket file has served its purpose (the
        // established connections outlive the unlink).
        let _ = std::fs::remove_file(path);
    }
    let links: Vec<Stream> = slots.into_iter().map(|s| s.expect("accepted peer")).collect();
    for l in &links {
        l.set_read_timeout(read_timeout())?;
    }
    Ok(links)
}

/// An error kind a dial loop should retry on: the server has not bound
/// yet (or a stale socket file was just unlinked).
fn dial_retryable(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::NotFound
            | io::ErrorKind::ConnectionRefused
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::AddrNotAvailable
    )
}

/// Dial `ep` with bounded exponential backoff (deterministic jitter
/// keyed on `salt`) until `deadline`; retries only on
/// [`dial_retryable`] kinds, and tags terminal errors with `what`.
fn dial_backoff(
    ep: &Endpoint,
    deadline: Instant,
    mut backoff: Backoff,
    what: &str,
) -> io::Result<Stream> {
    loop {
        let attempt = match ep {
            Endpoint::Unix(path) => UnixStream::connect(path).map(Stream::Unix),
            Endpoint::Tcp(addr) => TcpStream::connect(addr).map(Stream::Tcp),
        };
        match attempt {
            Ok(s) => {
                s.set_nodelay();
                return Ok(s);
            }
            Err(e) if dial_retryable(&e) && Instant::now() < deadline => {
                // Server not up yet; back off (exponentially, jittered)
                // and retry until the rendezvous deadline.
                let delay = backoff.next_delay();
                let left = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep(delay.min(left));
            }
            Err(e) => return Err(io_ctx(e, what)),
        }
    }
}

/// Rank > 0: dial the rendezvous endpoint (retrying with backoff until
/// the server binds) and run the hello/welcome handshake.
fn dial_root(ep: &Endpoint, rank: usize, world: usize, run_id: u64, gen: u64) -> io::Result<Stream> {
    let deadline = Instant::now() + rendezvous_timeout();
    let what = format!("rank {rank}: dial rendezvous {ep:?}");
    let mut s = dial_backoff(ep, deadline, Backoff::new(2, 200, rank as u64), &what)?;
    s.set_read_timeout(Some(rendezvous_timeout()))?;
    write_hello(&mut s, run_id, world, rank as u32, gen, INTENT_WORKER)?;
    let mut w = [0u8; 12];
    s.read_exact(&mut w).map_err(|e| io_ctx(e, &format!("rank {rank}: read welcome")))?;
    let magic = u64::from_le_bytes(w[0..8].try_into().unwrap());
    let status = u32::from_le_bytes(w[8..12].try_into().unwrap());
    if magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad welcome"));
    }
    if status != ST_OK {
        return Err(io::Error::new(
            io::ErrorKind::ConnectionRefused,
            format!("handshake rejected: {}", status_msg(status)),
        ));
    }
    s.set_read_timeout(read_timeout())?;
    Ok(s)
}

// ---------------------------------------------------------------------
// Peer mesh assembly (PROTOCOL.md §Peer mesh).

/// Bind this rank's mesh listener and return it with its advertised
/// address. Unix rendezvous endpoints derive per-rank sibling paths
/// (`<path>.m<rank>`); TCP binds an ephemeral port on the interface the
/// star link uses (loopback falls out naturally in tests).
fn mesh_listener(ep: &Endpoint, rank: usize, links: &[Stream]) -> io::Result<(Listener, String)> {
    match ep {
        Endpoint::Unix(path) => {
            let p = format!("{path}.m{rank}");
            // A stale mesh socket from a dead run blocks bind; remove it.
            let _ = std::fs::remove_file(&p);
            Ok((Listener::Unix(UnixListener::bind(&p)?), format!("unix:{p}")))
        }
        Endpoint::Tcp(_) => {
            let host = match links.first() {
                Some(Stream::Tcp(s)) => s.local_addr()?.ip().to_string(),
                _ => "127.0.0.1".to_string(),
            };
            let l = TcpListener::bind((host.as_str(), 0))?;
            let port = l.local_addr()?.port();
            Ok((Listener::Tcp(l), format!("tcp:{host}:{port}")))
        }
    }
}

/// Dial a peer's mesh listener (retrying until the rendezvous deadline —
/// the listener is guaranteed bound, but the accept loop may lag) and
/// identify ourselves with the 20-byte mesh hello.
fn dial_mesh_peer(addr: &str, my_rank: usize, run_id: u64) -> io::Result<Stream> {
    let ep = Endpoint::parse(addr);
    let deadline = Instant::now() + rendezvous_timeout();
    let what = format!("rank {my_rank}: dial mesh peer {addr}");
    let mut s = dial_backoff(&ep, deadline, Backoff::new(1, 100, my_rank as u64), &what)?;
    let mut hello = [0u8; 20];
    hello[0..8].copy_from_slice(&MAGIC.to_le_bytes());
    hello[8..16].copy_from_slice(&run_id.to_le_bytes());
    hello[16..20].copy_from_slice(&(my_rank as u32).to_le_bytes());
    s.write_all(&hello)?;
    s.flush()?;
    s.set_read_timeout(read_timeout())?;
    Ok(s)
}

/// Accept mesh connections from every higher-ranked peer, validating the
/// mesh hello (magic, run id, rank in range, no duplicates). Invalid
/// dialers — stale runs sharing a reused endpoint — are dropped and the
/// accept loop continues until the rendezvous deadline.
fn accept_mesh_peers(
    listener: &Listener,
    my_rank: usize,
    world: usize,
    run_id: u64,
    mesh: &mut [Option<Stream>],
) -> io::Result<()> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + rendezvous_timeout();
    let mut pending = world - 1 - my_rank;
    while pending > 0 {
        let now = Instant::now();
        if now >= deadline {
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                format!("mesh rendezvous timed out with {pending} peer(s) missing"),
            ));
        }
        let budget = deadline.saturating_duration_since(now).max(Duration::from_millis(1));
        match listener.accept() {
            Ok(mut s) => {
                s.set_nonblocking(false)?;
                s.set_nodelay();
                s.set_read_timeout(Some(budget))?;
                let mut hello = [0u8; 20];
                let ok = s.read_exact(&mut hello).is_ok()
                    && u64::from_le_bytes(hello[0..8].try_into().unwrap()) == MAGIC
                    && u64::from_le_bytes(hello[8..16].try_into().unwrap()) == run_id;
                let peer = u32::from_le_bytes(hello[16..20].try_into().unwrap()) as usize;
                if ok && peer > my_rank && peer < world && mesh[peer].is_none() {
                    s.set_read_timeout(read_timeout())?;
                    mesh[peer] = Some(s);
                    pending -= 1;
                } else {
                    s.shutdown();
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Borrow two distinct mesh links mutably (`i != j`).
fn two_links(mesh: &mut [Option<Stream>], i: usize, j: usize) -> (&mut Stream, &mut Stream) {
    assert_ne!(i, j);
    let (lo, hi) = (i.min(j), i.max(j));
    let (a, b) = mesh.split_at_mut(hi);
    let lo_link = a[lo].as_mut().expect("dist[socket]: mesh link missing");
    let hi_link = b[0].as_mut().expect("dist[socket]: mesh link missing");
    if i < j {
        (lo_link, hi_link)
    } else {
        (hi_link, lo_link)
    }
}

// ---------------------------------------------------------------------
// The communicator.

struct Inner {
    /// Rank 0: `world − 1` streams, index `r − 1` ↔ peer rank `r`.
    /// Rank > 0: a single stream to rank 0.
    links: Vec<Stream>,
    /// Exchange counter; stamped into every star frame (SPMD order check).
    seq: u64,
    /// Full peer mesh for point-to-point frames, indexed by peer rank
    /// (`None` at this rank's own slot; empty world-1 worlds never
    /// populate it).
    mesh: Vec<Option<Stream>>,
    /// Per-direction p2p frame counters: `p2p_sent[r]` stamps the next
    /// frame to rank `r`, `p2p_rcvd[r]` is the seq expected from rank
    /// `r` (SPMD order check on every mesh frame).
    p2p_sent: Vec<u64>,
    p2p_rcvd: Vec<u64>,
}

/// The shareable state behind a [`SocketComm`]: rank identity plus the
/// lock-guarded link set, behind one `Arc` so an in-flight engine op can
/// own it. Implements the inline (immediate-execution) `Communicator` —
/// the engine jobs of [`SocketComm`] run collectives over this type.
struct SocketCore {
    rank: usize,
    world: usize,
    algo: Algo,
    overlap: bool,
    wire: Dtype,
    inner: Mutex<Inner>,
}

/// One process's handle onto a socket-transport world. Implements the
/// same barrier-exchange [`Communicator`] contract as [`LocalComm`]; see
/// the module docs for topology, wire format and failure semantics.
///
/// Nonblocking `istart_*` calls lazily spawn this communicator's
/// progress engine ([`crate::dist::pending`]), which services one
/// operation at a time through the nonblocking duplex loop below; once
/// the engine is active, blocking calls are reimplemented as
/// `istart + wait` through the same FIFO queue, so a blocking collective
/// issued between two pending ops takes its place in the issue order
/// instead of racing the engine for the links. Dropping the communicator
/// drains every pending op before the goodbye frames go out.
///
/// [`LocalComm`]: crate::dist::LocalComm
pub struct SocketComm {
    core: Arc<SocketCore>,
    engine: OnceLock<Engine>,
}

impl SocketComm {
    /// Join (rank > 0) or assemble (rank 0) a `world`-process rendezvous
    /// at `rendezvous` under the default collective algorithm
    /// ([`crate::dist::default_algo`]). Blocks until every rank has
    /// handshaken — star and peer mesh — or the
    /// `SINGD_SOCK_TIMEOUT_SECS` deadline (default 30 s) expires.
    pub fn connect(
        rank: usize,
        world: usize,
        rendezvous: &str,
        run_id: u64,
    ) -> io::Result<SocketComm> {
        Self::connect_with(rank, world, rendezvous, run_id, crate::dist::default_algo())
    }

    /// [`SocketComm::connect`] with an explicit collective algorithm
    /// (overlap mode stays the [`crate::dist::default_overlap`] env
    /// default). Every rank of a world must pass the same `algo`.
    pub fn connect_with(
        rank: usize,
        world: usize,
        rendezvous: &str,
        run_id: u64,
        algo: Algo,
    ) -> io::Result<SocketComm> {
        Self::connect_opts(rank, world, rendezvous, run_id, algo, crate::dist::default_overlap())
    }

    /// [`SocketComm::connect`] with explicit collective algorithm *and*
    /// overlap mode (wire dtype stays the
    /// [`crate::dist::default_wire_dtype`] env default). Every rank of a
    /// world must pass the same values for both (the launcher pins
    /// `SINGD_ALGO` / `SINGD_OVERLAP` into worker environments for
    /// exactly this reason).
    pub fn connect_opts(
        rank: usize,
        world: usize,
        rendezvous: &str,
        run_id: u64,
        algo: Algo,
        overlap: bool,
    ) -> io::Result<SocketComm> {
        Self::connect_opts_wire(
            rank,
            world,
            rendezvous,
            run_id,
            algo,
            overlap,
            crate::dist::default_wire_dtype(),
        )
    }

    /// [`SocketComm::connect_opts`] with an explicit wire dtype (a
    /// run-level constant like the algorithm; the launcher pins
    /// `SINGD_WIRE_DTYPE` into worker environments so every rank
    /// agrees).
    #[allow(clippy::too_many_arguments)]
    pub fn connect_opts_wire(
        rank: usize,
        world: usize,
        rendezvous: &str,
        run_id: u64,
        algo: Algo,
        overlap: bool,
        wire: Dtype,
    ) -> io::Result<SocketComm> {
        Self::connect_impl(rank, world, rendezvous, run_id, 0, algo, overlap, wire)
    }

    /// Join generation `gen` of an elastic world (PROTOCOL.md §Elastic
    /// rendezvous v2): the data plane of generation `g > 0` lives at the
    /// sibling endpoint [`elastic_data_endpoint`] under the
    /// generation-mixed run id [`mix_run_id`], so stragglers from an
    /// older epoch can never handshake into a newer one. Generation 0 is
    /// exactly [`SocketComm::connect_opts`]. Unix rendezvous only.
    #[allow(clippy::too_many_arguments)]
    pub fn connect_elastic(
        rank: usize,
        world: usize,
        rendezvous: &str,
        run_id: u64,
        gen: u64,
        algo: Algo,
        overlap: bool,
        wire: Dtype,
    ) -> io::Result<SocketComm> {
        let ep = elastic_data_endpoint(rendezvous, gen)?;
        Self::connect_impl(rank, world, &ep, mix_run_id(run_id, gen), gen, algo, overlap, wire)
    }

    #[allow(clippy::too_many_arguments)]
    fn connect_impl(
        rank: usize,
        world: usize,
        rendezvous: &str,
        run_id: u64,
        gen: u64,
        algo: Algo,
        overlap: bool,
        wire: Dtype,
    ) -> io::Result<SocketComm> {
        assert!(world >= 1, "dist[socket]: world size must be >= 1");
        assert!(rank < world, "dist[socket]: rank {rank} out of range for world {world}");
        let ep = Endpoint::parse(rendezvous);
        let links = if world == 1 {
            Vec::new()
        } else if rank == 0 {
            accept_peers(&ep, world, run_id, gen)?
        } else {
            vec![dial_root(&ep, rank, world, run_id, gen)?]
        };
        let core = SocketCore {
            rank,
            world,
            algo,
            overlap,
            wire,
            inner: Mutex::new(Inner {
                links,
                seq: 0,
                mesh: (0..world).map(|_| None).collect(),
                p2p_sent: vec![0; world],
                p2p_rcvd: vec![0; world],
            }),
        };
        if world > 1 {
            core.build_mesh(&ep, run_id)?;
        }
        Ok(SocketComm { core: Arc::new(core), engine: OnceLock::new() })
    }

    fn engine(&self) -> &Engine {
        self.engine
            .get_or_init(|| Engine::new(&format!("singd-sock-eng-r{}", self.core.rank)))
    }

    /// Abruptly close every link — star and mesh — *without* the goodbye
    /// frame: simulates process death for the fault-injection tests;
    /// peers observe EOF mid-collective (including mid-pending-op)
    /// instead of a clean shutdown.
    pub fn sever(&self) {
        if crate::obs::trace::active() {
            crate::obs::trace::instant_rank(
                "sever",
                "elastic",
                self.core.rank,
                vec![("world", crate::obs::trace::ArgVal::U(self.core.world as u64))],
            );
        }
        self.core.sever();
    }
}

impl SocketCore {
    /// Assemble the full peer mesh: bind this rank's listener, advertise
    /// its address over the star (a barrier, so every listener is bound
    /// before anyone dials), dial every lower rank, accept every higher
    /// rank. See PROTOCOL.md §Peer mesh.
    fn build_mesh(&self, ep: &Endpoint, run_id: u64) -> io::Result<()> {
        let (listener, addr) = {
            let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            mesh_listener(ep, self.rank, &inner.links)?
        };
        let addrs: Vec<String> = self
            .exchange_bytes(KIND_MESH, addr.into_bytes())
            .into_iter()
            .map(|b| {
                String::from_utf8(b).map_err(|_| {
                    io::Error::new(io::ErrorKind::InvalidData, "bad mesh address advertisement")
                })
            })
            .collect::<io::Result<_>>()?;
        let mut mesh: Vec<Option<Stream>> = (0..self.world).map(|_| None).collect();
        for (j, peer_addr) in addrs.iter().enumerate().take(self.rank) {
            mesh[j] = Some(dial_mesh_peer(peer_addr, self.rank, run_id)?);
        }
        accept_mesh_peers(&listener, self.rank, self.world, run_id, &mut mesh)?;
        if let Endpoint::Unix(path) = ep {
            // Mesh assembled: the listener path has served its purpose.
            let _ = std::fs::remove_file(format!("{path}.m{}", self.rank));
        }
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).mesh = mesh;
        Ok(())
    }

    /// See [`SocketComm::sever`].
    fn sever(&self) {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        for link in &inner.links {
            link.shutdown();
        }
        for link in inner.mesh.iter().flatten() {
            link.shutdown();
        }
    }

    /// Clean shutdown: best-effort goodbye on every link — star and
    /// mesh — so peers can tell an early (SPMD-violating) exit from a
    /// crash; then close the links. Called from [`SocketComm`]'s drop,
    /// *after* the progress engine has drained every pending op.
    fn close(&self) {
        let mut guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *guard;
        let seq = inner.seq;
        for link in &mut inner.links {
            let _ = write_frame(link, KIND_GOODBYE, seq, &[]);
            link.shutdown();
        }
        for (r, link) in inner.mesh.iter_mut().enumerate() {
            if let Some(link) = link {
                let _ = write_frame(link, KIND_GOODBYE, inner.p2p_sent[r], &[]);
                link.shutdown();
            }
        }
    }

    /// The star exchange over raw payload bytes: every rank deposits one
    /// payload, every rank receives all `world` payloads in rank order.
    /// Panics (poisoning the world) on peer death, clean-but-early peer
    /// shutdown, or any SPMD call-order violation.
    fn exchange_bytes(&self, kind: u8, mine: Vec<u8>) -> Vec<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let seq = inner.seq;
        inner.seq += 1;
        if self.world == 1 {
            return vec![mine];
        }
        let gathered_kind = match kind {
            KIND_MATS | KIND_MATS_WIRE => KIND_GATHERED_MATS,
            KIND_MESH => KIND_GATHERED_MESH,
            _ => KIND_GATHERED_F64,
        };
        // Mesh-address advertisements are rendezvous overhead, not
        // collective traffic; everything else is accounted per rank.
        let count = kind != KIND_MESH;
        if self.rank == 0 {
            let mut parts: Vec<Vec<u8>> = Vec::with_capacity(self.world);
            parts.push(mine);
            for r in 1..self.world {
                let (k, s, payload) = read_frame(&mut inner.links[r - 1])
                    .unwrap_or_else(|e| peer_failed(r, &e));
                check_frame(k, kind, s, seq, r);
                parts.push(payload);
            }
            let blob = encode_gathered(&parts);
            if count {
                traffic::record_sent(
                    0,
                    (self.world as u64 - 1) * (FRAME_HEADER_BYTES + blob.len()) as u64,
                );
            }
            for r in 1..self.world {
                write_frame(&mut inner.links[r - 1], gathered_kind, seq, &blob)
                    .unwrap_or_else(|e| peer_failed(r, &e));
            }
            parts
        } else {
            if count {
                traffic::record_sent(self.rank, (FRAME_HEADER_BYTES + mine.len()) as u64);
            }
            write_frame(&mut inner.links[0], kind, seq, &mine)
                .unwrap_or_else(|e| peer_failed(0, &e));
            let (k, s, blob) =
                read_frame(&mut inner.links[0]).unwrap_or_else(|e| peer_failed(0, &e));
            check_frame(k, gathered_kind, s, seq, 0);
            decode_gathered(&blob)
                .unwrap_or_else(|e| panic!("dist[socket]: corrupt gathered frame: {e}"))
        }
    }
}

/// Interleaved nonblocking send + receive over mesh links — the
/// deadlock-free engine behind [`Communicator::send_recv_bytes`]: both
/// directions progress in one loop, so a cycle of ranks all sending
/// chunks larger than the kernel socket buffers still drains. `recv` is
/// `None` when the peer is the same for both directions (world 2: one
/// full-duplex stream).
fn duplex_exchange(
    send: &mut Stream,
    mut recv: Option<&mut Stream>,
    sbuf: &[u8],
    to: usize,
    from: usize,
    want_seq: u64,
) -> Vec<u8> {
    send.set_nonblocking(true).unwrap_or_else(|e| peer_failed(to, &e));
    if let Some(r) = recv.as_deref() {
        r.set_nonblocking(true).unwrap_or_else(|e| peer_failed(from, &e));
    }
    // Nonblocking mode disables the per-link read timeout, so the
    // SINGD_SOCK_TIMEOUT_SECS knob is honoured here as a stall deadline:
    // no progress in either direction for that long fails the step (the
    // default — no timeout — matches blocking reads, which also wait
    // indefinitely and rely on EOF for peer death).
    let stall_limit = read_timeout();
    let mut last_progress = Instant::now();
    // Idle-spin backoff: 100 µs doubling to a 2 ms cap, reset to 100 µs
    // whenever either direction makes progress — short stalls stay
    // low-latency, long stalls stop burning a core.
    let mut idle_us: u64 = 100;
    let mut sent = 0usize;
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    let mut got_hdr = 0usize;
    let mut body: Vec<u8> = Vec::new();
    let mut got_body = 0usize;
    let mut body_len: Option<usize> = None;
    loop {
        let mut progressed = false;
        if sent < sbuf.len() {
            match send.write(&sbuf[sent..]) {
                Ok(0) => peer_failed(
                    to,
                    &io::Error::new(io::ErrorKind::WriteZero, "connection closed"),
                ),
                Ok(n) => {
                    sent += n;
                    progressed = true;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => peer_failed(to, &e),
            }
        }
        if !body_len.is_some_and(|l| got_body == l) {
            let r: &mut Stream = match recv.as_mut() {
                Some(r) => &mut **r,
                None => &mut *send,
            };
            let res = if body_len.is_none() {
                r.read(&mut hdr[got_hdr..])
            } else {
                r.read(&mut body[got_body..])
            };
            match res {
                Ok(0) => peer_failed(
                    from,
                    &io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed"),
                ),
                Ok(n) => {
                    progressed = true;
                    if body_len.is_none() {
                        got_hdr += n;
                        if got_hdr == FRAME_HEADER_BYTES {
                            let kind = hdr[0];
                            let seq = u64::from_le_bytes(hdr[1..9].try_into().unwrap());
                            let len = u64::from_le_bytes(hdr[9..17].try_into().unwrap());
                            assert!(len <= MAX_FRAME, "dist[socket]: oversized p2p frame");
                            check_frame(kind, KIND_P2P, seq, want_seq, from);
                            body = vec![0u8; len as usize];
                            body_len = Some(len as usize);
                        }
                    } else {
                        got_body += n;
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => peer_failed(from, &e),
            }
        }
        if sent == sbuf.len() && body_len.is_some_and(|l| got_body == l) {
            break;
        }
        if progressed {
            last_progress = Instant::now();
            idle_us = 100;
        } else {
            if stall_limit.is_some_and(|t| last_progress.elapsed() >= t) {
                peer_failed(
                    from,
                    &io::Error::new(
                        io::ErrorKind::TimedOut,
                        "ring step stalled past SINGD_SOCK_TIMEOUT_SECS",
                    ),
                );
            }
            std::thread::sleep(Duration::from_micros(idle_us));
            idle_us = (idle_us * 2).min(2000);
        }
    }
    send.set_nonblocking(false).unwrap_or_else(|e| peer_failed(to, &e));
    if let Some(r) = recv.as_deref() {
        r.set_nonblocking(false).unwrap_or_else(|e| peer_failed(from, &e));
    }
    body
}

/// A peer's link failed mid-collective: poison this rank too.
fn peer_failed(rank: usize, e: &io::Error) -> ! {
    if e.kind() == io::ErrorKind::UnexpectedEof {
        panic!("dist[socket]: peer rank {rank} died (connection closed mid-collective)");
    }
    panic!("dist[socket]: link to rank {rank} failed: {e}");
}

fn check_frame(got_kind: u8, want_kind: u8, got_seq: u64, want_seq: u64, peer: usize) {
    if got_kind == KIND_GOODBYE {
        panic!(
            "dist[socket]: peer rank {peer} shut down while a collective was pending \
             (SPMD call-order violation or early exit)"
        );
    }
    assert_eq!(
        got_kind, want_kind,
        "dist[socket]: SPMD call order violated with rank {peer} (payload kind mismatch)"
    );
    assert_eq!(
        got_seq, want_seq,
        "dist[socket]: SPMD call order violated with rank {peer} (exchange seq mismatch)"
    );
}

impl Communicator for SocketCore {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn algo(&self) -> Algo {
        self.algo
    }

    fn overlap(&self) -> bool {
        self.overlap
    }

    fn wire_dtype(&self) -> Dtype {
        self.wire
    }

    fn send_bytes(&self, to: usize, payload: &[u8]) {
        assert!(to != self.rank && to < self.world, "dist[socket]: bad p2p target {to}");
        let mut guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *guard;
        let seq = inner.p2p_sent[to];
        inner.p2p_sent[to] += 1;
        traffic::record_sent(self.rank, (FRAME_HEADER_BYTES + payload.len()) as u64);
        let link = inner.mesh[to].as_mut().expect("dist[socket]: mesh link missing");
        write_frame(link, KIND_P2P, seq, payload).unwrap_or_else(|e| peer_failed(to, &e));
    }

    fn recv_bytes(&self, from: usize) -> Vec<u8> {
        assert!(from != self.rank && from < self.world, "dist[socket]: bad p2p source {from}");
        let mut guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *guard;
        let want = inner.p2p_rcvd[from];
        inner.p2p_rcvd[from] += 1;
        let link = inner.mesh[from].as_mut().expect("dist[socket]: mesh link missing");
        let (k, s, payload) = read_frame(link).unwrap_or_else(|e| peer_failed(from, &e));
        check_frame(k, KIND_P2P, s, want, from);
        payload
    }

    fn send_recv_bytes(&self, to: usize, payload: &[u8], from: usize) -> Vec<u8> {
        assert!(to != self.rank && to < self.world, "dist[socket]: bad p2p target {to}");
        assert!(from != self.rank && from < self.world, "dist[socket]: bad p2p source {from}");
        let mut guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *guard;
        let sseq = inner.p2p_sent[to];
        inner.p2p_sent[to] += 1;
        let rseq = inner.p2p_rcvd[from];
        inner.p2p_rcvd[from] += 1;
        traffic::record_sent(self.rank, (FRAME_HEADER_BYTES + payload.len()) as u64);
        let mut sbuf = Vec::with_capacity(FRAME_HEADER_BYTES + payload.len());
        sbuf.extend_from_slice(&frame_header(KIND_P2P, sseq, payload.len()));
        sbuf.extend_from_slice(payload);
        if to == from {
            let link = inner.mesh[to].as_mut().expect("dist[socket]: mesh link missing");
            duplex_exchange(link, None, &sbuf, to, from, rseq)
        } else {
            let (slink, rlink) = two_links(&mut inner.mesh, to, from);
            duplex_exchange(slink, Some(rlink), &sbuf, to, from, rseq)
        }
    }

    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        let parts = self.exchange_bytes(KIND_MATS, encode_mats(&mats));
        parts
            .iter()
            .map(|p| {
                Arc::new(decode_mats(p).unwrap_or_else(|e| {
                    panic!("dist[socket]: corrupt mats payload: {e}")
                }))
            })
            .collect()
    }

    fn exchange_mats_wire(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        if self.wire == Dtype::F32 {
            return self.exchange_mats(mats);
        }
        let parts = self.exchange_bytes(KIND_MATS_WIRE, encode_mats_wire(&mats, self.wire));
        parts
            .iter()
            .map(|p| {
                Arc::new(decode_mats_wire(p, self.wire).unwrap_or_else(|e| {
                    panic!("dist[socket]: corrupt wire mats payload: {e}")
                }))
            })
            .collect()
    }

    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>> {
        let parts = self.exchange_bytes(KIND_F64, encode_f64s(&vals));
        parts
            .iter()
            .map(|p| {
                Arc::new(decode_f64s(p).unwrap_or_else(|e| {
                    panic!("dist[socket]: corrupt f64 payload: {e}")
                }))
            })
            .collect()
    }

    fn istart_all_gather(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        // Inline core: already executing on the engine (or in a blocking
        // context) — run to completion immediately.
        PendingOp::ready(collectives::all_gather(self, mats))
    }

    fn istart_all_reduce_sum(&self, mats: Vec<Mat>) -> PendingOp<Vec<Mat>> {
        PendingOp::ready(collectives::all_reduce_sum(self, &mats))
    }
}

impl Communicator for SocketComm {
    fn rank(&self) -> usize {
        self.core.rank
    }

    fn world_size(&self) -> usize {
        self.core.world
    }

    fn algo(&self) -> Algo {
        self.core.algo
    }

    fn overlap(&self) -> bool {
        self.core.overlap
    }

    fn wire_dtype(&self) -> Dtype {
        self.core.wire
    }

    fn send_bytes(&self, to: usize, payload: &[u8]) {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            let payload = payload.to_vec();
            eng.submit(self.core.rank, move || core.send_bytes(to, &payload)).wait();
            return;
        }
        self.core.send_bytes(to, payload)
    }

    fn recv_bytes(&self, from: usize) -> Vec<u8> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.recv_bytes(from)).wait();
        }
        self.core.recv_bytes(from)
    }

    fn send_recv_bytes(&self, to: usize, payload: &[u8], from: usize) -> Vec<u8> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            let payload = payload.to_vec();
            return eng
                .submit(self.core.rank, move || core.send_recv_bytes(to, &payload, from))
                .wait();
        }
        self.core.send_recv_bytes(to, payload, from)
    }

    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.exchange_mats(mats)).wait();
        }
        self.core.exchange_mats(mats)
    }

    fn exchange_mats_wire(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.exchange_mats_wire(mats)).wait();
        }
        self.core.exchange_mats_wire(mats)
    }

    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.exchange_f64(vals)).wait();
        }
        self.core.exchange_f64(vals)
    }

    fn istart_exchange_mats(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        if self.core.world == 1 {
            return PendingOp::ready(self.core.exchange_mats(mats));
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || core.exchange_mats(mats))
    }

    fn istart_exchange_f64(&self, vals: Vec<f64>) -> PendingOp<Vec<Arc<Vec<f64>>>> {
        if self.core.world == 1 {
            return PendingOp::ready(self.core.exchange_f64(vals));
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || core.exchange_f64(vals))
    }

    fn istart_send_recv_bytes(
        &self,
        to: usize,
        payload: Vec<u8>,
        from: usize,
    ) -> PendingOp<Vec<u8>> {
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || core.send_recv_bytes(to, &payload, from))
    }

    fn istart_all_gather(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        if self.core.world == 1 {
            return PendingOp::ready(vec![Arc::new(mats)]);
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || collectives::all_gather(&*core, mats))
    }

    fn istart_all_reduce_sum(&self, mats: Vec<Mat>) -> PendingOp<Vec<Mat>> {
        if self.core.world == 1 {
            return PendingOp::ready(mats);
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || collectives::all_reduce_sum(&*core, &mats))
    }
}

impl Drop for SocketComm {
    fn drop(&mut self) {
        // Drain the progress engine first: every issued op executes
        // before the links close (peers depend on them; a goodbye under
        // an op still in flight would read as an SPMD violation).
        if let Some(engine) = self.engine.take() {
            drop(engine);
        }
        self.core.close();
    }
}

// ---------------------------------------------------------------------
// World assembly: env contract, launcher, in-process test harness.

/// A worker rank's identity, read from the `SINGD_RANK` / `SINGD_WORLD` /
/// `SINGD_RENDEZVOUS` / `SINGD_RUN_ID` environment set by
/// [`launch_workers`].
#[derive(Clone, Debug)]
pub struct WorkerEnv {
    /// This process's rank (`SINGD_RANK`).
    pub rank: usize,
    /// The world size (`SINGD_WORLD`).
    pub world: usize,
    /// The rendezvous endpoint (`SINGD_RENDEZVOUS`).
    pub rendezvous: String,
    /// The launch's run-id tag (`SINGD_RUN_ID`).
    pub run_id: u64,
}

/// `Some` iff this process was launched as a worker rank (the
/// `SINGD_RANK` env contract). Read fresh on every call — launchers and
/// tests manipulate these variables.
///
/// A *present but malformed* variable panics loudly (naming the
/// variable and value) instead of silently demoting the process to a
/// non-worker — a typo'd `SINGD_RANK` must not make a worker launch its
/// own world.
pub fn worker_env() -> Option<WorkerEnv> {
    let rank_raw = std::env::var(ENV_RANK).ok()?;
    let rank = parse_env_u64(ENV_RANK, &rank_raw)
        .unwrap_or_else(|e| panic!("dist[socket]: {e}")) as usize;
    let world_raw = std::env::var(ENV_WORLD).unwrap_or_else(|_| {
        panic!("dist[socket]: {ENV_RANK} is set but {ENV_WORLD} is missing")
    });
    let world =
        parse_env_u64(ENV_WORLD, &world_raw).unwrap_or_else(|e| panic!("dist[socket]: {e}"))
            as usize;
    let rendezvous = std::env::var(ENV_RENDEZVOUS).unwrap_or_else(|_| {
        panic!("dist[socket]: {ENV_RANK} is set but {ENV_RENDEZVOUS} is missing")
    });
    let run_id = match std::env::var(ENV_RUN_ID) {
        Ok(raw) => parse_env_u64(ENV_RUN_ID, &raw).unwrap_or_else(|e| panic!("dist[socket]: {e}")),
        Err(_) => 0,
    };
    assert!(
        rank < world,
        "dist[socket]: {ENV_RANK}={rank} is out of range for {ENV_WORLD}={world}"
    );
    Some(WorkerEnv { rank, world, rendezvous, run_id })
}

/// A process-unique Unix rendezvous endpoint under the temp dir.
pub fn fresh_rendezvous() -> String {
    static CTR: AtomicU64 = AtomicU64::new(0);
    let n = CTR.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!("singd-rv-{}-{n}.sock", std::process::id()));
    format!("unix:{}", path.display())
}

/// A run id tag that differs across launches, so peers of a dead run
/// cannot join a new world at a reused endpoint.
pub fn fresh_run_id() -> u64 {
    static CTR: AtomicU64 = AtomicU64::new(0);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    ((std::process::id() as u64) << 40) ^ t ^ CTR.fetch_add(1, Ordering::Relaxed)
}

/// Re-exec this binary as worker ranks `1..world` (torchrun-style): same
/// argv, plus the `SINGD_RANK`/`SINGD_WORLD`/`SINGD_RENDEZVOUS`/
/// `SINGD_RUN_ID` env contract. `SINGD_ALGO`, `SINGD_OVERLAP`,
/// `SINGD_STREAM` and `SINGD_WIRE_DTYPE` are pinned to the launcher's
/// resolved collective algorithm, overlap mode, streaming mode and wire
/// dtype so a programmatically-set [`crate::train::DistCfg`] reaches
/// workers whose argv/config do not carry them (every rank of a world
/// must agree on these run-level constants — streaming changes the
/// collective *issue* schedule, so a mixed world would deadlock);
/// `SINGD_TRACE` and `SINGD_LOG` are pinned to the
/// launcher's trace directory and log level so observability knobs
/// propagate to workers the same way (each worker exports its own
/// `r<N>` trace files into the shared directory). The calling process
/// is rank 0. Worker stdout is discarded — stdout is the launcher's
/// data channel, and workers log at `warn` by default anyway
/// (`SINGD_LOG` contract); stderr is inherited so worker panics and
/// rank-prefixed warnings stay visible.
pub fn launch_workers(
    world: usize,
    rendezvous: &str,
    run_id: u64,
    algo: Algo,
    overlap: bool,
    stream: bool,
    wire: Dtype,
) -> io::Result<Vec<std::process::Child>> {
    assert!(
        worker_env().is_none(),
        "dist[socket]: a worker rank must not launch further workers"
    );
    let exe = std::env::current_exe()?;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut children = Vec::with_capacity(world.saturating_sub(1));
    for r in 1..world {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(&args)
            .env(ENV_RANK, r.to_string())
            .env(ENV_WORLD, world.to_string())
            .env(ENV_RENDEZVOUS, rendezvous)
            .env(ENV_RUN_ID, run_id.to_string())
            .env("SINGD_ALGO", algo.name())
            .env("SINGD_OVERLAP", if overlap { "1" } else { "0" })
            .env("SINGD_STREAM", if stream { "1" } else { "0" })
            .env("SINGD_WIRE_DTYPE", wire.name())
            .stdout(std::process::Stdio::null());
        for knob in ["SINGD_TRACE", "SINGD_LOG"] {
            match std::env::var(knob) {
                Ok(v) => {
                    cmd.env(knob, v);
                }
                Err(_) => {
                    cmd.env_remove(knob);
                }
            }
        }
        children.push(cmd.spawn()?);
    }
    Ok(children)
}

/// Reap worker processes; an error names every rank that failed.
pub fn wait_workers(children: &mut Vec<std::process::Child>) -> Result<(), String> {
    let mut errs = Vec::new();
    for (i, c) in children.iter_mut().enumerate() {
        match c.wait() {
            Ok(st) if st.success() => {}
            Ok(st) => errs.push(format!("worker rank {} exited with {st}", i + 1)),
            Err(e) => errs.push(format!("worker rank {}: wait failed: {e}", i + 1)),
        }
    }
    children.clear();
    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("; "))
    }
}

/// Reap worker processes *leniently*: collect (don't propagate) failure
/// descriptions. The elastic driver uses this at the end of a run where
/// some workers died by design — a chaos-killed rank's non-zero exit is
/// an expected outcome there, not a launcher error.
pub fn wait_workers_lenient(children: &mut Vec<std::process::Child>) -> Vec<String> {
    let mut errs = Vec::new();
    for (i, c) in children.iter_mut().enumerate() {
        match c.wait() {
            Ok(st) if st.success() => {}
            Ok(st) => errs.push(format!("worker rank {} exited with {st}", i + 1)),
            Err(e) => errs.push(format!("worker rank {}: wait failed: {e}", i + 1)),
        }
    }
    children.clear();
    errs
}

/// Run `world` SPMD rank bodies over a real socket world inside this
/// process under the default collective algorithm and overlap mode; see
/// [`run_ranks_socket_with`].
pub fn run_ranks_socket<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(SocketComm) -> T + Sync,
{
    run_ranks_socket_with(world, crate::dist::default_algo(), crate::dist::default_overlap(), f)
}

/// [`run_ranks_socket_with`] with the overlap mode left at the
/// [`crate::dist::default_overlap`] env default (so the ci.sh matrix
/// drives existing suites through both modes).
pub fn run_ranks_socket_algo<T, F>(world: usize, algo: Algo, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(SocketComm) -> T + Sync,
{
    run_ranks_socket_with(world, algo, crate::dist::default_overlap(), f)
}

/// Run `world` SPMD rank bodies over a real socket world inside this
/// process (one thread per rank, a fresh Unix endpoint) and collect
/// results in rank order — the socket-transport analogue of
/// [`crate::dist::run_ranks_with`], used by the cross-transport
/// conformance and fault-injection suites. Every byte still travels
/// through the kernel socket layer, so the wire path is exactly the
/// multi-process one; only process isolation is mocked.
pub fn run_ranks_socket_with<T, F>(world: usize, algo: Algo, overlap: bool, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(SocketComm) -> T + Sync,
{
    run_ranks_socket_wire(world, algo, overlap, crate::dist::default_wire_dtype(), f)
}

/// [`run_ranks_socket_with`] with an explicit wire dtype — the socket
/// analogue of [`crate::dist::run_ranks_wire`] for the wire-compression
/// conformance suites.
pub fn run_ranks_socket_wire<T, F>(
    world: usize,
    algo: Algo,
    overlap: bool,
    wire: Dtype,
    f: F,
) -> Vec<T>
where
    T: Send,
    F: Fn(SocketComm) -> T + Sync,
{
    assert!(world >= 1, "run_ranks_socket: world size must be >= 1");
    let rendezvous = fresh_rendezvous();
    let run_id = fresh_run_id();
    let results: Vec<Mutex<Option<T>>> = (0..world).map(|_| Mutex::new(None)).collect();
    let (fr, rs, rv) = (&f, &results, &rendezvous);
    std::thread::scope(|s| {
        for r in 0..world {
            s.spawn(move || {
                let comm =
                    SocketComm::connect_opts_wire(r, world, rv, run_id, algo, overlap, wire)
                        .unwrap_or_else(|e| panic!("dist[socket]: rank {r} rendezvous: {e}"));
                *rs[r].lock().unwrap_or_else(|e| e.into_inner()) = Some(fr(comm));
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("run_ranks_socket: rank produced no result")
        })
        .collect()
}

// ---------------------------------------------------------------------
// Elastic rendezvous v2: generation-stamped membership (PROTOCOL.md
// §Elastic rendezvous v2). Rank 0 owns membership as the [`Coordinator`];
// survivors and joiners re-rendezvous through [`rejoin`] / [`join`], and
// anyone can probe the world with [`status`].

/// Run state advertised in a [`status`] reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunState {
    /// Training is progressing under the advertised generation.
    Running,
    /// A membership regroup is being negotiated.
    Regrouping,
    /// The run has finished; joining is pointless.
    Done,
}

impl RunState {
    fn to_u32(self) -> u32 {
        match self {
            RunState::Running => 0,
            RunState::Regrouping => 1,
            RunState::Done => 2,
        }
    }

    fn from_u32(v: u32) -> io::Result<RunState> {
        match v {
            0 => Ok(RunState::Running),
            1 => Ok(RunState::Regrouping),
            2 => Ok(RunState::Done),
            _ => Err(io::Error::new(io::ErrorKind::InvalidData, "bad run state in status reply")),
        }
    }
}

/// A [`status`] query's answer: the coordinator's view of the world.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorldStatus {
    /// Current world size.
    pub world: usize,
    /// Current membership generation.
    pub gen: u64,
    /// Current run state.
    pub state: RunState,
    /// Live telemetry snapshot from the coordinator process (current
    /// step, loss, bytes sent, scaler scale, generation) — the 40-byte
    /// metrics block every STATUS reply carries (PROTOCOL.md §control
    /// frames). All-`u64` so [`WorldStatus`] stays `Eq`; decode floats
    /// with [`crate::obs::metrics::StatusMetrics::loss`] /
    /// [`crate::obs::metrics::StatusMetrics::scale`].
    pub metrics: crate::obs::metrics::StatusMetrics,
}

/// A rank's identity in a regrouped world: the outcome of
/// [`Coordinator::regroup`], [`rejoin`] or [`join`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Membership {
    /// This process's rank in the new world.
    pub rank: usize,
    /// The new world size.
    pub world: usize,
    /// The membership generation the grant is for.
    pub gen: u64,
}

/// Derive the Unix socket path of an elastic sibling endpoint. Elastic
/// mode is Unix-only: TCP endpoints cannot derive per-generation
/// sibling addresses, so they are rejected loudly here.
fn unix_base(rendezvous: &str, what: &str) -> io::Result<String> {
    match Endpoint::parse(rendezvous) {
        Endpoint::Unix(path) => Ok(path),
        Endpoint::Tcp(addr) => Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "elastic {what} requires a unix: rendezvous endpoint \
                 (tcp:{addr} cannot derive per-generation sibling endpoints)"
            ),
        )),
    }
}

/// The data-plane rendezvous endpoint of generation `gen`: the base
/// endpoint for generation 0, the sibling `unix:<path>.g<gen>` after.
/// Mesh listener paths derive from this base, so each generation's mesh
/// is automatically disjoint from its predecessors'.
pub fn elastic_data_endpoint(rendezvous: &str, gen: u64) -> io::Result<String> {
    if gen == 0 {
        return Ok(rendezvous.to_string());
    }
    Ok(format!("unix:{}.g{gen}", unix_base(rendezvous, "data plane")?))
}

/// Mix a membership generation into a run id (SplitMix64-style odd
/// multiplier), so a straggler's data-plane hello from generation `g`
/// can never pass the handshake of generation `g' ≠ g` even if the
/// endpoints were somehow confused. Generation 0 is the identity —
/// non-elastic runs are untouched.
pub fn mix_run_id(run_id: u64, gen: u64) -> u64 {
    run_id ^ gen.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

fn ctrl_endpoint(base: &str) -> String {
    format!("{base}.ctrl")
}

fn membership_endpoint(base: &str, gen: u64) -> String {
    format!("{base}.r{gen}")
}

/// Coordinator-side shared view of the world, advertised over `/status`.
struct CtrlShared {
    world: u32,
    gen: u64,
    state: RunState,
}

/// Rank 0's membership authority (elastic rendezvous v2). Owns the
/// `<path>.ctrl` control endpoint: a background thread answers
/// [`status`] queries and parks [`join`] requests; [`Coordinator::regroup`]
/// negotiates a new generation after a failure (or to admit joiners).
/// The coordinator itself is the fixed point of the protocol — its death
/// is fatal to the world, by design (see the module docs).
pub struct Coordinator {
    base: String,
    run_id: u64,
    shared: Arc<Mutex<CtrlShared>>,
    parked: Arc<Mutex<Vec<Stream>>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Bind the control endpoint for an elastic world of initial size
    /// `world` and start answering status/join traffic. Unix rendezvous
    /// endpoints only.
    pub fn new(rendezvous: &str, run_id: u64, world: usize) -> io::Result<Coordinator> {
        let base = unix_base(rendezvous, "coordinator")?;
        let ctrl = ctrl_endpoint(&base);
        // A stale control socket from a dead run blocks bind; remove it.
        let _ = std::fs::remove_file(&ctrl);
        let listener = UnixListener::bind(&ctrl)
            .map_err(|e| io_ctx(e, &format!("bind control endpoint unix:{ctrl}")))?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Mutex::new(CtrlShared {
            world: world as u32,
            gen: 0,
            state: RunState::Running,
        }));
        let parked: Arc<Mutex<Vec<Stream>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (sh, pk, st) = (Arc::clone(&shared), Arc::clone(&parked), Arc::clone(&stop));
        let thread = std::thread::Builder::new()
            .name("singd-elastic-ctrl".into())
            .spawn(move || ctrl_serve(listener, run_id, sh, pk, st))
            .map_err(|e| io_ctx(e, "spawn control thread"))?;
        Ok(Coordinator { base, run_id, shared, parked, stop, thread: Some(thread) })
    }

    /// True iff a [`join`] request is parked at the control endpoint —
    /// the elastic driver polls this once per step (rank 0 folds it into
    /// a scalar exchange) and triggers a regroup to admit the joiner.
    pub fn join_pending(&self) -> bool {
        !self.parked.lock().unwrap_or_else(|e| e.into_inner()).is_empty()
    }

    /// Negotiate membership generation `gen`: announce to parked
    /// joiners, collect survivor/joiner hellos at the per-generation
    /// membership endpoint until the arrival quiesce window closes, and
    /// grant the new world's ranks (coordinator first, survivors by old
    /// rank, joiners last, in arrival order). Returns this process's
    /// (rank 0) membership in the new world.
    pub fn regroup(&self, gen: u64) -> io::Result<Membership> {
        let old_world = {
            let mut sh = self.shared.lock().unwrap_or_else(|e| e.into_inner());
            sh.state = RunState::Regrouping;
            sh.world as usize
        };
        let mpath = membership_endpoint(&self.base, gen);
        let _ = std::fs::remove_file(&mpath);
        let listener = UnixListener::bind(&mpath)
            .map_err(|e| io_ctx(e, &format!("bind membership endpoint unix:{mpath}")))?;
        listener.set_nonblocking(true)?;
        // Announce the regroup to parked joiners; each then dials the
        // membership endpoint like a survivor (with RANK_NONE).
        let mut n_join = 0usize;
        for mut s in self.parked.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            if write_reply28(&mut s, ST_OK, 0, gen, u32::MAX).is_ok() {
                n_join += 1;
            }
            s.shutdown();
        }
        // Quiesce-collect hellos: the window starts QUIESCE after bind,
        // extends QUIESCE past every arrival, is capped by the rendezvous
        // deadline, and closes early once every possible member (all
        // old_world − 1 survivors + every announced joiner) has arrived.
        const QUIESCE: Duration = Duration::from_millis(1500);
        let hard_deadline = Instant::now() + rendezvous_timeout();
        let mut window = Instant::now() + QUIESCE;
        let mut survivors: Vec<(usize, Stream)> = Vec::new();
        let mut joiners: Vec<Stream> = Vec::new();
        loop {
            let now = Instant::now();
            if now >= window.min(hard_deadline) {
                break;
            }
            if survivors.len() + joiners.len() == old_world - 1 + n_join {
                break;
            }
            match listener.accept() {
                Ok((s, _)) => {
                    let mut s = Stream::Unix(s);
                    s.set_nonblocking(false)?;
                    s.set_read_timeout(Some(Duration::from_secs(5)))?;
                    match read_hello(&mut s) {
                        Ok(h)
                            if h.run_id == self.run_id
                                && h.gen == gen
                                && h.intent == INTENT_REJOIN =>
                        {
                            if h.rank == RANK_NONE {
                                joiners.push(s);
                                window = Instant::now() + QUIESCE;
                            } else {
                                let r = h.rank as usize;
                                let dup = survivors.iter().any(|(or, _)| *or == r);
                                if r == 0 || r >= old_world || dup {
                                    let _ = write_reply28(&mut s, ST_BAD_RANK, 0, gen, 0);
                                    s.shutdown();
                                } else {
                                    survivors.push((r, s));
                                    window = Instant::now() + QUIESCE;
                                }
                            }
                        }
                        Ok(_) => {
                            let _ = write_reply28(&mut s, ST_BAD_GEN, 0, gen, 0);
                            s.shutdown();
                        }
                        Err(_) => s.shutdown(),
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(io_ctx(e, "accept membership hello")),
            }
        }
        let _ = std::fs::remove_file(&mpath);
        // Assign the new world: coordinator keeps rank 0, survivors sort
        // by old rank (a deterministic, shard-map-friendly order),
        // joiners follow in arrival order.
        survivors.sort_by_key(|(r, _)| *r);
        let new_world = 1 + survivors.len() + joiners.len();
        let mut new_rank = 1u32;
        for (_, mut s) in survivors.into_iter().chain(joiners.into_iter().map(|s| (0usize, s))) {
            // A grant that fails to send means that member died between
            // hello and grant; it simply misses the generation (and the
            // data-plane rendezvous will time out if it was counted —
            // the next regroup excises it).
            let _ = write_reply28(&mut s, ST_OK, new_world as u32, gen, new_rank);
            s.shutdown();
            new_rank += 1;
        }
        let mut sh = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        sh.world = new_world as u32;
        sh.gen = gen;
        sh.state = RunState::Running;
        if crate::obs::trace::active() {
            crate::obs::trace::instant_rank(
                "regroup",
                "elastic",
                0,
                vec![
                    ("gen", crate::obs::trace::ArgVal::U(gen)),
                    ("world", crate::obs::trace::ArgVal::U(new_world as u64)),
                    ("joiners", crate::obs::trace::ArgVal::U(n_join as u64)),
                ],
            );
        }
        Ok(Membership { rank: 0, world: new_world, gen })
    }

    /// Mark the run finished in status replies (joiners are turned away
    /// with `GEN_DONE` from this point on).
    pub fn finish(&self) {
        self.shared.lock().unwrap_or_else(|e| e.into_inner()).state = RunState::Done;
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        // Tell parked joiners the world is gone rather than ghosting
        // them into their read timeout.
        for mut s in self.parked.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = write_reply28(&mut s, ST_OK, 0, GEN_DONE, u32::MAX);
            s.shutdown();
        }
        let _ = std::fs::remove_file(ctrl_endpoint(&self.base));
    }
}

/// The control thread body: answer status queries, park join requests.
fn ctrl_serve(
    listener: UnixListener,
    run_id: u64,
    shared: Arc<Mutex<CtrlShared>>,
    parked: Arc<Mutex<Vec<Stream>>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((s, _)) => {
                let mut s = Stream::Unix(s);
                if s.set_nonblocking(false).is_err()
                    || s.set_read_timeout(Some(Duration::from_secs(5))).is_err()
                {
                    continue;
                }
                match read_hello(&mut s) {
                    Ok(h) if h.run_id != run_id => {
                        let _ = write_reply28(&mut s, ST_BAD_RUN_ID, 0, 0, 0);
                        s.shutdown();
                    }
                    Ok(h) if h.intent == INTENT_STATUS => {
                        let (w, g, st) = {
                            let sh = shared.lock().unwrap_or_else(|e| e.into_inner());
                            (sh.world, sh.gen, sh.state)
                        };
                        // The live telemetry block: step/loss/scale from
                        // the always-on obs snapshot this (coordinator =
                        // rank 0) process maintains, bytes from its
                        // traffic slots — a `/status` endpoint readable
                        // mid-run without touching the data plane.
                        let m = crate::obs::metrics::status_snapshot(
                            crate::dist::traffic::total_sent(),
                        );
                        if write_reply28(&mut s, ST_OK, w, g, st.to_u32()).is_ok() {
                            let _ = write_status_metrics(&mut s, &m);
                        }
                        s.shutdown();
                    }
                    Ok(h) if h.intent == INTENT_JOIN => {
                        let done = {
                            let sh = shared.lock().unwrap_or_else(|e| e.into_inner());
                            sh.state == RunState::Done
                        };
                        if done {
                            let _ = write_reply28(&mut s, ST_OK, 0, GEN_DONE, u32::MAX);
                            s.shutdown();
                        } else {
                            parked.lock().unwrap_or_else(|e| e.into_inner()).push(s);
                        }
                    }
                    Ok(_) => {
                        // WORKER/REJOIN intents belong on the data and
                        // membership endpoints, not the control one.
                        let _ = write_reply28(&mut s, ST_BAD_GEN, 0, 0, 0);
                        s.shutdown();
                    }
                    Err(_) => s.shutdown(),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Survivor side of a regroup: dial generation `gen`'s membership
/// endpoint (with backoff — the coordinator may not have bound it yet),
/// present this process's old rank, and receive the new membership
/// grant. `old_rank == RANK_NONE as usize` marks a fresh joiner
/// (see [`join`], which wraps this).
pub fn rejoin(rendezvous: &str, run_id: u64, old_rank: usize, gen: u64) -> io::Result<Membership> {
    let base = unix_base(rendezvous, "rejoin")?;
    let mpath = membership_endpoint(&base, gen);
    let ep = Endpoint::Unix(mpath.clone());
    let deadline = Instant::now() + rendezvous_timeout();
    let what = format!("rejoin: dial membership endpoint unix:{mpath}");
    let mut s = dial_backoff(&ep, deadline, Backoff::new(2, 200, old_rank as u64), &what)?;
    s.set_read_timeout(Some(rendezvous_timeout()))?;
    write_hello(&mut s, run_id, 0, old_rank as u32, gen, INTENT_REJOIN)?;
    let (status, world, got_gen, rank) =
        read_reply28(&mut s).map_err(|e| io_ctx(e, "rejoin: read membership grant"))?;
    s.shutdown();
    if status != ST_OK {
        return Err(io::Error::new(
            io::ErrorKind::ConnectionRefused,
            format!("rejoin rejected: {}", status_msg(status)),
        ));
    }
    if got_gen != gen || rank == u32::MAX {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "malformed membership grant"));
    }
    if crate::obs::trace::active() {
        crate::obs::trace::instant_rank(
            "rejoin",
            "elastic",
            rank as usize,
            vec![
                ("gen", crate::obs::trace::ArgVal::U(gen)),
                ("world", crate::obs::trace::ArgVal::U(world as u64)),
            ],
        );
    }
    Ok(Membership { rank: rank as usize, world: world as usize, gen })
}

/// Join a running elastic world as a fresh worker: park a join request
/// at the control endpoint, block until the coordinator announces a
/// regroup (bounded by the `SINGD_SOCK_TIMEOUT_SECS` read timeout when
/// set), then [`rejoin`] into the announced generation. Errors if the
/// run already finished.
pub fn join(rendezvous: &str, run_id: u64) -> io::Result<Membership> {
    let base = unix_base(rendezvous, "join")?;
    let cpath = ctrl_endpoint(&base);
    let ep = Endpoint::Unix(cpath.clone());
    let deadline = Instant::now() + rendezvous_timeout();
    let what = format!("join: dial control endpoint unix:{cpath}");
    let mut s = dial_backoff(&ep, deadline, Backoff::new(2, 200, 0x6a6f_696e), &what)?;
    write_hello(&mut s, run_id, 0, RANK_NONE, 0, INTENT_JOIN)?;
    // Block until the next regroup is announced; an env-set socket
    // timeout bounds the wait, the default waits indefinitely.
    s.set_read_timeout(read_timeout())?;
    let (status, _world, gen, _extra) =
        read_reply28(&mut s).map_err(|e| io_ctx(e, "join: read regroup announcement"))?;
    s.shutdown();
    if status != ST_OK {
        return Err(io::Error::new(
            io::ErrorKind::ConnectionRefused,
            format!("join rejected: {}", status_msg(status)),
        ));
    }
    if gen == GEN_DONE {
        return Err(io::Error::new(io::ErrorKind::NotConnected, "join refused: world finished"));
    }
    rejoin(rendezvous, run_id, RANK_NONE as usize, gen)
}

/// Query a running elastic world's membership epoch and state from its
/// control endpoint.
pub fn status(rendezvous: &str, run_id: u64) -> io::Result<WorldStatus> {
    let base = unix_base(rendezvous, "status query")?;
    let cpath = ctrl_endpoint(&base);
    let ep = Endpoint::Unix(cpath.clone());
    let deadline = Instant::now() + rendezvous_timeout();
    let what = format!("status: dial control endpoint unix:{cpath}");
    let mut s = dial_backoff(&ep, deadline, Backoff::new(2, 200, 0x7374_6174), &what)?;
    s.set_read_timeout(Some(rendezvous_timeout()))?;
    write_hello(&mut s, run_id, 0, RANK_NONE, 0, INTENT_STATUS)?;
    let (status, world, gen, state) =
        read_reply28(&mut s).map_err(|e| io_ctx(e, "status: read reply"))?;
    if status != ST_OK {
        s.shutdown();
        return Err(io::Error::new(
            io::ErrorKind::ConnectionRefused,
            format!("status query rejected: {}", status_msg(status)),
        ));
    }
    let metrics =
        read_status_metrics(&mut s).map_err(|e| io_ctx(e, "status: read metrics block"))?;
    s.shutdown();
    Ok(WorldStatus { world: world as usize, gen, state: RunState::from_u32(state)?, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Pcg;

    #[test]
    fn transport_parse_roundtrip() {
        for t in [Transport::Local, Transport::Socket] {
            assert_eq!(Transport::parse(t.name()), Some(t));
        }
        assert_eq!(Transport::parse("uds"), Some(Transport::Socket));
        assert!(Transport::parse("carrier-pigeon").is_none());
    }

    #[test]
    fn endpoint_parse_families() {
        assert_eq!(Endpoint::parse("unix:/tmp/x.sock"), Endpoint::Unix("/tmp/x.sock".into()));
        assert_eq!(Endpoint::parse("tcp:127.0.0.1:4000"), Endpoint::Tcp("127.0.0.1:4000".into()));
        assert_eq!(Endpoint::parse("/tmp/bare.sock"), Endpoint::Unix("/tmp/bare.sock".into()));
    }

    #[test]
    fn mats_payload_roundtrips_bitwise() {
        let mut rng = Pcg::new(41);
        let mats = vec![
            rng.normal_mat(3, 5, 1.0),
            Mat::zeros(0, 7),
            Mat::from_vec(1, 1, vec![f32::MIN_POSITIVE]),
            rng.normal_mat(8, 2, 1e-8),
        ];
        let decoded = decode_mats(&encode_mats(&mats)).unwrap();
        assert_eq!(decoded.len(), mats.len());
        for (d, m) in decoded.iter().zip(&mats) {
            assert_eq!(d.shape(), m.shape());
            assert_eq!(d.data(), m.data());
        }
        // Empty list.
        assert!(decode_mats(&encode_mats(&[])).unwrap().is_empty());
    }

    #[test]
    fn f64_payload_roundtrips_bitwise() {
        let vals = vec![0.1f64, -3.5e300, f64::MIN_POSITIVE, 0.0];
        let decoded = decode_f64s(&encode_f64s(&vals)).unwrap();
        assert_eq!(decoded.len(), vals.len());
        for (d, v) in decoded.iter().zip(&vals) {
            assert_eq!(d.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn corrupt_payloads_are_rejected() {
        let good = encode_mats(&[Mat::zeros(2, 2)]);
        assert!(decode_mats(&good[..good.len() - 1]).is_err(), "truncation");
        let mut extra = good.clone();
        extra.push(0);
        assert!(decode_mats(&extra).is_err(), "trailing bytes");
        assert!(decode_f64s(&encode_mats(&[Mat::zeros(1, 1)])).is_err(), "type confusion");
    }

    #[test]
    fn gathered_roundtrip() {
        let parts = vec![vec![1u8, 2, 3], Vec::new(), vec![9u8; 100]];
        assert_eq!(decode_gathered(&encode_gathered(&parts)).unwrap(), parts);
    }

    #[test]
    fn socket_world_exchanges_in_rank_order() {
        for world in [1usize, 2, 4] {
            let outs = run_ranks_socket(world, |c| {
                assert_eq!(c.world_size(), world);
                let parts = c.exchange_f64(vec![c.rank() as f64 * 10.0]);
                parts.iter().map(|p| p[0]).collect::<Vec<_>>()
            });
            for got in outs {
                assert_eq!(got, (0..world).map(|r| r as f64 * 10.0).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn socket_repeated_exchanges_keep_rounds_separated() {
        let world = 3;
        let outs = run_ranks_socket(world, |c| {
            let mut acc = Vec::new();
            for round in 0..20u32 {
                if c.rank() == round as usize % world {
                    std::hint::black_box((0..500).map(|i| i as f64).sum::<f64>());
                }
                let parts = c.exchange_f64(vec![round as f64 * 100.0 + c.rank() as f64]);
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(p[0], round as f64 * 100.0 + r as f64);
                }
                acc.push(parts[2][0]);
            }
            acc
        });
        assert!(outs.iter().all(|v| v == &outs[0]));
    }

    #[test]
    fn mesh_p2p_roundtrips_in_ring_order() {
        for world in [2usize, 3, 4] {
            let outs = run_ranks_socket(world, |c| {
                let right = (c.rank() + 1) % world;
                let left = (c.rank() + world - 1) % world;
                let payload = vec![c.rank() as u8; 8];
                c.send_recv_bytes(right, &payload, left)
            });
            for (r, got) in outs.iter().enumerate() {
                let left = (r + world - 1) % world;
                assert_eq!(got, &vec![left as u8; 8], "world {world} rank {r}");
            }
        }
    }

    #[test]
    fn mesh_p2p_fifo_and_empty_payloads() {
        let outs = run_ranks_socket(2, |c| {
            let other = 1 - c.rank();
            // Asymmetric-by-rank but SPMD-paired: rank 0 sends two frames
            // first (they fit in the socket buffer), rank 1 receives then
            // replies with an empty frame.
            if c.rank() == 0 {
                c.send_bytes(other, &[1, 2, 3]);
                c.send_bytes(other, &[]);
                c.recv_bytes(other)
            } else {
                let a = c.recv_bytes(other);
                let b = c.recv_bytes(other);
                assert_eq!(a, vec![1, 2, 3]);
                assert_eq!(b, Vec::<u8>::new());
                c.send_bytes(other, &[9]);
                vec![0]
            }
        });
        assert_eq!(outs[0], vec![9]);
    }

    #[test]
    fn duplex_survives_payloads_larger_than_socket_buffers() {
        // Both ranks send 2 MiB to each other simultaneously — far past
        // the kernel's socket buffers, so a blocking send-then-recv
        // schedule would deadlock. The duplex progress loop must drain
        // both directions.
        let n = 2 << 20;
        let outs = run_ranks_socket(2, |c| {
            let other = 1 - c.rank();
            let payload = vec![c.rank() as u8 + 1; n];
            let got = c.send_recv_bytes(other, &payload, other);
            (got.len(), got.iter().all(|&b| b == other as u8 + 1))
        });
        for (r, (len, ok)) in outs.iter().enumerate() {
            assert_eq!(*len, n, "rank {r}");
            assert!(ok, "rank {r}: payload corrupted");
        }
    }

    #[test]
    fn stale_run_id_is_rejected_at_handshake() {
        let rendezvous = fresh_rendezvous();
        let run_id = fresh_run_id();
        let rv = &rendezvous;
        std::thread::scope(|s| {
            let server = s.spawn(move || SocketComm::connect(0, 2, rv, run_id));
            // A peer from a previous (dead) run: wrong run id.
            let stale = s.spawn(move || SocketComm::connect(1, 2, rv, run_id ^ 0xdead));
            let err = stale.join().unwrap();
            assert!(err.is_err(), "stale peer must be rejected");
            let msg = err.err().unwrap().to_string();
            assert!(msg.contains("stale peer"), "unexpected rejection reason: {msg}");
            // The real peer still assembles the world.
            let fresh = s.spawn(move || SocketComm::connect(1, 2, rv, run_id));
            let c0 = server.join().unwrap().expect("server");
            let c1 = fresh.join().unwrap().expect("fresh peer");
            let h = s.spawn(move || {
                let parts = c1.exchange_f64(vec![4.0]);
                (parts[0][0], parts[1][0])
            });
            let parts = c0.exchange_f64(vec![3.0]);
            assert_eq!((parts[0][0], parts[1][0]), (3.0, 4.0));
            assert_eq!(h.join().unwrap(), (3.0, 4.0));
        });
    }

    #[test]
    fn world_size_mismatch_is_rejected_at_handshake() {
        let rendezvous = fresh_rendezvous();
        let run_id = fresh_run_id();
        let rv = &rendezvous;
        std::thread::scope(|s| {
            let server = s.spawn(move || SocketComm::connect(0, 2, rv, run_id));
            let wrong = s.spawn(move || {
                // Dials claiming a 4-rank world against a 2-rank server.
                let ep = Endpoint::parse(rv);
                dial_root(&ep, 1, 4, run_id, 0)
            });
            assert!(wrong.join().unwrap().is_err(), "world mismatch must be rejected");
            let ok = s.spawn(move || SocketComm::connect(1, 2, rv, run_id));
            assert!(server.join().unwrap().is_ok());
            assert!(ok.join().unwrap().is_ok());
        });
    }

    #[test]
    fn worker_env_requires_rank_below_world() {
        // Pure parsing logic (no env mutation — tests run concurrently):
        // rank >= world yields None via the guard.
        assert!(worker_env().is_none() || worker_env().unwrap().rank < worker_env().unwrap().world);
    }

    #[test]
    fn fresh_rendezvous_is_unique() {
        let a = fresh_rendezvous();
        let b = fresh_rendezvous();
        assert_ne!(a, b);
        assert!(a.starts_with("unix:"));
    }

    #[test]
    fn stale_generation_is_rejected_at_handshake() {
        let rendezvous = fresh_rendezvous();
        let run_id = fresh_run_id();
        let rv = &rendezvous;
        std::thread::scope(|s| {
            let server = s.spawn(move || SocketComm::connect(0, 2, rv, run_id));
            // A straggler stamped with generation 1 dials a generation-0
            // world at the same endpoint and run id.
            let stale = s.spawn(move || {
                let ep = Endpoint::parse(rv);
                dial_root(&ep, 1, 2, run_id, 1)
            });
            let err = stale.join().unwrap();
            assert!(err.is_err(), "stale generation must be rejected");
            let msg = err.err().unwrap().to_string();
            assert!(msg.contains("stale generation"), "unexpected rejection reason: {msg}");
            let ok = s.spawn(move || SocketComm::connect(1, 2, rv, run_id));
            assert!(server.join().unwrap().is_ok());
            assert!(ok.join().unwrap().is_ok());
        });
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_growing() {
        let delays = |salt: u64| -> Vec<u64> {
            let mut b = Backoff::new(2, 200, salt);
            (0..12).map(|_| b.next_delay().as_millis() as u64).collect()
        };
        let a = delays(3);
        let b = delays(3);
        assert_eq!(a, b, "same salt must replay the same schedule");
        // Every delay sits inside the jitter envelope [exp/2, exp] of the
        // capped exponential.
        for (i, &d) in a.iter().enumerate() {
            let exp = (2u64 << i.min(16)).min(200) / 2 * 2; // base<<i, capped
            let exp = exp.min(200).max(2);
            assert!(d >= exp / 2 && d <= exp, "attempt {i}: delay {d} outside [{}, {exp}]", exp / 2);
        }
        // Late attempts are pinned at the cap envelope.
        assert!(a[11] >= 100 && a[11] <= 200, "capped delay out of range: {}", a[11]);
        // Different salts decorrelate (not all delays identical).
        assert_ne!(delays(0), delays(1));
    }

    #[test]
    fn timeout_env_values_parse_loudly() {
        assert_eq!(parse_timeout_secs("30"), Ok(30));
        assert_eq!(parse_timeout_secs(" 5 "), Ok(5));
        assert!(parse_timeout_secs("0").is_err(), "zero timeout must be rejected");
        assert!(parse_timeout_secs("ten").is_err());
        assert!(parse_timeout_secs("-3").is_err());
        assert!(parse_timeout_secs("1.5").is_err());
        assert_eq!(parse_env_u64("SINGD_RANK", "7"), Ok(7));
        let e = parse_env_u64("SINGD_RANK", "x7").unwrap_err();
        assert!(e.contains("SINGD_RANK") && e.contains("x7"), "error must name var+value: {e}");
    }

    #[test]
    fn elastic_endpoints_derive_from_unix_base() {
        assert_eq!(elastic_data_endpoint("unix:/tmp/a.sock", 0).unwrap(), "unix:/tmp/a.sock");
        assert_eq!(elastic_data_endpoint("/tmp/a.sock", 2).unwrap(), "unix:/tmp/a.sock.g2");
        assert!(elastic_data_endpoint("tcp:127.0.0.1:4000", 1).is_err(), "tcp must be rejected");
        assert_eq!(mix_run_id(42, 0), 42, "generation 0 must not change the run id");
        assert_ne!(mix_run_id(42, 1), 42);
        assert_ne!(mix_run_id(42, 1), mix_run_id(42, 2));
    }

    #[test]
    fn status_join_rejoin_roundtrip_through_coordinator() {
        let rendezvous = fresh_rendezvous();
        let run_id = fresh_run_id();
        let coord = Coordinator::new(&rendezvous, run_id, 3).expect("coordinator");
        // Status reflects the initial world. The metrics block mirrors
        // live process-wide telemetry (other tests may be stepping or
        // sending concurrently), so assert the membership fields only.
        let st = status(&rendezvous, run_id).expect("status");
        assert_eq!((st.world, st.gen, st.state), (3, 0, RunState::Running));
        // A stale-run status probe is rejected.
        let bad = status(&rendezvous, run_id ^ 1).unwrap_err().to_string();
        assert!(bad.contains("stale peer"), "unexpected status rejection: {bad}");
        // Survivors 1 and 2 of a 3-world rejoin generation 1 while a
        // fresh worker joins: world grows to 4, survivors keep their
        // rank order, the joiner lands last.
        let rv = &rendezvous;
        std::thread::scope(|s| {
            let j = s.spawn(move || join(rv, run_id));
            // Let the join request park before regrouping.
            while !coord.join_pending() {
                std::thread::sleep(Duration::from_millis(2));
            }
            let s2 = s.spawn(move || rejoin(rv, run_id, 2, 1));
            let s1 = s.spawn(move || rejoin(rv, run_id, 1, 1));
            let m0 = coord.regroup(1).expect("regroup");
            assert_eq!(m0, Membership { rank: 0, world: 4, gen: 1 });
            assert_eq!(s1.join().unwrap().unwrap(), Membership { rank: 1, world: 4, gen: 1 });
            assert_eq!(s2.join().unwrap().unwrap(), Membership { rank: 2, world: 4, gen: 1 });
            assert_eq!(j.join().unwrap().unwrap(), Membership { rank: 3, world: 4, gen: 1 });
        });
        let st = status(&rendezvous, run_id).expect("status after regroup");
        assert_eq!((st.world, st.gen, st.state), (4, 1, RunState::Running));
        // After finish(), joiners are turned away.
        coord.finish();
        let refused = join(&rendezvous, run_id).unwrap_err().to_string();
        assert!(refused.contains("world finished"), "unexpected join refusal: {refused}");
    }
}
