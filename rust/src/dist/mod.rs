//! Distributed execution subsystem: deterministic collectives over
//! pluggable transports and ZeRO-style sharded Kronecker-factor
//! preconditioning.
//!
//! The full design — layer diagram, wire protocol, and the reasoning
//! behind every invariant below — lives in `ARCHITECTURE.md` and
//! `PROTOCOL.md` at the repository root.
//!
//! Two transports implement the [`Communicator`] primitives:
//!
//! - [`Transport::Local`] ([`LocalComm`]) runs an `R`-rank data-parallel
//!   job inside one process: ranks are SPMD closures executed
//!   concurrently (on the persistent worker pool of
//!   [`crate::tensor::pool`] when it is large enough, on dedicated
//!   scoped threads otherwise) over a shared-memory rendezvous plus
//!   per-pair point-to-point mailboxes.
//! - [`Transport::Socket`] ([`SocketComm`], [`transport`]) joins `R`
//!   separate OS processes over Unix-domain sockets (TCP fallback) with
//!   a length-prefixed wire format: a rank-0 star for barrier exchanges
//!   and a full peer mesh (established at rendezvous) for point-to-point
//!   sends. Byte-exact payload images keep every collective bitwise
//!   identical to the local transport.
//!
//! On top of the primitives, [`collectives`] offers two interchangeable
//! collective algorithms ([`Algo`]): the rank-0 fan-in **star** and the
//! bandwidth-optimal **ring** (pairwise-exchange reduce-scatter + ring
//! all-gather, `~2·(R−1)/R·N` bytes per rank instead of the star's
//! rank-0 hotspot). Both produce bitwise-identical results because the
//! ring reduces every chunk at its destination with the same fixed
//! halving tree the star uses — see [`collectives`] for the schedule.
//! Ring is the default ([`default_algo`]); `SINGD_ALGO`, `[dist] algo`
//! and `--algo` select explicitly.
//!
//! Every collective also exists in **nonblocking** form: the
//! `istart_*` methods on [`Communicator`] return a [`PendingOp`] handle
//! serviced by a per-communicator FIFO progress engine
//! ([`pending`]), so callers overlap compute with communication and
//! block only at [`PendingOp::wait`]. With overlap enabled
//! ([`default_overlap`]; `SINGD_OVERLAP`, `[dist] overlap`,
//! `--overlap`, on by default) the ring all-reduce additionally runs
//! **chunk-pipelined** ([`collectives::all_reduce_sum_pipelined`]) and
//! the training driver issues its statistics gather and update
//! all-reduce as pending ops. None of this can change a single bit —
//! see contract 4 below.
//!
//! Layer-wise decomposition is the natural parallel axis for
//! Kronecker-factored methods (Koroko et al., 2023), and the
//! inverse-free SINGD update is nothing but matrix
//! multiplications and subtractions — exactly the ops that shard without
//! any rank ever holding a full inverse.
//!
//! # Determinism contract
//!
//! This module extends the crate's serial/pooled bitwise-parity contract
//! (`rust/tests/parallel.rs`) across world sizes:
//!
//! 1. **Collectives use a fixed reduction order.** Every reducing
//!    collective combines rank contributions with the balanced halving
//!    tree of [`collectives::tree_sum_f64`] — under *both* algorithms
//!    and on *both* transports, the floating-point reduction order is a
//!    function of the world size alone, never of scheduling
//!    (`rust/tests/dist.rs` asserts star/ring × local/socket bitwise
//!    conformance on randomized shapes).
//! 2. **Rank-count invariance** is achieved by exchanging *exact* data:
//!    the training driver ([`crate::train::train_dist`]) all-gathers raw
//!    per-row Kronecker statistics (a concatenation, no floating-point
//!    reduction) and recomputes contractions from the gathered
//!    full-batch matrices with the standard kernels, and the sharded
//!    optimizer path all-reduces zero-padded per-layer updates (each
//!    element has exactly one nonzero contributor, so reduction order
//!    cannot change the result). Under this scheme `ranks = R` training
//!    is bitwise identical to `ranks = 1` for any power-of-two `R`
//!    dividing the batch size (see `rust/tests/dist.rs`).
//! 3. A poisoned rendezvous (a rank panicking) wakes every peer —
//!    including peers blocked in point-to-point receives — so the
//!    failure propagates instead of deadlocking the process.
//! 4. **Overlap invariance.** Nonblocking and pipelined schedules are
//!    bitwise identical to their blocking counterparts, because the
//!    progress engine executes operations strictly in issue order (an
//!    SPMD-identical sequence), so the per-link wire order and every
//!    destination reduction tree are exactly those of the blocking
//!    schedule — overlap reorders *time*, never *reduction order*. The
//!    `SINGD_OVERLAP ∈ {0,1}` digest suites in `rust/tests/dist.rs` and
//!    `rust/tests/dist_proc.rs` enforce this end to end.
//!
//! Scalar exchanges ([`Communicator::exchange_f64`]: loss partials,
//! divergence flags) always ride the barrier-exchange star regardless of
//! [`Algo`] — they are a few bytes per step and double as the SPMD
//! heartbeat. They are also never compressed: the wire dtype below
//! applies to bulk tensor payloads only, so the control plane stays
//! exact.
//!
//! # Wire dtype (compressed collectives)
//!
//! [`Communicator::wire_dtype`] selects the element format bulk tensor
//! collectives move over the wire: stats all-gathers and reducing
//! all-reduces snap their contributions to the wire-representable set
//! ([`crate::numerics::Dtype::round`]) and ship 2-byte element images
//! under `bf16`/`fp16` — halving per-rank collective bytes — while
//! [`crate::numerics::Dtype::F32`] (the default) is the identity: exact
//! 4-byte frames, bitwise identical to the uncompressed protocol. The
//! reduction contract becomes `snap(tree(snap(contributions)))`, so the
//! determinism guarantee is refined to **bitwise within a wire dtype**:
//! at a fixed wire dtype and world size, results are still invariant
//! across transport × algorithm × overlap (ARCHITECTURE.md contract 7);
//! at a half wire dtype the serial-equality and rank-count-invariance
//! contracts deliberately no longer apply. Checkpoint state gathers and
//! broadcasts stay exact ([`Communicator::exchange_mats`]) regardless of
//! the knob.
//!
//! # The `SINGD_RANKS` / `SINGD_TRANSPORT` / `SINGD_ALGO` / `SINGD_OVERLAP` / `SINGD_STREAM` / `SINGD_WIRE_DTYPE` contract
//!
//! `SINGD_RANKS=<n>` sets the *default* world size,
//! `SINGD_TRANSPORT=<local|socket>` the *default* transport,
//! `SINGD_ALGO=<star|ring>` the *default* collective algorithm,
//! `SINGD_OVERLAP=<0|1>` the *default* overlap mode,
//! `SINGD_STREAM=<0|1>` the *default* layer-streaming mode and
//! `SINGD_WIRE_DTYPE=<f32|bf16|fp16>` the *default* wire dtype used by
//! config-driven entry points ([`crate::config::JobConfig`]); explicit
//! `[dist]` config keys and `--ranks` / `--transport` / `--algo` /
//! `--overlap` / `--stream` / `--wire-dtype` CLI flags override them.
//! Read once, cached. Like the algorithm, the overlap mode, streaming
//! mode and wire dtype are run-level constants: every rank of a world
//! must be constructed with the same value (the socket launcher pins
//! them into workers' environments).
#![deny(missing_docs)]

pub mod bucket;
pub mod collectives;
pub mod pending;
pub mod shard;
pub mod traffic;
pub mod transport;

pub use collectives::Algo;
pub use pending::PendingOp;
pub use transport::{SocketComm, Transport};

use crate::numerics::Dtype;
use crate::tensor::{pool, Mat};
use pending::Engine;
use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// How optimizer state is laid out across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistStrategy {
    /// Every rank holds the full optimizer state and performs every
    /// layer's update redundantly (classic data parallelism).
    Replicated,
    /// ZeRO-style layer sharding: each rank owns the Kronecker factors
    /// (and momenta) of its layer shard only, updates them locally, and
    /// the preconditioned updates are exchanged — per-rank factor memory
    /// drops by roughly the world size.
    FactorSharded,
}

impl DistStrategy {
    /// Parse `"replicated"` / `"factor-sharded"` (aliases: `"sharded"`,
    /// `"zero"`).
    pub fn parse(s: &str) -> Option<DistStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "replicated" | "rep" | "ddp" => Some(DistStrategy::Replicated),
            "factor-sharded" | "factor_sharded" | "sharded" | "zero" => {
                Some(DistStrategy::FactorSharded)
            }
            _ => None,
        }
    }

    /// Canonical name (the string [`DistStrategy::parse`] round-trips).
    pub fn name(&self) -> &'static str {
        match self {
            DistStrategy::Replicated => "replicated",
            DistStrategy::FactorSharded => "factor-sharded",
        }
    }
}

/// A rank's view of the distributed topology, handed to optimizers so
/// their per-layer loops know which layers this rank owns.
#[derive(Clone, Copy, Debug)]
pub struct DistCtx {
    /// Optimizer-state layout across ranks.
    pub strategy: DistStrategy,
    /// This rank's index in `0..world`.
    pub rank: usize,
    /// World size.
    pub world: usize,
}

impl DistCtx {
    /// The single-process topology: one rank, replicated.
    pub fn single() -> DistCtx {
        DistCtx { strategy: DistStrategy::Replicated, rank: 0, world: 1 }
    }

    /// A validated topology handle (`rank < world`, `world >= 1`).
    pub fn new(strategy: DistStrategy, rank: usize, world: usize) -> DistCtx {
        assert!(world >= 1, "dist: world size must be >= 1");
        assert!(rank < world, "dist: rank {rank} out of range for world {world}");
        DistCtx { strategy, rank, world }
    }

    /// Whether this rank owns layer `l` (always true when replicated).
    /// The factor-sharded layout is the round-robin assignment of
    /// [`shard::round_robin_owner`], shared with the training driver.
    pub fn owns_layer(&self, l: usize) -> bool {
        match self.strategy {
            DistStrategy::Replicated => true,
            DistStrategy::FactorSharded => shard::round_robin_owner(l, self.world) == self.rank,
        }
    }

    /// The owned-layer set in the [`crate::optim::Optimizer::owned_layers`]
    /// convention: `None` when every layer is owned (replicated or
    /// single-rank), `Some(list)` under multi-rank factor sharding. The
    /// single source of truth the optimizers and the training driver's
    /// update exchange both delegate to.
    pub fn owned_layers(&self, n_layers: usize) -> Option<Vec<usize>> {
        if self.world > 1 && self.strategy == DistStrategy::FactorSharded {
            Some((0..n_layers).filter(|&l| self.owns_layer(l)).collect())
        } else {
            None
        }
    }
}

/// Default world size: `SINGD_RANKS` (read once, cached), else 1.
pub fn default_ranks() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_RANKS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map(|n| n.max(1))
            .unwrap_or(1)
    })
}

/// Default transport: `SINGD_TRANSPORT` (read once, cached), else
/// [`Transport::Local`]. Explicit `[dist] transport` config keys and
/// `--transport` CLI flags override it, mirroring the `SINGD_RANKS`
/// contract.
pub fn default_transport() -> Transport {
    static CACHED: OnceLock<Transport> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_TRANSPORT")
            .ok()
            .and_then(|v| Transport::parse(&v))
            .unwrap_or(Transport::Local)
    })
}

/// Default collective algorithm: `SINGD_ALGO` (read once, cached), else
/// [`Algo::Ring`] — the bandwidth-optimal schedule is the default for
/// every multi-rank world (world 1 short-circuits every collective, so
/// the knob is moot there). Explicit `[dist] algo` config keys and
/// `--algo` CLI flags override it.
pub fn default_algo() -> Algo {
    static CACHED: OnceLock<Algo> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_ALGO").ok().and_then(|v| Algo::parse(&v)).unwrap_or(Algo::Ring)
    })
}

/// Parse an overlap-mode string: `"1"` / `"true"` / `"on"` / `"yes"` ⇒
/// overlap, `"0"` / `"false"` / `"off"` / `"no"` ⇒ blocking. The single
/// parser behind `SINGD_OVERLAP`, `[dist] overlap` string forms and the
/// `--overlap` CLI flag.
pub fn parse_overlap(s: &str) -> Option<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

/// Default overlap mode: `SINGD_OVERLAP` (read once, cached), else `true`
/// — nonblocking handles, the chunk-pipelined ring and the training
/// driver's comm/compute overlap are on by default (bitwise identical to
/// blocking by contract 4; the knob exists for the determinism suites
/// and for perf A/B runs). Explicit `[dist] overlap` config keys and
/// `--overlap` CLI flags override it.
pub fn default_overlap() -> bool {
    static CACHED: OnceLock<bool> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_OVERLAP").ok().and_then(|v| parse_overlap(&v)).unwrap_or(true)
    })
}

/// Default layer-streaming mode: `SINGD_STREAM` (read once, cached; same
/// `0|1|on|off` grammar as [`parse_overlap`]), else `true` — the training
/// driver issues each layer's statistics gather from inside the backward
/// pass (see `DistCfg::stream` in [`crate::train`]). Streaming rides the
/// overlap engine, is a no-op when overlap is off, and is bitwise
/// identical either way (determinism contract 8). Explicit `[dist]
/// stream` config keys and `--stream` CLI flags override it.
pub fn default_stream() -> bool {
    static CACHED: OnceLock<bool> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_STREAM").ok().and_then(|v| parse_overlap(&v)).unwrap_or(true)
    })
}

/// Default wire dtype for compressed collectives: `SINGD_WIRE_DTYPE`
/// (read once, cached), else [`Dtype::F32`] — exact 4-byte frames, the
/// bitwise-identical-to-serial default. `bf16` / `fp16` halve the bulk
/// collective bytes at the cost of snapping contributions to the wire
/// format (see the module docs). Explicit `[dist] wire_dtype` config
/// keys and `--wire-dtype` CLI flags override it.
pub fn default_wire_dtype() -> Dtype {
    static CACHED: OnceLock<Dtype> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_WIRE_DTYPE")
            .ok()
            .and_then(|v| Dtype::parse(&v))
            .unwrap_or(Dtype::F32)
    })
}

/// Rank/topology plus the communication primitives every collective is
/// built on: a barrier exchange (each rank contributes one payload per
/// call and receives all ranks' payloads in rank order), point-to-point
/// byte transfers (the seam the ring schedules — and any future
/// NCCL-style backend — plug into), and nonblocking `istart_*` variants
/// returning [`PendingOp`] handles serviced by the communicator's
/// progress engine ([`pending`]).
///
/// # SPMD call-order obligations
///
/// All ranks must issue the same global sequence of *collective
/// operations*; within one operation, the per-rank primitive calls may
/// differ only in the pattern the operation prescribes (e.g. a ring step
/// sends to `(r+s) % R` while receiving from `(r−s) % R`). Concretely:
///
/// - every [`exchange_mats`](Communicator::exchange_mats) /
///   [`exchange_f64`](Communicator::exchange_f64) /
///   [`barrier`](Communicator::barrier) must be issued by **every** rank,
///   in the same order;
/// - every [`send_bytes`](Communicator::send_bytes) to rank `p` must be
///   matched by exactly one [`recv_bytes`](Communicator::recv_bytes)
///   from this rank on `p`, in the same per-link order (both transports
///   stamp and check a per-direction sequence number, so violations fail
///   loudly instead of delivering garbage);
/// - a rank must never `send`/`recv` with itself;
/// - an `istart_*` call *issues* its operation at the call site: the
///   issue point — not the `wait` — is the operation's position in the
///   global SPMD sequence. Issuing is therefore obligatory on every
///   rank in the same order, while `wait`/`poll`/drop are local actions
///   with no cross-rank meaning. A dropped [`PendingOp`] still executes
///   (peers depend on it); see [`pending`] for the exact semantics.
///
/// Violations panic (poisoning the world) rather than misdeliver.
pub trait Communicator {
    /// This rank's index in `0..world_size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the world.
    fn world_size(&self) -> usize;

    /// The collective algorithm the [`collectives`] dispatchers use on
    /// this communicator (a run-level constant: every rank of a world
    /// must be constructed with the same value).
    fn algo(&self) -> Algo;

    /// Whether overlapped schedules are enabled on this communicator (a
    /// run-level constant, like [`algo`](Communicator::algo)): the
    /// chunk-pipelined ring all-reduce and the training driver's
    /// comm/compute overlap dispatch on it. Bitwise-neutral by contract
    /// 4 — the knob trades progress-engine overhead for overlap.
    fn overlap(&self) -> bool;

    /// The element format bulk tensor collectives move over the wire (a
    /// run-level constant, like [`algo`](Communicator::algo)): the
    /// [`collectives`] dispatchers snap contributions to this format's
    /// representable set and transports ship dtype-width element images.
    /// [`Dtype::F32`] (the default) is the identity — exact 4-byte
    /// frames, bitwise identical to the uncompressed protocol.
    fn wire_dtype(&self) -> Dtype {
        Dtype::F32
    }

    /// Exchange a list of matrices; returns every rank's payload in rank
    /// order. A *barrier*: no rank returns before every rank has
    /// deposited. Every rank must call it, in the same global order.
    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>>;

    /// [`exchange_mats`](Communicator::exchange_mats) over wire-dtype
    /// frames: payload elements travel (or, on pointer-sharing
    /// transports, are *accounted*) at
    /// [`wire_dtype`](Communicator::wire_dtype) width. Callers must snap
    /// payloads to the wire-representable set first
    /// ([`collectives`] does) so the narrowing encode is lossless. The
    /// default is the exact exchange — correct for the `F32` wire;
    /// transports with a half wire dtype override it. Checkpoint state
    /// gathers keep calling the exact
    /// [`exchange_mats`](Communicator::exchange_mats) directly.
    fn exchange_mats_wire(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        self.exchange_mats(mats)
    }

    /// Exchange a list of f64 scalars (loss partials, divergence flags);
    /// same barrier/call-order obligations as
    /// [`exchange_mats`](Communicator::exchange_mats).
    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>>;

    /// Block until every rank reaches this point (an empty exchange).
    fn barrier(&self) {
        let _ = self.exchange_f64(Vec::new());
    }

    /// Point-to-point: send `payload` to rank `to` (`to != rank()`).
    /// May block until the peer's matching
    /// [`recv_bytes`](Communicator::recv_bytes) drains the link, so a
    /// symmetric schedule where every rank sends before receiving must
    /// use [`send_recv_bytes`](Communicator::send_recv_bytes) instead.
    /// Delivery is FIFO per `(sender, receiver)` pair.
    fn send_bytes(&self, to: usize, payload: &[u8]);

    /// Point-to-point: receive the next payload from rank `from`
    /// (`from != rank()`). Blocks until the peer's matching
    /// [`send_bytes`](Communicator::send_bytes) arrives; panics if the
    /// peer died or shut down with this receive pending.
    fn recv_bytes(&self, from: usize) -> Vec<u8>;

    /// Combined send-to-`to` + receive-from-`from`, progressing both
    /// directions concurrently — the deadlock-free primitive for
    /// symmetric schedules (every ring step is one `send_recv_bytes`).
    /// Equivalent to a [`send_bytes`](Communicator::send_bytes) and a
    /// [`recv_bytes`](Communicator::recv_bytes) whose relative order the
    /// transport may interleave.
    fn send_recv_bytes(&self, to: usize, payload: &[u8], from: usize) -> Vec<u8> {
        self.send_bytes(to, payload);
        self.recv_bytes(from)
    }

    /// Nonblocking [`exchange_mats`](Communicator::exchange_mats): the
    /// exchange is issued here (taking its place in the SPMD order) and
    /// serviced by the progress engine; the result arrives at
    /// [`PendingOp::wait`]. The default is the degenerate
    /// already-completed form — correct, but with no overlap; engine-
    /// backed transports override it.
    fn istart_exchange_mats(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        PendingOp::ready(self.exchange_mats(mats))
    }

    /// Nonblocking [`exchange_f64`](Communicator::exchange_f64); same
    /// issue-order semantics as
    /// [`istart_exchange_mats`](Communicator::istart_exchange_mats).
    fn istart_exchange_f64(&self, vals: Vec<f64>) -> PendingOp<Vec<Arc<Vec<f64>>>> {
        PendingOp::ready(self.exchange_f64(vals))
    }

    /// Nonblocking [`send_recv_bytes`](Communicator::send_recv_bytes)
    /// (owned payload, since the transfer may outlive the call site) —
    /// the micro-op the chunk-pipelined ring schedules with. Same
    /// issue-order semantics as the other `istart_*` methods.
    fn istart_send_recv_bytes(
        &self,
        to: usize,
        payload: Vec<u8>,
        from: usize,
    ) -> PendingOp<Vec<u8>> {
        PendingOp::ready(self.send_recv_bytes(to, &payload, from))
    }

    /// Nonblocking [`collectives::all_gather`]: issued here, serviced by
    /// the progress engine (no default — each transport submits the
    /// whole gather as one engine op over its shareable core, so the
    /// issuing thread overlaps compute with the transfer).
    fn istart_all_gather(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>>;

    /// Nonblocking [`collectives::all_reduce_sum`]; same contract as
    /// [`istart_all_gather`](Communicator::istart_all_gather). The
    /// bucketed update exchange of the training driver issues one of
    /// these per bucket and packs the next bucket while it flies.
    fn istart_all_reduce_sum(&self, mats: Vec<Mat>) -> PendingOp<Vec<Mat>>;

    /// Zero-copy barrier gather, or `Err(mats)` (the default) when this
    /// transport moves real bytes. [`collectives::all_gather`] consults
    /// it under [`Algo::Ring`]: a gather is pure data movement, so on a
    /// shared-memory transport the ring's encode/forward/decode hops are
    /// pure overhead — the pointer-sharing exchange returns identical
    /// bits for free. An implementation must record the *ring* schedule's
    /// wire-byte model, so traffic accounting stays algorithm-faithful.
    /// Reducing collectives never use this — their ring path is also
    /// cheaper in compute (`O(N)` adds per rank vs the star's `O(R·N)`).
    fn gather_zero_copy(&self, mats: Vec<Mat>) -> Result<Vec<Arc<Vec<Mat>>>, Vec<Mat>> {
        Err(mats)
    }
}

/// Shared-memory rendezvous backing [`LocalComm`]: a slot per rank plus a
/// two-phase (deposit → read) generation protocol for barrier exchanges,
/// and a per-`(from, to)` FIFO mailbox matrix for point-to-point sends.
struct Rendezvous {
    world: usize,
    state: Mutex<RvState>,
    cv: Condvar,
    /// Mailbox `from * world + to`: FIFO of pending `(seq, payload)`
    /// p2p frames. The per-direction sequence number mirrors the socket
    /// transport's `KIND_P2P` seq field: the sender stamps its send
    /// count for that link, the receiver checks it against its receive
    /// count, so SPMD call-order violations fail loudly on this
    /// transport too instead of misdelivering a stale payload.
    mail: Mutex<Vec<VecDeque<(u64, Vec<u8>)>>>,
    mail_cv: Condvar,
    /// Set when a rank panicked; wakes and fails every peer (both the
    /// barrier waiters and the mailbox waiters).
    poisoned: AtomicBool,
}

struct RvState {
    slots: Vec<Option<Arc<dyn Any + Send + Sync>>>,
    deposited: usize,
    taken: usize,
    /// Deposit phase (false) vs read phase (true).
    reading: bool,
}

impl Rendezvous {
    fn new(world: usize) -> Rendezvous {
        Rendezvous {
            world,
            state: Mutex::new(RvState {
                slots: (0..world).map(|_| None).collect(),
                deposited: 0,
                taken: 0,
                reading: false,
            }),
            cv: Condvar::new(),
            mail: Mutex::new((0..world * world).map(|_| VecDeque::new()).collect()),
            mail_cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        }
    }

    fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        // Notify under each lock so a waiter cannot check the flag and
        // park between our store and the notification.
        {
            let _g = self.state.lock().unwrap_or_else(|e| e.into_inner());
            self.cv.notify_all();
        }
        {
            let _g = self.mail.lock().unwrap_or_else(|e| e.into_inner());
            self.mail_cv.notify_all();
        }
    }

    fn check_poison(&self) {
        assert!(!self.poisoned.load(Ordering::SeqCst), "dist: a peer rank failed");
    }

    fn exchange(
        &self,
        rank: usize,
        payload: Arc<dyn Any + Send + Sync>,
    ) -> Vec<Arc<dyn Any + Send + Sync>> {
        if self.world == 1 {
            return vec![payload];
        }
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        // Deposit phase: wait for the previous exchange to fully drain.
        loop {
            self.check_poison();
            if !st.reading && st.slots[rank].is_none() {
                break;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.slots[rank] = Some(payload);
        st.deposited += 1;
        if st.deposited == self.world {
            st.reading = true;
            self.cv.notify_all();
        }
        // Read phase: wait for every rank's deposit.
        loop {
            self.check_poison();
            if st.reading {
                break;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let out: Vec<_> = st.slots.iter().map(|s| s.clone().expect("rendezvous slot")).collect();
        st.taken += 1;
        if st.taken == self.world {
            // Last reader resets the rendezvous for the next exchange.
            for s in &mut st.slots {
                *s = None;
            }
            st.deposited = 0;
            st.taken = 0;
            st.reading = false;
            self.cv.notify_all();
        }
        out
    }

    /// Deposit a p2p frame into the `(from, to)` mailbox. Never blocks
    /// (the mailboxes are unbounded), so symmetric schedules cannot
    /// deadlock on the local transport.
    fn send(&self, from: usize, to: usize, seq: u64, payload: Vec<u8>) {
        assert!(to < self.world && to != from, "dist: bad p2p target {to} (rank {from})");
        self.check_poison();
        let mut mail = self.mail.lock().unwrap_or_else(|e| e.into_inner());
        mail[from * self.world + to].push_back((seq, payload));
        self.mail_cv.notify_all();
    }

    /// Pop the next `(from, to)` frame, blocking until one arrives or
    /// the world is poisoned; its seq must be exactly `want`.
    fn recv(&self, to: usize, from: usize, want: u64) -> Vec<u8> {
        assert!(from < self.world && from != to, "dist: bad p2p source {from} (rank {to})");
        let mut mail = self.mail.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            self.check_poison();
            if let Some((seq, p)) = mail[from * self.world + to].pop_front() {
                assert_eq!(
                    seq, want,
                    "dist: SPMD call order violated with rank {from} (p2p seq mismatch)"
                );
                return p;
            }
            mail = self.mail_cv.wait(mail).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// The shareable state behind a [`LocalComm`]: everything an in-flight
/// engine op needs, behind one `Arc` so the op's closure can own it.
/// Implements the inline (immediate-execution) [`Communicator`] — the
/// engine jobs of [`LocalComm`] run collectives over this type directly.
struct LocalCore {
    rank: usize,
    world: usize,
    algo: Algo,
    overlap: bool,
    wire: Dtype,
    rv: Arc<Rendezvous>,
    /// Per-direction p2p frame counters (`[to]` on send, `[from]` on
    /// receive), mirroring the socket transport's link seq checking.
    p2p_sent: Mutex<Vec<u64>>,
    p2p_rcvd: Mutex<Vec<u64>>,
}

impl LocalCore {
    fn exchange_any(&self, p: Arc<dyn Any + Send + Sync>) -> Vec<Arc<dyn Any + Send + Sync>> {
        self.rv.exchange(self.rank, p)
    }

    /// Record the wire bytes this rank *would* send for a star exchange
    /// (the socket transport's exact frame model): a worker sends its
    /// own payload frame to rank 0, rank 0 fans the gathered blob out to
    /// every worker. `own` / `parts` are encoded payload lengths.
    fn record_star_traffic(&self, own: usize, parts: &[usize]) {
        if self.world == 1 {
            return;
        }
        let frame = |len: usize| (transport::FRAME_HEADER_BYTES + len) as u64;
        if self.rank == 0 {
            let gathered = transport::encoded_len_gathered(parts);
            traffic::record_sent(0, (self.world as u64 - 1) * frame(gathered));
        } else {
            traffic::record_sent(self.rank, frame(own));
        }
    }
}

impl Communicator for LocalCore {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn algo(&self) -> Algo {
        self.algo
    }

    fn overlap(&self) -> bool {
        self.overlap
    }

    fn wire_dtype(&self) -> Dtype {
        self.wire
    }

    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        let own = transport::encoded_len_mats(&mats);
        let parts: Vec<Arc<Vec<Mat>>> = self
            .exchange_any(Arc::new(mats))
            .into_iter()
            .map(|a| a.downcast::<Vec<Mat>>().expect("dist: SPMD call order violated (mats)"))
            .collect();
        let lens: Vec<usize> = parts.iter().map(|p| transport::encoded_len_mats(p)).collect();
        self.record_star_traffic(own, &lens);
        parts
    }

    fn exchange_mats_wire(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        if self.wire == Dtype::F32 {
            return self.exchange_mats(mats);
        }
        // Pointer-sharing exchange (payloads are pre-snapped, so sharing
        // the f32 images is bitwise identical to an encode/decode round
        // trip), accounted at wire-dtype frame sizes.
        let own = transport::encoded_len_mats_wire(&mats, self.wire);
        let parts: Vec<Arc<Vec<Mat>>> = self
            .exchange_any(Arc::new(mats))
            .into_iter()
            .map(|a| a.downcast::<Vec<Mat>>().expect("dist: SPMD call order violated (mats)"))
            .collect();
        let lens: Vec<usize> =
            parts.iter().map(|p| transport::encoded_len_mats_wire(p, self.wire)).collect();
        self.record_star_traffic(own, &lens);
        parts
    }

    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>> {
        let own = transport::encoded_len_f64s(vals.len());
        let parts: Vec<Arc<Vec<f64>>> = self
            .exchange_any(Arc::new(vals))
            .into_iter()
            .map(|a| a.downcast::<Vec<f64>>().expect("dist: SPMD call order violated (f64)"))
            .collect();
        let lens: Vec<usize> = parts.iter().map(|p| transport::encoded_len_f64s(p.len())).collect();
        self.record_star_traffic(own, &lens);
        parts
    }

    fn send_bytes(&self, to: usize, payload: &[u8]) {
        traffic::record_sent(self.rank, (transport::FRAME_HEADER_BYTES + payload.len()) as u64);
        let seq = {
            let mut sent = self.p2p_sent.lock().unwrap_or_else(|e| e.into_inner());
            let s = sent[to];
            sent[to] += 1;
            s
        };
        self.rv.send(self.rank, to, seq, payload.to_vec());
    }

    fn recv_bytes(&self, from: usize) -> Vec<u8> {
        let want = {
            let mut rcvd = self.p2p_rcvd.lock().unwrap_or_else(|e| e.into_inner());
            let w = rcvd[from];
            rcvd[from] += 1;
            w
        };
        self.rv.recv(self.rank, from, want)
    }

    fn istart_all_gather(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        // Inline core: already executing on the engine (or in a blocking
        // context) — run to completion immediately.
        PendingOp::ready(collectives::all_gather(self, mats))
    }

    fn istart_all_reduce_sum(&self, mats: Vec<Mat>) -> PendingOp<Vec<Mat>> {
        PendingOp::ready(collectives::all_reduce_sum(self, &mats))
    }

    fn gather_zero_copy(&self, mats: Vec<Mat>) -> Result<Vec<Arc<Vec<Mat>>>, Vec<Mat>> {
        // Share pointers through the rendezvous, but account the bytes
        // the *ring* schedule would put on a wire (this rank forwards
        // its own list, then each list received from its left neighbor,
        // once each — frames of ranks `rank`, `rank−1`, …).
        let parts: Vec<Arc<Vec<Mat>>> = self
            .exchange_any(Arc::new(mats))
            .into_iter()
            .map(|a| a.downcast::<Vec<Mat>>().expect("dist: SPMD call order violated (mats)"))
            .collect();
        if self.world > 1 {
            let lens: Vec<usize> =
                parts.iter().map(|p| transport::encoded_len_mats_wire(p, self.wire)).collect();
            let mut sent = 0u64;
            for k in 0..self.world - 1 {
                let idx = (self.rank + self.world - k) % self.world;
                sent += (transport::FRAME_HEADER_BYTES + lens[idx]) as u64;
            }
            traffic::record_sent(self.rank, sent);
        }
        Ok(parts)
    }
}

/// One rank's handle onto an in-process shared-memory world. Created by
/// [`run_ranks`] / [`run_ranks_algo`] / [`run_ranks_with`]; cheap to
/// move into the rank closure.
///
/// Nonblocking `istart_*` calls lazily spawn this communicator's
/// progress engine ([`pending`]); once it is active, blocking calls are
/// reimplemented as `istart + wait` through the same FIFO queue, so a
/// blocking collective issued between two pending ops takes its place in
/// the issue order instead of racing the engine for the rendezvous.
pub struct LocalComm {
    core: Arc<LocalCore>,
    engine: OnceLock<Engine>,
}

impl LocalComm {
    fn engine(&self) -> &Engine {
        self.engine
            .get_or_init(|| Engine::new(&format!("singd-dist-eng-r{}", self.core.rank)))
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> usize {
        self.core.rank
    }

    fn world_size(&self) -> usize {
        self.core.world
    }

    fn algo(&self) -> Algo {
        self.core.algo
    }

    fn overlap(&self) -> bool {
        self.core.overlap
    }

    fn wire_dtype(&self) -> Dtype {
        self.core.wire
    }

    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.exchange_mats(mats)).wait();
        }
        self.core.exchange_mats(mats)
    }

    fn exchange_mats_wire(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.exchange_mats_wire(mats)).wait();
        }
        self.core.exchange_mats_wire(mats)
    }

    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.exchange_f64(vals)).wait();
        }
        self.core.exchange_f64(vals)
    }

    fn send_bytes(&self, to: usize, payload: &[u8]) {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            let payload = payload.to_vec();
            eng.submit(self.core.rank, move || core.send_bytes(to, &payload)).wait();
            return;
        }
        self.core.send_bytes(to, payload)
    }

    fn recv_bytes(&self, from: usize) -> Vec<u8> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.recv_bytes(from)).wait();
        }
        self.core.recv_bytes(from)
    }

    fn send_recv_bytes(&self, to: usize, payload: &[u8], from: usize) -> Vec<u8> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            let payload = payload.to_vec();
            return eng
                .submit(self.core.rank, move || core.send_recv_bytes(to, &payload, from))
                .wait();
        }
        self.core.send_recv_bytes(to, payload, from)
    }

    fn istart_exchange_mats(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        if self.core.world == 1 {
            return PendingOp::ready(self.core.exchange_mats(mats));
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || core.exchange_mats(mats))
    }

    fn istart_exchange_f64(&self, vals: Vec<f64>) -> PendingOp<Vec<Arc<Vec<f64>>>> {
        if self.core.world == 1 {
            return PendingOp::ready(self.core.exchange_f64(vals));
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || core.exchange_f64(vals))
    }

    fn istart_send_recv_bytes(
        &self,
        to: usize,
        payload: Vec<u8>,
        from: usize,
    ) -> PendingOp<Vec<u8>> {
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || core.send_recv_bytes(to, &payload, from))
    }

    fn istart_all_gather(&self, mats: Vec<Mat>) -> PendingOp<Vec<Arc<Vec<Mat>>>> {
        if self.core.world == 1 {
            return PendingOp::ready(vec![Arc::new(mats)]);
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || collectives::all_gather(&*core, mats))
    }

    fn istart_all_reduce_sum(&self, mats: Vec<Mat>) -> PendingOp<Vec<Mat>> {
        if self.core.world == 1 {
            return PendingOp::ready(mats);
        }
        let core = Arc::clone(&self.core);
        self.engine().submit(self.core.rank, move || collectives::all_reduce_sum(&*core, &mats))
    }

    fn gather_zero_copy(&self, mats: Vec<Mat>) -> Result<Vec<Arc<Vec<Mat>>>, Vec<Mat>> {
        if let Some(eng) = self.engine.get() {
            let core = Arc::clone(&self.core);
            return eng.submit(self.core.rank, move || core.gather_zero_copy(mats)).wait();
        }
        self.core.gather_zero_copy(mats)
    }
}

/// Run `world` SPMD rank bodies to completion under the default
/// collective algorithm ([`default_algo`]) and overlap mode
/// ([`default_overlap`]) and collect their results in rank order. See
/// [`run_ranks_with`].
pub fn run_ranks<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(LocalComm) -> T + Sync,
{
    run_ranks_with(world, default_algo(), default_overlap(), f)
}

/// [`run_ranks`] with an explicit collective algorithm (overlap mode
/// stays the [`default_overlap`] env default, so the ci.sh matrix drives
/// existing suites through both modes).
pub fn run_ranks_algo<T, F>(world: usize, algo: Algo, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(LocalComm) -> T + Sync,
{
    run_ranks_with(world, algo, default_overlap(), f)
}

/// Run `world` SPMD rank bodies to completion and collect their results
/// in rank order, with collectives dispatched to `algo` and overlapped
/// schedules enabled iff `overlap`.
///
/// Ranks run on the persistent worker pool when it is safe to do so
/// (caller is not itself a pool worker, parallelism is enabled, and the
/// pool has at least `world` workers so no rank body can be queued behind
/// a blocked peer — rank bodies block on collective rendezvous, unlike
/// ordinary pool jobs); otherwise on dedicated scoped threads. Both paths
/// produce identical results: collectives order floating-point reductions
/// by rank index, never by scheduling.
///
/// A panicking rank poisons the rendezvous (waking every peer, including
/// peers blocked in point-to-point receives and peers waiting on pending
/// nonblocking ops) and the panic propagates to the caller; the pool
/// stays usable.
pub fn run_ranks_with<T, F>(world: usize, algo: Algo, overlap: bool, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(LocalComm) -> T + Sync,
{
    run_ranks_wire(world, algo, overlap, default_wire_dtype(), f)
}

/// [`run_ranks_with`] with an explicit wire dtype (the other entry
/// points use the [`default_wire_dtype`] env default) — the conformance
/// suites and benchmarks pin the wire format per world with this.
pub fn run_ranks_wire<T, F>(world: usize, algo: Algo, overlap: bool, wire: Dtype, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(LocalComm) -> T + Sync,
{
    assert!(world >= 1, "run_ranks: world size must be >= 1");
    let rv = Arc::new(Rendezvous::new(world));
    let mk_comm = |rank: usize| LocalComm {
        core: Arc::new(LocalCore {
            rank,
            world,
            algo,
            overlap,
            wire,
            rv: Arc::clone(&rv),
            p2p_sent: Mutex::new(vec![0; world]),
            p2p_rcvd: Mutex::new(vec![0; world]),
        }),
        engine: OnceLock::new(),
    };
    if world == 1 {
        return vec![f(mk_comm(0))];
    }
    run_rank_bodies(world, &rv, |r| f(mk_comm(r)))
}

/// The SPMD scheduling shared by [`run_ranks_with`] and
/// [`LocalWorld::run`]: execute `f(rank)` for every rank concurrently
/// (pool workers when safe, scoped threads otherwise — see
/// [`run_ranks_with`]) and collect results in rank order. A panicking
/// body poisons `rv` (waking every blocked peer) and re-raises.
fn run_rank_bodies<T, F>(world: usize, rv: &Rendezvous, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let results: Vec<Mutex<Option<T>>> = (0..world).map(|_| Mutex::new(None)).collect();
    let fr = &f;
    let rs = &results;
    let make_body = |r: usize| {
        move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| fr(r)));
            match out {
                Ok(v) => *rs[r].lock().unwrap_or_else(|e| e.into_inner()) = Some(v),
                Err(e) => {
                    rv.poison();
                    std::panic::resume_unwind(e);
                }
            }
        }
    };
    let pool_safe =
        !pool::is_worker_thread() && pool::current_threads() > 1 && pool::num_threads() >= world;
    if pool_safe {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            (0..world).map(|r| Box::new(make_body(r)) as Box<dyn FnOnce() + Send + '_>).collect();
        pool::run_jobs(jobs);
    } else {
        std::thread::scope(|s| {
            for r in 0..world {
                s.spawn(make_body(r));
            }
        });
    }
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("run_ranks: rank produced no result")
        })
        .collect()
}

/// A reusable in-process SPMD world: the rendezvous, the communicators
/// and their (lazily spawned) progress engines persist across
/// [`LocalWorld::run`] rounds. A driver that runs one collective round
/// per training step — [`crate::train::train_dist`]'s local path — pays
/// the per-rank engine thread spawn once per run instead of once per
/// step, and its per-link p2p sequence counters continue across steps,
/// exactly like a long-lived [`SocketComm`] world's. Results are
/// bitwise identical to per-round [`run_ranks_with`] worlds either way
/// (collectives order reductions by rank index, never by lifecycle).
pub struct LocalWorld {
    rv: Arc<Rendezvous>,
    comms: Vec<LocalComm>,
}

impl LocalWorld {
    /// Build a `world`-rank shared-memory world with the given
    /// collective algorithm and overlap mode (run-level constants, as
    /// everywhere).
    pub fn new(world: usize, algo: Algo, overlap: bool) -> LocalWorld {
        LocalWorld::new_wire(world, algo, overlap, default_wire_dtype())
    }

    /// [`LocalWorld::new`] with an explicit wire dtype (a run-level
    /// constant; [`LocalWorld::new`] uses the [`default_wire_dtype`] env
    /// default).
    pub fn new_wire(world: usize, algo: Algo, overlap: bool, wire: Dtype) -> LocalWorld {
        assert!(world >= 1, "LocalWorld: world size must be >= 1");
        let rv = Arc::new(Rendezvous::new(world));
        let comms = (0..world)
            .map(|rank| LocalComm {
                core: Arc::new(LocalCore {
                    rank,
                    world,
                    algo,
                    overlap,
                    wire,
                    rv: Arc::clone(&rv),
                    p2p_sent: Mutex::new(vec![0; world]),
                    p2p_rcvd: Mutex::new(vec![0; world]),
                }),
                engine: OnceLock::new(),
            })
            .collect();
        LocalWorld { rv, comms }
    }

    /// World size of this persistent world.
    pub fn world_size(&self) -> usize {
        self.comms.len()
    }

    /// Run one SPMD round over the persistent communicators and collect
    /// the per-rank results in rank order. Scheduling and failure
    /// semantics match [`run_ranks_with`]: a panicking rank poisons the
    /// rendezvous — waking every blocked peer — and the panic
    /// propagates; the world is not reusable after a poisoned round.
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&LocalComm) -> T + Sync,
    {
        if self.comms.len() == 1 {
            return vec![f(&self.comms[0])];
        }
        run_rank_bodies(self.comms.len(), &self.rv, |r| f(&self.comms[r]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_ranks_world1_runs_inline() {
        let out = run_ranks(1, |c| {
            assert_eq!(c.rank(), 0);
            assert_eq!(c.world_size(), 1);
            42usize
        });
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn run_ranks_collects_in_rank_order() {
        for world in [2usize, 3, 4, 7] {
            let out = run_ranks(world, |c| c.rank() * 10);
            assert_eq!(out, (0..world).map(|r| r * 10).collect::<Vec<_>>(), "world {world}");
        }
    }

    #[test]
    fn exchange_f64_delivers_all_payloads() {
        let world = 4;
        let out = run_ranks(world, |c| {
            let parts = c.exchange_f64(vec![c.rank() as f64, 100.0 + c.rank() as f64]);
            parts.iter().map(|p| p[0]).collect::<Vec<_>>()
        });
        for got in out {
            assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn repeated_exchanges_do_not_cross_phases() {
        // Many back-to-back exchanges with asymmetric compute between
        // them: the two-phase reset must keep rounds separated.
        let world = 3;
        let out = run_ranks(world, |c| {
            let mut acc = 0.0f64;
            for round in 0..50u32 {
                if c.rank() == round as usize % world {
                    std::hint::black_box((0..500).map(|i| i as f64).sum::<f64>());
                }
                let parts = c.exchange_f64(vec![(round as f64) * 10.0 + c.rank() as f64]);
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(p[0], (round as f64) * 10.0 + r as f64);
                    acc += p[0];
                }
            }
            acc
        });
        assert!(out.iter().all(|&x| x == out[0]));
    }

    #[test]
    fn p2p_mailboxes_deliver_fifo_per_pair() {
        let world = 3;
        let out = run_ranks(world, |c| {
            let right = (c.rank() + 1) % world;
            let left = (c.rank() + world - 1) % world;
            // Two pipelined sends, then two receives: FIFO per pair.
            c.send_bytes(right, &[c.rank() as u8, 1]);
            c.send_bytes(right, &[c.rank() as u8, 2]);
            let a = c.recv_bytes(left);
            let b = c.recv_bytes(left);
            (a, b)
        });
        for (r, (a, b)) in out.iter().enumerate() {
            let left = (r + world - 1) % world;
            assert_eq!(a, &vec![left as u8, 1]);
            assert_eq!(b, &vec![left as u8, 2]);
        }
    }

    #[test]
    fn p2p_send_recv_pairs_symmetric_schedule() {
        // Every rank sends to its right and receives from its left in
        // one combined call — the ring step shape.
        let world = 4;
        let out = run_ranks(world, |c| {
            let right = (c.rank() + 1) % world;
            let left = (c.rank() + world - 1) % world;
            let payload = vec![c.rank() as u8; 8];
            c.send_recv_bytes(right, &payload, left)
        });
        for (r, got) in out.iter().enumerate() {
            let left = (r + world - 1) % world;
            assert_eq!(got, &vec![left as u8; 8]);
        }
    }

    #[test]
    fn p2p_seq_mismatch_is_flagged_as_spmd_violation() {
        // A stale frame (sender's link counter ahead of the receiver's)
        // must panic — the local transport checks the same per-direction
        // seq the socket transport stamps into KIND_P2P frames.
        let rv = Rendezvous::new(2);
        rv.send(0, 1, 5, vec![1, 2, 3]);
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| rv.recv(1, 0, 0)));
        assert!(out.is_err(), "seq mismatch must fail loudly, not deliver");
    }

    #[test]
    fn p2p_empty_payload_roundtrips() {
        let out = run_ranks(2, |c| {
            let other = 1 - c.rank();
            c.send_recv_bytes(other, &[], other)
        });
        assert_eq!(out, vec![Vec::<u8>::new(), Vec::new()]);
    }

    #[test]
    fn istart_exchange_overlaps_and_delivers_rank_order() {
        // Issue, compute, then wait: results identical to the blocking
        // exchange, and the issue point (not the wait) is the SPMD slot.
        let world = 4;
        let out = run_ranks(world, |c| {
            let op = c.istart_exchange_f64(vec![c.rank() as f64 * 2.0]);
            // Overlapped "compute" while the engine services the op.
            let busy: f64 = (0..100).map(|i| i as f64).sum();
            std::hint::black_box(busy);
            let parts = op.wait();
            parts.iter().map(|p| p[0]).collect::<Vec<_>>()
        });
        for got in out {
            assert_eq!(got, vec![0.0, 2.0, 4.0, 6.0]);
        }
    }

    #[test]
    fn blocking_calls_queue_behind_pending_ops_in_issue_order() {
        // A blocking exchange issued after an unwaited istart must land
        // after it on every rank (FIFO through the engine) — the
        // issue-order guarantee contract 4 rests on.
        let world = 3;
        let out = run_ranks(world, |c| {
            let op = c.istart_exchange_f64(vec![1.0 + c.rank() as f64]);
            let second = c.exchange_f64(vec![10.0 + c.rank() as f64]);
            let first = op.wait();
            let sum1: f64 = first.iter().map(|p| p[0]).sum();
            let sum2: f64 = second.iter().map(|p| p[0]).sum();
            (sum1, sum2)
        });
        for (s1, s2) in out {
            assert_eq!(s1, 6.0);
            assert_eq!(s2, 33.0);
        }
    }

    #[test]
    fn istart_send_recv_ring_step_matches_blocking() {
        let world = 4;
        let out = run_ranks(world, |c| {
            let right = (c.rank() + 1) % world;
            let left = (c.rank() + world - 1) % world;
            let op = c.istart_send_recv_bytes(right, vec![c.rank() as u8; 4], left);
            op.wait()
        });
        for (r, got) in out.iter().enumerate() {
            let left = (r + world - 1) % world;
            assert_eq!(got, &vec![left as u8; 4]);
        }
    }

    #[test]
    fn local_world_reuses_comms_across_rounds() {
        // The persistent world the local training driver runs on: the
        // same communicators (and engines) serve many rounds, and the
        // per-link p2p counters continue across rounds like a long-lived
        // socket world's.
        let w = LocalWorld::new(3, Algo::Ring, true);
        assert_eq!(w.world_size(), 3);
        for round in 0..5u32 {
            let outs = w.run(|c| {
                let op = c.istart_exchange_f64(vec![c.rank() as f64 + round as f64]);
                op.wait().iter().map(|p| p[0]).sum::<f64>()
            });
            assert_eq!(outs, vec![3.0 + 3.0 * round as f64; 3], "round {round}");
        }
        for _ in 0..2 {
            let outs = w.run(|c| {
                let right = (c.rank() + 1) % 3;
                let left = (c.rank() + 2) % 3;
                c.send_recv_bytes(right, &[c.rank() as u8], left)
            });
            for (r, got) in outs.iter().enumerate() {
                assert_eq!(got, &vec![((r + 2) % 3) as u8]);
            }
        }
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            assert_eq!(DistStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(DistStrategy::parse("sharded"), Some(DistStrategy::FactorSharded));
        assert!(DistStrategy::parse("bogus").is_none());
    }

    #[test]
    fn dist_ctx_ownership() {
        let replicated = DistCtx::new(DistStrategy::Replicated, 1, 4);
        assert!((0..8).all(|l| replicated.owns_layer(l)));
        let sharded = DistCtx::new(DistStrategy::FactorSharded, 1, 4);
        let owned: Vec<usize> = (0..8).filter(|&l| sharded.owns_layer(l)).collect();
        assert_eq!(owned, vec![1, 5]);
    }

    #[test]
    fn default_algo_follows_env_or_ring() {
        let want = std::env::var("SINGD_ALGO")
            .ok()
            .and_then(|v| Algo::parse(&v))
            .unwrap_or(Algo::Ring);
        assert_eq!(default_algo(), want);
    }

    #[test]
    fn overlap_parse_and_env_default() {
        for on in ["1", "true", "on", "yes", " ON "] {
            assert_eq!(parse_overlap(on), Some(true), "{on}");
        }
        for off in ["0", "false", "off", "no"] {
            assert_eq!(parse_overlap(off), Some(false), "{off}");
        }
        assert_eq!(parse_overlap("sideways"), None);
        let want = std::env::var("SINGD_OVERLAP")
            .ok()
            .and_then(|v| parse_overlap(&v))
            .unwrap_or(true);
        assert_eq!(default_overlap(), want);
    }

    #[test]
    fn default_stream_follows_env_or_on() {
        let want = std::env::var("SINGD_STREAM")
            .ok()
            .and_then(|v| parse_overlap(&v))
            .unwrap_or(true);
        assert_eq!(default_stream(), want);
    }

    #[test]
    fn default_wire_dtype_follows_env_or_f32() {
        let want = std::env::var("SINGD_WIRE_DTYPE")
            .ok()
            .and_then(|v| Dtype::parse(&v))
            .unwrap_or(Dtype::F32);
        assert_eq!(default_wire_dtype(), want);
    }

    #[test]
    fn explicit_wire_dtype_reaches_every_rank() {
        for wire in [Dtype::F32, Dtype::Bf16, Dtype::Fp16] {
            let out = run_ranks_wire(3, Algo::Ring, false, wire, |c| c.wire_dtype());
            assert_eq!(out, vec![wire; 3]);
        }
        let world = LocalWorld::new_wire(2, Algo::Star, false, Dtype::Bf16);
        let out = world.run(|c| c.wire_dtype());
        assert_eq!(out, vec![Dtype::Bf16; 2]);
    }
}
