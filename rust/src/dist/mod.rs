//! Distributed execution subsystem: deterministic collectives over
//! pluggable transports and ZeRO-style sharded Kronecker-factor
//! preconditioning.
//!
//! Two transports implement the [`Communicator`] exchange primitive:
//!
//! - [`Transport::Local`] ([`LocalComm`]) runs an `R`-rank data-parallel
//!   job inside one process: ranks are SPMD closures executed
//!   concurrently (on the persistent worker pool of
//!   [`crate::tensor::pool`] when it is large enough, on dedicated
//!   scoped threads otherwise) over a shared-memory rendezvous.
//! - [`Transport::Socket`] ([`SocketComm`], [`transport`]) joins `R`
//!   separate OS processes over Unix-domain sockets (TCP fallback) with
//!   a length-prefixed wire format; byte-exact payload images keep every
//!   collective bitwise identical to the local transport.
//!
//! Layer-wise decomposition is the natural parallel axis for
//! Kronecker-factored methods (Koroko et al., 2023), and the
//! inverse-free SINGD update is nothing but matrix
//! multiplications and subtractions — exactly the ops that shard without
//! any rank ever holding a full inverse.
//!
//! # Determinism contract
//!
//! This module extends the crate's serial/pooled bitwise-parity contract
//! (`rust/tests/parallel.rs`) across world sizes:
//!
//! 1. **Collectives use a fixed reduction tree.** Every reducing
//!    collective combines rank contributions with the balanced halving
//!    tree of [`collectives::tree_sum_f64`] — the reduction order is a
//!    function of the world size alone, never of scheduling.
//! 2. **Rank-count invariance** is achieved by exchanging *exact* data:
//!    the training driver ([`crate::train::train_dist`]) all-gathers raw
//!    per-row Kronecker statistics (a concatenation, no floating-point
//!    reduction) and recomputes contractions from the gathered
//!    full-batch matrices with the standard kernels, and the sharded
//!    optimizer path all-reduces zero-padded per-layer updates (each
//!    element has exactly one nonzero contributor, so tree order cannot
//!    change the result). Under this scheme `ranks = R` training is
//!    bitwise identical to `ranks = 1` for any power-of-two `R` dividing
//!    the batch size (see `rust/tests/dist.rs`).
//! 3. A poisoned rendezvous (a rank panicking) wakes every peer so the
//!    failure propagates instead of deadlocking the process.
//!
//! # The `SINGD_RANKS` / `SINGD_TRANSPORT` contract
//!
//! `SINGD_RANKS=<n>` sets the *default* world size and
//! `SINGD_TRANSPORT=<local|socket>` the *default* transport used by
//! config-driven entry points ([`crate::config::JobConfig`]); explicit
//! `[dist]` config keys and `--ranks` / `--transport` CLI flags
//! override them. Read once, cached.

pub mod bucket;
pub mod collectives;
pub mod shard;
pub mod transport;

pub use transport::{SocketComm, Transport};

use crate::tensor::{pool, Mat};
use std::any::Any;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// How optimizer state is laid out across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistStrategy {
    /// Every rank holds the full optimizer state and performs every
    /// layer's update redundantly (classic data parallelism).
    Replicated,
    /// ZeRO-style layer sharding: each rank owns the Kronecker factors
    /// (and momenta) of its layer shard only, updates them locally, and
    /// the preconditioned updates are exchanged — per-rank factor memory
    /// drops by roughly the world size.
    FactorSharded,
}

impl DistStrategy {
    /// Parse `"replicated"` / `"factor-sharded"` (aliases: `"sharded"`,
    /// `"zero"`).
    pub fn parse(s: &str) -> Option<DistStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "replicated" | "rep" | "ddp" => Some(DistStrategy::Replicated),
            "factor-sharded" | "factor_sharded" | "sharded" | "zero" => {
                Some(DistStrategy::FactorSharded)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DistStrategy::Replicated => "replicated",
            DistStrategy::FactorSharded => "factor-sharded",
        }
    }
}

/// A rank's view of the distributed topology, handed to optimizers so
/// their per-layer loops know which layers this rank owns.
#[derive(Clone, Copy, Debug)]
pub struct DistCtx {
    pub strategy: DistStrategy,
    pub rank: usize,
    pub world: usize,
}

impl DistCtx {
    /// The single-process topology: one rank, replicated.
    pub fn single() -> DistCtx {
        DistCtx { strategy: DistStrategy::Replicated, rank: 0, world: 1 }
    }

    pub fn new(strategy: DistStrategy, rank: usize, world: usize) -> DistCtx {
        assert!(world >= 1, "dist: world size must be >= 1");
        assert!(rank < world, "dist: rank {rank} out of range for world {world}");
        DistCtx { strategy, rank, world }
    }

    /// Whether this rank owns layer `l` (always true when replicated).
    /// The factor-sharded layout is the round-robin assignment of
    /// [`shard::round_robin_owner`], shared with the training driver.
    pub fn owns_layer(&self, l: usize) -> bool {
        match self.strategy {
            DistStrategy::Replicated => true,
            DistStrategy::FactorSharded => shard::round_robin_owner(l, self.world) == self.rank,
        }
    }

    /// The owned-layer set in the [`crate::optim::Optimizer::owned_layers`]
    /// convention: `None` when every layer is owned (replicated or
    /// single-rank), `Some(list)` under multi-rank factor sharding. The
    /// single source of truth the optimizers and the training driver's
    /// update exchange both delegate to.
    pub fn owned_layers(&self, n_layers: usize) -> Option<Vec<usize>> {
        if self.world > 1 && self.strategy == DistStrategy::FactorSharded {
            Some((0..n_layers).filter(|&l| self.owns_layer(l)).collect())
        } else {
            None
        }
    }
}

/// Default world size: `SINGD_RANKS` (read once, cached), else 1.
pub fn default_ranks() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_RANKS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map(|n| n.max(1))
            .unwrap_or(1)
    })
}

/// Default transport: `SINGD_TRANSPORT` (read once, cached), else
/// [`Transport::Local`]. Explicit `[dist] transport` config keys and
/// `--transport` CLI flags override it, mirroring the `SINGD_RANKS`
/// contract.
pub fn default_transport() -> Transport {
    static CACHED: OnceLock<Transport> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("SINGD_TRANSPORT")
            .ok()
            .and_then(|v| Transport::parse(&v))
            .unwrap_or(Transport::Local)
    })
}

/// Rank/topology plus the SPMD exchange primitive every collective is
/// built on: each rank contributes one payload per call and receives all
/// ranks' payloads in rank order.
///
/// The exchange is a *barrier*: no rank returns before every rank has
/// deposited, so collectives built on it are trivially synchronized. All
/// ranks must issue the same sequence of calls (SPMD discipline).
pub trait Communicator {
    fn rank(&self) -> usize;
    fn world_size(&self) -> usize;
    /// Exchange a list of matrices; returns every rank's payload.
    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>>;
    /// Exchange a list of f64 scalars (loss partials, counters).
    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>>;
    /// Block until every rank reaches this point.
    fn barrier(&self) {
        let _ = self.exchange_f64(Vec::new());
    }
}

/// Shared-memory rendezvous backing [`LocalComm`]: a slot per rank plus a
/// two-phase (deposit → read) generation protocol.
struct Rendezvous {
    world: usize,
    state: Mutex<RvState>,
    cv: Condvar,
}

struct RvState {
    slots: Vec<Option<Arc<dyn Any + Send + Sync>>>,
    deposited: usize,
    taken: usize,
    /// Deposit phase (false) vs read phase (true).
    reading: bool,
    /// Set when a rank panicked; wakes and fails every peer.
    poisoned: bool,
}

impl Rendezvous {
    fn new(world: usize) -> Rendezvous {
        Rendezvous {
            world,
            state: Mutex::new(RvState {
                slots: (0..world).map(|_| None).collect(),
                deposited: 0,
                taken: 0,
                reading: false,
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn poison(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.poisoned = true;
        self.cv.notify_all();
    }

    fn exchange(
        &self,
        rank: usize,
        payload: Arc<dyn Any + Send + Sync>,
    ) -> Vec<Arc<dyn Any + Send + Sync>> {
        if self.world == 1 {
            return vec![payload];
        }
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        // Deposit phase: wait for the previous exchange to fully drain.
        loop {
            assert!(!st.poisoned, "dist: a peer rank failed");
            if !st.reading && st.slots[rank].is_none() {
                break;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.slots[rank] = Some(payload);
        st.deposited += 1;
        if st.deposited == self.world {
            st.reading = true;
            self.cv.notify_all();
        }
        // Read phase: wait for every rank's deposit.
        loop {
            assert!(!st.poisoned, "dist: a peer rank failed");
            if st.reading {
                break;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let out: Vec<_> = st.slots.iter().map(|s| s.clone().expect("rendezvous slot")).collect();
        st.taken += 1;
        if st.taken == self.world {
            // Last reader resets the rendezvous for the next exchange.
            for s in &mut st.slots {
                *s = None;
            }
            st.deposited = 0;
            st.taken = 0;
            st.reading = false;
            self.cv.notify_all();
        }
        out
    }
}

/// One rank's handle onto an in-process shared-memory world. Created by
/// [`run_ranks`]; cheap to move into the rank closure.
pub struct LocalComm {
    rank: usize,
    world: usize,
    rv: Arc<Rendezvous>,
}

impl LocalComm {
    fn exchange_any(&self, p: Arc<dyn Any + Send + Sync>) -> Vec<Arc<dyn Any + Send + Sync>> {
        self.rv.exchange(self.rank, p)
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn exchange_mats(&self, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
        self.exchange_any(Arc::new(mats))
            .into_iter()
            .map(|a| a.downcast::<Vec<Mat>>().expect("dist: SPMD call order violated (mats)"))
            .collect()
    }

    fn exchange_f64(&self, vals: Vec<f64>) -> Vec<Arc<Vec<f64>>> {
        self.exchange_any(Arc::new(vals))
            .into_iter()
            .map(|a| a.downcast::<Vec<f64>>().expect("dist: SPMD call order violated (f64)"))
            .collect()
    }
}

/// Run `world` SPMD rank bodies to completion and collect their results
/// in rank order.
///
/// Ranks run on the persistent worker pool when it is safe to do so
/// (caller is not itself a pool worker, parallelism is enabled, and the
/// pool has at least `world` workers so no rank body can be queued behind
/// a blocked peer — rank bodies block on collective rendezvous, unlike
/// ordinary pool jobs); otherwise on dedicated scoped threads. Both paths
/// produce identical results: collectives order floating-point reductions
/// by rank index, never by scheduling.
///
/// A panicking rank poisons the rendezvous (waking every peer) and the
/// panic propagates to the caller; the pool stays usable.
pub fn run_ranks<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(LocalComm) -> T + Sync,
{
    assert!(world >= 1, "run_ranks: world size must be >= 1");
    let rv = Arc::new(Rendezvous::new(world));
    if world == 1 {
        return vec![f(LocalComm { rank: 0, world, rv })];
    }
    let results: Vec<Mutex<Option<T>>> = (0..world).map(|_| Mutex::new(None)).collect();
    let fr = &f;
    let rs = &results;
    let make_body = |r: usize| {
        let comm = LocalComm { rank: r, world, rv: Arc::clone(&rv) };
        let rv = Arc::clone(&rv);
        move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| fr(comm)));
            match out {
                Ok(v) => *rs[r].lock().unwrap_or_else(|e| e.into_inner()) = Some(v),
                Err(e) => {
                    rv.poison();
                    std::panic::resume_unwind(e);
                }
            }
        }
    };
    let pool_safe =
        !pool::is_worker_thread() && pool::current_threads() > 1 && pool::num_threads() >= world;
    if pool_safe {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            (0..world).map(|r| Box::new(make_body(r)) as Box<dyn FnOnce() + Send + '_>).collect();
        pool::run_jobs(jobs);
    } else {
        std::thread::scope(|s| {
            for r in 0..world {
                s.spawn(make_body(r));
            }
        });
    }
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("run_ranks: rank produced no result")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_ranks_world1_runs_inline() {
        let out = run_ranks(1, |c| {
            assert_eq!(c.rank(), 0);
            assert_eq!(c.world_size(), 1);
            42usize
        });
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn run_ranks_collects_in_rank_order() {
        for world in [2usize, 3, 4, 7] {
            let out = run_ranks(world, |c| c.rank() * 10);
            assert_eq!(out, (0..world).map(|r| r * 10).collect::<Vec<_>>(), "world {world}");
        }
    }

    #[test]
    fn exchange_f64_delivers_all_payloads() {
        let world = 4;
        let out = run_ranks(world, |c| {
            let parts = c.exchange_f64(vec![c.rank() as f64, 100.0 + c.rank() as f64]);
            parts.iter().map(|p| p[0]).collect::<Vec<_>>()
        });
        for got in out {
            assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn repeated_exchanges_do_not_cross_phases() {
        // Many back-to-back exchanges with asymmetric compute between
        // them: the two-phase reset must keep rounds separated.
        let world = 3;
        let out = run_ranks(world, |c| {
            let mut acc = 0.0f64;
            for round in 0..50u32 {
                if c.rank() == round as usize % world {
                    std::hint::black_box((0..500).map(|i| i as f64).sum::<f64>());
                }
                let parts = c.exchange_f64(vec![(round as f64) * 10.0 + c.rank() as f64]);
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(p[0], (round as f64) * 10.0 + r as f64);
                    acc += p[0];
                }
            }
            acc
        });
        assert!(out.iter().all(|&x| x == out[0]));
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            assert_eq!(DistStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(DistStrategy::parse("sharded"), Some(DistStrategy::FactorSharded));
        assert!(DistStrategy::parse("bogus").is_none());
    }

    #[test]
    fn dist_ctx_ownership() {
        let replicated = DistCtx::new(DistStrategy::Replicated, 1, 4);
        assert!((0..8).all(|l| replicated.owns_layer(l)));
        let sharded = DistCtx::new(DistStrategy::FactorSharded, 1, 4);
        let owned: Vec<usize> = (0..8).filter(|&l| sharded.owns_layer(l)).collect();
        assert_eq!(owned, vec![1, 5]);
    }
}
