//! Gradient bucketing: coalesce small per-layer matrices into
//! size-bounded flat buckets before all-reduce.
//!
//! A model has many small layers (bias-folded linear layers, LayerNorm
//! scales); all-reducing each one separately pays one rendezvous round
//! per layer. Bucketing packs consecutive layers into flat buffers of at
//! most [`DEFAULT_BUCKET_ELEMS`] elements (the knob every DDP
//! implementation exposes) so the number of collective rounds is bounded
//! by total bytes, not layer count.
//!
//! Bucketing is *bitwise transparent*: the all-reduce is elementwise, so
//! summing a packed buffer in one tree is exactly the per-element tree of
//! the unbucketed reduction — asserted in the tests below and relied on
//! by the determinism contract of [`crate::dist`].

use super::{collectives, Communicator};
use crate::tensor::Mat;
use std::ops::Range;

/// Default bucket capacity in f32 elements (1 MiB of f32s).
pub const DEFAULT_BUCKET_ELEMS: usize = 1 << 18;

/// In-flight bound for the overlapped bucketed all-reduce: at most this
/// many packed buckets ahead of the drain cursor, so overlap costs
/// `O(depth · bucket)` extra memory instead of a packed copy of the
/// whole layer list (mirrors the pipelined ring's issue depth).
const BUCKET_PIPELINE_DEPTH: usize = 2;

/// A partition of a layer list into contiguous, size-bounded buckets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketPlan {
    /// Half-open layer-index ranges; concatenated they cover `0..n`.
    pub buckets: Vec<Range<usize>>,
}

impl BucketPlan {
    /// Greedy contiguous packing: a bucket closes when adding the next
    /// layer would push it past `max_elems`. Every bucket holds at least
    /// one layer, so a single oversized layer still travels (alone).
    /// The plan is a function of `(sizes, max_elems)` only.
    pub fn new(sizes: &[usize], max_elems: usize) -> BucketPlan {
        let cap = max_elems.max(1);
        let mut buckets = Vec::new();
        let mut start = 0usize;
        let mut in_bucket = 0usize;
        for (i, &sz) in sizes.iter().enumerate() {
            if i > start && in_bucket + sz > cap {
                buckets.push(start..i);
                start = i;
                in_bucket = 0;
            }
            in_bucket += sz;
        }
        if start < sizes.len() {
            buckets.push(start..sizes.len());
        }
        BucketPlan { buckets }
    }

    /// Largest bucket size in elements under this plan.
    pub fn max_bucket_elems(&self, sizes: &[usize]) -> usize {
        self.buckets.iter().map(|b| sizes[b.clone()].iter().sum()).max().unwrap_or(0)
    }
}

/// All-reduce (sum) `mats` in place, coalescing them into buckets of at
/// most `max_elems` f32s. Bitwise identical to all-reducing each matrix
/// individually; one collective round per bucket.
///
/// With overlap enabled ([`Communicator::overlap`]) buckets are issued
/// as nonblocking ops ([`Communicator::istart_all_reduce_sum`]) a
/// bounded window ahead of the drain — bucket `k+1`'s flatten overlaps
/// bucket `k`'s wire time at `O(window · bucket)` extra memory — and
/// the results are waited and scattered in issue order. Same
/// [`BucketPlan`], same per-bucket reduction, so the overlapped path is
/// bitwise identical to the blocking one (contract 4 of
/// [`crate::dist`]).
pub fn all_reduce_sum_bucketed(comm: &dyn Communicator, mats: &mut [Mat], max_elems: usize) {
    if comm.world_size() == 1 || mats.is_empty() {
        return;
    }
    let sizes: Vec<usize> = mats.iter().map(|m| m.len()).collect();
    let plan = BucketPlan::new(&sizes, max_elems);
    let pack = |mats: &[Mat], b: &Range<usize>, total: usize| -> Mat {
        let mut flat = Vec::with_capacity(total);
        for m in &mats[b.clone()] {
            flat.extend_from_slice(m.data());
        }
        Mat::from_vec(1, total.max(1), if total == 0 { vec![0.0] } else { flat })
    };
    let scatter = |mats: &mut [Mat], b: &Range<usize>, red: &[f32]| {
        let mut off = 0usize;
        for m in &mut mats[b.clone()] {
            let n = m.len();
            m.data_mut().copy_from_slice(&red[off..off + n]);
            off += n;
        }
    };
    if comm.overlap() {
        // Bounded pipeline: at most BUCKET_PIPELINE_DEPTH buckets are
        // packed and in flight ahead of the drain cursor, so the engine
        // reduces bucket k while this thread packs bucket k+1 — the
        // same overlap as issuing everything up front, without holding
        // a packed copy of the whole parameter set. Issue order (and
        // therefore the wire order, contract 4) is the plain bucket
        // order either way.
        let mut in_flight = std::collections::VecDeque::new();
        let issue = |mats: &[Mat], b: &Range<usize>| {
            let total: usize = sizes[b.clone()].iter().sum();
            let packed = pack(mats, b, total);
            (b.clone(), total, comm.istart_all_reduce_sum(vec![packed]))
        };
        for m in 0..BUCKET_PIPELINE_DEPTH.min(plan.buckets.len()) {
            in_flight.push_back(issue(mats, &plan.buckets[m]));
        }
        for m in 0..plan.buckets.len() {
            if m + BUCKET_PIPELINE_DEPTH < plan.buckets.len() {
                in_flight.push_back(issue(mats, &plan.buckets[m + BUCKET_PIPELINE_DEPTH]));
            }
            let (b, total, op) = in_flight.pop_front().expect("bucket op issued");
            let reduced = op.wait();
            if total == 0 {
                continue;
            }
            scatter(mats, &b, reduced[0].data());
        }
    } else {
        for b in &plan.buckets {
            let total: usize = sizes[b.clone()].iter().sum();
            let packed = pack(mats, b, total);
            let reduced = collectives::all_reduce_sum(comm, std::slice::from_ref(&packed));
            if total == 0 {
                continue;
            }
            scatter(mats, &b, reduced[0].data());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::run_ranks;
    use crate::proptest::Pcg;

    #[test]
    fn plan_respects_capacity_and_covers_all_layers() {
        let sizes = [10usize, 20, 5, 100, 1, 1, 1, 50];
        let plan = BucketPlan::new(&sizes, 32);
        // Coverage: concatenated ranges == 0..n, in order.
        let mut next = 0usize;
        for b in &plan.buckets {
            assert_eq!(b.start, next);
            assert!(b.end > b.start);
            next = b.end;
        }
        assert_eq!(next, sizes.len());
        // Capacity: only single-layer buckets may exceed the cap.
        for b in &plan.buckets {
            let total: usize = sizes[b.clone()].iter().sum();
            assert!(total <= 32 || b.len() == 1, "bucket {b:?} holds {total}");
        }
        // The oversized layer (100) travels alone.
        assert!(plan.buckets.contains(&(3..4)));
    }

    #[test]
    fn plan_is_deterministic() {
        let sizes = [7usize, 7, 7, 7, 7];
        assert_eq!(BucketPlan::new(&sizes, 14), BucketPlan::new(&sizes, 14));
        assert_eq!(BucketPlan::new(&sizes, 14).buckets, vec![0..2, 2..4, 4..5]);
    }

    #[test]
    fn bucketed_all_reduce_bitwise_matches_unbucketed() {
        let mut rng = Pcg::new(23);
        let world = 4;
        let shapes = [(3usize, 4usize), (1, 1), (8, 2), (2, 2), (5, 5)];
        let inputs: Vec<Vec<Mat>> = (0..world)
            .map(|_| shapes.iter().map(|&(r, c)| rng.normal_mat(r, c, 1.0)).collect())
            .collect();
        let inp = &inputs;
        for cap in [1usize, 8, 17, 1 << 20] {
            let outs = run_ranks(world, |comm| {
                let r = comm.rank();
                let mut bucketed: Vec<Mat> = inp[r].clone();
                all_reduce_sum_bucketed(&comm, &mut bucketed, cap);
                let plain = collectives::all_reduce_sum(&comm, &inp[r]);
                (bucketed, plain)
            });
            for (bucketed, plain) in outs {
                for (b, p) in bucketed.iter().zip(&plain) {
                    assert_eq!(b.data(), p.data(), "cap {cap}");
                }
            }
        }
    }

    #[test]
    fn overlapped_bucketed_all_reduce_bitwise_matches_blocking() {
        // Same plan, same per-bucket reduction — issuing buckets as
        // pending ops must not change a bit, under either algorithm.
        let mut rng = Pcg::new(0x0b0c);
        let world = 4;
        let shapes = [(3usize, 4usize), (1, 1), (0, 5), (8, 2), (2, 2)];
        let inputs: Vec<Vec<Mat>> = (0..world)
            .map(|_| shapes.iter().map(|&(r, c)| rng.normal_mat(r, c, 1.0)).collect())
            .collect();
        let inp = &inputs;
        for algo in [crate::dist::Algo::Star, crate::dist::Algo::Ring] {
            for cap in [1usize, 10, 1 << 20] {
                let blocking = crate::dist::run_ranks_with(world, algo, false, |comm| {
                    let mut mats = inp[comm.rank()].clone();
                    all_reduce_sum_bucketed(&comm, &mut mats, cap);
                    mats
                });
                let overlapped = crate::dist::run_ranks_with(world, algo, true, |comm| {
                    let mut mats = inp[comm.rank()].clone();
                    all_reduce_sum_bucketed(&comm, &mut mats, cap);
                    mats
                });
                for (rank, (b, o)) in blocking.iter().zip(&overlapped).enumerate() {
                    for (l, (mb, mo)) in b.iter().zip(o).enumerate() {
                        assert_eq!(
                            mb.data(),
                            mo.data(),
                            "{} cap {cap} rank {rank} layer {l}",
                            algo.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_padded_all_reduce_is_exact() {
        // The sharded-optimizer exchange: each element has exactly one
        // nonzero contributor, so any reduction tree returns its bits.
        let mut rng = Pcg::new(29);
        let world = 4;
        let owners = [2usize, 0, 3, 1, 0];
        let values: Vec<Mat> = (0..owners.len()).map(|_| rng.normal_mat(3, 3, 1e-3)).collect();
        let (ow, vals) = (&owners, &values);
        let outs = run_ranks(world, |comm| {
            let mut mine: Vec<Mat> = ow
                .iter()
                .zip(vals)
                .map(|(&o, v)| if o == comm.rank() { v.clone() } else { Mat::zeros(3, 3) })
                .collect();
            all_reduce_sum_bucketed(&comm, &mut mine, 4);
            mine
        });
        for out in outs {
            for (got, want) in out.iter().zip(vals) {
                assert_eq!(got.data(), want.data());
            }
        }
    }
}
