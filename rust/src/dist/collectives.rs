//! Deterministic collectives over [`Mat`] buffers.
//!
//! Every reducing collective combines rank contributions with one fixed
//! balanced halving tree ([`tree_sum_f64`] / the private `tree_combine`),
//! so the floating-point reduction order is a function of the world size
//! alone — never of thread scheduling. This extends the crate's
//! serial/pooled bitwise-parity contract (`rust/tests/parallel.rs`) to
//! the distributed layer.
//!
//! # Rank-count invariance
//!
//! A tree-ordered reduction makes results reproducible *at a fixed world
//! size*. Bitwise invariance *across* world sizes additionally needs the
//! leaf partition to align with the tree: a sum over `m` items sharded
//! contiguously across `R = 2^k` ranks (with `R | m`) reproduces the
//! single-rank halving tree exactly, because each rank's local subtree is
//! a complete subtree of the global one and the cross-rank combine is the
//! tree's top `k` levels. The training driver relies on this for loss
//! accumulation, and sidesteps the question entirely for gradients by
//! gathering raw statistics rows (exact concatenation) and all-reducing
//! zero-padded updates (one nonzero contributor per element — any tree
//! gives the same bits).

use super::Communicator;
use crate::tensor::Mat;
use std::sync::Arc;

/// Balanced halving-tree sum: `tree(x) = tree(x[..⌈n/2⌉]) + tree(x[⌈n/2⌉..])`.
///
/// The reduction tree is a function of `n` alone. For `n` divisible by a
/// power of two `R`, the first `log2(R)` split points land on multiples
/// of `n/R`, so contiguous equal shards are complete subtrees — the
/// alignment property the rank-invariance contract builds on.
pub fn tree_sum_f64(xs: &[f64]) -> f64 {
    match xs.len() {
        0 => 0.0,
        1 => xs[0],
        n => {
            let mid = n.div_ceil(2);
            tree_sum_f64(&xs[..mid]) + tree_sum_f64(&xs[mid..])
        }
    }
}

/// Elementwise halving-tree sum of per-rank matrix lists.
fn tree_combine(parts: &[Arc<Vec<Mat>>]) -> Vec<Mat> {
    match parts.len() {
        0 => Vec::new(),
        1 => parts[0].as_ref().clone(),
        n => {
            let mid = n.div_ceil(2);
            let mut acc = tree_combine(&parts[..mid]);
            let hi = tree_combine(&parts[mid..]);
            assert_eq!(acc.len(), hi.len(), "all_reduce: payload length mismatch");
            for (a, b) in acc.iter_mut().zip(&hi) {
                a.axpy(1.0, b);
            }
            acc
        }
    }
}

/// All-reduce (sum) a list of matrices: every rank contributes its list,
/// every rank receives the elementwise tree-ordered sum. Shapes must
/// agree across ranks.
pub fn all_reduce_sum(comm: &dyn Communicator, mats: &[Mat]) -> Vec<Mat> {
    if comm.world_size() == 1 {
        return mats.to_vec();
    }
    let parts = comm.exchange_mats(mats.to_vec());
    tree_combine(&parts)
}

/// Broadcast `root`'s matrices to every rank. Non-root contributions are
/// ignored (ranks other than `root` may pass an empty list).
pub fn broadcast(comm: &dyn Communicator, root: usize, mats: Vec<Mat>) -> Vec<Mat> {
    assert!(root < comm.world_size(), "broadcast: bad root");
    if comm.world_size() == 1 {
        return mats;
    }
    let payload = if comm.rank() == root { mats } else { Vec::new() };
    let parts = comm.exchange_mats(payload);
    parts[root].as_ref().clone()
}

/// All-gather arbitrary per-rank matrix lists, returned in rank order.
pub fn all_gather(comm: &dyn Communicator, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
    comm.exchange_mats(mats)
}

/// All-gather by row concatenation: every rank contributes a
/// `rows_r × cols` block; every rank receives the `Σ rows_r × cols`
/// vertical stack in rank order. Pure data movement — no floating-point
/// reduction — so the result is exact for any world size.
pub fn all_gather_rows(comm: &dyn Communicator, m: &Mat) -> Mat {
    if comm.world_size() == 1 {
        return m.clone();
    }
    let parts = comm.exchange_mats(vec![m.clone()]);
    concat_rows(&parts, 0)
}

/// Stack `parts[r][idx]` over ranks `r` (shared by `all_gather_rows` and
/// the multi-matrix gathers in the training driver).
pub fn concat_rows(parts: &[Arc<Vec<Mat>>], idx: usize) -> Mat {
    let cols = parts[0][idx].cols();
    let rows: usize = parts.iter().map(|p| p[idx].rows()).sum();
    let mut out = Mat::zeros(rows, cols);
    let mut r0 = 0usize;
    for p in parts {
        let blk = &p[idx];
        assert_eq!(blk.cols(), cols, "concat_rows: column mismatch");
        out.data_mut()[r0 * cols..(r0 + blk.rows()) * cols].copy_from_slice(blk.data());
        r0 += blk.rows();
    }
    out
}

/// Reduce-scatter over rows: tree-sum every rank's `rows × cols`
/// contribution, then hand rank `r` its contiguous row block under the
/// canonical shard plan of [`super::shard::row_shard_range`]. World
/// sizes that do not divide the row count follow that padding rule
/// (shard heights differ by at most one; a block is empty only when
/// `rows < world`); when `world` divides `rows` every rank receives
/// exactly `rows/world` rows.
pub fn reduce_scatter_rows(comm: &dyn Communicator, m: &Mat) -> Mat {
    let world = comm.world_size();
    if world == 1 {
        return m.clone();
    }
    let summed = all_reduce_sum(comm, std::slice::from_ref(m));
    let total = &summed[0];
    let block = super::shard::row_shard_range(total.rows(), world, comm.rank());
    Mat::from_fn(block.len(), total.cols(), |r, c| total.at(block.start + r, c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::run_ranks;
    use crate::proptest::Pcg;

    #[test]
    fn tree_sum_uses_fixed_halving_order() {
        let xs = [0.1f64, 0.2, 0.3, 0.4];
        let want = (0.1 + 0.2) + (0.3 + 0.4);
        assert_eq!(tree_sum_f64(&xs), want);
        let xs5 = [0.1f64, 0.2, 0.3, 0.4, 0.5];
        let want5 = ((0.1 + 0.2) + 0.3) + (0.4 + 0.5);
        assert_eq!(tree_sum_f64(&xs5), want5);
        assert_eq!(tree_sum_f64(&[]), 0.0);
        assert_eq!(tree_sum_f64(&[7.0]), 7.0);
    }

    #[test]
    fn shard_subtrees_compose_to_the_global_tree() {
        // The alignment property: contiguous 2^k-way shards of a
        // divisible length reduce to the same bits as the global tree.
        let mut rng = Pcg::new(11);
        let xs: Vec<f64> = (0..96).map(|_| rng.normal() as f64).collect();
        let full = tree_sum_f64(&xs);
        for shards in [2usize, 4, 8] {
            let q = xs.len() / shards;
            let partials: Vec<f64> =
                (0..shards).map(|s| tree_sum_f64(&xs[s * q..(s + 1) * q])).collect();
            assert_eq!(tree_sum_f64(&partials).to_bits(), full.to_bits(), "shards {shards}");
        }
    }

    #[test]
    fn all_reduce_sums_with_rank_order_tree() {
        let mut rng = Pcg::new(13);
        let world = 4;
        let inputs: Vec<Mat> = (0..world).map(|_| rng.normal_mat(5, 3, 1.0)).collect();
        let want = {
            // Manual (r0+r1)+(r2+r3).
            let mut a = inputs[0].clone();
            a.axpy(1.0, &inputs[1]);
            let mut b = inputs[2].clone();
            b.axpy(1.0, &inputs[3]);
            a.axpy(1.0, &b);
            a
        };
        let inp = &inputs;
        let outs = run_ranks(world, |c| all_reduce_sum(&c, std::slice::from_ref(&inp[c.rank()])));
        for out in outs {
            assert_eq!(out[0].data(), want.data(), "tree order must be rank-indexed");
        }
    }

    #[test]
    fn broadcast_delivers_root_payload() {
        let m = Mat::from_fn(2, 2, |r, c| (r * 2 + c) as f32);
        let mr = &m;
        let outs = run_ranks(3, |c| {
            let payload = if c.rank() == 1 { vec![mr.clone()] } else { Vec::new() };
            broadcast(&c, 1, payload)
        });
        for out in outs {
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].data(), m.data());
        }
    }

    #[test]
    fn all_gather_rows_stacks_in_rank_order() {
        let outs = run_ranks(4, |c| {
            let mine = Mat::from_fn(2, 3, |r, col| (c.rank() * 100 + r * 10 + col) as f32);
            all_gather_rows(&c, &mine)
        });
        for out in outs {
            assert_eq!(out.shape(), (8, 3));
            for rank in 0..4 {
                for r in 0..2 {
                    for col in 0..3 {
                        assert_eq!(out.at(rank * 2 + r, col), (rank * 100 + r * 10 + col) as f32);
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_hands_out_summed_row_blocks() {
        let world = 4;
        let outs = run_ranks(world, |c| {
            let mine = Mat::from_fn(8, 2, |r, col| (c.rank() + r + col) as f32);
            reduce_scatter_rows(&c, &mine)
        });
        // Sum over ranks of (rank + r + col) = 6 + 4(r + col).
        for (rank, out) in outs.iter().enumerate() {
            assert_eq!(out.shape(), (2, 2));
            for r in 0..2 {
                for col in 0..2 {
                    let gr = rank * 2 + r;
                    assert_eq!(out.at(r, col), (6 + 4 * (gr + col)) as f32, "rank {rank}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_padding_rule_for_non_dividing_world() {
        // rows = 10, world = 4 → blocks 3, 3, 2, 2 of the summed matrix
        // (the row_shard_range padding rule).
        let world = 4;
        let outs = run_ranks(world, |c| {
            let mine = Mat::from_fn(10, 2, |r, col| (c.rank() + r + col) as f32);
            reduce_scatter_rows(&c, &mine)
        });
        let heights = [3usize, 3, 2, 2];
        let starts = [0usize, 3, 6, 8];
        for (rank, out) in outs.iter().enumerate() {
            assert_eq!(out.shape(), (heights[rank], 2), "rank {rank}");
            for r in 0..heights[rank] {
                for col in 0..2 {
                    let gr = starts[rank] + r;
                    // Sum over ranks of (rank + r + col) = 6 + 4(r + col).
                    assert_eq!(out.at(r, col), (6 + 4 * (gr + col)) as f32, "rank {rank}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_single_row_goes_to_rank0() {
        // 1×1 input, world 4: rank 0 receives the summed row, the rest
        // receive empty 0×1 blocks.
        let outs = run_ranks(4, |c| {
            let mine = Mat::from_vec(1, 1, vec![(c.rank() + 1) as f32]);
            reduce_scatter_rows(&c, &mine)
        });
        assert_eq!(outs[0].shape(), (1, 1));
        assert_eq!(outs[0].at(0, 0), 10.0);
        for out in &outs[1..] {
            assert_eq!(out.shape(), (0, 1));
        }
    }

    #[test]
    fn world1_collectives_are_identity() {
        let mut rng = Pcg::new(17);
        let m = rng.normal_mat(4, 4, 1.0);
        let mr = &m;
        let out = run_ranks(1, |c| {
            (
                all_reduce_sum(&c, std::slice::from_ref(mr)),
                all_gather_rows(&c, mr),
                broadcast(&c, 0, vec![mr.clone()]),
            )
        });
        let (ar, ag, bc) = &out[0];
        assert_eq!(ar[0].data(), m.data());
        assert_eq!(ag.data(), m.data());
        assert_eq!(bc[0].data(), m.data());
    }
}
